#!/usr/bin/env bash
# Tier-1 profile with optional process fan-out.  Semantically identical
# to the canonical `PYTHONPATH=src python -m pytest -q` tier-1 run (the
# `-m "not slow"` profile comes from pytest.ini either way); when the
# *optional* pytest-xdist dependency is installed, the suite fans out
# across worker processes (`-n auto`) — the cold-CI lever ROADMAP
# names: tier-1 is compile-bound, and each xdist worker re-runs
# tests/conftest.py, so every worker gets its own 8-way host-device
# simulation and they all share the persistent jit cache in
# .jax_cache/.  Without xdist this is exactly the serial run — the
# dependency is never required.
set -euo pipefail
cd "$(dirname "$0")/.."
if python -c "import xdist" >/dev/null 2>&1; then
    XDIST_ARGS=(-n auto)
else
    XDIST_ARGS=()
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q "${XDIST_ARGS[@]}" "$@"
