#!/usr/bin/env bash
# Full (nightly) test profile: includes the @slow solver-oracle shapes
# and full-batch equivalence sweeps that the tier-1 default
# (`pytest.ini` addopts = -m "not slow") skips.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q -m "slow or not slow" "$@"
