#!/usr/bin/env bash
# Full (nightly) test profile: reprolint (static rules + the semantic
# registry audit), then the @slow solver-oracle shapes, full-batch
# equivalence sweeps and the heavy Monte-Carlo nonideality shapes that
# the tier-1 default (`pytest.ini` addopts = -m "not slow") skips, plus
# the whole-model deployment, fault-tolerance, line-open-sweep,
# serving-health, serving-load and mapping-strategy-matrix benchmarks
# (fused planning / plan-cache / CIM serving / fault+variation
# distributions / spare-line vs fault-aware under structural line opens
# / monitored vs unmonitored lifetime resilience / continuous-batching
# throughput+latency+redeploy gates / row-x-column strategy NF
# numbers recorded into results/benchmarks.json).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    ./scripts/lint.sh --audit src benchmarks scripts
# Fan the suite out across workers when pytest-xdist is available; the
# suite is xdist-clean (per-test tempdirs, no shared module state), but
# the dependency is optional — fall back to in-process serially.
if python -c "import xdist" >/dev/null 2>&1; then
    XDIST_ARGS=(-n auto)
else
    XDIST_ARGS=()
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q "${XDIST_ARGS[@]}" -m "slow or not slow" "$@"
# Benchmarks run with span tracing on: each leaves a JSONL trace in
# results/trace/<bench>.jsonl (archived with the nightly results) and
# the per-phase wall/self-time summary lands in
# results/trace/summary.txt via scripts/trace_report.py.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --trace --only deploy_throughput
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --trace --only fault_tolerance
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --trace --only fault_line_open
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --trace --only serving_health
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --trace --only serving_load
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --trace --only mapping_matrix
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/trace_report.py results/trace/*.jsonl \
    | tee results/trace/summary.txt
