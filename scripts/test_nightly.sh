#!/usr/bin/env bash
# Full (nightly) test profile: includes the @slow solver-oracle shapes
# and full-batch equivalence sweeps that the tier-1 default
# (`pytest.ini` addopts = -m "not slow") skips, plus the whole-model
# deployment benchmark (fused planning / plan-cache / CIM serving
# numbers recorded into results/benchmarks.json).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q -m "slow or not slow" "$@"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only deploy_throughput
