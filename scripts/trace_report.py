#!/usr/bin/env python
"""Summarise telemetry trace files into per-phase time tables.

    PYTHONPATH=src python scripts/trace_report.py results/trace/*.jsonl
    PYTHONPATH=src python scripts/trace_report.py --json trace.jsonl

For each JSONL trace (written by ``repro.telemetry.trace_to``) prints a
table of per-phase wall time (total), self time (total minus direct
children), counts and min/max, plus the coverage line: what fraction
of the root spans' wall time the phase self-times account for.

Deliberately jax-free (imports only ``repro.telemetry``): runnable on
a box with no accelerator stack, same contract as ``scripts/lint.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.telemetry.report import aggregate, load_spans  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="trace_report",
        description="per-phase wall/self-time summary of telemetry "
                    "JSONL traces")
    ap.add_argument("paths", nargs="+", help="trace .jsonl file(s)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output")
    args = ap.parse_args(argv)

    from repro.telemetry.report import format_table

    out_json: dict = {}
    status = 0
    for path in args.paths:
        try:
            spans = load_spans(path)
        except OSError as e:
            print(f"{path}: cannot read trace: {e}", file=sys.stderr)
            status = 1
            continue
        stats, wall = aggregate(spans)
        if args.json:
            out_json[path] = {"wall": wall, "spans": len(spans),
                              "phases": stats}
        else:
            print(f"== {path} ({len(spans)} span(s)) ==")
            if not spans:
                print("(empty trace)")
            else:
                print(format_table(stats, wall))
            print()
    if args.json:
        print(json.dumps(out_json, indent=1))
    return status


if __name__ == "__main__":
    sys.exit(main())
