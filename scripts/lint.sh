#!/usr/bin/env bash
# reprolint over the library tree (the CI contract gate).  Extra args
# pass through: `scripts/lint.sh --json`, `scripts/lint.sh src tests`.
# Exit 0 iff zero unsuppressed findings.
set -euo pipefail
cd "$(dirname "$0")/.."
exec python scripts/lint.py "$@"
