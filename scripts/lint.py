#!/usr/bin/env python
"""reprolint entry point: ``python scripts/lint.py [paths...]``.

Thin wrapper so the CLI works without PYTHONPATH gymnastics; all logic
lives in ``repro.analysis`` (jax-free unless ``--audit`` is passed).
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (os.path.join(_ROOT, "src"), _ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
