"""Benchmark: model accuracy under PR noise injection (paper §V-C, Fig 6).

Trains a small LM on the deterministic synthetic language, then
evaluates its cross-entropy with Eq-17 position-dependent noise folded
into every weight matrix, for each MDM ablation and several noise
coefficients.  The paper's analogue injects into ImageNet CNNs/ViTs; the
methodology (post-training, position-keyed, eta-calibrated) is identical
— see DESIGN.md §2 for the substrate swap rationale.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core.noise import calibrate_eta, tree_noisy_weights
from repro.core.tiling import CrossbarSpec
from repro.data import SyntheticTokenDataset
from repro.distributed.sharding import ShardingCtx
from repro.models import model as M
from repro.train import Trainer

MODES = ("baseline", "reverse", "sort", "mdm")


def run(train_steps: int = 250, etas=None, eta_scales=(1.0, 50.0, 150.0),
        verbose: bool = True, arch: str = "phi3-mini-3.8b") -> dict:
    """The eta sweep grid is anchored to the *circuit-calibrated* eta
    (one fused batched solve against ``repro.crossbar``; the paper's
    SPICE analogue gives 2e-3 at r=2.5ohm; this spec's 64x64 tiles
    calibrate to ~1.5e-4): grid = eta_circuit * ``eta_scales``.  The 1x
    point is the physical operating point; at that scale the CE deltas
    sit inside evaluation noise for this model size, so the 50x/150x
    points (landing on the formerly hand-picked 1e-2..3e-2 regime) keep
    the degradation ordering unambiguous.  Pass ``etas`` explicitly to
    override the calibrated grid.  Expected pattern under first-order
    Eq-17 injection: sort < baseline < mdm (reversal hurts the
    2^-k-weighted distortion) — the *circuit-level* check in
    nf_reduction.py shows full MDM winning once second-order IR-drop
    physics is included; see DESIGN.md §5b."""
    t0 = time.perf_counter()
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    tcfg = TrainConfig(total_steps=train_steps, learning_rate=2e-3,
                       checkpoint_every=10 ** 9,
                       checkpoint_dir="/tmp/repro_bench_acc")
    ds = SyntheticTokenDataset(cfg.vocab_size, 64, 16, seed=0)
    tr = Trainer(cfg, tcfg, ds)
    tr.init_state()
    log = tr.run(train_steps)

    ctx = ShardingCtx()
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    eval_batches = [
        {"tokens": jnp.asarray(ds.batch_at(10_000 + i))} for i in range(4)]

    @jax.jit
    def eval_ce(params):
        losses = [M.train_loss(params, cfg, ctx, b)[1]["ce"]
                  for b in eval_batches]
        return sum(losses) / len(losses)

    clean = float(eval_ce(tr.params))
    # Circuit-grounded eta at the benchmark's crossbar spec: one fused
    # batched solve (repro.crossbar.batched) instead of the paper's
    # SPICE sweep.  The mixed f32/f64 precision policy matches the f64
    # oracle far below the fit noise at a fraction of the solve cost.
    eta_circuit = calibrate_eta(spec, n_tiles=8, precision="mixed")
    if etas is None:
        etas = tuple(eta_circuit * s for s in eta_scales)
    out = {"train_final_loss": log[-1]["loss"], "clean_ce": clean,
           "eta_circuit_calibrated": eta_circuit,
           "eta_grid": list(etas), "noisy": {}}
    if verbose:
        print(f"  trained {train_steps} steps: loss {log[-1]['loss']:.3f}; "
              f"clean eval CE {clean:.4f}; "
              f"circuit-calibrated eta {eta_circuit:.2e} -> grid "
              + ",".join(f"{e:.2e}" for e in etas))
    for eta in etas:
        row = {}
        for mode in MODES:
            noisy = tree_noisy_weights(tr.params, spec, mode, eta=eta,
                                       min_size=1024)
            row[mode] = float(eval_ce(noisy))
        out["noisy"][eta] = row
        if verbose:
            rel = {m: row[m] - clean for m in MODES}
            print(f"  eta={eta:g}: " + " ".join(
                f"{m}:+{rel[m]:.4f}" for m in MODES))
    out["_elapsed_s"] = time.perf_counter() - t0
    return out


if __name__ == "__main__":
    run()
