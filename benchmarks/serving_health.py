"""Benchmark: lifetime resilience of the monitored serving path.

Serves twin engines built from the same seed — one with the health
subsystem armed (probe rounds + the recalibrate/reprogram/demote
remediation ladder, :mod:`repro.health`), one that ages identically
but is never probed or healed — through an aging sweep, and measures
the probe error of every deployed matrix through the production
``cim_mvm`` against the digital reference at each point.

Headline acceptance (the ISSUE-8 resilience claim):

* **unmonitored degrades**: at the heaviest swept age the unmonitored
  engine's median probe error is >= 2x its fresh level;
* **monitored recovers**: after the controller has climbed as far up
  the ladder as it needs (recalibration fixes column-separable drift;
  the per-cell relaxation residual forces a reprogram), the monitored
  engine's median probe error is back within 10% (+ small absolute
  slack) of fresh;
* **zero flapping**: no spontaneous detector clear-edges anywhere in
  the sweep (the hysteresis contract);
* **deterministic escalations**: a same-seed twin of the monitored
  engine, driven through the identical call sequence, produces the
  identical remediation event history;
* **bit-deterministic serving**: the tokens generated before and after
  every hot-swap match between the same-seed twins exactly.
"""
from __future__ import annotations

import tempfile

import jax
import numpy as np

from repro.configs.base import CimConfig, ModelConfig
from repro.deploy import PlanCache
from repro.health import DetectorConfig, HealthConfig
from repro.models.model import init_params
from repro.nonideal import NonidealModel
from repro.serve import ServeEngine

_REL_SLACK = 1.1     # monitored-recovers: within 10% of fresh...
_ABS_SLACK = 0.02    # ...plus this absolute slack on tiny errors


def _cfg() -> ModelConfig:
    return ModelConfig(
        name="cim-serving-health", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, block_pattern=("attn",),
        remat="none", dtype="float32", attn_chunk=32,
        cim=CimConfig(enabled=True, mode="mdm", rows=16, cols=16,
                      n_bits=4))


def _engine(cfg, params, tmp, model, seed, health=None) -> ServeEngine:
    return ServeEngine(cfg, params, max_seq=64,
                       plan_cache=PlanCache(tmp), nonideal=model,
                       nonideal_seed=seed, health=health)


def _probe_err(eng: ServeEngine) -> float:
    """Median per-matrix probe error through the *served* path.

    A demoted matrix serves the digital full-precision fallback
    (``models.model._cim_matmul`` routes on the runtime sentinel), so
    its served error is exactly zero — graceful degradation counts as
    recovery, not as crossbar error."""
    from repro.health.monitor import probe_error
    from repro.kernels.cim_mvm.ops import cim_mvm

    errs = []
    for name, lt in eng.lifetime.items():
        if lt.demoted:
            errs.append(0.0)
            continue
        mon = eng.health.monitors[name]
        y = np.asarray(cim_mvm(mon.probes_dev, lt.dep))
        errs.append(probe_error(y, mon.y_ref))
    return float(np.median(errs))


def _history(rep) -> list[tuple[int, str, str]]:
    return [(e["round"], e["matrix"], e["event"]) for e in rep.events]


def run(ages=(3e2, 1e4, 3e5), drift_nu: float = 0.1,
        sigma_relax: float = 0.08, n_warmup: int = 4,
        n_heal_rounds: int = 3, seed: int = 3,
        verbose: bool = True) -> dict:
    model = NonidealModel(drift_nu=drift_nu, sigma_relax=sigma_relax,
                          sigma_program=0.03)
    # Endurance budget 2: the first two age points heal on-crossbar
    # (recal + reprogram each — relaxation residuals always force the
    # second rung), the third exhausts endurance and demonstrates
    # graceful demotion to the digital fallback.
    health = HealthConfig(
        n_probes=8, max_reprograms=2,
        detector=DetectorConfig(warmup=3, z_trip=6.0, z_clear=2.0))
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)

    out: dict = {"ages": list(ages), "drift_nu": drift_nu,
                 "sigma_relax": sigma_relax}
    with tempfile.TemporaryDirectory() as tmp:
        def monitored_arc(s):
            """Warmup -> age -> heal -> measure, collecting evidence."""
            eng = _engine(cfg, params, tmp, model, s, health=health)
            toks = [np.asarray(eng.generate(prompts, 3))]
            for _ in range(n_warmup):
                eng.check_health()
            errs, prev = [], 1.0
            for age in ages:
                eng.advance(age - prev)
                prev = age
                # The ladder climbs as far as it needs: recalibration
                # repairs column-separable drift, the relaxation
                # residual re-trips into a reprogram (clock reset —
                # subsequent ages re-age the fresh draw), exhausted
                # endurance demotes to the digital fallback.  Probe
                # until a round passes with no new trips (remediation
                # rearms the detector, so the tripped list is always
                # empty post-round — the trip *counter* is the signal).
                for _ in range(n_heal_rounds):
                    before = eng.health.counters["trips"]
                    rep = eng.check_health()
                    if rep.counters["trips"] == before:
                        break
                errs.append(_probe_err(eng))
                toks.append(np.asarray(eng.generate(prompts, 3)))
            return eng, errs, toks, eng.health_report

        mon_eng, healed, toks_a, rep_a = monitored_arc(seed)
        twin_eng, healed_b, toks_b, rep_b = monitored_arc(seed)

        un_eng = _engine(cfg, params, tmp, model, seed, health=health)
        fresh = _probe_err(un_eng)
        degraded, prev = [], 1.0
        for age in ages:
            un_eng.advance(age - prev)
            prev = age
            degraded.append(_probe_err(un_eng))

    worst_unmonitored = max(degraded)
    worst_healed = max(healed)
    out["fresh_err"] = fresh
    out["unmonitored_err"] = degraded
    out["monitored_err"] = healed
    out["counters"] = rep_a.counters
    out["events"] = len(rep_a.events)
    out["unmonitored_degrades_2x"] = bool(
        worst_unmonitored >= 2.0 * max(fresh, 1e-3))
    out["monitored_within_10pct"] = bool(
        worst_healed <= _REL_SLACK * fresh + _ABS_SLACK)
    out["zero_flaps"] = bool(rep_a.flaps == 0 and rep_b.flaps == 0)
    out["deterministic_escalations"] = bool(
        _history(rep_a) == _history(rep_b)
        and np.allclose(healed, healed_b))
    out["generation_deterministic_across_swaps"] = bool(
        all(np.array_equal(a, b) for a, b in zip(toks_a, toks_b)))
    out["all_gates"] = bool(
        out["unmonitored_degrades_2x"]
        and out["monitored_within_10pct"] and out["zero_flaps"]
        and out["deterministic_escalations"]
        and out["generation_deterministic_across_swaps"])
    if verbose:
        print(f"  fresh_err={fresh:.4f}")
        for i, age in enumerate(ages):
            print(f"  age={age:<8g} unmonitored={degraded[i]:.4f} "
                  f"monitored={healed[i]:.4f}")
        print(f"  counters={rep_a.counters}")
        for gate in ("unmonitored_degrades_2x", "monitored_within_10pct",
                     "zero_flaps", "deterministic_escalations",
                     "generation_deterministic_across_swaps"):
            print(f"  {gate}={out[gate]}")
    return out


if __name__ == "__main__":
    run()
