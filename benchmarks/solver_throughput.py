"""Micro-benchmark: batched crossbar solver vs the seed ``lax.map`` path.

Solves the same tile batch with the fused engine
(``repro.crossbar.batched``: one jitted PCG over the whole stack, line-
tridiagonal preconditioner, per-tile early exit) and with the seed
behaviour (``measured_nf_sequential``: one Jacobi-CG per tile under
``jax.lax.map``), and reports warm-run throughput in tiles/second.

Acceptance bar (ISSUE 1): >= 10x speedup on a 64-tile batch while both
paths agree with each other (and, transitively, with the dense nodal
oracle pinned in tests/test_solver.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import CrossbarSpec
from repro.crossbar.batched import measured_nf_batched
from repro.crossbar.solver import measured_nf_sequential


def _time(fn, *args, repeats: int = 3) -> tuple[float, object]:
    out = fn(*args)
    jax.block_until_ready(out)          # warm-up / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(n_tiles: int = 64, rows: int = 64, cols: int = 64,
        sparsity: float = 0.8, verbose: bool = True, seed: int = 0) -> dict:
    spec = CrossbarSpec(rows=rows, cols=cols, n_bits=8)
    key = jax.random.PRNGKey(seed)
    masks = (jax.random.uniform(key, (n_tiles, rows, cols))
             < (1 - sparsity)).astype(jnp.float32)

    t_batched, res_b = _time(measured_nf_batched, masks, spec)
    t_seq, res_s = _time(measured_nf_sequential, masks, spec)

    # Both paths converge to 1e-12 residual independently; the solution
    # gap scales with the chain condition number (~J^2), and nf_total =
    # |sum di| further amplifies it by cancellation.  1e-5 / 1e-4 are
    # orders of magnitude below the ~1e-3 NF signal being measured.
    np.testing.assert_allclose(np.asarray(res_b.currents),
                               np.asarray(res_s.currents), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(res_b.nf_total),
                               np.asarray(res_s.nf_total), rtol=1e-4)
    speedup = t_seq / t_batched
    out = {
        "n_tiles": n_tiles, "rows": rows, "cols": cols,
        "batched_s": t_batched, "sequential_s": t_seq,
        "batched_tiles_per_s": n_tiles / t_batched,
        "sequential_tiles_per_s": n_tiles / t_seq,
        "speedup": speedup,
        "cg_iterations": int(res_b.iterations),
        "max_residual": float(np.asarray(res_b.residual).max()),
    }
    if verbose:
        print(f"  {n_tiles} tiles {rows}x{cols}: "
              f"batched {t_batched*1e3:.0f}ms "
              f"({out['batched_tiles_per_s']:.0f} tiles/s, "
              f"{out['cg_iterations']} CG iters) vs "
              f"lax.map {t_seq*1e3:.0f}ms "
              f"({out['sequential_tiles_per_s']:.0f} tiles/s) "
              f"-> {speedup:.1f}x")
    return out


if __name__ == "__main__":
    run()
