"""Micro-benchmark: crossbar solver scale-out matrix.

Rows (all solving the same tile population to 1e-12 relative residual
unless noted):

* ``sequential``      — seed behaviour, one Jacobi-CG per tile under
  ``jax.lax.map`` (timed on a small subset; it is ~100x off the pace);
* ``batched_f64``     — PR-1 fused engine: one PCG over the whole
  stack, line-tridiagonal preconditioner, per-tile early exit;
* ``batched_mixed``   — same engine under the MIXED precision policy
  (f32 CG iterations + warm-started f64 polish);
* ``sharded_f64``     — the batch shard_mapped over all local devices
  (``repro.distributed.solver_shard``), per-shard early exit, one psum
  for the global convergence check;
* ``sharded_mixed``   — sharding and mixed precision composed: the
  layer-scale production configuration.

Acceptance bar (ISSUE 2): on an 8-way host-device simulation with a
512-tile batch, ``sharded_mixed`` reaches >= 2x the tiles/s of the
PR-1 ``batched_f64`` engine while its currents stay within 1e-10
relative of the f64 oracle.  Run standalone this module forces the
8-device simulation itself; under ``benchmarks/run.py`` the harness
sets the flag before JAX initialises.

Measurement honesty note — the ratio is regime-dependent.  The 8
simulated devices share however many *physical* cores the host has
(2 on the CI box), and the preconditioner's chain solves lower to
sequential scans:

* 512 tiles of 64x64 (the paper-scale geometry) are *work-bound*
  there: every row shares a ~0.3 s/CG-iteration floor, sharding buys
  only the scheduling gap (~1.1-1.2x) and the f32 coarse phase nothing
  (the scans are step-latency-bound and dtype-insensitive on CPU).
* 512 tiles of 32x32 are *latency-bound*: the per-shard programs are
  small enough that concurrent shard execution hides the scan steps,
  and the sharded engine clears the >= 2x bar outright (sharded_f64
  typically 2.5-4x, sharded_mixed 1.6-2.4x, vs the PR-1 engine).

Both geometries are recorded via ``benchmarks/run.py``
(``solver_throughput`` and ``solver_throughput_32x32``) so the
trajectory tracks both regimes; on real accelerators (devices with
their own memory bandwidth) the 64x64 regime is where sharding and
mixed precision pay as designed.
"""
from __future__ import annotations

import os
import time

if __name__ == "__main__":  # must precede any jax import/backend init
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import CrossbarSpec
from repro.crossbar.batched import MIXED, measured_nf_batched
from repro.crossbar.solver import measured_nf_sequential
from repro.distributed.solver_shard import measured_nf_sharded


def _time_interleaved(configs: dict, rounds: int = 4
                      ) -> tuple[dict, dict]:
    """Best-of-N wall time per config, measured in *interleaved* rounds
    (cfg A, B, C, A, B, C, ...) so slow machine-level drift — thermal /
    cgroup-quota throttling over a multi-second benchmark — degrades
    every config equally instead of whichever happened to run last."""
    outs = {k: fn() for k, fn in configs.items()}   # warm-up / compile
    for o in outs.values():
        jax.block_until_ready(o)
    best = {k: float("inf") for k in configs}
    for _ in range(rounds):
        for k, fn in configs.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[k] = min(best[k], time.perf_counter() - t0)
    return best, outs


def _max_rel_err(res, oracle) -> float:
    a = np.asarray(res.currents)
    b = np.asarray(oracle.currents)
    return float(np.max(np.abs(a - b) / np.abs(b)))


def run(n_tiles: int = 512, rows: int = 64, cols: int = 64,
        sparsity: float = 0.8, verbose: bool = True, seed: int = 0,
        seq_tiles: int = 64) -> dict:
    spec = CrossbarSpec(rows=rows, cols=cols, n_bits=8)
    key = jax.random.PRNGKey(seed)
    masks = (jax.random.uniform(key, (n_tiles, rows, cols))
             < (1 - sparsity)).astype(jnp.float32)
    n_dev = len(jax.local_devices())

    # Seed lax.map baseline on a subset (full 512 takes minutes),
    # normalised to tiles/s for comparison.
    seq_tiles = min(seq_tiles, n_tiles)
    times, results = _time_interleaved({
        "batched_f64": lambda: measured_nf_batched(masks, spec),
        "batched_mixed": lambda: measured_nf_batched(masks, spec,
                                                     precision=MIXED),
        "sharded_f64": lambda: measured_nf_sharded(masks, spec),
        "sharded_mixed": lambda: measured_nf_sharded(masks, spec,
                                                     precision=MIXED),
        "sequential": lambda: measured_nf_sequential(masks[:seq_tiles],
                                                     spec),
    })
    t_b64, res_b64 = times["batched_f64"], results["batched_f64"]
    t_bmx, res_bmx = times["batched_mixed"], results["batched_mixed"]
    t_s64, res_s64 = times["sharded_f64"], results["sharded_f64"]
    t_smx, res_smx = times["sharded_mixed"], results["sharded_mixed"]
    t_seq, res_seq = times["sequential"], results["sequential"]

    # Cross-path agreement: sequential vs batched on the shared subset
    # (both 1e-12-residual solves, different preconditioners; see PR-1),
    # mixed/sharded vs the f64 oracle everywhere.
    np.testing.assert_allclose(np.asarray(res_b64.currents[:seq_tiles]),
                               np.asarray(res_seq.currents), rtol=1e-5)
    err_bmx = _max_rel_err(res_bmx, res_b64)
    err_s64 = _max_rel_err(res_s64, res_b64)
    err_smx = _max_rel_err(res_smx, res_b64)

    rows_out = {
        "sequential": {"seconds": t_seq, "n_tiles": seq_tiles,
                       "tiles_per_s": seq_tiles / t_seq},
        "batched_f64": {"seconds": t_b64, "n_tiles": n_tiles,
                        "tiles_per_s": n_tiles / t_b64,
                        "iterations": int(res_b64.iterations)},
        "batched_mixed": {"seconds": t_bmx, "n_tiles": n_tiles,
                          "tiles_per_s": n_tiles / t_bmx,
                          "iterations": int(res_bmx.iterations),
                          "max_rel_err_vs_f64": err_bmx},
        "sharded_f64": {"seconds": t_s64, "n_tiles": n_tiles,
                        "tiles_per_s": n_tiles / t_s64,
                        "iterations": int(res_s64.iterations),
                        "max_rel_err_vs_f64": err_s64},
        "sharded_mixed": {"seconds": t_smx, "n_tiles": n_tiles,
                          "tiles_per_s": n_tiles / t_smx,
                          "iterations": int(res_smx.iterations),
                          "max_rel_err_vs_f64": err_smx},
    }
    out = {
        "n_tiles": n_tiles, "rows": rows, "cols": cols,
        "n_devices": n_dev,
        "rows_detail": rows_out,
        # PR-1 metric (kept for trajectory): fused engine vs seed walk.
        "batched_s": t_b64, "sequential_s": t_seq,
        "batched_tiles_per_s": n_tiles / t_b64,
        "sequential_tiles_per_s": seq_tiles / t_seq,
        "speedup": (n_tiles / t_b64) / (seq_tiles / t_seq),
        # ISSUE-2 metrics: scale-out engine vs the PR-1 engine.
        "sharded_mixed_tiles_per_s": n_tiles / t_smx,
        "speedup_sharded_mixed_vs_batched_f64": t_b64 / t_smx,
        "speedup_sharded_f64_vs_batched_f64": t_b64 / t_s64,
        "speedup_scaleout_best_vs_batched_f64": t_b64 / min(t_s64, t_smx),
        "mixed_max_rel_voltage_err": err_bmx,
        "sharded_mixed_max_rel_voltage_err": err_smx,
        "cg_iterations": int(res_b64.iterations),
        "max_residual": float(np.asarray(res_b64.residual).max()),
    }
    if verbose:
        print(f"  {n_tiles} tiles {rows}x{cols} on {n_dev} device(s):")
        for name, r in rows_out.items():
            extra = ""
            if "max_rel_err_vs_f64" in r:
                extra = f"  err_vs_f64 {r['max_rel_err_vs_f64']:.1e}"
            print(f"    {name:14s} {r['seconds']*1e3:8.0f} ms "
                  f"({r['tiles_per_s']:7.0f} tiles/s on "
                  f"{r['n_tiles']} tiles){extra}")
        print(f"    scale-out best vs batched_f64: "
              f"x{out['speedup_scaleout_best_vs_batched_f64']:.2f} "
              f"(mixed x{out['speedup_sharded_mixed_vs_batched_f64']:.2f};"
              f" bar: >= 2x, err <= 1e-10)")
    return out


if __name__ == "__main__":
    run()
