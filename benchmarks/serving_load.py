"""Benchmark: continuous-batching serving tier under open-loop load.

Drives :class:`repro.serve.ContinuousEngine` — the multi-tenant
slot-pool tier over the CIM path — through three measurement sections:

* **throughput sweep**: a saturating backlog (every request submitted
  up front) served at slot capacities 1 -> 8; continuous batching
  amortises the per-iteration dispatch cost over live slots, so
  tokens/sec must climb with capacity;
* **open-loop latency**: Poisson arrivals (exponential gaps drawn from
  ``RandomState(arrival_seed)`` — the seed is recorded in the results
  entry) replayed through a discrete-event loop that charges each
  scheduler iteration its *measured* wall time, at an underloaded and a
  saturating arrival rate calibrated from the throughput sweep;
  reports p50/p95 request latency, tokens/sec and mean occupancy;
* **mid-load async redeploy**: a second checkpoint deploys through the
  shared plan-cache manifest in a background thread while the first
  keeps serving; the swap lands between iterations.

Headline acceptance (the ISSUE-10 serving-tier claim):

* **throughput scales**: tokens/sec strictly increases across the
  capacity sweep at saturating load;
* **one decode trace**: batch composition churn (admissions, evictions,
  mixed temperatures, epoch swaps) never retraces the decode lowerable
  — <= 2 traces across the whole run is the gate (1 expected);
* **zero-downtime redeploy**: the mid-load redeploy finishes with zero
  failed requests, in-flight outputs bit-identical to a swap-free twin,
  and post-swap admissions bit-identical to a fresh engine on the new
  checkpoint.
"""
from __future__ import annotations

import tempfile

import jax
import numpy as np

from repro import telemetry as tm
from repro.configs.base import CimConfig, ModelConfig
from repro.deploy import PlanCache
from repro.models.model import init_params
from repro.nonideal import NonidealModel
from repro.serve import ContinuousEngine


def _cfg() -> ModelConfig:
    return ModelConfig(
        name="cim-serving-load", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, block_pattern=("attn",),
        remat="none", dtype="float32", attn_chunk=32,
        cim=CimConfig(enabled=True, mode="mdm", rows=16, cols=16,
                      n_bits=4))


def _prompts(n: int, length: int, vocab: int, seed: int) -> np.ndarray:
    rs = np.random.RandomState(seed)
    return rs.randint(0, vocab, size=(n, length)).astype(np.int32)


def _engine(cfg, params, tmp, capacity, **kw) -> ContinuousEngine:
    return ContinuousEngine(cfg, params, capacity=capacity, max_seq=64,
                            max_prompt=16, plan_cache=PlanCache(tmp),
                            **kw)


# -- throughput ------------------------------------------------------------


def _throughput(cfg, params, tmp, capacity: int, n_requests: int,
                max_tokens: int) -> dict:
    """Saturating-backlog tokens/sec at one slot capacity.

    Every request is submitted before the loop starts, so the pool
    stays full until the tail drains — the regime where continuous
    batching pays.  A one-request warmup run compiles the prefill /
    decode / join / evict lowerables outside the timed section.
    """
    eng = _engine(cfg, params, tmp, capacity)
    prompts = _prompts(n_requests, 8, cfg.vocab_size, seed=7)
    eng.submit(prompts[0], 2, seed=0)
    eng.run()                                     # warm the lowerables
    for i in range(n_requests):
        eng.submit(prompts[i], max_tokens, temperature=0.7, seed=i)
    t0 = tm.monotonic()
    eng.run()
    dt = tm.monotonic() - t0
    total = n_requests * max_tokens
    return {"capacity": capacity, "tokens": total, "seconds": dt,
            "tokens_per_s": total / dt,
            "decode_traces": eng.traces["decode"]}


# -- open-loop latency -----------------------------------------------------


def _open_loop(cfg, params, tmp, capacity: int, n_requests: int,
               max_tokens: int, rate: float, arrival_seed: int) -> dict:
    """Replay Poisson arrivals through a discrete-event serving loop.

    Arrival times are fixed up front (open loop: the workload does not
    react to service); the simulated clock advances by the *measured*
    wall time of each scheduler iteration, and jumps forward when the
    engine is idle waiting for the next arrival — queueing behaviour
    under real service times, with no sleeping.
    """
    eng = _engine(cfg, params, tmp, capacity)
    prompts = _prompts(n_requests, 8, cfg.vocab_size, seed=11)
    eng.submit(prompts[0], 2, seed=0)
    eng.run()                                     # warm the lowerables
    rs = np.random.RandomState(arrival_seed)
    arrivals = np.cumsum(rs.exponential(1.0 / rate, size=n_requests))
    now, i = 0.0, 0
    submit_t: dict[int, float] = {}
    done_t: dict[int, float] = {}
    occupancy = []
    while len(done_t) < n_requests:
        if not eng.scheduler.pending and i < n_requests \
                and arrivals[i] > now:
            now = float(arrivals[i])              # idle: jump to arrival
        while i < n_requests and arrivals[i] <= now:
            rid = eng.submit(prompts[i], max_tokens, temperature=0.7,
                             seed=i)
            submit_t[rid] = float(arrivals[i])
            i += 1
        t0 = tm.monotonic()
        eng.step()
        now += tm.monotonic() - t0
        occupancy.append(eng.pool.n_live / capacity)
        for rid in eng.results:
            if rid in submit_t and rid not in done_t:
                done_t[rid] = now
    lat = np.array([done_t[r] - submit_t[r] for r in sorted(done_t)])
    return {"capacity": capacity, "rate_req_per_s": rate,
            "p50_s": float(np.percentile(lat, 50)),
            "p95_s": float(np.percentile(lat, 95)),
            "tokens_per_s": n_requests * max_tokens / now,
            "mean_occupancy": float(np.mean(occupancy)),
            "decode_traces": eng.traces["decode"]}


# -- mid-load async redeploy -----------------------------------------------


def _redeploy(cfg, params, params2, tmp, capacity: int,
              max_tokens: int) -> dict:
    """Zero-downtime redeploy gates (twin-run bit-determinism).

    Run A serves group G1 swap-free; run B serves the identical G1 but
    kicks off a background redeploy to ``params2`` mid-flight, then
    admits group G2 after the swap; run C is a fresh engine deployed
    directly on ``params2`` serving G2.  In-flight outputs must be
    bit-identical A vs B (the swap never touches pinned epochs), G2
    outputs bit-identical B vs C (new admissions see exactly the new
    bank).
    """
    model = NonidealModel(drift_nu=0.05, sigma_program=0.02)
    # G1 fits the pool: every sequence is *in flight* (pinned to epoch
    # 0) before the redeploy kicks off — the set the bit-identical
    # contract covers.  A queued request could land on either side of
    # the swap depending on deploy-thread timing, which is correct
    # behaviour but not a deterministic gate.
    g1 = _prompts(capacity, 8, cfg.vocab_size, seed=21)
    g2 = _prompts(3, 8, cfg.vocab_size, seed=22)

    def serve(eng, prompts, seed0):
        rids = [eng.submit(p, max_tokens, temperature=0.5 * (i % 2),
                           seed=seed0 + i)
                for i, p in enumerate(prompts)]
        eng.run()
        return [eng.results[r] for r in rids]

    eng_a = _engine(cfg, params, tmp, capacity, nonideal=model)
    out_a = serve(eng_a, g1, seed0=100)

    eng_b = _engine(cfg, params, tmp, capacity, nonideal=model)
    rids1 = [eng_b.submit(p, max_tokens, temperature=0.5 * (i % 2),
                          seed=100 + i) for i, p in enumerate(g1)]
    for _ in range(2):                            # get G1 in flight
        eng_b.step()
    thread = eng_b.begin_redeploy(params2)
    eng_b.run()                                   # drain G1 under swap
    thread.join()
    eng_b.step()                                  # install if not yet
    swapped = eng_b.serving_epoch > 0
    out_b1 = [eng_b.results[r] for r in rids1]
    out_b2 = serve(eng_b, g2, seed0=200)

    eng_c = _engine(cfg, params2, tmp, capacity, nonideal=model)
    out_c2 = serve(eng_c, g2, seed0=200)

    complete = all(len(t) == max_tokens for t in out_b1 + out_b2)
    return {
        "swap_installed": bool(swapped),
        "zero_failed_requests": bool(complete),
        "inflight_bit_identical": bool(out_a == out_b1),
        "new_admissions_on_new_bank": bool(out_b2 == out_c2),
        "decode_traces": eng_b.traces["decode"],
    }


# -- harness ---------------------------------------------------------------


def run(capacities=(1, 2, 4, 8), n_requests: int = 16,
        max_tokens: int = 8, latency_n: int = 24,
        arrival_seed: int = 1234, verbose: bool = True) -> dict:
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    params2 = init_params(cfg, jax.random.PRNGKey(1))
    out: dict = {"capacities": list(capacities),
                 "arrival_seed": arrival_seed}

    with tempfile.TemporaryDirectory() as tmp:
        sweep = [_throughput(cfg, params, tmp, c, n_requests, max_tokens)
                 for c in capacities]
        out["throughput"] = {str(r["capacity"]): r for r in sweep}

        # Arrival rates calibrated from the measured service rate at
        # the latency capacity: 0.5x is underload (latency ~ service
        # time), 2x oversubscribes the pool (queueing dominates).
        lat_cap = capacities[len(capacities) // 2]
        svc = out["throughput"][str(lat_cap)]["tokens_per_s"] / max_tokens
        out["latency"] = {}
        for frac in (0.5, 2.0):
            r = _open_loop(cfg, params, tmp, lat_cap, latency_n,
                           max_tokens, rate=frac * svc,
                           arrival_seed=arrival_seed)
            out["latency"][f"{frac:g}x"] = r

        out["redeploy"] = _redeploy(cfg, params, params2, tmp,
                                    capacity=4, max_tokens=max_tokens)

    rates = [r["tokens_per_s"] for r in sweep]
    out["throughput_scales"] = bool(
        all(b > a for a, b in zip(rates, rates[1:])))
    out["decode_single_trace"] = bool(
        max(r["decode_traces"] for r in sweep) <= 2
        and max(r["decode_traces"] for r in out["latency"].values()) <= 2
        and out["redeploy"]["decode_traces"] <= 2)
    red = out["redeploy"]
    out["redeploy_zero_downtime"] = bool(
        red["swap_installed"] and red["zero_failed_requests"]
        and red["inflight_bit_identical"]
        and red["new_admissions_on_new_bank"])
    out["all_gates"] = bool(out["throughput_scales"]
                            and out["decode_single_trace"]
                            and out["redeploy_zero_downtime"])
    if verbose:
        for r in sweep:
            print(f"  capacity={r['capacity']:<2d} "
                  f"{r['tokens_per_s']:8.1f} tok/s "
                  f"decode_traces={r['decode_traces']}")
        for k, r in out["latency"].items():
            print(f"  load={k:<4s} p50={r['p50_s'] * 1e3:7.1f}ms "
                  f"p95={r['p95_s'] * 1e3:7.1f}ms "
                  f"occ={r['mean_occupancy']:.2f} "
                  f"{r['tokens_per_s']:8.1f} tok/s")
        for gate in ("throughput_scales", "decode_single_trace",
                     "redeploy_zero_downtime"):
            print(f"  {gate}={out[gate]}")
    return out


if __name__ == "__main__":
    run()
