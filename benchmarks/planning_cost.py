"""Benchmark: MDM planning overhead (the paper's "lightweight" claim).

Times plan_layer (bit-slice + score + sort + NF bookkeeping) and the
Pallas scoring kernel on layer-sized matrices, plus the fused
whole-model planner (``repro.deploy``) on the same workload expressed
as a multi-matrix population; MDM is a one-off deployment-time
transformation, so these must be trivially small next to
training/serving costs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.mdm import plan_layer
from repro.core.tiling import CrossbarSpec
from repro.deploy import plan_matrices
from repro.kernels.manhattan_score import manhattan_score
from repro.kernels.runtime import INTERPRET


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(verbose: bool = True) -> dict:
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    key = jax.random.PRNGKey(0)
    out = {}
    layers = {}
    for (i, n) in [(1024, 1024), (4096, 4096)]:
        w = jax.random.normal(jax.random.fold_in(key, i), (i, n)) * 0.02
        layers[f"{i}x{n}"] = w
        dt = _time(lambda w: plan_layer(w, spec, "mdm"), w)
        ti, tn = spec.grid(i, n)
        out[f"plan_{i}x{n}"] = {"seconds": dt, "tiles": ti * tn,
                                "us_per_tile": dt / (ti * tn) * 1e6}
        if verbose:
            print(f"  plan_layer {i}x{n}: {dt*1e3:.1f} ms "
                  f"({ti*tn} tiles, {dt/(ti*tn)*1e6:.1f} us/tile)")

    # Fused whole-model planner on the same matrices as one population.
    def fused(mats):
        plans, _ = plan_matrices(mats, spec, "mdm")
        return jax.block_until_ready(
            jnp.stack([p.nf_after.sum() for p in plans.values()]))

    dt = _time(fused, layers)
    tiles = sum(v["tiles"] for k, v in out.items() if k.startswith("plan_"))
    out["plan_model_fused"] = {"seconds": dt, "tiles": tiles,
                               "us_per_tile": dt / tiles * 1e6}
    if verbose:
        print(f"  fused whole-model planner ({len(layers)} matrices, "
              f"{tiles} tiles): {dt*1e3:.1f} ms "
              f"({dt/tiles*1e6:.1f} us/tile)")

    masks = (jax.random.uniform(jax.random.fold_in(key, 0),
                                (256, 64, 64)) < 0.2).astype(jnp.uint8)
    dt = _time(lambda m: manhattan_score(m, nf_unit=spec.nf_unit), masks)
    out["score_kernel_256tiles"] = {"seconds": dt, "interpret": INTERPRET}
    if verbose:
        label = "interpret" if INTERPRET else "compiled"
        print(f"  manhattan_score kernel (256 tiles, {label}): "
              f"{dt*1e3:.1f} ms")
    return out


if __name__ == "__main__":
    run()
