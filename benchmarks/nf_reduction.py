"""Benchmark: NF reduction with MDM (paper §V-B, Fig 5).

For bell-shaped weight ensembles representative of the assigned model
families, computes the analytical (Eq-16) NF under every mapping
pipeline ablation — the paper's four (baseline/reverse/sort/mdm) plus
the X-CHANGR-style bitline-sorted composite — and reports the %
reduction (paper: up to 46%, with reversed dataflow improving MDM by up
to 50% over conventional).  Mappings are selected through the
:mod:`repro.mapping` registry, so a strategy added for a new paper
appears in this table by adding its name to ``PIPELINES``.

Additionally validates the *dataflow-reversal physics* with the circuit
solver: the first-order Eq-17 noise model cannot show the benefit of
draining dense low-order columns early (see tests/test_noise.py), but
the Kirchhoff solve can — we report the significance-weighted output
error of a bit-sliced tile, conventional vs reversed.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitslice import bitslice
from repro.core.mdm import placed_masks, plan_from_bits
from repro.core.tiling import CrossbarSpec
from repro.crossbar.batched import measured_nf_batched
from repro.mapping import named_pipelines

_NAMED = named_pipelines()
# Analytic table: the paper's ablations + the bitline-sorted composite.
PIPELINES = ("baseline", "reverse", "sort", "mdm", "xchangr")
# Circuit check sweeps the paper's four ablations.
CIRCUIT_PIPELINES = ("baseline", "reverse", "sort", "mdm")


ENSEMBLES = {
    # bell-shaped, heavier tails -> higher bit sparsity (the paper's
    # models sit at >= 76-80% bit-level sparsity)
    "resnet-like (gaussian)": lambda k, n: jax.random.normal(
        k, (n, 64)) * 0.02,
    "cnn-pruned (laplace)": lambda k, n: jax.random.laplace(
        k, (n, 64)) * 0.01,
    "transformer-like (flat)": lambda k, n: jax.random.truncated_normal(
        k, -2.5, 2.5, (n, 64)) * 0.05,
    "outlier-heavy (student-t3)": lambda k, n: jax.random.t(
        k, 3.0, (n, 64)) * 0.01,
}

GEOMETRIES = {
    # the paper's crossbars: 128 rows x 10 bit-columns, one weight/row
    "128x10 (paper)": CrossbarSpec(rows=128, cols=10, n_bits=10),
    # packed tiles: 8 weights per row
    "64x64 tiles": CrossbarSpec(rows=64, cols=64, n_bits=8),
}


def run(n_rows: int = 512, verbose: bool = True) -> dict:
    key = jax.random.PRNGKey(0)
    out = {}
    for gname, spec in GEOMETRIES.items():
        for name, gen in ENSEMBLES.items():
            key, k = jax.random.split(key)
            w = gen(k, n_rows)
            sliced = bitslice(w, spec.n_bits)
            sparsity = 1.0 - float(jnp.mean(sliced.bits))
            nf = {}
            for pname in PIPELINES:
                plan = plan_from_bits(sliced.bits, sliced.scale, spec,
                                      _NAMED[pname])
                nf[pname] = float(jnp.sum(plan.nf_after))
            red = {m: 100 * (1 - nf[m] / nf["baseline"])
                   for m in PIPELINES}
            out[f"{gname} | {name}"] = {
                "nf": nf, "reduction_pct": red, "bit_sparsity": sparsity}
            if verbose:
                print(f"  {gname:15s} {name:28s} sp={sparsity:.2f} "
                      + " ".join(f"{m}={red[m]:5.1f}%" for m in PIPELINES
                                 if m != "baseline"))
    out["circuit_reversal_check"] = _circuit_reversal_check(
        CrossbarSpec(rows=64, cols=64, n_bits=8), verbose)
    return out


def _circuit_reversal_check(_spec_unused: CrossbarSpec,
                            verbose: bool) -> dict:
    """Circuit-level validation of the full MDM stack on the paper's
    128x10 geometry: the digitally *significance-weighted* output error
    (what actually hits model accuracy after shift-add) for every
    ablation.  First-order Eq-17 cannot credit dataflow reversal (the
    2^-k weighting punishes far high-order bits exactly as much as the
    NF metric rewards near low-order ones); the Kirchhoff solve shows
    reverse+sort is nonetheless the best *weighted*-error mapping —
    matching the paper's accuracy result."""
    t0 = time.perf_counter()
    spec = CrossbarSpec(rows=128, cols=10, n_bits=10)
    key = jax.random.PRNGKey(7)
    results = {m: {"nf": 0.0, "weighted": 0.0} for m in CIRCUIT_PIPELINES}
    n_tiles = 4
    # Build every (tile, pipeline) physical mask first, then solve the
    # whole stack in ONE batched call (16 tiles, one fused PCG).
    stack = []
    for i in range(n_tiles):
        key, k = jax.random.split(key)
        w = jnp.abs(jax.random.laplace(k, (128, 1))) * 0.02
        sliced = bitslice(w, spec.n_bits)
        for pname in CIRCUIT_PIPELINES:
            plan = plan_from_bits(sliced.bits, sliced.scale, spec,
                                  _NAMED[pname])
            stack.append(placed_masks(sliced.bits, plan, spec)[0, 0])
    # Mixed precision (f32 CG + f64 polish): tracks the f64 oracle to
    # ~1e-11 relative, orders of magnitude under the ~1e-3 weighted-
    # error signal measured here.
    res = measured_nf_batched(jnp.stack(stack), spec, precision="mixed")
    di_all = np.asarray(res.currents) - np.asarray(res.ideal)
    for i in range(n_tiles):
        for mi, pname in enumerate(CIRCUIT_PIPELINES):
            t = i * len(CIRCUIT_PIPELINES) + mi
            k_of_col = np.arange(spec.cols) % spec.n_bits
            if _NAMED[pname].reversed_dataflow:
                k_of_col = k_of_col[::-1]
            wgt = 2.0 ** -(1.0 + k_of_col)
            results[pname]["nf"] += float(res.nf_total[t]) / n_tiles
            results[pname]["weighted"] += float(
                np.abs(di_all[t] * wgt).sum()) / n_tiles
    base = results["baseline"]["weighted"]
    gains = {m: 100 * (1 - results[m]["weighted"] / base)
             for m in CIRCUIT_PIPELINES}
    if verbose:
        print("  circuit-level weighted-error check (128x10): "
              + " ".join(f"{m}={gains[m]:+.1f}%"
                         for m in CIRCUIT_PIPELINES if m != "baseline")
              + f"  [{time.perf_counter()-t0:.1f}s]")
    return {"results": results, "weighted_error_reduction_pct": gains}


if __name__ == "__main__":
    run()
