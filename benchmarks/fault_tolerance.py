"""Benchmark: fault/variation tolerance of MDM mappings.

Sweeps stuck-at-OFF fault rate x programming-variation sigma over three
mappings — baseline, plain MDM, and fault-aware MDM (the known physical
fault map folded into the row sort,
:func:`repro.core.manhattan.fault_aware_row_order`) — and records the
circuit-measured **distributions** (mean/std/p95 over the Monte-Carlo
fault+variation ensemble, :mod:`repro.nonideal.montecarlo`):

* ``nf``: aggregate current-deficit NF per tile;
* ``weighted_err``: bit-significance-weighted relative output error —
  the accuracy-degradation proxy (what the digital shift-add actually
  accumulates, the same metric as ``nf_reduction``'s circuit check).

The comparison is paired: one physical fault map is sampled per fault
rate (hardware defects do not move when the mapping changes) and the
per-sample variation draws share the PRNG key across mappings.  The
headline check — recorded per rate — is fault-aware MDM beating plain
MDM on both distributions under known stuck-at-OFF faults.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitslice import bitslice
from repro.core.mdm import placed_masks, plan_from_bits
from repro.core.tiling import CrossbarSpec
from repro.nonideal import NonidealModel, mc_nf, sample_stuck, summarize

# mapping name -> (MDM mode, fold the known fault map into the sort?)
MAPPINGS = {
    "baseline": ("baseline", False),
    "mdm": ("mdm", False),
    "mdm_fault_aware": ("mdm", True),
}


def _col_significance(spec: CrossbarSpec, mode: str) -> np.ndarray:
    """2^-(k+1) weight of each physical column's bit plane."""
    k_of_col = np.arange(spec.cols) % spec.n_bits
    if mode in ("reverse", "mdm"):
        k_of_col = k_of_col[::-1]
    return (2.0 ** -(1.0 + k_of_col)).astype(np.float32)


def run(n_rows: int = 256, n_samples: int = 6,
        rates=(0.002, 0.01, 0.05), sigmas=(0.0, 0.1),
        verbose: bool = True) -> dict:
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    key = jax.random.PRNGKey(0)
    w = jax.random.laplace(key, (n_rows, 64)) * 0.01
    sliced = bitslice(w, spec.n_bits)
    ti, tn = spec.grid(*w.shape)
    T = ti * tn

    out: dict = {"tiles": T, "n_samples": n_samples}
    aware_wins = {}
    for ri, rate in enumerate(rates):
        # One fixed physical fault map per rate: defects belong to the
        # hardware, shared by every mapping under comparison.
        stuck = sample_stuck(jax.random.fold_in(key, 100 + ri),
                             (ti, tn, spec.rows, spec.cols), rate, 0.0)
        for sigma in sigmas:
            model = NonidealModel(p_stuck_off=rate,
                                  sigma_program=sigma, sigma_read=0.01)
            mc_key = jax.random.fold_in(key, 1000 + ri)
            entry: dict = {}
            for name, (mode, aware) in MAPPINGS.items():
                plan = plan_from_bits(sliced.bits, sliced.scale, spec,
                                      mode, stuck if aware else None)
                placed = placed_masks(sliced.bits, plan, spec,
                                      masks=None)
                res = mc_nf(
                    placed.reshape(T, spec.rows, spec.cols), spec,
                    model, n_samples, mc_key,
                    stuck=jnp.asarray(stuck).reshape(T, spec.rows,
                                                     spec.cols),
                    col_weights=_col_significance(spec, mode),
                    precision="mixed")
                entry[name] = {
                    "nf": summarize(res.nf_total),
                    "weighted_err": summarize(res.weighted_err),
                    "unconverged": int(res.unconverged),
                }
                if verbose:
                    e = entry[name]
                    print(f"  rate={rate:<6g} sigma={sigma:<4g} "
                          f"{name:16s} nf={e['nf']['mean']:.4f}"
                          f"+-{e['nf']['std']:.4f} "
                          f"p95={e['nf']['p95']:.4f}  werr="
                          f"{e['weighted_err']['mean']:.5f}")
            out[f"rate={rate:g}|sigma={sigma:g}"] = entry
            if sigma == sigmas[0]:
                aware_wins[f"{rate:g}"] = bool(
                    entry["mdm_fault_aware"]["weighted_err"]["mean"]
                    < entry["mdm"]["weighted_err"]["mean"]
                    and entry["mdm_fault_aware"]["nf"]["mean"]
                    < entry["mdm"]["nf"]["mean"])
    out["fault_aware_beats_mdm"] = aware_wins
    out["fault_aware_beats_mdm_any_rate"] = any(aware_wins.values())
    if verbose:
        print("  fault-aware MDM beats plain MDM (nf & weighted err):",
              aware_wins)
    return out


if __name__ == "__main__":
    run()
