"""Benchmark: fault/variation tolerance of mapping pipelines.

Sweeps stuck-at-OFF fault rate x programming-variation sigma over four
mapping pipelines — baseline, plain MDM, fault-aware MDM (uniform fault
currency) and significance-weighted fault-aware MDM (stuck columns
weighted by the hosted bit plane's 2^-(k+1) shift-add weight,
:class:`repro.mapping.SignificanceWeightedRows`) — and records the
circuit-measured **distributions** (mean/std/p95 over the Monte-Carlo
fault+variation ensemble, :mod:`repro.nonideal.montecarlo`):

* ``nf``: aggregate current-deficit NF per tile;
* ``weighted_err``: bit-significance-weighted relative output error —
  the accuracy-degradation proxy (what the digital shift-add actually
  accumulates, the same metric as ``nf_reduction``'s circuit check).

The comparison is paired: one physical fault map is sampled per fault
rate (hardware defects do not move when the mapping changes) and the
per-sample variation draws share the PRNG key across mappings.  Two
headline checks are recorded per rate: fault-aware MDM beating plain
MDM on both distributions, and the significance-weighted strategy
matching-or-beating plain fault-aware on the accuracy proxy at equal
NF currency (the ROADMAP follow-up this strategy implements).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitslice import bitslice
from repro.core.mdm import placed_masks, plan_from_bits
from repro.core.tiling import CrossbarSpec
from repro.mapping import MappingPipeline, named_pipelines
from repro.nonideal import NonidealModel, mc_nf, sample_stuck, summarize

# mapping name -> (MappingPipeline, feed the known fault map to the sort?)
_P = named_pipelines()
MAPPINGS: dict[str, tuple[MappingPipeline, bool]] = {
    "baseline": (_P["baseline"], False),
    "mdm": (_P["mdm"], False),
    "mdm_fault_aware": (_P["fault_aware"], True),
    "mdm_sig_weighted": (_P["significance_weighted"], True),
}


def _col_significance(spec: CrossbarSpec, pipe: MappingPipeline,
                      plan, n_tiles: int) -> np.ndarray:
    """Per-tile 2^-(k+1) weight of each *physical* column's bit plane.

    Column-permuting pipelines host a different bit plane per physical
    bitline per tile (``plan.col_perm``), so the weighted-error metric
    needs the (T, cols) grid; identity pipelines broadcast the fixed
    layout."""
    from repro.core.mdm import physical_column_significance

    col_perm = (None if plan.col_perm is None
                else jnp.reshape(plan.col_perm, (n_tiles, spec.cols)))
    return np.asarray(physical_column_significance(
        spec, pipe.reversed_dataflow, col_perm, n_tiles))


def run(n_rows: int = 256, n_samples: int = 6,
        rates=(0.002, 0.01, 0.05), sigmas=(0.0, 0.1),
        verbose: bool = True) -> dict:
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    key = jax.random.PRNGKey(0)
    w = jax.random.laplace(key, (n_rows, 64)) * 0.01
    sliced = bitslice(w, spec.n_bits)
    ti, tn = spec.grid(*w.shape)
    T = ti * tn

    out: dict = {"tiles": T, "n_samples": n_samples}
    aware_wins = {}
    sig_wins = {}
    for ri, rate in enumerate(rates):
        # One fixed physical fault map per rate: defects belong to the
        # hardware, shared by every mapping under comparison.
        stuck = sample_stuck(jax.random.fold_in(key, 100 + ri),
                             (ti, tn, spec.rows, spec.cols), rate, 0.0)
        for sigma in sigmas:
            model = NonidealModel(p_stuck_off=rate,
                                  sigma_program=sigma, sigma_read=0.01)
            mc_key = jax.random.fold_in(key, 1000 + ri)
            entry: dict = {}
            for name, (pipe, aware) in MAPPINGS.items():
                plan = plan_from_bits(sliced.bits, sliced.scale, spec,
                                      pipe, stuck if aware else None)
                placed = placed_masks(sliced.bits, plan, spec,
                                      masks=None)
                res = mc_nf(
                    placed.reshape(T, spec.rows, spec.cols), spec,
                    model, n_samples, mc_key,
                    stuck=jnp.asarray(stuck).reshape(T, spec.rows,
                                                     spec.cols),
                    col_weights=_col_significance(spec, pipe, plan, T),
                    precision="mixed")
                entry[name] = {
                    "nf": summarize(res.nf_total),
                    "weighted_err": summarize(res.weighted_err),
                    "unconverged": int(res.unconverged),
                }
                if verbose:
                    e = entry[name]
                    print(f"  rate={rate:<6g} sigma={sigma:<4g} "
                          f"{name:16s} nf={e['nf']['mean']:.4f}"
                          f"+-{e['nf']['std']:.4f} "
                          f"p95={e['nf']['p95']:.4f}  werr="
                          f"{e['weighted_err']['mean']:.5f}")
            out[f"rate={rate:g}|sigma={sigma:g}"] = entry
            if sigma == sigmas[0]:
                aware_wins[f"{rate:g}"] = bool(
                    entry["mdm_fault_aware"]["weighted_err"]["mean"]
                    < entry["mdm"]["weighted_err"]["mean"]
                    and entry["mdm_fault_aware"]["nf"]["mean"]
                    < entry["mdm"]["nf"]["mean"])
                # The significance-weighted acceptance: >= plain
                # fault-aware on the accuracy proxy (weighted err) at
                # equal NF — NF is allowed to tie or trade marginally
                # (the strategy deliberately spends NF currency on
                # significance).
                sig_wins[f"{rate:g}"] = bool(
                    entry["mdm_sig_weighted"]["weighted_err"]["mean"]
                    <= entry["mdm_fault_aware"]["weighted_err"]["mean"]
                    * (1 + 1e-6))
    out["fault_aware_beats_mdm"] = aware_wins
    out["fault_aware_beats_mdm_any_rate"] = any(aware_wins.values())
    out["sig_weighted_matches_fault_aware"] = sig_wins
    out["sig_weighted_matches_fault_aware_all_rates"] = all(
        sig_wins.values())
    if verbose:
        print("  fault-aware MDM beats plain MDM (nf & weighted err):",
              aware_wins)
        print("  significance-weighted >= fault-aware (weighted err):",
              sig_wins)
    return out


def run_line_open(n_rows: int = 256, n_samples: int = 2,
                  rates=((0.02, 0.01), (0.05, 0.02), (0.08, 0.05)),
                  verbose: bool = True) -> dict:
    """Line-open-rate sweep: spare-line remapping vs the row-only sorts.

    Sweeps (wordline, bitline) open-rate pairs — bitline opens are the
    structurally hard case, since row-sorting pipelines cannot move
    columns — over baseline / plain MDM / fault-aware MDM / the
    ``spare_line`` pipeline (fault-aware rows *and* columns with the
    ``open_penalty`` surcharge).  One physical open map per rate pair is
    shared by every mapping (defects belong to the hardware), and two
    headline metrics are recorded per mapping:

    * the circuit-measured NF distribution (Monte-Carlo engine) and the
      significance-weighted output error (the accuracy proxy);
    * ``bits_lost``: programmed active bits landing on severed lines,
      plus ``weighted_lost``: the significance-weighted current those
      lines silence (off-cells included at the r_on/r_off ratio).

    Headline check — in the **accuracy currency**: now that the column
    steering is significance-weighted (``SpareLineCols`` threads the
    per-plane 2^-(k+1) weights and the off-current floor into
    ``fault_aware_col_order``), spare-line must cut the weighted
    severed current vs plain fault-aware MDM at every swept rate, and
    its weighted-err proxy must no longer trail fault-aware.  Raw NF /
    raw ``bits_lost`` are recorded but no longer gate: the weighted
    steering deliberately sacrifices dense low-order planes (many
    cheap bits) to protect sparse high-order ones (few expensive
    bits).
    """
    from repro.nonideal.models import OPEN, sample_line_open

    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    key = jax.random.PRNGKey(0)
    w = jax.random.laplace(key, (n_rows, 64)) * 0.01
    sliced = bitslice(w, spec.n_bits)
    ti, tn = spec.grid(*w.shape)
    T = ti * tn

    mappings: dict[str, tuple[MappingPipeline, bool]] = {
        "baseline": (_P["baseline"], False),
        "mdm": (_P["mdm"], False),
        "mdm_fault_aware": (_P["fault_aware"], True),
        "spare_line": (_P["spare_line"], True),
    }
    out: dict = {"tiles": T, "n_samples": n_samples}
    spare_wins = {}
    werr_wins = {}
    for ri, (p_wl, p_bl) in enumerate(rates):
        tag = f"wl={p_wl:g}|bl={p_bl:g}"
        stuck = sample_line_open(jax.random.fold_in(key, 100 + ri),
                                 (ti, tn, spec.rows, spec.cols),
                                 p_wl, p_bl)
        model = NonidealModel(p_open_wordline=p_wl, p_open_bitline=p_bl,
                              sigma_program=0.05)
        mc_key = jax.random.fold_in(key, 1000 + ri)
        entry: dict = {}
        for name, (pipe, aware) in mappings.items():
            plan = plan_from_bits(sliced.bits, sliced.scale, spec,
                                  pipe, stuck if aware else None)
            placed = placed_masks(sliced.bits, plan, spec, masks=None)
            flat = placed.reshape(T, spec.rows, spec.cols)
            stuck_flat = jnp.asarray(stuck).reshape(T, spec.rows,
                                                    spec.cols)
            cw = _col_significance(spec, pipe, plan, T)
            res = mc_nf(flat, spec, model, n_samples, mc_key,
                        stuck=stuck_flat,
                        col_weights=cw,
                        precision="mixed")
            lost = int(jnp.sum((flat > 0)
                               & (stuck_flat == OPEN)))
            rho = spec.r_on / spec.r_off
            cell_cur = jnp.where(flat > 0, 1.0, rho)
            wlost = float(jnp.sum(jnp.asarray(cw)[:, None, :] * cell_cur
                                  * (stuck_flat == OPEN)))
            entry[name] = {
                "nf": summarize(res.nf_total),
                "weighted_err": summarize(res.weighted_err),
                "bits_lost": lost,
                "weighted_lost": wlost,
                "unconverged": int(res.unconverged),
            }
            if verbose:
                e = entry[name]
                print(f"  {tag:20s} {name:16s} "
                      f"nf={e['nf']['mean']:.4f} "
                      f"werr={e['weighted_err']['mean']:.5f} "
                      f"bits_lost={lost} wlost={wlost:.1f}")
        out[tag] = entry
        # Accuracy-currency gate: spare-line must cut the weighted
        # severed current and its weighted-err proxy must not trail
        # plain fault-aware (small slack for Monte-Carlo noise at
        # equal draws).
        spare_wins[tag] = bool(
            entry["spare_line"]["weighted_lost"]
            < entry["mdm_fault_aware"]["weighted_lost"]
            and entry["spare_line"]["weighted_err"]["mean"]
            <= entry["mdm_fault_aware"]["weighted_err"]["mean"]
            * (1 + 1e-6))
        werr_wins[tag] = bool(
            entry["spare_line"]["weighted_err"]["mean"]
            <= entry["mdm_fault_aware"]["weighted_err"]["mean"]
            * (1 + 1e-6))
    out["spare_line_beats_fault_aware"] = spare_wins
    out["spare_line_beats_fault_aware_all_rates"] = all(
        spare_wins.values())
    out["spare_line_weighted_err_leads"] = werr_wins
    out["spare_line_weighted_err_leads_all_rates"] = all(
        werr_wins.values())
    if verbose:
        print("  spare-line beats fault-aware (weighted lost & werr):",
              spare_wins)
        print("  spare-line weighted-err no longer trails fault-aware:",
              werr_wins)
    return out


if __name__ == "__main__":
    run()
    run_line_open()
