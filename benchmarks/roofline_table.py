"""Render the §Dry-run / §Roofline tables from results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(tag: str | None = None) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        r = json.load(open(p))
        if (r.get("tag") or "") != (tag or ""):
            continue
        recs.append(r)
    return recs


def fmt_bytes(b):
    return f"{b/1e9:.2f}" if b is not None else "?"


def roofline_markdown(mesh: str = "pod_16x16", tag: str | None = None) -> str:
    rows = ["| arch | shape | peak GB/dev | t_comp (s) | t_mem (s) | "
            "t_coll (s) | dominant | MODEL/HLO flops | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in load(tag):
        if r["mesh"] != mesh:
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                        f"{r.get('error','?')} | | | | | | |")
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{fmt_bytes(r['memory']['peak_bytes'])} | "
            f"{rf['t_compute_s']:.3g} | {rf['t_memory_s']:.3g} | "
            f"{rf['t_collective_s']:.3g} | {rf['dominant']} | "
            f"{rf['useful_flop_ratio']:.3f} | "
            f"{rf['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def dryrun_markdown(tag: str | None = None) -> str:
    rows = ["| arch | shape | mesh | ok | compile (s) | peak GB/dev | "
            "coll GB (AG/AR/RS/A2A/CP) |",
            "|---|---|---|---|---|---|---|"]
    for r in load(tag):
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"FAIL | | | {r.get('error','')[:60]} |")
            continue
        cb = r["roofline"]["coll_breakdown"]
        coll = "/".join(f"{cb.get(k, 0)/1e9:.1f}" for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']:.1f} | "
            f"{fmt_bytes(r['memory']['peak_bytes'])} | {coll} |")
    return "\n".join(rows)


def perf_comparison_markdown(mesh: str = "pod_16x16") -> str:
    """Baseline vs optimized-config (tag=opt) roofline fractions."""
    base = {(r["arch"], r["shape"]): r for r in load(None)
            if r["mesh"] == mesh and r.get("ok")}
    opt = {(r["arch"], r["shape"]): r for r in load("opt")
           if r["mesh"] == mesh and r.get("ok")}
    rows = ["| arch | shape | baseline frac | optimized frac | gain |"
            " dominant (opt) |",
            "|---|---|---|---|---|---|"]
    for key in sorted(base):
        if key not in opt:
            continue
        b = base[key]["roofline"]["roofline_fraction"]
        o = opt[key]["roofline"]["roofline_fraction"]
        gain = o / b if b else float("inf")
        rows.append(f"| {key[0]} | {key[1]} | {b:.4f} | {o:.4f} | "
                    f"x{gain:.1f} | {opt[key]['roofline']['dominant']} |")
    return "\n".join(rows)


def run(verbose: bool = True) -> dict:
    recs = load()
    n_ok = sum(1 for r in recs if r.get("ok"))
    if verbose:
        print(f"  {n_ok}/{len(recs)} dry-run cells ok")
        print(roofline_markdown())
    return {"cells": len(recs), "ok": n_ok}


if __name__ == "__main__":
    print(dryrun_markdown())
    print()
    print(roofline_markdown())
    print()
    print(roofline_markdown(mesh="multipod_2x16x16"))
    print()
    print(perf_comparison_markdown())
