"""Benchmark: Manhattan Hypothesis accuracy (paper §V-A, Fig 4).

Generates randomized ~80%-sparse crossbar tiles, measures NF with the
circuit-level solver (the SPICE stand-in), computes the Eq-16 analytical
NF, least-squares fits the linear map between them, and reports the
relative-error distribution of the fit (paper: mu = -0.126%,
sigma = 11.2% on 500 tiles at r = 2.5 ohm).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import manhattan
from repro.core.tiling import CrossbarSpec
from repro.distributed.solver_shard import measured_nf_sharded


def run(n_tiles: int = 500, sparsity: float = 0.8, rows: int = 64,
        cols: int = 64, verbose: bool = True, seed: int = 0) -> dict:
    spec = CrossbarSpec(rows=rows, cols=cols, n_bits=8)
    key = jax.random.PRNGKey(seed)
    masks = (jax.random.uniform(key, (n_tiles, rows, cols))
             < (1 - sparsity)).astype(jnp.float32)

    t0 = time.perf_counter()
    # Device-sharded fused PCG (all local devices; f64 oracle policy —
    # this is the Fig-4 *validation*, so no mixed-precision shortcut).
    res = measured_nf_sharded(masks, spec)
    measured = np.asarray(res.nf_total, np.float64)
    solve_s = time.perf_counter() - t0

    predicted = np.asarray(manhattan.nonideality_factor(
        masks, spec.r, spec.r_on), np.float64)

    # least-squares linear map predicted -> measured (paper's procedure)
    A = np.stack([predicted, np.ones_like(predicted)], axis=1)
    coef, *_ = np.linalg.lstsq(A, measured, rcond=None)
    fit = A @ coef
    rel_err = (fit - measured) / np.maximum(np.abs(measured), 1e-12)
    r2 = 1 - np.sum((fit - measured) ** 2) / np.sum(
        (measured - measured.mean()) ** 2)
    out = {
        "n_tiles": n_tiles,
        "sparsity": sparsity,
        "slope": float(coef[0]), "intercept": float(coef[1]),
        "fit_err_mean_pct": float(rel_err.mean() * 100),
        "fit_err_std_pct": float(rel_err.std() * 100),
        "pearson_r": float(np.corrcoef(measured, predicted)[0, 1]),
        "r2": float(r2),
        "solver_s": solve_s,
        "solver_tiles_per_s": n_tiles / max(solve_s, 1e-9),
        "cg_iterations": int(res.iterations),
        "max_cg_residual": float(np.asarray(res.residual).max()),
    }
    if verbose:
        print(f"  tiles={n_tiles} sparsity={sparsity:.2f} "
              f"r={out['pearson_r']:.4f} R2={out['r2']:.4f} "
              f"err mu={out['fit_err_mean_pct']:.3f}% "
              f"sigma={out['fit_err_std_pct']:.2f}% "
              f"(paper: mu=-0.126%, sigma=11.2%)")
    return out


if __name__ == "__main__":
    run()
