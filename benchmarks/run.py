"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints a ``name,seconds,derived`` CSV line per benchmark plus each
module's detailed output, and dumps results/benchmarks.json.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# 8-way host-device simulation for the sharded-solver rows (must land
# before the first jax import initialises the backend); append so an
# operator-supplied XLA_FLAGS still wins.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

from benchmarks import (  # noqa: E402
    accuracy_noise,
    cim_traffic,
    deploy_throughput,
    fault_tolerance,
    hypothesis_fit,
    mapping_matrix,
    nf_reduction,
    planning_cost,
    roofline_table,
    solver_throughput,
    theorem1,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced tile counts / training steps")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    q = args.quick
    benches = {
        # paper §III-A (Theorem 1)
        "theorem1_sparsity": lambda: theorem1.run(),
        # paper Fig 4
        "manhattan_hypothesis_fit": lambda: hypothesis_fit.run(
            n_tiles=64 if q else 500),
        # paper Fig 5
        "nf_reduction": lambda: nf_reduction.run(),
        # paper Fig 6
        "accuracy_under_noise": lambda: accuracy_noise.run(
            train_steps=60 if q else 250),
        # paper §IV "lightweight" claim
        "mdm_planning_cost": lambda: planning_cost.run(),
        # §Perf: solver scale-out matrix (seed lax.map vs batched vs
        # sharded/mixed on the 8-way device simulation), both regimes:
        # 64x64 paper-scale tiles (work-bound on small hosts) and
        # 32x32 tiles (latency-bound; the sharded engine's >= 2x row).
        "solver_throughput": lambda: solver_throughput.run(
            n_tiles=128 if q else 512, rows=32 if q else 64,
            cols=32 if q else 64, seq_tiles=32 if q else 64),
        "solver_throughput_32x32": lambda: solver_throughput.run(
            n_tiles=128 if q else 512, rows=32, cols=32,
            seq_tiles=32 if q else 64),
        # §Perf: fused CIM path vs materialised bit-planes
        "cim_traffic": lambda: cim_traffic.run(),
        # §Perf: whole-model deployment engine — fused vs per-layer
        # planning, cache-hit redeploy, CIM serving tokens/s
        "deploy_throughput": lambda: deploy_throughput.run(
            n_per_shape=1 if q else 3),
        # §Nonideal: stuck-fault x variation Monte-Carlo distributions,
        # baseline vs MDM vs fault-aware vs significance-weighted MDM
        "fault_tolerance": lambda: fault_tolerance.run(
            n_rows=128 if q else 256, n_samples=3 if q else 6,
            rates=(0.01, 0.05) if q else (0.002, 0.01, 0.05),
            sigmas=(0.0,) if q else (0.0, 0.1)),
        # §Mapping API: registered row x column strategy matrix (Eq-16
        # NF on the standard 64x64 population)
        "mapping_matrix": lambda: mapping_matrix.run(
            n_rows=128 if q else 512),
        # §Dry-run / §Roofline summary
        "roofline_table": lambda: roofline_table.run(),
    }

    results, csv_lines = {}, ["name,seconds,derived"]
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        print(f"== {name} ==")
        t0 = time.perf_counter()
        try:
            res = fn()
            dt = time.perf_counter() - t0
            results[name] = {"ok": True, "seconds": dt, "result": res}
            derived = _derive(name, res)
        except Exception as e:  # pragma: no cover
            dt = time.perf_counter() - t0
            results[name] = {"ok": False, "seconds": dt, "error": repr(e)}
            derived = f"ERROR:{e!r}"
        csv_lines.append(f"{name},{dt:.3f},{derived}")
        print()

    print("\n".join(csv_lines))
    out = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "benchmarks.json")
    # Merge into the existing record so `--only NAME` refreshes one
    # entry instead of clobbering the rest of the matrix.
    merged = {}
    try:
        with open(path) as f:
            merged = json.load(f)
    except (FileNotFoundError, ValueError):
        pass
    if not isinstance(merged, dict):
        merged = {}
    merged.update(results)
    with open(path, "w") as f:
        json.dump(merged, f, indent=1, default=str)


def _derive(name: str, res: dict) -> str:
    try:
        if name == "manhattan_hypothesis_fit":
            return (f"r={res['pearson_r']:.4f};sigma="
                    f"{res['fit_err_std_pct']:.2f}%")
        if name == "nf_reduction":
            best = max(v["reduction_pct"]["mdm"]
                       for k, v in res.items() if isinstance(v, dict)
                       and isinstance(v.get("reduction_pct"), dict))
            return f"best_mdm_nf_reduction={best:.1f}%"
        if name == "accuracy_under_noise":
            eta = max(res["noisy"])
            row = res["noisy"][eta]
            gain = row["baseline"] - row["mdm"]
            return f"ce_gain_mdm_vs_baseline@eta={eta:g}:{gain:.4f}"
        if name == "theorem1_sparsity":
            return "bound_ok=" + str(all(
                v.get("bound_ok") for v in res.values()
                if isinstance(v, dict) and "bound_ok" in v))
        if name == "roofline_table":
            return f"cells_ok={res['ok']}/{res['cells']}"
        if name == "mdm_planning_cost":
            return f"plan_4096x4096={res['plan_4096x4096']['seconds']:.3f}s"
        if name.startswith("solver_throughput"):
            return (f"speedup=x{res['speedup']:.1f};"
                    f"{res['batched_tiles_per_s']:.0f}tiles/s;"
                    f"scaleout=x"
                    f"{res['speedup_scaleout_best_vs_batched_f64']:.2f};"
                    f"sharded_mixed=x"
                    f"{res['speedup_sharded_mixed_vs_batched_f64']:.2f}"
                    f"@{res['sharded_mixed_tiles_per_s']:.0f}tiles/s;"
                    f"mixed_err={res['mixed_max_rel_voltage_err']:.1e}")
        if name == "cim_traffic":
            return (f"kernel_traffic_reduction=x{res['kernel_ratio']:.1f};"
                    f"xla=x{res['xla_ratio']:.2f}")
        if name == "deploy_throughput":
            p = res["planning_64x64"]
            return (f"fused_cold=x{p['speedup_cold']:.1f};"
                    f"cache_hit=x{p['cache_hit_speedup_vs_cold']:.1f};"
                    f"serve_cim="
                    f"{res['serving']['cim_mdm']['tokens_per_s']:.0f}tok/s")
        if name == "fault_tolerance":
            wins = res["fault_aware_beats_mdm"]
            return ("fault_aware_beats_mdm="
                    + ",".join(f"{k}:{v}" for k, v in wins.items())
                    + ";sig_ge_aware="
                    + str(res["sig_weighted_matches_fault_aware_all_rates"]))
        if name == "mapping_matrix":
            return (f"best={res['best_cell']}@"
                    f"{res['best_reduction_pct']:.1f}%")
    except Exception as e:
        return f"derive_error:{e!r}"
    return "ok"


if __name__ == "__main__":
    main()
