"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME] [--list]

Prints a ``name,seconds,derived`` CSV line per benchmark plus each
module's detailed output, and dumps results/benchmarks.json.

The :data:`BENCHES` table is the **registry of record**: the semantic
auditor (``repro.analysis.audit``) cross-checks it against the module
files on disk and against the ``--only`` names ``scripts/test_nightly
.sh`` invokes, so a benchmark module that exists but is not registered
— or a nightly entry that silently matches nothing — fails CI.
``--only`` accepts either the registered benchmark name or the module
name (one module may back several benchmarks) and **errors** on an
unknown token instead of no-opping: a typo'd nightly line must fail
loudly, not skip the benchmark and exit 0.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Callable

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import telemetry as tm  # noqa: E402  (stdlib-only, jax-free)

# 8-way host-device simulation for the sharded-solver rows (must land
# before the first jax import initialises the backend); append so an
# operator-supplied XLA_FLAGS still wins.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

from benchmarks import (  # noqa: E402
    accuracy_noise,
    cim_traffic,
    deploy_throughput,
    fault_tolerance,
    hypothesis_fit,
    mapping_matrix,
    nf_reduction,
    planning_cost,
    roofline_table,
    serving_health,
    serving_load,
    solver_throughput,
    theorem1,
)


@dataclasses.dataclass(frozen=True)
class Bench:
    """One registered benchmark.

    ``name`` is the historical results/benchmarks.json key (stable —
    changing it orphans recorded history); ``module`` is the backing
    ``benchmarks/<module>.py`` file; ``run`` takes the ``--quick``
    flag.  ``seed`` is the registered workload seed for stochastic
    open-loop benchmarks (recorded into the results entry so a run is
    replayable from its record alone); None for deterministic ones.
    """

    name: str
    module: str
    run: Callable[[bool], dict]
    seed: int | None = None


BENCHES: tuple[Bench, ...] = (
    # paper §III-A (Theorem 1)
    Bench("theorem1_sparsity", "theorem1", lambda q: theorem1.run()),
    # paper Fig 4
    Bench("manhattan_hypothesis_fit", "hypothesis_fit",
          lambda q: hypothesis_fit.run(n_tiles=64 if q else 500)),
    # paper Fig 5
    Bench("nf_reduction", "nf_reduction", lambda q: nf_reduction.run()),
    # paper Fig 6
    Bench("accuracy_under_noise", "accuracy_noise",
          lambda q: accuracy_noise.run(train_steps=60 if q else 250)),
    # paper §IV "lightweight" claim
    Bench("mdm_planning_cost", "planning_cost",
          lambda q: planning_cost.run()),
    # §Perf: solver scale-out matrix (seed lax.map vs batched vs
    # sharded/mixed on the 8-way device simulation), both regimes:
    # 64x64 paper-scale tiles (work-bound on small hosts) and
    # 32x32 tiles (latency-bound; the sharded engine's >= 2x row).
    Bench("solver_throughput", "solver_throughput",
          lambda q: solver_throughput.run(
              n_tiles=128 if q else 512, rows=32 if q else 64,
              cols=32 if q else 64, seq_tiles=32 if q else 64)),
    Bench("solver_throughput_32x32", "solver_throughput",
          lambda q: solver_throughput.run(
              n_tiles=128 if q else 512, rows=32, cols=32,
              seq_tiles=32 if q else 64)),
    # §Perf: fused CIM path vs materialised bit-planes
    Bench("cim_traffic", "cim_traffic", lambda q: cim_traffic.run()),
    # §Perf: whole-model deployment engine — fused vs per-layer
    # planning, cache-hit redeploy, CIM serving tokens/s
    Bench("deploy_throughput", "deploy_throughput",
          lambda q: deploy_throughput.run(n_per_shape=1 if q else 3)),
    # §Nonideal: stuck-fault x variation Monte-Carlo distributions,
    # baseline vs MDM vs fault-aware vs significance-weighted MDM
    Bench("fault_tolerance", "fault_tolerance",
          lambda q: fault_tolerance.run(
              n_rows=128 if q else 256, n_samples=3 if q else 6,
              rates=(0.01, 0.05) if q else (0.002, 0.01, 0.05),
              sigmas=(0.0,) if q else (0.0, 0.1))),
    # §Nonideal: line-open (wordline + bitline) rate sweep — spare-line
    # row+column remapping vs the row-only sorts (structural faults)
    Bench("fault_line_open", "fault_tolerance",
          lambda q: fault_tolerance.run_line_open(
              n_rows=128 if q else 256, n_samples=2,
              rates=((0.05, 0.02),) if q
              else ((0.02, 0.01), (0.05, 0.02), (0.08, 0.05)))),
    # §Nonideal: lifetime resilience — monitored (probe + remediation
    # ladder) vs unmonitored twin engines through an aging sweep
    Bench("serving_health", "serving_health",
          lambda q: serving_health.run(
              ages=(3e2, 1e4) if q else (3e2, 1e4, 3e5))),
    # §Serving tier: continuous batching over the CIM path — saturating
    # capacity sweep, open-loop Poisson latency (the registered seed
    # drives the arrival process), mid-load async redeploy gates
    Bench("serving_load", "serving_load",
          lambda q: serving_load.run(
              capacities=(1, 2, 4) if q else (1, 2, 4, 8),
              n_requests=8 if q else 16, max_tokens=6 if q else 8,
              latency_n=12 if q else 24, arrival_seed=1234),
          seed=1234),
    # §Mapping API: registered row x column strategy matrix (Eq-16
    # NF on the standard 64x64 population)
    Bench("mapping_matrix", "mapping_matrix",
          lambda q: mapping_matrix.run(n_rows=128 if q else 512)),
    # §Dry-run / §Roofline summary
    Bench("roofline_table", "roofline_table",
          lambda q: roofline_table.run()),
)


def registered_modules() -> frozenset[str]:
    """Module names the registry covers (auditor entry point)."""
    return frozenset(b.module for b in BENCHES)


def resolve_only(token: str) -> list[Bench]:
    """Benches selected by one ``--only`` token (name or module).

    An exact registered-name match selects that one benchmark; only
    otherwise does the token select every benchmark its module backs —
    so a name that doubles as a module name (``fault_tolerance``) stays
    addressable on its own.  Raises ``KeyError`` on an unknown token —
    the silent-no-op behaviour this replaced let a typo'd nightly entry
    skip its benchmark while exiting 0.
    """
    hits = [b for b in BENCHES if token == b.name]
    if not hits:
        hits = [b for b in BENCHES if token == b.module]
    if not hits:
        raise KeyError(
            f"unknown benchmark {token!r}; known names: "
            f"{[b.name for b in BENCHES]} (module names also accepted)")
    return hits


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced tile counts / training steps")
    ap.add_argument("--only", default="",
                    help="run one benchmark (registered name or module "
                         "name); unknown names are an error")
    ap.add_argument("--list", action="store_true",
                    help="list registered benchmarks and exit")
    ap.add_argument("--trace", action="store_true",
                    help="record a results/trace/<name>.jsonl span "
                         "trace per benchmark (summarise with "
                         "scripts/trace_report.py)")
    args = ap.parse_args()

    if args.list:
        for b in BENCHES:
            tail = "" if b.seed is None else f" seed={b.seed}"
            print(f"{b.name} (benchmarks/{b.module}.py){tail}")
        return

    if args.only:
        try:
            selected = resolve_only(args.only)
        except KeyError as e:
            ap.error(str(e))
    else:
        selected = list(BENCHES)

    out = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out, exist_ok=True)

    # The harness runs with telemetry on: every entry in
    # results/benchmarks.json carries the metrics the instrumented
    # library paths recorded during that benchmark (registry reset
    # per bench, so counters are per-entry, not cumulative).
    tm.enable()
    results, csv_lines = {}, ["name,seconds,derived"]
    for bench in selected:
        print(f"== {bench.name} ==")
        tm.registry().reset()
        trace_rel = None
        if args.trace:
            trace_rel = os.path.join("trace", f"{bench.name}.jsonl")
            tm.trace_to(os.path.join(out, trace_rel))
        started_at = tm.wall_time()
        t0 = tm.monotonic()
        try:
            with tm.span(f"bench/{bench.name}", quick=args.quick):
                res = bench.run(args.quick)
            dt = tm.monotonic() - t0
            results[bench.name] = {"ok": True, "seconds": dt,
                                   "result": res}
            derived = _derive(bench.name, res)
        except Exception as e:  # pragma: no cover
            dt = tm.monotonic() - t0
            results[bench.name] = {"ok": False, "seconds": dt,
                                   "error": repr(e)}
            derived = f"ERROR:{e!r}"
        if args.trace:
            tm.trace_stop()
        results[bench.name]["started_at"] = started_at
        if bench.seed is not None:
            # The registered workload seed (e.g. serving_load's
            # open-loop arrival process) travels with the entry, so a
            # recorded run is replayable without consulting the code.
            results[bench.name]["seed"] = bench.seed
        results[bench.name]["telemetry"] = {
            "metrics": tm.registry().snapshot(),
            "trace": trace_rel,
        }
        csv_lines.append(f"{bench.name},{dt:.3f},{derived}")
        print()

    print("\n".join(csv_lines))
    path = os.path.join(out, "benchmarks.json")
    # Merge into the existing record so `--only NAME` refreshes one
    # entry instead of clobbering the rest of the matrix.
    merged = {}
    try:
        with open(path) as f:
            merged = json.load(f)
    except (FileNotFoundError, ValueError):
        pass
    if not isinstance(merged, dict):
        merged = {}
    merged.update(results)
    with open(path, "w") as f:
        json.dump(merged, f, indent=1, default=str)

    failed = {k: v["error"] for k, v in results.items() if not v["ok"]}
    if failed:
        # A crashed benchmark must fail the harness (and the nightly
        # lines driving it), not just leave an ERROR cell in the CSV.
        print(f"\nFAILED {len(failed)}/{len(results)} benchmark(s):",
              file=sys.stderr)
        for name, err in failed.items():
            print(f"  {name}: {err}", file=sys.stderr)
        sys.exit(1)


def _derive(name: str, res: dict) -> str:
    try:
        if name == "manhattan_hypothesis_fit":
            return (f"r={res['pearson_r']:.4f};sigma="
                    f"{res['fit_err_std_pct']:.2f}%")
        if name == "nf_reduction":
            best = max(v["reduction_pct"]["mdm"]
                       for k, v in res.items() if isinstance(v, dict)
                       and isinstance(v.get("reduction_pct"), dict))
            return f"best_mdm_nf_reduction={best:.1f}%"
        if name == "accuracy_under_noise":
            eta = max(res["noisy"])
            row = res["noisy"][eta]
            gain = row["baseline"] - row["mdm"]
            return f"ce_gain_mdm_vs_baseline@eta={eta:g}:{gain:.4f}"
        if name == "theorem1_sparsity":
            return "bound_ok=" + str(all(
                v.get("bound_ok") for v in res.values()
                if isinstance(v, dict) and "bound_ok" in v))
        if name == "roofline_table":
            return f"cells_ok={res['ok']}/{res['cells']}"
        if name == "mdm_planning_cost":
            return f"plan_4096x4096={res['plan_4096x4096']['seconds']:.3f}s"
        if name.startswith("solver_throughput"):
            return (f"speedup=x{res['speedup']:.1f};"
                    f"{res['batched_tiles_per_s']:.0f}tiles/s;"
                    f"scaleout=x"
                    f"{res['speedup_scaleout_best_vs_batched_f64']:.2f};"
                    f"sharded_mixed=x"
                    f"{res['speedup_sharded_mixed_vs_batched_f64']:.2f}"
                    f"@{res['sharded_mixed_tiles_per_s']:.0f}tiles/s;"
                    f"mixed_err={res['mixed_max_rel_voltage_err']:.1e}")
        if name == "cim_traffic":
            return (f"kernel_traffic_reduction=x{res['kernel_ratio']:.1f};"
                    f"xla=x{res['xla_ratio']:.2f}")
        if name == "deploy_throughput":
            p = res["planning_64x64"]
            return (f"fused_cold=x{p['speedup_cold']:.1f};"
                    f"cache_hit=x{p['cache_hit_speedup_vs_cold']:.1f};"
                    f"serve_cim="
                    f"{res['serving']['cim_mdm']['tokens_per_s']:.0f}tok/s")
        if name == "fault_tolerance":
            wins = res["fault_aware_beats_mdm"]
            return ("fault_aware_beats_mdm="
                    + ",".join(f"{k}:{v}" for k, v in wins.items())
                    + ";sig_ge_aware="
                    + str(res["sig_weighted_matches_fault_aware_all_rates"]))
        if name == "fault_line_open":
            wins = res["spare_line_beats_fault_aware"]
            return ("spare_line_beats_fault_aware="
                    + ",".join(f"{k}:{v}" for k, v in wins.items())
                    + ";all_rates="
                    + str(res["spare_line_beats_fault_aware_all_rates"]))
        if name == "serving_health":
            return (f"fresh={res['fresh_err']:.3f};"
                    f"unmon_worst={max(res['unmonitored_err']):.3f};"
                    f"mon_worst={max(res['monitored_err']):.3f};"
                    f"all_gates={res['all_gates']}")
        if name == "serving_load":
            caps = res["capacities"]
            t = res["throughput"]
            hot = res["latency"]["2x"]
            return (f"tok/s@c{caps[0]}->c{caps[-1]}="
                    f"{t[str(caps[0])]['tokens_per_s']:.0f}->"
                    f"{t[str(caps[-1])]['tokens_per_s']:.0f};"
                    f"p95@2x={hot['p95_s'] * 1e3:.0f}ms;"
                    f"all_gates={res['all_gates']}")
        if name == "mapping_matrix":
            return (f"best={res['best_cell']}@"
                    f"{res['best_reduction_pct']:.1f}%")
    except Exception as e:
        return f"derive_error:{e!r}"
    return "ok"


if __name__ == "__main__":
    main()
