"""Benchmark: Theorem-1 bit-level structured sparsity (paper §III-A).

Reports per-bit-plane densities p_k for bell-shaped weight ensembles and
for actually-trained model weights, the theorem bound, and the overall
crossbar sparsity (the paper observes >=76-80% across its models).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import theory


def run(verbose: bool = True) -> dict:
    k_gauss, k_laplace = jax.random.split(jax.random.PRNGKey(0))
    ensembles = {
        "gaussian(0.02)": jax.random.normal(k_gauss, (512, 512)) * 0.02,
        "laplace(0.02)": jax.random.laplace(k_laplace, (512, 512)) * 0.02,
        "trained-lm": _trained_weights(),
    }
    out = {}
    t0 = time.perf_counter()
    for name, w in ensembles.items():
        scale = float(jnp.max(jnp.abs(w)))
        dens = np.asarray(theory.empirical_bit_densities(w, 8))
        # f(0) of the magnitude density, estimated near zero
        mags = np.abs(np.asarray(w)).ravel() / scale
        f0 = (mags < 0.01).mean() / 0.01
        bounds = [theory.theorem1_bound(f0, k + 1) for k in range(8)]
        # Empirical tolerance: trained weights only approximately satisfy
        # the strictly-decreasing-density hypothesis (optimizer structure
        # near the LSB scale), so allow ~2% slack around 1/2; the exact
        # theorem is verified by quadrature in tests/test_theory.py.
        ok = all(d < 0.52 and abs(d - 0.5) <= b + 0.03
                 for d, b in zip(dens, bounds))
        sparsity = 1.0 - dens.mean()
        out[name] = {"densities": dens.round(4).tolist(),
                     "sparsity": round(float(sparsity), 4),
                     "bound_ok": bool(ok)}
        if verbose:
            print(f"  {name:16s} sparsity={sparsity:.3f} "
                  f"p_k={np.round(dens, 3)} bound_ok={ok}")
    out["_elapsed_s"] = time.perf_counter() - t0
    return out


def _trained_weights():
    """Quick 60-step training of a tiny LM; returns one trained matrix."""
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.data import SyntheticTokenDataset
    from repro.train import Trainer
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    tcfg = TrainConfig(total_steps=60, learning_rate=1e-3,
                       checkpoint_every=10**9,
                       checkpoint_dir="/tmp/repro_bench_t1")
    ds = SyntheticTokenDataset(cfg.vocab_size, 64, 8, seed=0)
    tr = Trainer(cfg, tcfg, ds)
    tr.init_state()
    tr.run(60)
    w = tr.params["slot0_attn"]["ffn_w_up"][0]
    return w.astype(jnp.float32)


if __name__ == "__main__":
    run()
