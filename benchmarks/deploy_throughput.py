"""Benchmark: whole-model CIM deployment engine (repro.deploy).

Three measurements on a multi-layer model (>= 16 matrices, mixed
shapes — the whole-network granularity remapping schemes are evaluated
at):

1. **Whole-model planning**: the per-layer ``plan_layer`` loop vs the
   fused engine (``plan_matrices``).  Cold numbers are the deployment
   scenario — a fresh engine process planning a new checkpoint, where
   the per-layer loop pays one jit compile per distinct layer shape
   while the fused engine compiles a single population planner.  Warm
   (steady-state, jits cached) numbers are reported alongside.
2. **Cache-hit redeploy**: replanning the same checkpoint through the
   persistent ``PlanCache`` vs the cold plan.
3. **CIM serving**: ``ServeEngine`` tokens/s on a small config with
   ``cim.enabled`` (backend-dispatched ``cim_mvm``) vs the clean
   engine.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mdm import plan_layer
from repro.core.tiling import CrossbarSpec
from repro.deploy import PlanCache, plan_matrices

# A CNN/transformer-ish whole-model shape mix: many distinct layer
# geometries (16 here), several layers per geometry.
SHAPE_MIX = [
    (256, 256), (256, 512), (512, 256), (384, 256),
    (256, 384), (512, 512), (320, 256), (256, 320),
    (448, 256), (256, 448), (512, 384), (384, 512),
    (640, 256), (256, 640), (384, 384), (576, 256),
]


def _model_matrices(n_per_shape: int, key) -> dict[str, np.ndarray]:
    """Host-resident weights, as a checkpoint being deployed would be."""
    mats = {}
    k = 0
    for layer in range(n_per_shape):
        for (i, n) in SHAPE_MIX:
            k += 1
            mats[f"L{layer}/{i}x{n}"] = np.asarray(
                jax.random.normal(jax.random.fold_in(key, k), (i, n)) * 0.02)
    return mats


def _block_plans(plans) -> None:
    jax.block_until_ready([p.row_perm for p in plans.values()
                           if isinstance(p.row_perm, jax.Array)])


def _time_per_layer(mats, spec) -> float:
    t0 = time.perf_counter()
    plans = {n: plan_layer(w, spec, "mdm") for n, w in mats.items()}
    _block_plans(plans)
    return time.perf_counter() - t0


def _time_fused(mats, spec, cache=None) -> float:
    t0 = time.perf_counter()
    plans, _ = plan_matrices(mats, spec, "mdm", cache=cache)
    _block_plans(plans)
    return time.perf_counter() - t0


def _xla_vs_interpret(verbose: bool) -> dict:
    """The dispatch criterion at a 2048x2048 layer: the fused XLA
    fallback must match the interpret kernel numerically and beat it by
    a wide margin — interpret mode walks the grid block-by-block in
    Python and must never land on a serving path."""
    from repro.kernels.cim_mvm.ops import cim_mvm, deploy

    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    w = jax.random.normal(jax.random.PRNGKey(7), (2048, 2048)) * 0.02
    x = jax.random.normal(jax.random.PRNGKey(8), (64, 2048))
    dep, _ = deploy(w, spec, "mdm")

    y = cim_mvm(x, dep, impl="xla")
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(cim_mvm(x, dep, impl="xla"))
    t_xla = (time.perf_counter() - t0) / 3

    yi = cim_mvm(x, dep, impl="interpret")   # compile/trace  # reprolint: disable=RPL004 -- this benchmark *measures* the interpret path's cost vs xla
    jax.block_until_ready(yi)
    t0 = time.perf_counter()
    jax.block_until_ready(cim_mvm(x, dep, impl="interpret"))  # reprolint: disable=RPL004 -- measured interpret timing sample
    t_int = time.perf_counter() - t0

    ya, yb = np.asarray(y), np.asarray(yi)
    rel = np.abs(ya - yb).max() / np.abs(yb).max()
    out = {"xla_s": t_xla, "interpret_s": t_int,
           "speedup": t_int / t_xla, "max_rel_err": float(rel)}
    if verbose:
        print(f"  cim_mvm 2048x2048: xla {t_xla*1e3:.1f} ms vs interpret "
              f"{t_int*1e3:.0f} ms -> x{out['speedup']:.1f} "
              f"(rel err {rel:.1e})")
    return out


def _serving_tokens_per_s(verbose: bool) -> dict:
    from repro.configs.base import CimConfig, ModelConfig
    from repro.serve import ServeEngine

    cfg = ModelConfig(name="deploy-bench", n_layers=4, d_model=128,
                      n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512,
                      block_pattern=("attn",), remat="none",
                      dtype="float32", attn_chunk=64)
    from repro.models.model import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                 cfg.vocab_size)
    out = {}
    for label, ccfg in [
        ("clean", cfg),
        ("cim_mdm", cfg.replace(cim=CimConfig(enabled=True, mode="mdm"))),
    ]:
        cache_dir = tempfile.mkdtemp(prefix="mdm_bench_cache_")
        try:
            t0 = time.perf_counter()
            eng = ServeEngine(ccfg, params, max_seq=128,
                              plan_cache=PlanCache(cache_dir))
            t_init = time.perf_counter() - t0
            n_tok = 32
            eng.generate(prompts, 2)        # compile prefill + decode
            t0 = time.perf_counter()
            toks = eng.generate(prompts, n_tok)
            jax.block_until_ready(toks)
            dt = time.perf_counter() - t0
            tps = toks.shape[0] * n_tok / dt
            out[label] = {"tokens_per_s": tps, "init_s": t_init}
            if label != "clean" and eng.deploy_report:
                out[label]["deploy_report"] = {
                    k: eng.deploy_report[k]
                    for k in ("n_matrices", "tiles_planned", "cache_hits",
                              "cache_misses")}
            if verbose:
                print(f"  serve[{label}]: {tps:.0f} tok/s "
                      f"(engine init {t_init:.2f}s)")
        finally:
            shutil.rmtree(cache_dir, ignore_errors=True)
    out["cim_slowdown"] = (out["clean"]["tokens_per_s"]
                           / out["cim_mdm"]["tokens_per_s"])
    return out


def _planning_matrix(mats, spec: CrossbarSpec, verbose: bool) -> dict:
    """Per-layer vs fused vs cache-hit timings at one crossbar geometry.

    Order matters: the per-layer loop runs first so neither path
    benefits from the other's compiles; "cold" therefore reflects a
    fresh deployment process for both.
    """
    n_tiles = sum(int(np.prod(spec.grid(*w.shape)))
                  for w in mats.values())
    t_pl_cold = _time_per_layer(mats, spec)
    cache_dir = tempfile.mkdtemp(prefix="mdm_bench_cache_")
    try:
        t_cold = _time_fused(mats, spec, cache=PlanCache(cache_dir))
        # Best-of-5 (the repo's interleaved best-of timing convention):
        # a full-model hit is ~tens of ms and visibly jittered by CI
        # box load.
        t_hit = min(_time_fused(mats, spec, cache=PlanCache(cache_dir))
                    for _ in range(5))
        t_pl_warm = _time_per_layer(mats, spec)
        t_warm = _time_fused(mats, spec)
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    out = {
        "n_matrices": len(mats),
        "n_shapes": len(SHAPE_MIX),
        "n_tiles": n_tiles,
        "per_layer_cold_s": t_pl_cold,
        "fused_cold_s": t_cold,
        "speedup_cold": t_pl_cold / t_cold,
        "per_layer_warm_s": t_pl_warm,
        "fused_warm_s": t_warm,
        "speedup_warm": t_pl_warm / t_warm,
        "cache_hit_s": t_hit,
        "cache_hit_speedup_vs_cold": t_cold / t_hit,
        "fused_us_per_tile_warm": t_warm / n_tiles * 1e6,
    }
    if verbose:
        print(f"  whole-model planning @ {spec.rows}x{spec.cols} "
              f"({len(mats)} matrices, {len(SHAPE_MIX)} shapes, "
              f"{n_tiles} tiles):")
        print(f"    cold: per-layer {t_pl_cold:.2f}s vs fused "
              f"{t_cold:.2f}s -> x{out['speedup_cold']:.1f}")
        print(f"    warm: per-layer {t_pl_warm:.2f}s vs fused "
              f"{t_warm:.2f}s -> x{out['speedup_warm']:.1f}")
        print(f"    cache-hit redeploy {t_hit*1e3:.0f} ms -> "
              f"x{out['cache_hit_speedup_vs_cold']:.1f} vs cold plan")
    return out


def run(n_per_shape: int = 3, verbose: bool = True, serve: bool = True
        ) -> dict:
    mats = _model_matrices(n_per_shape, jax.random.PRNGKey(0))
    # Both solver-benchmark geometries: 64x64 is the paper's tile size
    # (planning is work-bound there on small hosts); 32x32 packs ~8x
    # the tiles per weight byte, the regime where planning dominates
    # the cache-lookup costs.
    out: dict = {
        "planning_64x64": _planning_matrix(
            mats, CrossbarSpec(rows=64, cols=64, n_bits=8), verbose),
        "planning_32x32": _planning_matrix(
            mats, CrossbarSpec(rows=32, cols=32, n_bits=8), verbose),
    }
    out["cim_mvm_2048"] = _xla_vs_interpret(verbose)
    if serve:
        out["serving"] = _serving_tokens_per_s(verbose)
    return out


if __name__ == "__main__":
    run()
