"""Benchmark: CIM evaluation-path memory traffic — paper-faithful
materialised bit-planes vs the fused cim_mvm deployment.

The paper's PyTorch flow materialises the K bit-planes of every weight
(uint8, K bytes/weight) plus the distorted f32 weights to evaluate a CIM
deployment.  The fused path stores int16 signed codes (2 bytes/weight)
and expands/distorts on the fly (in VMEM on TPU).  Both pure-JAX paths
are *lowered and walked* with the trip-count-aware cost model here, plus
the analytic kernel bound, so the comparison uses the same metric as
§Roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.bitslice import bitslice
from repro.core.mdm import plan_from_bits
from repro.core.noise import noisy_magnitude
from repro.core.tiling import CrossbarSpec
from repro.kernels.cim_mvm.ops import cim_mvm, deploy
from repro.launch import hlo_cost


def run(I: int = 2048, N: int = 2048, M: int = 256,
        verbose: bool = True) -> dict:
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    eta = 2e-3
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (I, N)) * 0.02
    sliced = bitslice(w, spec.n_bits)
    plan = plan_from_bits(sliced.bits, sliced.scale, spec, "mdm")
    sign = sliced.sign
    dep, _ = deploy(w, spec, "mdm", eta=eta, plan=plan)
    x = jax.ShapeDtypeStruct((M, I), jnp.float32)

    def paper_path(x, bits, sign, scale):
        """Materialised bit-planes -> distorted weights -> matmul."""
        mag = noisy_magnitude(bits, scale, plan, spec, eta)
        return x @ (mag * sign.astype(jnp.float32))

    # The fused path IS the production XLA fallback of the cim_mvm op
    # (repro.kernels.cim_mvm.xla): int16 codes expanded on the fly.
    def fused_path(x, dep):
        return cim_mvm(x, dep, impl="xla")

    t0 = time.perf_counter()
    a_bits = jax.ShapeDtypeStruct(sliced.bits.shape, jnp.uint8)
    a_sign = jax.ShapeDtypeStruct(sign.shape, jnp.int8)
    a_scale = jax.ShapeDtypeStruct((), jnp.float32)
    c_paper = hlo_cost.analyze(
        jax.jit(paper_path).lower(x, a_bits, a_sign, a_scale)
        .compile().as_text())
    c_fused = hlo_cost.analyze(
        jax.jit(fused_path).lower(x, dep).compile().as_text())

    # analytic kernel bound: weight-stream = 2 B/weight, x + y once
    kernel_bytes = 2 * I * N + 4 * M * I + 4 * M * N
    out = {
        "paper_bytes": c_paper.bytes_accessed,
        "fused_xla_bytes": c_fused.bytes_accessed,
        "kernel_bound_bytes": float(kernel_bytes),
        "xla_ratio": c_paper.bytes_accessed / c_fused.bytes_accessed,
        "kernel_ratio": c_paper.bytes_accessed / kernel_bytes,
        "elapsed_s": time.perf_counter() - t0,
    }
    if verbose:
        print(f"  paper path (materialised planes): "
              f"{c_paper.bytes_accessed/1e9:.2f} GB")
        print(f"  fused XLA path (int16 codes):     "
              f"{c_fused.bytes_accessed/1e9:.2f} GB "
              f"(x{out['xla_ratio']:.2f})")
        print(f"  cim_mvm kernel bound:             "
              f"{kernel_bytes/1e9:.3f} GB (x{out['kernel_ratio']:.1f})")
    return out


if __name__ == "__main__":
    run()
