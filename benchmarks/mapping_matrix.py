"""Benchmark: mapping-strategy matrix sweep (row x column strategies).

The composable-pipeline counterpart of ``nf_reduction``: every
registered row-order strategy ({identity, mdm, fault_aware,
significance_weighted}) crossed with every column-order strategy
({identity, xchangr}) on the standard 64x64 tile population, under
reversed dataflow.  Fault-aware strategies plan against one fixed
stuck-at-OFF map (rate 1%) — the same paired-hardware protocol as
``fault_tolerance`` — so the whole matrix is comparable.

Reported per cell: analytical Eq-16 NF (sum over tiles), % reduction
vs. the baseline pipeline, and the fused planning wall-clock.  This is
the registry smoke screen: a strategy added from a new paper shows up
here by name with zero harness changes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.bitslice import bitslice
from repro.core.mdm import plan_from_bits
from repro.core.tiling import CrossbarSpec
from repro.mapping import MappingPipeline, get_strategy
from repro.nonideal import sample_stuck

ROW_STRATEGIES = ("identity", "mdm", "fault_aware",
                  "significance_weighted")
COL_STRATEGIES = ("identity", "xchangr")
FAULT_RATE = 0.01


def run(n_rows: int = 512, verbose: bool = True) -> dict:
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    key = jax.random.PRNGKey(0)
    w = jax.random.laplace(key, (n_rows, 64)) * 0.01
    sliced = bitslice(w, spec.n_bits)
    ti, tn = spec.grid(*w.shape)
    stuck = sample_stuck(jax.random.fold_in(key, 1),
                         (ti, tn, spec.rows, spec.cols), FAULT_RATE, 0.0)

    base_plan = plan_from_bits(sliced.bits, sliced.scale, spec,
                               MappingPipeline(
                                   dataflow="conventional",
                                   rows=get_strategy("rows", "identity")))
    nf_base = float(jnp.sum(base_plan.nf_after))

    out: dict = {"tiles": ti * tn, "nf_baseline": nf_base,
                 "fault_rate": FAULT_RATE}
    for row in ROW_STRATEGIES:
        for col in COL_STRATEGIES:
            pipe = MappingPipeline(rows=get_strategy("rows", row),
                                   cols=get_strategy("cols", col))
            needs_faults = pipe.rows.uses_faults
            t0 = time.perf_counter()
            plan = plan_from_bits(sliced.bits, sliced.scale, spec, pipe,
                                  stuck if needs_faults else None)
            jax.block_until_ready(plan.nf_after)
            dt = time.perf_counter() - t0
            nf = float(jnp.sum(plan.nf_after))
            red = 100.0 * (1.0 - nf / max(nf_base, 1e-30))
            out[f"row={row}|col={col}"] = {
                "nf": nf, "reduction_vs_baseline_pct": red,
                "plan_seconds": dt, "cache_token": pipe.cache_token(),
            }
            if verbose:
                print(f"  row={row:22s} col={col:9s} NF={nf:8.4f} "
                      f"({red:+5.1f}% vs baseline)  [{dt:.2f}s]")
    best = min((v["nf"], k) for k, v in out.items()
               if isinstance(v, dict) and "nf" in v)
    out["best_cell"] = best[1]
    out["best_reduction_pct"] = out[best[1]]["reduction_vs_baseline_pct"]
    if verbose:
        print(f"  best: {best[1]} "
              f"({out['best_reduction_pct']:.1f}% NF reduction)")
    return out


if __name__ == "__main__":
    run()
