"""Continuous-batching serving engine over the CIM path.

:class:`ContinuousEngine` is the multi-tenant tier on top of the
single-batch :class:`repro.serve.engine.ServeEngine` machinery: an
Orca-style scheduler iteration loop (``step``) that admits queued
prompts mid-flight into a fixed-capacity :class:`repro.serve.kvcache
.SlotPool`, runs one batched decode for every live slot, streams the
sampled tokens, and evicts finished sequences — no request ever waits
for a batch, only for a slot.

**Recompilation guarantee.**  Prefill runs at a fixed ``(1,
max_prompt)`` shape and joins into the pool by index update; decode
runs at a fixed ``(capacity,)`` shape with dead slots self-masked
(``kpos == EMPTY_POS``, temperature 0).  Batch composition never
changes a shape, so each lowerable compiles exactly once per
``(capacity, max_seq, max_prompt)`` (``self.traces`` is the receipt).
Per-sequence ``temperature`` and sampling keys are runtime operands —
mixed-temperature tenants share the one trace.

**Bank epochs and hot swaps.**  Every sequence is pinned at admission
to the ``(params, cim)`` *bank* then serving.  Any hot swap — a health
heal/advance restack or an async redeploy — installs a *new* bank
epoch between decode iterations (fresh tree objects, never mutation):
in-flight sequences keep decoding against their admission bank
bit-deterministically, new admissions see the new bank, and a bank is
garbage-collected once nothing references it.  When live sequences
span several epochs, each epoch decodes the full slot batch against
its own bank and the per-slot states merge by mask — still one decode
trace, since banks share shapes.

**Async redeploy.**  ``begin_redeploy(new_params)`` deploys the new
checkpoint's tiles through the shared :class:`repro.deploy.PlanCache`
manifest in a background thread while the old bank keeps serving; the
finished bank is installed at the next iteration boundary via the same
fresh-tree atomicity contract.  Zero downtime, zero failed requests.

Per-sequence sampling is bit-deterministic per request ``seed``: token
``n`` draws from ``fold_in(PRNGKey(seed), n)`` through
:func:`repro.serve.engine.sample_tokens_batch`, whose row independence
(plus the per-lane attention masking and row-wise matmuls) makes a
sequence's output independent of its slot and batchmates.  (Per-read
conductance noise ``sigma_read > 0`` draws one key per *iteration*, so
only the noiseless path is composition-independent.)
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as tm
from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingCtx
from repro.models.model import apply_model
from repro.serve.engine import (
    _C_SWAPS,
    _H_DECODE,
    deploy_serving_bank,
    sample_tokens_batch,
)
from repro.serve.kvcache import SlotPool
from repro.serve.scheduler import Request, RequestScheduler

_H_OCCUPANCY = tm.histogram(
    "repro_serve_batch_occupancy",
    "Live slots / capacity per scheduler iteration.",
    buckets=(0.0625, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0))
_C_REDEPLOYS = tm.counter(
    "repro_serve_redeploys_total",
    "Async checkpoint redeploys installed into the serving loop.")

_UNSET = object()


@dataclasses.dataclass(frozen=True)
class Bank:
    """One immutable serving bank: a checkpoint's params + cim tree."""

    epoch: int
    params: Any
    cim: Any


def make_slot_prefill(cfg: ModelConfig, ctx: ShardingCtx):
    """(params, state(B=1), tokens (1, P), length, key, temp[, cim,
    read_key]) -> (first_token (1,), state).

    ``tokens`` is the prompt padded to the fixed ``max_prompt`` P (one
    trace for all prompt lengths); the first token samples from the
    logits row at the true ``length - 1``.  The join step downstream
    masks the padding tail out of the cache.
    """

    def prefill(params, state, tokens, length, key, temp,
                cim=None, read_key=None):
        logits, state, _ = apply_model(params, cfg, ctx, tokens=tokens,
                                       state=state, decode=False,
                                       cim=cim, read_key=read_key)
        lg = jax.lax.dynamic_index_in_dim(logits, length - 1, axis=1,
                                          keepdims=False)
        k0 = jax.random.fold_in(key, 0)
        tok = sample_tokens_batch(lg, k0[None], temp[None])
        return tok, state

    return prefill


def make_slot_decode(cfg: ModelConfig, ctx: ShardingCtx):
    """(params, state, tokens (B,), keys (B, 2), counts (B,), temps
    (B,)[, cim, read_key]) -> (next_tokens (B,), state).

    ``keys`` are the per-sequence base keys, ``counts`` the tokens each
    sequence has emitted so far: token n draws from ``fold_in(base,
    n)``, independent of slot index and batch composition.  Dead slots
    carry temperature 0 (greedy over garbage logits, discarded) and
    EMPTY_POS cache lanes, so they cost nothing semantically.
    """

    def decode(params, state, tokens, keys, counts, temps,
               cim=None, read_key=None):
        step_keys = jax.vmap(jax.random.fold_in)(keys, counts)
        logits, state, _ = apply_model(params, cfg, ctx,
                                       tokens=tokens[:, None],
                                       state=state, decode=True,
                                       cim=cim, read_key=read_key)
        tok = sample_tokens_batch(logits[:, 0], step_keys, temps)
        return tok, state

    return decode


class ContinuousEngine:
    """Multi-tenant continuous-batching engine (see module docstring)."""

    def __init__(self, cfg: ModelConfig, params,
                 ctx: ShardingCtx | None = None, capacity: int = 4,
                 max_seq: int = 256, max_prompt: int = 32,
                 plan_cache=None, nonideal=None, nonideal_seed: int = 0,
                 fault_aware: bool = True, pipeline=None, health=None):
        if cfg.frontend:
            raise ValueError("ContinuousEngine serves token frontends "
                             "only (embedding prompts are not paged)")
        if cfg.attn_impl == "pallas":
            raise NotImplementedError(
                "the slot-pool decode path carries per-lane (B, S) "
                "positions, which the TPU flash kernel does not take; "
                "use attn_impl='jax'")
        if max_prompt > max_seq:
            raise ValueError("max_prompt must be <= max_seq")
        self.cfg = cfg
        self.ctx = ctx or ShardingCtx()
        self.capacity = capacity
        self.max_seq = max_seq
        self.max_prompt = max_prompt
        self.plan_cache = None
        if cfg.cim.enabled:
            from repro.deploy import PlanCache
            self.plan_cache = (plan_cache if plan_cache is not None
                               else PlanCache())
        self._nonideal = nonideal
        self._nonideal_seed = nonideal_seed
        self._fault_aware = fault_aware
        self._pipeline = pipeline
        self._health_cfg = health
        cim, self.deploy_report, self.lifetime, self.health = \
            deploy_serving_bank(
                cfg, params, self.ctx, plan_cache=self.plan_cache,
                nonideal=nonideal, nonideal_seed=nonideal_seed,
                fault_aware=fault_aware, pipeline=pipeline,
                health=health)
        self.banks: dict[int, Bank] = {0: Bank(0, params, cim)}
        self.serving_epoch = 0
        self._next_epoch = 1

        self.scheduler = RequestScheduler()
        self.pool = SlotPool(cfg, capacity, max_seq)
        # Per-slot host mirrors of the decode operands (index-updated
        # on join/evict, like the device state).
        self._tok = np.zeros(capacity, np.int32)
        self._keys = np.zeros((capacity, 2), np.uint32)
        self._nem = np.zeros(capacity, np.int32)
        self._temp = np.zeros(capacity, np.float32)

        self._read_noise = bool(cfg.cim.enabled and nonideal is not None
                                and nonideal.sigma_read > 0.0)
        self._read_base = jax.random.fold_in(
            jax.random.PRNGKey(nonideal_seed), 11)
        self._read_round = 0
        self._probe_base = jax.random.PRNGKey(nonideal_seed)

        self.traces = {"prefill": 0, "decode": 0}
        p_fn = make_slot_prefill(cfg, self.ctx)
        d_fn = make_slot_decode(cfg, self.ctx)

        def p_counted(*a, **kw):
            self.traces["prefill"] += 1
            return p_fn(*a, **kw)

        def d_counted(*a, **kw):
            self.traces["decode"] += 1
            return d_fn(*a, **kw)

        self._prefill = jax.jit(p_counted, donate_argnums=(1,))
        self._decode = jax.jit(d_counted, donate_argnums=(1,))

        self._lock = threading.Lock()
        self._pending = None
        self._redeploy_thread: threading.Thread | None = None
        self.iterations = 0

    # -- public API ----------------------------------------------------

    def submit(self, prompt, max_tokens: int, temperature: float = 0.0,
               seed: int = 0, on_token=None) -> int:
        """Enqueue one request; returns its rid (tokens land in
        ``results[rid]`` once finished, streamed via ``on_token``)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size > self.max_prompt:
            raise ValueError(f"prompt length {prompt.size} > "
                             f"max_prompt {self.max_prompt}")
        return self.scheduler.submit(prompt, max_tokens, temperature,
                                     seed, on_token)

    @property
    def results(self) -> dict[int, list[int]]:
        return self.scheduler.results

    def run(self, max_iters: int | None = None) -> dict[int, list[int]]:
        """Step until every submitted request has finished."""
        it = 0
        while self.scheduler.pending:
            self.step()
            it += 1
            if max_iters is not None and it >= max_iters:
                break
        return dict(self.scheduler.results)

    def step(self) -> None:
        """One scheduler iteration: install pending bank -> admit ->
        batched decode -> stream -> evict."""
        with tm.span("serve/iteration", it=self.iterations,
                     live=self.pool.n_live,
                     queued=self.scheduler.queue_depth):
            self._install_pending()
            while self.scheduler.queue and self.pool.n_free:
                req = self.scheduler.pop_admission()
                with tm.span("serve/admit", rid=req.rid):
                    self._admit(req)
            _H_OCCUPANCY.observe(self.pool.n_live / self.capacity)
            if self.scheduler.live:
                self._decode_iteration()
        self.iterations += 1

    # -- admission -----------------------------------------------------

    def _admit(self, req: Request) -> None:
        bank = self.banks[self.serving_epoch]
        slot = self.pool.acquire()
        L = int(req.prompt.size)
        prompt = np.zeros((1, self.max_prompt), np.int32)
        prompt[0, :L] = req.prompt
        base = np.asarray(jax.random.PRNGKey(req.seed), np.uint32)
        st = self.pool.fresh_seq_state()
        tok, st = self._prefill(bank.params, st, jnp.asarray(prompt),
                                jnp.int32(L), jnp.asarray(base),
                                jnp.float32(req.temperature),
                                bank.cim, self._next_read_key())
        self.pool.join(slot, st, L)
        self.scheduler.start(req, slot, self.serving_epoch)
        tok0 = int(np.asarray(tok)[0])
        self._tok[slot] = tok0
        self._keys[slot] = base
        self._nem[slot] = 1
        self._temp[slot] = req.temperature
        if self.scheduler.record_token(slot, tok0):
            self._evict(slot)

    def _evict(self, slot: int) -> None:
        self.scheduler.finish(slot)
        self.pool.evict(slot)
        self._tok[slot] = 0
        self._keys[slot] = 0
        self._nem[slot] = 0
        self._temp[slot] = 0.0

    # -- decode --------------------------------------------------------

    def _decode_iteration(self) -> None:
        live = self.scheduler.live
        t_on = tm.enabled()
        t0 = tm.monotonic() if t_on else 0.0
        with tm.span("serve/decode_batch", live=len(live),
                     epochs=len(self.scheduler.epochs_live())):
            tok_host = self._decode_all_banks()
        if t_on:
            _H_DECODE.observe(tm.monotonic() - t0)
        finished = []
        for slot in sorted(live):
            t = int(tok_host[slot])
            self._tok[slot] = t
            self._nem[slot] += 1
            if self.scheduler.record_token(slot, t):
                finished.append(slot)
        for slot in finished:
            self._evict(slot)

    def _decode_all_banks(self) -> np.ndarray:
        """One decode step for all live slots, grouped by bank epoch.

        The common case is one epoch: a single donating decode on the
        pool state.  Across a hot swap, each live epoch decodes the
        full batch against its own bank (the single trace serves every
        bank — shapes match) and the per-slot states merge by epoch
        mask; tokens are taken per-slot from the owning epoch's call.
        """
        epochs = self.scheduler.epochs_live()
        tok = jnp.asarray(self._tok)
        keys = jnp.asarray(self._keys)
        nem = jnp.asarray(self._nem)
        temps = jnp.asarray(self._temp)
        rk = self._next_read_key()

        if len(epochs) == 1:
            bank = self.banks[epochs[0]]
            tok_out, state = self._decode(bank.params, self.pool.state,
                                          tok, keys, nem, temps,
                                          bank.cim, rk)
            self.pool.state = state
            return np.asarray(tok_out)

        per_epoch_tok: dict[int, np.ndarray] = {}
        merged = None
        for i, e in enumerate(epochs):
            bank = self.banks[e]
            st_in = (self.pool.fork() if i < len(epochs) - 1
                     else self.pool.state)
            tok_out, st_out = self._decode(bank.params, st_in, tok,
                                           keys, nem, temps, bank.cim,
                                           rk)
            per_epoch_tok[e] = np.asarray(tok_out)
            if merged is None:
                merged = st_out
            else:
                take_b = np.zeros(self.capacity, bool)
                for slot, seq in self.scheduler.live.items():
                    take_b[slot] = seq.epoch == e
                merged = self.pool.merge(merged, st_out, take_b)
        self.pool.state = merged
        tok_host = per_epoch_tok[epochs[0]].copy()
        for slot, seq in self.scheduler.live.items():
            tok_host[slot] = per_epoch_tok[seq.epoch][slot]
        return tok_host

    def _next_read_key(self):
        if not self._read_noise:
            return None
        self._read_round += 1
        return jax.random.fold_in(self._read_base, self._read_round)

    # -- banks / hot swap ----------------------------------------------

    def _install_bank(self, params, cim) -> int:
        """Install a new serving bank epoch (fresh-tree atomicity)."""
        e = self._next_epoch
        self._next_epoch += 1
        self.banks[e] = Bank(e, params, cim)
        self.serving_epoch = e
        self._gc_banks()
        return e

    def _gc_banks(self) -> None:
        held = {seq.epoch for seq in self.scheduler.live.values()}
        held.add(self.serving_epoch)
        for e in [e for e in self.banks if e not in held]:
            del self.banks[e]

    def _swap(self, dirty: set) -> None:
        """Restack heal-refreshed groups into a *new* bank epoch.

        Unlike ``ServeEngine._swap`` (which replaces the whole serving
        tree under a snapshotting generate loop), the continuous tier
        models every swap as a bank epoch: in-flight sequences stay
        pinned to their admission epoch, only new admissions (and the
        next decode of sequences already on the serving epoch — which
        is the same set, since pinning is by epoch) see the heal.
        """
        if not dirty:
            return
        from repro.deploy import restack_group
        with tm.span("serve/swap", groups=len(dirty)):
            cur = self.banks[self.serving_epoch]
            cim = {slot: dict(sub) for slot, sub in cur.cim.items()}
            for slot, pname in dirty:
                cim[slot][pname] = restack_group(self.lifetime, slot,
                                                 pname)
            self._install_bank(cur.params, cim)
        _C_SWAPS.inc(len(dirty))

    def advance(self, dt: float) -> None:
        """Advance the drift clock; heal-swaps land as a new epoch."""
        if self.health is None:
            return
        self._swap(self.health.advance(dt))

    def check_health(self, read_key=None):
        """One probe round + remediation; swaps land as a new epoch."""
        if self.health is None:
            return None
        if read_key is None and self._read_noise:
            read_key = jax.random.fold_in(
                jax.random.fold_in(self._probe_base, 9),
                self.health.rounds)
        self._swap(self.health.probe(read_key))
        return self.health.report()

    # -- async redeploy ------------------------------------------------

    def begin_redeploy(self, params, *, nonideal=_UNSET,
                       nonideal_seed=_UNSET, fault_aware=_UNSET,
                       pipeline=_UNSET, health=_UNSET
                       ) -> threading.Thread:
        """Deploy a new checkpoint in the background; swap when ready.

        Tile planning/packaging runs in a worker thread through the
        shared plan-cache manifest while the current bank keeps
        serving; the finished bank (with fresh lifetime capture +
        health controller when armed) is installed at the next
        ``step()`` boundary.  Unspecified keyword arguments inherit the
        engine's init-time deployment settings.  Returns the thread
        (``join()`` it to rendezvous; serving never has to).
        """
        if (self._redeploy_thread is not None
                and self._redeploy_thread.is_alive()):
            raise RuntimeError("a redeploy is already in progress")
        nonideal = self._nonideal if nonideal is _UNSET else nonideal
        seed = (self._nonideal_seed if nonideal_seed is _UNSET
                else nonideal_seed)
        fault_aware = (self._fault_aware if fault_aware is _UNSET
                       else fault_aware)
        pipeline = self._pipeline if pipeline is _UNSET else pipeline
        health = self._health_cfg if health is _UNSET else health

        def work():
            with tm.span("serve/redeploy"):
                cim, report, lifetime, controller = deploy_serving_bank(
                    self.cfg, params, self.ctx,
                    plan_cache=self.plan_cache, nonideal=nonideal,
                    nonideal_seed=seed, fault_aware=fault_aware,
                    pipeline=pipeline, health=health)
            with self._lock:
                self._pending = (params, cim, report, lifetime,
                                 controller)

        t = threading.Thread(target=work, name="repro-serve-redeploy",
                             daemon=True)
        self._redeploy_thread = t
        t.start()
        return t

    def redeploy_ready(self) -> bool:
        with self._lock:
            return self._pending is not None

    def _install_pending(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, None
        if pending is None:
            return
        params, cim, report, lifetime, controller = pending
        self._install_bank(params, cim)
        self.deploy_report = report
        # The old lifetime/monitors describe the retired checkpoint;
        # the redeploy captured fresh ones (or none, when health is
        # unarmed).
        self.lifetime, self.health = lifetime, controller
        _C_REDEPLOYS.inc()
