"""Iteration-level request scheduling for continuous batching.

Orca-style admission model: the scheduler owns an open FIFO queue of
:class:`Request`s and the per-slot :class:`Sequence` bookkeeping of
everything in flight.  The engine drives one *scheduler iteration* at a
time — admit queued requests into free slots (prefill-then-join,
mid-flight, without disturbing running sequences), run one batched
decode step for every live slot, stream the new tokens, and evict
sequences that hit their token budget.  No request ever waits for a
*batch* to finish; it waits for a *slot*.

The scheduler is pure host-side policy + bookkeeping: device state
lives in :class:`repro.serve.kvcache.SlotPool`, the lowerables in
:class:`repro.serve.continuous.ContinuousEngine`.  Metrics follow the
telemetry idiom — declared once at module level, recorded per event:
queue depth gauge, admitted/evicted counters (AUD007-audited names).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import numpy as np

from repro import telemetry as tm

_G_QUEUE = tm.gauge(
    "repro_serve_queue_depth",
    "Requests waiting for a slot (updated on submit/admit).")
_C_ADMITTED = tm.counter(
    "repro_serve_admitted_total", "Requests admitted into a slot.")
_C_EVICTED = tm.counter(
    "repro_serve_evicted_total", "Finished sequences evicted from slots.")

TokenCallback = Callable[[int, int, bool], None]


@dataclasses.dataclass
class Request:
    """One generation request.

    ``max_tokens`` counts *all* generated tokens (the prefill-sampled
    first token included — same convention as ``ServeEngine.generate``).
    ``seed`` roots the per-sequence sampling key: token n is drawn with
    ``fold_in(PRNGKey(seed), n)``, so a request's output is
    bit-deterministic per seed regardless of slot or batchmates.
    ``on_token(rid, token, done)`` streams tokens as they are sampled.
    """

    rid: int
    prompt: np.ndarray
    max_tokens: int
    temperature: float = 0.0
    seed: int = 0
    on_token: TokenCallback | None = None


@dataclasses.dataclass
class Sequence:
    """In-flight state of one admitted request."""

    req: Request
    slot: int
    epoch: int                 # bank epoch pinned at admission
    n_emitted: int = 0
    tokens: list = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.n_emitted >= self.req.max_tokens


class RequestScheduler:
    """Open request queue + per-slot sequence bookkeeping."""

    def __init__(self):
        self.queue: deque[Request] = deque()
        self.live: dict[int, Sequence] = {}       # slot -> Sequence
        self.results: dict[int, list[int]] = {}   # rid -> tokens (done)
        self._next_rid = 0

    # -- queue ---------------------------------------------------------

    def submit(self, prompt, max_tokens: int, temperature: float = 0.0,
               seed: int = 0, on_token: TokenCallback | None = None
               ) -> int:
        """Enqueue a request; returns its rid."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt, max_tokens,
                                  float(temperature), int(seed),
                                  on_token))
        _G_QUEUE.set(len(self.queue))
        return rid

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    @property
    def pending(self) -> int:
        """Requests not yet finished (queued + in flight)."""
        return len(self.queue) + len(self.live)

    # -- admission / eviction ------------------------------------------

    def pop_admission(self) -> Request | None:
        """Next queued request (FIFO), or None."""
        if not self.queue:
            return None
        req = self.queue.popleft()
        _G_QUEUE.set(len(self.queue))
        return req

    def start(self, req: Request, slot: int, epoch: int) -> Sequence:
        """Register an admitted request as live in ``slot``."""
        if slot in self.live:
            raise ValueError(f"slot {slot} already occupied")
        seq = Sequence(req, slot, epoch)
        self.live[slot] = seq
        _C_ADMITTED.inc()
        return seq

    def record_token(self, slot: int, token: int) -> bool:
        """Append one sampled token to the slot's sequence.

        Returns True when the sequence just hit its budget (caller
        evicts).  Streams through the request callback either way.
        """
        seq = self.live[slot]
        seq.tokens.append(int(token))
        seq.n_emitted += 1
        done = seq.done
        if seq.req.on_token is not None:
            seq.req.on_token(seq.req.rid, int(token), done)
        return done

    def finish(self, slot: int) -> Sequence:
        """Evict a finished sequence; its tokens land in ``results``."""
        seq = self.live.pop(slot)
        self.results[seq.req.rid] = list(seq.tokens)
        _C_EVICTED.inc()
        return seq

    # -- batch views ---------------------------------------------------

    def epochs_live(self) -> list[int]:
        """Distinct bank epochs currently in flight, ascending."""
        return sorted({seq.epoch for seq in self.live.values()})
