"""Slot-pool ("paged") KV cache for continuous batching.

A :class:`SlotPool` owns one fixed-capacity per-slot decode state
(``init_decode_state(per_slot=True)``): the batch axis is a pool of
``capacity`` slots, each holding one sequence's ring-buffer KV cache,
recurrent state and position clock.  Batch composition changes by
**index update only** — a sequence joins by scattering its prefilled
B=1 state into its slot, and evicts by resetting that slot's ``kpos``
lanes to ``EMPTY_POS`` (self-masking: a dead slot attends to nothing
and nothing attends to it).  Shapes never change, so the decode
lowerable downstream compiles once per ``(capacity, max_seq)`` and is
reused for every composition — the recompilation guarantee the
serving-tier gates pin (``traces`` counts actual retraces).

Join masks the tail of the padded prompt out of the cache: prefill runs
at a fixed ``max_prompt`` length (one trace for all prompts), so cache
entries at positions >= the true prompt length are garbage — their
``kpos`` is rewritten to ``EMPTY_POS``.  Unlike ring-buffer garbage
*behind* the clock, padding garbage sits at positions future queries
would attend to, so it must be masked explicitly.

All three state transforms (join / evict / fork-merge for multi-bank
decode) are jits over the pool state (join/evict donate it); slot
index and length are traced scalars, so serving any slot reuses one
trace.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import EMPTY_POS
from repro.models.model import init_decode_state


def _map_slot_state(pool: dict, src: dict | None, leaf_fn, pos_fn,
                    kpos_fn):
    """Rebuild a per-slot state dict, dispatching on the special keys.

    ``pos`` (B,) and ``kpos`` (R, B, C) carry per-slot occupancy and
    need their own updates; every other leaf is (R, B, ...) and gets
    the generic ``leaf_fn``.
    """
    out = {}
    for slot_name, sub in pool.items():
        if slot_name == "pos":
            out[slot_name] = pos_fn(sub, None if src is None
                                    else src[slot_name])
            continue
        out[slot_name] = {}
        for k, leaf in sub.items():
            s = None if src is None else src[slot_name][k]
            fn = kpos_fn if k == "kpos" else leaf_fn
            out[slot_name][k] = fn(leaf, s)
    return out


class SlotPool:
    """Fixed-capacity slot pool over the per-slot decode state."""

    def __init__(self, cfg: ModelConfig, capacity: int, max_seq: int):
        self.cfg = cfg
        self.capacity = capacity
        self.max_seq = max_seq
        self.state = init_decode_state(cfg, capacity, max_seq,
                                       per_slot=True)
        self._free = sorted(range(capacity))
        # Host-side retrace counters: the bodies below run only when
        # jax traces them, so these count compilations, not calls —
        # the receipt behind the "compiles once per (capacity,
        # max_seq)" guarantee.
        self.traces = {"join": 0, "evict": 0, "merge": 0}

        def join_fn(pool, src, slot, length):
            self.traces["join"] += 1
            return _map_slot_state(
                pool, src,
                leaf_fn=lambda p, s: p.at[:, slot].set(s[:, 0]),
                pos_fn=lambda p, s: p.at[slot].set(length),
                kpos_fn=lambda p, s: p.at[:, slot].set(
                    jnp.where(s[:, 0] >= length, EMPTY_POS, s[:, 0])))

        def evict_fn(pool, slot):
            self.traces["evict"] += 1
            return _map_slot_state(
                pool, None,
                leaf_fn=lambda p, s: p,
                pos_fn=lambda p, s: p.at[slot].set(0),
                kpos_fn=lambda p, s: p.at[:, slot].set(EMPTY_POS))

        def merge_fn(a, b, take_b):
            self.traces["merge"] += 1

            def pick(x, y):
                m = take_b.reshape((1, -1) + (1,) * (x.ndim - 2)) \
                    if x.ndim >= 2 else take_b
                return jnp.where(m, y, x)

            return _map_slot_state(
                a, b, leaf_fn=pick, pos_fn=pick, kpos_fn=pick)

        # Only the pool state donates: the B=1 source is *read* (sliced
        # into the scatter), so its buffers can't alias the output.
        self._join = jax.jit(join_fn, donate_argnums=(0,))
        self._evict = jax.jit(evict_fn, donate_argnums=(0,))
        # merge: jnp.where can't alias every operand pair, so donation
        # would only warn; the copy is transient (multi-epoch swaps).
        self._merge = jax.jit(merge_fn)

    # -- slot bookkeeping ----------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return self.capacity - len(self._free)

    def acquire(self) -> int | None:
        """Lowest free slot, or None when the pool is full."""
        return self._free.pop(0) if self._free else None

    # -- state transforms ----------------------------------------------

    def fresh_seq_state(self):
        """A B=1 per-slot state for one prefill (same cache depth)."""
        return init_decode_state(self.cfg, 1, self.max_seq,
                                 per_slot=True)

    def join(self, slot: int, seq_state, length) -> None:
        """Scatter a prefilled B=1 state into ``slot``.

        ``length`` is the true (unpadded) prompt length: the slot's
        clock is set to it and every cache entry the padded prefill
        wrote at positions >= length is masked to EMPTY_POS.
        """
        self.state = self._join(self.state, seq_state,
                                jnp.int32(slot), jnp.int32(length))

    def evict(self, slot: int) -> None:
        """Mask ``slot`` dead (kpos -> EMPTY_POS, clock -> 0), free it."""
        self.state = self._evict(self.state, jnp.int32(slot))
        self._free.append(slot)
        self._free.sort()

    def merge(self, state_a, state_b, take_b):
        """Per-slot merge of two post-decode states (pure; returns it).

        ``take_b`` is a (capacity,) bool mask: slots where it is True
        take ``state_b``'s lanes, the rest take ``state_a``'s — the
        join step of multi-bank decode (in-flight sequences pinned to
        different checkpoint epochs decode separately, then merge).
        Pure (no donation — ``where`` can't alias both operands); the
        caller installs the result.
        """
        return self._merge(state_a, state_b, jnp.asarray(take_b, bool))

    def fork(self):
        """A device copy of the pool state (fodder for a donating jit)."""
        return jax.tree_util.tree_map(
            lambda x: jnp.array(x, copy=True), self.state)
