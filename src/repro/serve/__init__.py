from repro.serve.continuous import (  # noqa: F401
    Bank,
    ContinuousEngine,
    make_slot_decode,
    make_slot_prefill,
)
from repro.serve.engine import (  # noqa: F401
    ServeEngine,
    deploy_serving_bank,
    make_decode_step,
    make_prefill,
    sample_tokens,
    sample_tokens_batch,
)
from repro.serve.kvcache import SlotPool  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    Request,
    RequestScheduler,
    Sequence,
)
