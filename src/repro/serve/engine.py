"""Serving: prefill + batched autoregressive decode over ring-buffer
caches, with greedy/temperature sampling.

``make_prefill`` / ``make_decode_step`` are the two lowerables the
inference dry-run cells compile (prefill_32k lowers prefill; decode_32k
and long_500k lower one decode step against a seq_len-deep cache).

With ``cfg.cim.enabled`` the engine deploys every projection matrix
onto crossbars at init (``repro.deploy.deploy_model_params``, through
the persistent plan cache + per-checkpoint manifest, so redeploying an
unchanged checkpoint is ~free) and both lowerables route those matmuls
through the backend-dispatched ``cim_mvm`` — the model serves under the
paper's parasitic-resistance distortion for any ``cfg.cim.mode``
ablation.  Passing ``nonideal`` (a :class:`repro.nonideal.models
.NonidealModel`) additionally serves on *imperfect devices*: stuck-at
faults and programming variation are sampled once per ``nonideal_seed``
at deployment, folded into the deployment codes / per-weight gain, and
(with ``fault_aware``) steered around by the MDM row sort.  Line-open
faults that outrun the mapping's spare capacity demote the affected
matrices to the digital fallback (``CimDeployment.degraded``); the
demotions and their reasons are listed in ``deploy_report["degraded"]``.
A ``nonideal.sigma_read > 0`` additionally draws fresh per-read
conductance noise on every prefill/decode forward pass.
Both prefill and decode donate the decode state: prefill consumes the
freshly initialised cache and decode consumes its predecessor's, so
there is no full cache copy at the prefill->decode handoff.

**Lifetime resilience** (``health=HealthConfig(...)``): the engine
additionally captures per-matrix lifetime state at deployment
(:mod:`repro.deploy.lifetime`) and owns a
:class:`repro.health.HealthController`.  ``advance(dt)`` ages the
deployed conductances on the runtime drift clock (power-law drift +
stochastic relaxation re-evaluated against the clock; same draws, later
point on the trajectory); ``check_health()`` runs one calibration-probe
round and climbs the remediation ladder (recalibrate -> reprogram ->
demote) on any matrix whose drift detector trips.  Refreshed
deployments are **hot-swapped atomically**: the cim tree is replaced by
fresh dicts, never mutated, and ``generate`` snapshots the tree once at
entry — a generation in flight keeps the exact bank it started with,
bit-deterministically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import telemetry as tm
from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingCtx
from repro.models.model import apply_model, init_decode_state

_H_PREFILL = tm.histogram(
    "repro_serve_prefill_seconds",
    "Prefill wall time per generate() call (synced when telemetry on).")
_H_DECODE = tm.histogram(
    "repro_serve_decode_step_seconds",
    "Per-step decode wall time (synced when telemetry on).")
_C_REQUESTS = tm.counter(
    "repro_serve_requests_total", "generate() calls served.")
_C_TOKENS = tm.counter(
    "repro_serve_tokens_total", "Tokens generated (batch x steps).")
_C_SWAPS = tm.counter(
    "repro_serve_hot_swaps_total",
    "Deployment groups hot-swapped into the serving tree.")


def sample_tokens(logits: jax.Array, key: jax.Array,
                  temperature: float | jax.Array = 0.0) -> jax.Array:
    """logits: (B, V) -> (B,) int32.

    ``temperature`` is either a Python float — the historical trace-time
    constant, kept bit-identical (greedy argmax at <= 0, else one
    categorical draw over the batch) — or a jax array (scalar or (B,)),
    which makes temperature a *runtime* operand: mixed-temperature
    batches share one trace, rows with t <= 0 decode greedily and rows
    with t > 0 sample at their own temperature.
    """
    if not isinstance(temperature, jax.Array):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature,
                                      axis=-1).astype(jnp.int32)
    t = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32),
                         logits.shape[:1])
    scaled = logits / jnp.maximum(t, 1e-6)[:, None]
    sampled = jax.random.categorical(key, scaled, axis=-1)
    greedy = jnp.argmax(logits, axis=-1)
    return jnp.where(t > 0.0, sampled, greedy).astype(jnp.int32)


def sample_tokens_batch(logits: jax.Array, keys: jax.Array,
                        temperatures: jax.Array) -> jax.Array:
    """Per-sequence sampling: logits (B, V), keys (B, 2) uint32 key data,
    temperatures (B,) -> (B,) int32.

    Each row draws from its *own* PRNG key, so a sequence's sample
    depends only on its logits row, its key and its temperature — never
    on which slot it occupies or who else is in the batch.  That row
    independence is what makes continuous-batching decode
    bit-deterministic per request seed across batch compositions.
    Rows with t <= 0 decode greedily (dead slots pass t = 0).
    """
    t = jnp.asarray(temperatures, jnp.float32)
    scaled = logits / jnp.maximum(t, 1e-6)[:, None]
    sampled = jax.vmap(
        lambda lg, k: jax.random.categorical(k, lg))(scaled, keys)
    greedy = jnp.argmax(logits, axis=-1)
    return jnp.where(t > 0.0, sampled, greedy).astype(jnp.int32)


def deploy_serving_bank(cfg: ModelConfig, params, ctx: ShardingCtx, *,
                        plan_cache=None, nonideal=None,
                        nonideal_seed: int = 0, fault_aware: bool = True,
                        pipeline=None, health=None):
    """Deploy one checkpoint's crossbar bank for serving.

    The shared init path of :class:`ServeEngine` and the
    continuous-batching :class:`repro.serve.continuous.ContinuousEngine`
    (and of the latter's *async redeploy*, which runs this in a
    background thread).  Returns ``(cim, report, lifetime, controller)``
    — ``cim`` is None when ``cfg.cim.enabled`` is off; ``lifetime`` /
    ``controller`` are populated only when ``health`` is armed on a
    non-ideal deployment (ideal devices don't age).
    """
    if not cfg.cim.enabled:
        return None, None, {}, None
    from repro.deploy import PlanCache, deploy_model_params
    cache = plan_cache if plan_cache is not None else PlanCache()
    lifetime: dict = {}
    want_health = (health is not None and nonideal is not None
                   and not nonideal.is_ideal)
    cim, report = deploy_model_params(
        params, cfg, cache=cache, ctx=ctx, nonideal=nonideal,
        nonideal_key=nonideal_seed, fault_aware=fault_aware,
        pipeline=pipeline, lifetime=lifetime if want_health else None)
    controller = None
    if want_health:
        from repro.health import HealthController
        controller = HealthController(lifetime, health)
    return cim, report, lifetime, controller


def make_prefill(cfg: ModelConfig, ctx: ShardingCtx, temperature: float = 0.0):
    """(params, state, tokens|embeds, key[, cim, read_key]) ->
    (first_token, state).  ``read_key`` draws fresh per-read crossbar
    conductance noise for this forward pass (None = noiseless)."""

    def prefill(params, state, inputs, key, cim=None, read_key=None):
        kw = {"embeds": inputs} if cfg.frontend else {"tokens": inputs}
        logits, state, _ = apply_model(params, cfg, ctx, state=state,
                                       decode=False, cim=cim,
                                       read_key=read_key, **kw)
        tok = sample_tokens(logits[:, -1], key, temperature)
        return tok, state

    return prefill


def make_decode_step(cfg: ModelConfig, ctx: ShardingCtx,
                     temperature: float = 0.0):
    """(params, state, token (B,), key[, cim, read_key]) ->
    (next_token, state).  ``read_key`` draws fresh per-read crossbar
    conductance noise for this step (None = noiseless)."""

    def decode_step(params, state, token, key, cim=None, read_key=None):
        logits, state, _ = apply_model(params, cfg, ctx,
                                       tokens=token[:, None], state=state,
                                       decode=True, cim=cim,
                                       read_key=read_key)
        tok = sample_tokens(logits[:, 0], key, temperature)
        return tok, state

    return decode_step


class ServeEngine:
    """Minimal batched engine: prefill a batch of prompts, decode N steps."""

    def __init__(self, cfg: ModelConfig, params, ctx: ShardingCtx | None = None,
                 max_seq: int = 2048, temperature: float = 0.0,
                 plan_cache=None, nonideal=None, nonideal_seed: int = 0,
                 fault_aware: bool = True, pipeline=None, health=None):
        self.cfg = cfg
        self.ctx = ctx or ShardingCtx()
        self.params = params
        self.max_seq = max_seq
        # ``pipeline`` (a repro.mapping.MappingPipeline, named pipeline
        # or spec string) selects the mapping strategy; default is
        # cfg.cim.mode (legacy mode strings keep working through the
        # deprecation shim).  ``nonideal``
        # (repro.nonideal.models.NonidealModel) serves the model on
        # imperfect devices: stuck faults / variation are sampled once
        # at deployment (keyed by nonideal_seed), folded into the
        # deployment codes/gain, and — with fault_aware — steered
        # around by the MDM row sort.  ``health`` (a
        # repro.health.HealthConfig) additionally captures lifetime
        # state and arms the monitor/remediation controller.
        self.cim, self.deploy_report, self.lifetime, self.health = \
            deploy_serving_bank(
                cfg, params, self.ctx, plan_cache=plan_cache,
                nonideal=nonideal, nonideal_seed=nonideal_seed,
                fault_aware=fault_aware, pipeline=pipeline, health=health)
        # Per-read conductance noise: only drawn when the nonideal model
        # asks for it — otherwise read_key stays None and both
        # lowerables trace the bit-identical noiseless graph.
        self._read_noise = bool(self.cim is not None
                                and nonideal is not None
                                and nonideal.sigma_read > 0.0)
        # Donate the state on both lowerables: prefill writes the whole
        # cache anyway, so aliasing the fresh buffers avoids one full
        # cache copy at the prefill->decode handoff.
        self._prefill = jax.jit(make_prefill(cfg, self.ctx, temperature),
                                donate_argnums=(1,))
        self._decode = jax.jit(
            make_decode_step(cfg, self.ctx, temperature),
            donate_argnums=(1,))
        self._probe_base = jax.random.PRNGKey(nonideal_seed)

    # -- lifetime resilience -------------------------------------------

    def _swap(self, dirty: set) -> None:
        """Atomically swap refreshed deployments into the serving tree.

        Builds a *fresh* dict tree containing the restacked groups and
        replaces ``self.cim`` in one assignment — the old tree object
        is never mutated, so any generation loop that snapshotted it
        keeps serving a fully consistent bank (the hot-swap atomicity
        contract, pinned in tests/test_health.py).
        """
        if not dirty:
            return
        from repro.deploy import restack_group
        cim = {slot: dict(sub) for slot, sub in self.cim.items()}
        for slot, pname in dirty:
            cim[slot][pname] = restack_group(self.lifetime, slot, pname)
        self.cim = cim
        _C_SWAPS.inc(len(dirty))

    def advance(self, dt: float) -> None:
        """Advance the serving drift clock by ``dt`` (t0 units).

        Ages every live matrix (power-law drift + relaxation evaluated
        against the new age — same draws, later point on the
        trajectory) and hot-swaps the re-derived deployments.  This is
        the physics, not a remediation: an unmonitored engine ages the
        same way, it just never probes or heals.
        """
        if self.health is None:
            return
        self._swap(self.health.advance(dt))

    def check_health(self, read_key: jax.Array | None = None):
        """One probe round + remediation pass; returns a HealthReport.

        Probes run through the production ``cim_mvm`` against the
        currently-served (aged) deployments; with per-read noise armed,
        each round derives a fresh probe read key off the deployment
        seed (deterministic per engine seed and round count).
        """
        if self.health is None:
            return None
        if read_key is None and self._read_noise:
            read_key = jax.random.fold_in(
                jax.random.fold_in(self._probe_base, 9),
                self.health.rounds)
        self._swap(self.health.probe(read_key))
        return self.health.report()

    @property
    def health_report(self):
        """Current HealthReport, or None when health is not armed."""
        return None if self.health is None else self.health.report()

    def generate(self, prompts: jax.Array, n_tokens: int,
                 seed: int = 0) -> jax.Array:
        """prompts: (B, S) tokens (or (B, S, D) embeds for stub frontends).
        Returns (B, n_tokens) generated ids.

        With per-read noise enabled (``nonideal.sigma_read > 0``) every
        forward pass — the prefill and each decode step — draws fresh
        crossbar read noise from a key forked off that step's sampling
        key; generation stays deterministic per ``seed``.

        The cim tree is snapshotted once at entry: a concurrent
        ``advance``/``check_health`` hot-swap replaces ``self.cim``
        with a fresh tree and never mutates the old one, so this
        generation serves the exact bank it started with.  With
        ``health.age_per_token > 0`` the served tokens advance the
        drift clock (simulated reads) *after* the batch completes.
        """
        cim = self.cim
        B = prompts.shape[0]
        state = init_decode_state(self.cfg, B, self.max_seq)
        key = jax.random.PRNGKey(seed)
        rk = lambda k: jax.random.fold_in(k, 1) if self._read_noise else None
        # Telemetry adds block_until_ready syncs so the latency
        # histograms measure real step time; the values computed are
        # identical either way (syncing never changes a result), and
        # with telemetry off this is exactly the bare async loop.
        t_on = tm.enabled()
        with tm.span("serve/generate", batch=B, n_tokens=n_tokens):
            key, k0 = jax.random.split(key)
            if t_on:
                t0 = tm.monotonic()
                with tm.span("serve/prefill", batch=B):
                    tok, state = self._prefill(self.params, state,
                                               prompts, k0, cim, rk(k0))
                    jax.block_until_ready(tok)
                _H_PREFILL.observe(tm.monotonic() - t0)
            else:
                tok, state = self._prefill(self.params, state, prompts,
                                           k0, cim, rk(k0))
            out = [tok]
            if t_on:
                with tm.span("serve/decode", steps=n_tokens - 1):
                    for _ in range(n_tokens - 1):
                        key, k = jax.random.split(key)
                        t0 = tm.monotonic()
                        tok, state = self._decode(self.params, state,
                                                  tok, k, cim, rk(k))
                        jax.block_until_ready(tok)
                        _H_DECODE.observe(tm.monotonic() - t0)
                        out.append(tok)
            else:
                for _ in range(n_tokens - 1):
                    key, k = jax.random.split(key)
                    tok, state = self._decode(self.params, state, tok, k,
                                              cim, rk(k))
                    out.append(tok)
            _C_REQUESTS.inc()
            _C_TOKENS.inc(B * n_tokens)
            if (self.health is not None
                    and self.health.cfg.age_per_token > 0.0):
                self.advance(n_tokens * self.health.cfg.age_per_token)
        return jnp.stack(out, axis=1)
