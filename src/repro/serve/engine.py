"""Serving: prefill + batched autoregressive decode over ring-buffer
caches, with greedy/temperature sampling.

``make_prefill`` / ``make_decode_step`` are the two lowerables the
inference dry-run cells compile (prefill_32k lowers prefill; decode_32k
and long_500k lower one decode step against a seq_len-deep cache).

With ``cfg.cim.enabled`` the engine deploys every projection matrix
onto crossbars at init (``repro.deploy.deploy_model_params``, through
the persistent plan cache + per-checkpoint manifest, so redeploying an
unchanged checkpoint is ~free) and both lowerables route those matmuls
through the backend-dispatched ``cim_mvm`` — the model serves under the
paper's parasitic-resistance distortion for any ``cfg.cim.mode``
ablation.  Passing ``nonideal`` (a :class:`repro.nonideal.models
.NonidealModel`) additionally serves on *imperfect devices*: stuck-at
faults and programming variation are sampled once per ``nonideal_seed``
at deployment, folded into the deployment codes / per-weight gain, and
(with ``fault_aware``) steered around by the MDM row sort.  Line-open
faults that outrun the mapping's spare capacity demote the affected
matrices to the digital fallback (``CimDeployment.degraded``); the
demotions and their reasons are listed in ``deploy_report["degraded"]``.
A ``nonideal.sigma_read > 0`` additionally draws fresh per-read
conductance noise on every prefill/decode forward pass.
Both prefill and decode donate the decode state: prefill consumes the
freshly initialised cache and decode consumes its predecessor's, so
there is no full cache copy at the prefill->decode handoff.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingCtx
from repro.models.model import apply_model, init_decode_state


def sample_tokens(logits: jax.Array, key: jax.Array,
                  temperature: float = 0.0) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature,
                                  axis=-1).astype(jnp.int32)


def make_prefill(cfg: ModelConfig, ctx: ShardingCtx, temperature: float = 0.0):
    """(params, state, tokens|embeds, key[, cim, read_key]) ->
    (first_token, state).  ``read_key`` draws fresh per-read crossbar
    conductance noise for this forward pass (None = noiseless)."""

    def prefill(params, state, inputs, key, cim=None, read_key=None):
        kw = {"embeds": inputs} if cfg.frontend else {"tokens": inputs}
        logits, state, _ = apply_model(params, cfg, ctx, state=state,
                                       decode=False, cim=cim,
                                       read_key=read_key, **kw)
        tok = sample_tokens(logits[:, -1], key, temperature)
        return tok, state

    return prefill


def make_decode_step(cfg: ModelConfig, ctx: ShardingCtx,
                     temperature: float = 0.0):
    """(params, state, token (B,), key[, cim, read_key]) ->
    (next_token, state).  ``read_key`` draws fresh per-read crossbar
    conductance noise for this step (None = noiseless)."""

    def decode_step(params, state, token, key, cim=None, read_key=None):
        logits, state, _ = apply_model(params, cfg, ctx,
                                       tokens=token[:, None], state=state,
                                       decode=True, cim=cim,
                                       read_key=read_key)
        tok = sample_tokens(logits[:, 0], key, temperature)
        return tok, state

    return decode_step


class ServeEngine:
    """Minimal batched engine: prefill a batch of prompts, decode N steps."""

    def __init__(self, cfg: ModelConfig, params, ctx: ShardingCtx | None = None,
                 max_seq: int = 2048, temperature: float = 0.0,
                 plan_cache=None, nonideal=None, nonideal_seed: int = 0,
                 fault_aware: bool = True, pipeline=None):
        self.cfg = cfg
        self.ctx = ctx or ShardingCtx()
        self.params = params
        self.max_seq = max_seq
        self.cim = None
        self.deploy_report = None
        if cfg.cim.enabled:
            from repro.deploy import PlanCache, deploy_model_params
            cache = plan_cache if plan_cache is not None else PlanCache()
            # ``pipeline`` (a repro.mapping.MappingPipeline, named
            # pipeline or spec string) selects the mapping strategy;
            # default is cfg.cim.mode (legacy mode strings keep working
            # through the deprecation shim).  ``nonideal``
            # (repro.nonideal.models.NonidealModel) serves the model on
            # imperfect devices: stuck faults / variation are sampled
            # once at deployment (keyed by nonideal_seed), folded into
            # the deployment codes/gain, and — with fault_aware —
            # steered around by the MDM row sort.
            self.cim, self.deploy_report = deploy_model_params(
                params, cfg, cache=cache, ctx=self.ctx,
                nonideal=nonideal, nonideal_key=nonideal_seed,
                fault_aware=fault_aware, pipeline=pipeline)
        # Per-read conductance noise: only drawn when the nonideal model
        # asks for it — otherwise read_key stays None and both
        # lowerables trace the bit-identical noiseless graph.
        self._read_noise = bool(self.cim is not None
                                and nonideal is not None
                                and nonideal.sigma_read > 0.0)
        # Donate the state on both lowerables: prefill writes the whole
        # cache anyway, so aliasing the fresh buffers avoids one full
        # cache copy at the prefill->decode handoff.
        self._prefill = jax.jit(make_prefill(cfg, self.ctx, temperature),
                                donate_argnums=(1,))
        self._decode = jax.jit(
            make_decode_step(cfg, self.ctx, temperature),
            donate_argnums=(1,))

    def generate(self, prompts: jax.Array, n_tokens: int,
                 seed: int = 0) -> jax.Array:
        """prompts: (B, S) tokens (or (B, S, D) embeds for stub frontends).
        Returns (B, n_tokens) generated ids.

        With per-read noise enabled (``nonideal.sigma_read > 0``) every
        forward pass — the prefill and each decode step — draws fresh
        crossbar read noise from a key forked off that step's sampling
        key; generation stays deterministic per ``seed``.
        """
        B = prompts.shape[0]
        state = init_decode_state(self.cfg, B, self.max_seq)
        key = jax.random.PRNGKey(seed)
        rk = lambda k: jax.random.fold_in(k, 1) if self._read_noise else None
        key, k0 = jax.random.split(key)
        tok, state = self._prefill(self.params, state, prompts, k0,
                                   self.cim, rk(k0))
        out = [tok]
        for _ in range(n_tokens - 1):
            key, k = jax.random.split(key)
            tok, state = self._decode(self.params, state, tok, k,
                                      self.cim, rk(k))
            out.append(tok)
        return jnp.stack(out, axis=1)
