"""Serving: prefill + batched autoregressive decode over ring-buffer
caches, with greedy/temperature sampling.

``make_prefill`` / ``make_decode_step`` are the two lowerables the
inference dry-run cells compile (prefill_32k lowers prefill; decode_32k
and long_500k lower one decode step against a seq_len-deep cache).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingCtx
from repro.models.model import apply_model, init_decode_state


def sample_tokens(logits: jax.Array, key: jax.Array,
                  temperature: float = 0.0) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature,
                                  axis=-1).astype(jnp.int32)


def make_prefill(cfg: ModelConfig, ctx: ShardingCtx, temperature: float = 0.0):
    """(params, state, tokens|embeds, key) -> (first_token, state)."""

    def prefill(params, state, inputs, key):
        kw = {"embeds": inputs} if cfg.frontend else {"tokens": inputs}
        logits, state, _ = apply_model(params, cfg, ctx, state=state,
                                       decode=False, **kw)
        tok = sample_tokens(logits[:, -1], key, temperature)
        return tok, state

    return prefill


def make_decode_step(cfg: ModelConfig, ctx: ShardingCtx,
                     temperature: float = 0.0):
    """(params, state, token (B,), key) -> (next_token, state)."""

    def decode_step(params, state, token, key):
        logits, state, _ = apply_model(params, cfg, ctx,
                                       tokens=token[:, None], state=state,
                                       decode=True)
        tok = sample_tokens(logits[:, 0], key, temperature)
        return tok, state

    return decode_step


class ServeEngine:
    """Minimal batched engine: prefill a batch of prompts, decode N steps."""

    def __init__(self, cfg: ModelConfig, params, ctx: ShardingCtx | None = None,
                 max_seq: int = 2048, temperature: float = 0.0):
        self.cfg = cfg
        self.ctx = ctx or ShardingCtx()
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(make_prefill(cfg, self.ctx, temperature))
        self._decode = jax.jit(
            make_decode_step(cfg, self.ctx, temperature),
            donate_argnums=(1,))

    def generate(self, prompts: jax.Array, n_tokens: int,
                 seed: int = 0) -> jax.Array:
        """prompts: (B, S) tokens (or (B, S, D) embeds for stub frontends).
        Returns (B, n_tokens) generated ids."""
        B = prompts.shape[0]
        state = init_decode_state(self.cfg, B, self.max_seq)
        key = jax.random.PRNGKey(seed)
        key, k0 = jax.random.split(key)
        tok, state = self._prefill(self.params, state, prompts, k0)
        out = [tok]
        for _ in range(n_tokens - 1):
            key, k = jax.random.split(key)
            tok, state = self._decode(self.params, state, tok, k)
            out.append(tok)
        return jnp.stack(out, axis=1)
