"""Recurrent sequence mixers: selective SSM (Mamba), mLSTM, sLSTM.

Training uses *chunked* recurrences: an outer ``lax.scan`` carries the
state across fixed-size chunks while the inside of a chunk is computed
in parallel (associative scan for the SSM, decay-masked quasi-attention
for mLSTM).  This keeps the transient (B, chunk, dim, state) tensors in
on-chip memory range instead of materialising (B, S, dim, state).

sLSTM keeps the genuine per-step recurrence of the xLSTM paper (its
hidden-to-gate feedback is not associative); its state is O(d_model) so
the sequential scan is memory-light.  Simplifications vs. the papers
(documented in DESIGN.md): sigmoid input gates instead of stabilised
exponential gates; hymba's hybrid block averages the two paths after
separate projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _silu(x):
    return x * jax.nn.sigmoid(x)


# ------------------------------ Mamba ------------------------------------

def mamba_chunk_scan(a: jax.Array, b: jax.Array, h0: jax.Array):
    """h_t = a_t * h_{t-1} + b_t along axis 1 (chunk), with initial h0.

    a, b: (B, c, Di, N); h0: (B, Di, N). Returns (h (B,c,Di,N), h_last).
    """

    def op(l, r):
        al, bl = l
        ar, br = r
        return ar * al, ar * bl + br

    a_cum, b_cum = jax.lax.associative_scan(op, (a, b), axis=1)
    h = a_cum * h0[:, None] + b_cum
    return h, h[:, -1]


def mamba_mixer(p: dict, x: jax.Array, state: tuple | None,
                chunk: int = 64, prefix: str = ""):
    """Selective SSM over (B, S, D). state = (conv_state (B, K-1, Di),
    ssm_state (B, Di, N)) or None for zero-init training.
    Returns (y (B, S, D), new_state)."""
    g = lambda n: p[prefix + n]
    B, S, D = x.shape
    conv_w = g("conv_w")
    K, Di = conv_w.shape
    N = g("a_log").shape[-1]

    xz = x @ g("w_in")
    x_in, z = jnp.split(xz, 2, axis=-1)                   # (B, S, Di)

    conv_state = (jnp.zeros((B, K - 1, Di), x_in.dtype)
                  if state is None else state[0])
    h0 = (jnp.zeros((B, Di, N), jnp.float32)
          if state is None else state[1])

    x_pad = jnp.concatenate([conv_state.astype(x_in.dtype), x_in], axis=1)
    xf = x_pad.astype(jnp.float32)                        # match decode path
    conv = sum(xf[:, k:k + S] * g("conv_w").astype(jnp.float32)[k]
               for k in range(K)) + g("conv_b").astype(jnp.float32)
    new_conv_state = x_pad[:, S:][:, -(K - 1):] if K > 1 else conv_state
    xc = _silu(conv)                                      # (B, S, Di) f32

    dt = jax.nn.softplus(
        xc @ g("w_dt").astype(jnp.float32) + g("b_dt")).astype(jnp.float32)
    bc = (xc @ g("w_bc").astype(jnp.float32)).astype(jnp.float32)
    b_ssm, c_ssm = jnp.split(bc, 2, axis=-1)              # (B, S, N)
    A = -jnp.exp(g("a_log").astype(jnp.float32))          # (Di, N)

    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        xc_p = jnp.pad(xc.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_p = jnp.pad(b_ssm, ((0, 0), (0, pad), (0, 0)))
        c_p = jnp.pad(c_ssm, ((0, 0), (0, pad), (0, 0)))
    else:
        xc_p, dt_p, b_p, c_p = xc.astype(jnp.float32), dt, b_ssm, c_ssm

    def reshape_chunks(t):
        return t.reshape(B, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = tuple(map(reshape_chunks, (xc_p, dt_p, b_p, c_p)))

    def body(h, inp):
        xc_c, dt_c, b_c, c_c = inp                        # (B, c, ...)
        a = jnp.exp(dt_c[..., None] * A)                  # (B, c, Di, N)
        bx = (dt_c * xc_c)[..., None] * b_c[:, :, None, :]
        h_all, h_last = mamba_chunk_scan(a, bx, h)
        y_c = jnp.einsum("bcdn,bcn->bcd", h_all, c_c)
        return h_last, y_c

    h_last, ys = jax.lax.scan(body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, n_chunks * chunk, Di)[:, :S]
    y = y + xc.astype(jnp.float32) * g("d_skip")
    y = (y * _silu(z.astype(jnp.float32))) @ g("w_out").astype(jnp.float32)
    return y.astype(x.dtype), (new_conv_state, h_last)


def mamba_decode(p: dict, x: jax.Array, state: tuple, prefix: str = ""):
    """Single-token step. x: (B, 1, D)."""
    g = lambda n: p[prefix + n]
    B = x.shape[0]
    conv_w = g("conv_w")
    K, Di = conv_w.shape
    conv_state, h = state

    xz = x[:, 0] @ g("w_in")
    x_in, z = jnp.split(xz, 2, axis=-1)                   # (B, Di)

    window = jnp.concatenate([conv_state, x_in[:, None]], axis=1)  # (B,K,Di)
    conv = jnp.einsum("bkd,kd->bd", window.astype(jnp.float32),
                      conv_w.astype(jnp.float32)) + g("conv_b")
    new_conv_state = window[:, 1:]
    xc = _silu(conv)

    dt = jax.nn.softplus(
        xc @ g("w_dt").astype(jnp.float32) + g("b_dt")).astype(jnp.float32)
    bc = (xc @ g("w_bc").astype(jnp.float32)).astype(jnp.float32)
    b_ssm, c_ssm = jnp.split(bc, 2, axis=-1)
    A = -jnp.exp(g("a_log").astype(jnp.float32))
    a = jnp.exp(dt[..., None] * A)                        # (B, Di, N)
    h_new = a * h + (dt * xc.astype(jnp.float32))[..., None] * b_ssm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h_new, c_ssm)
    y = y + xc.astype(jnp.float32) * g("d_skip")
    y = (y * _silu(z.astype(jnp.float32))) @ g("w_out").astype(jnp.float32)
    return y[:, None].astype(x.dtype), (new_conv_state, h_new)


# ------------------------------ mLSTM ------------------------------------

def mlstm_mixer(p: dict, x: jax.Array, state: tuple | None,
                chunk: int = 128):
    """Chunkwise matrix-LSTM. x: (B, S, D).
    state = (S_mat (B,H,Dh,Dh), n_vec (B,H,Dh)) or None."""
    B, S, D = x.shape
    up = x @ p["w_up"]
    xi, o_pre = jnp.split(up, 2, axis=-1)                 # (B, S, Di)
    Di = xi.shape[-1]
    H = p["wq"].shape[1]
    Dh = p["wq"].shape[2]

    q = jnp.einsum("bsi,ihd->bshd", xi, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bsi,ihd->bshd", xi, p["wk"]).astype(jnp.float32) * Dh ** -0.5
    v = jnp.einsum("bsi,ihd->bshd", xi, p["wv"]).astype(jnp.float32)
    if_pre = (xi @ p["w_if"] + p["b_if"]).astype(jnp.float32)  # (B, S, 2H)
    i_g = jax.nn.sigmoid(if_pre[..., :H])
    logf = jax.nn.log_sigmoid(if_pre[..., H:])            # (B, S, H)

    S0 = jnp.zeros((B, H, Dh, Dh), jnp.float32) if state is None else state[0]
    n0 = jnp.zeros((B, H, Dh), jnp.float32) if state is None else state[1]

    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S

    def pc(t):
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        return t.reshape(B, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (pc(q), pc(k), pc(v), pc(i_g), pc(logf))

    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def body(carry, inp):
        S_m, n_v = carry
        q_c, k_c, v_c, i_c, lf_c = inp                    # (B, c, ...)
        lf_cum = jnp.cumsum(lf_c, axis=1)                 # (B, c, H)
        decay = jnp.exp(lf_cum)
        # inter-chunk
        y_int = jnp.einsum("bchd,bhde->bche", q_c, S_m) * decay[..., None]
        n_int = jnp.einsum("bchd,bhd->bch", q_c, n_v) * decay
        # intra-chunk
        att = jnp.einsum("bchd,bshd->bhcs", q_c, k_c)     # (B, H, c, s)
        # decay ratio exp(lf_cum[t] - lf_cum[s]) for s <= t:
        dm = lf_cum.transpose(0, 2, 1)                    # (B, H, c)
        dmat = jnp.exp(jnp.clip(dm[..., :, None] - dm[..., None, :], -60, 0))
        w = att * dmat * causal * i_c.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhcs,bshd->bchd", w, v_c)
        n_intra = jnp.sum(w, axis=-1).transpose(0, 2, 1)  # (B, c, H)
        num = y_int + y_intra
        den = jnp.maximum(jnp.abs(n_int + n_intra), 1.0)[..., None]
        y_c = num / den
        # state update
        tot = jnp.exp(lf_cum[:, -1])                      # (B, H)
        decay_to_end = jnp.exp(jnp.clip(
            lf_cum[:, -1][:, None] - lf_cum, -60, 0)) * i_c  # (B, c, H)
        S_new = S_m * tot[..., None, None] + jnp.einsum(
            "bchd,bche,bch->bhde", k_c, v_c, decay_to_end)
        n_new = n_v * tot[..., None] + jnp.einsum(
            "bchd,bch->bhd", k_c, decay_to_end)
        return (S_new, n_new), y_c

    (S_m, n_v), ys = jax.lax.scan(body, (S0, n0), xs)
    y = ys.swapaxes(0, 1).reshape(B, n_chunks * chunk, H * Dh)[:, :S]
    y = y * jax.nn.sigmoid(o_pre.astype(jnp.float32))
    y = y @ p["w_down"].astype(jnp.float32)
    return y.astype(x.dtype), (S_m, n_v)


def mlstm_decode(p: dict, x: jax.Array, state: tuple):
    """Single-token mLSTM step. x: (B, 1, D)."""
    B = x.shape[0]
    up = x[:, 0] @ p["w_up"]
    xi, o_pre = jnp.split(up, 2, axis=-1)
    H, Dh = p["wq"].shape[1], p["wq"].shape[2]
    q = jnp.einsum("bi,ihd->bhd", xi, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bi,ihd->bhd", xi, p["wk"]).astype(jnp.float32) * Dh ** -0.5
    v = jnp.einsum("bi,ihd->bhd", xi, p["wv"]).astype(jnp.float32)
    if_pre = (xi @ p["w_if"] + p["b_if"]).astype(jnp.float32)
    i_g = jax.nn.sigmoid(if_pre[..., :H])
    f_g = jax.nn.sigmoid(if_pre[..., H:])
    S_m, n_v = state
    S_new = S_m * f_g[..., None, None] + (i_g[..., None, None]
                                          * k[..., :, None] * v[..., None, :])
    n_new = n_v * f_g[..., None] + i_g[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, S_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)), 1.0)
    y = (num / den[..., None]).reshape(B, -1)
    y = y * jax.nn.sigmoid(o_pre.astype(jnp.float32))
    y = y @ p["w_down"].astype(jnp.float32)
    return y[:, None].astype(x.dtype), (S_new, n_new)


# ------------------------------ sLSTM ------------------------------------

def slstm_mixer(p: dict, x: jax.Array, state: tuple | None,
                ctx=None, tp: str = "shard"):
    """Sequential scalar-LSTM with block-diagonal (per-head) recurrence.
    x: (B, S, D). state = (h (B,H,Dh), c (B,H,Dh)).

    tp="replicate": gx is all-gathered once per layer and the per-step
    recurrence runs replicated on every model shard — trading one bulk
    collective for 98k per-step all-reduces (§Perf xlstm iteration)."""
    B, S, D = x.shape
    H, Dh4 = p["w_gates"].shape[1], p["w_gates"].shape[2]
    Dh = Dh4 // 4
    gx = jnp.einsum("bsd,dhg->bshg", x, p["w_gates"]) + p["b_gates"]
    if tp == "replicate" and ctx is not None:
        from repro.distributed.sharding import shard
        gx = shard(gx, ctx, "batch", "seq", None, None)  # bulk gather

    h0 = jnp.zeros((B, H, Dh), jnp.float32) if state is None else state[0]
    c0 = jnp.zeros((B, H, Dh), jnp.float32) if state is None else state[1]

    def body(carry, g_t):
        h, c = carry
        pre = g_t.astype(jnp.float32) + jnp.einsum(
            "bhd,hdg->bhg", h, p["r_gates"].astype(jnp.float32))
        i, f, z, o = jnp.split(pre, 4, axis=-1)
        c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(z)
        h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
        return (h_new, c_new), h_new

    (h, c), hs = jax.lax.scan(body, (h0, c0), gx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B, S, H * Dh)
    y = y @ p["w_out"].astype(jnp.float32)
    return y.astype(x.dtype), (h, c)


def slstm_decode(p: dict, x: jax.Array, state: tuple):
    y, st = slstm_mixer(p, x, state)
    return y, st
