"""Model assembly: pattern-scanned decoder stack covering the whole
assigned architecture pool (dense GQA / MoE / Mamba hybrid / xLSTM /
frontend-stub VLM & audio).

Layers are stacked per pattern slot and iterated with ``lax.scan`` so the
HLO stays O(1) in depth (essential for the 80-layer dry-runs).  The same
``apply_model`` serves training (no state), prefill (state threaded, all
positions) and decode (state threaded, one position): attention caches
are ring buffers keyed by absolute positions, recurrent blocks carry
O(1) states.

When a ``cim`` deployment tree is threaded in (``cfg.cim.enabled``
serving — built by ``repro.deploy.deploy_model_params`` at engine
init), the attention q/k/v/o and dense-MLP projection matmuls route
through the backend-dispatched ``cim_mvm`` op instead of plain
einsum/matmul, evaluating the model under the deployed crossbars'
parasitic-resistance distortion.  The deployments ride the layer scan
as stacked pytrees, exactly like the parameters they shadow.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingCtx, logical_spec, shard
from repro.models import schema as sch
from repro.models.attention import EMPTY_POS, flash_attention, rope
from repro.models.moe import moe_ffn
from repro.models.recurrent import (
    mamba_decode,
    mamba_mixer,
    mlstm_decode,
    mlstm_mixer,
    slstm_mixer,
)

ModelState = dict[str, Any]


def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (n * w.astype(jnp.float32)).astype(x.dtype)


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _cim_matmul(x: jax.Array, w: jax.Array, dep,
                read_key: jax.Array | None = None) -> jax.Array:
    """x @ w, through the deployed crossbars when a CimDeployment exists.

    A deployment carrying ``degraded != 0`` is demoted to the digital
    matmul on the full-precision weight: positive counts are programmed
    bits lost to line-open faults after the spare-line remap (spares
    exhausted — the deploy report lists every demotion with its
    reason); the negative sentinel is a *runtime* demotion by the
    health controller (:mod:`repro.health`) after the remediation
    ladder ran out of rungs.  Either way the crossbar output would be
    wrong, so the full-precision fallback serves.  ``read_key`` threads
    per-read conductance noise into ``cim_mvm`` (None = noiseless).
    """
    if dep is None:
        return x @ w
    from repro.kernels.cim_mvm.ops import cim_mvm
    if dep.degraded is None:
        return cim_mvm(x, dep, read_key=read_key).astype(x.dtype)
    w2 = w.reshape(dep.in_dim, dep.out_dim)
    return jax.lax.cond(
        dep.degraded != 0,
        lambda: (x @ w2).astype(x.dtype),
        lambda: cim_mvm(x, dep, read_key=read_key).astype(x.dtype))


def dense_mlp(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
              prefix: str = "ffn_", cim: dict | None = None,
              read_key: jax.Array | None = None) -> jax.Array:
    g = lambda n: p[prefix + n]
    c = lambda n: None if cim is None else cim.get(prefix + n)
    mm = lambda a, w, dep: _cim_matmul(a, w, dep, read_key=read_key)
    if cfg.mlp_type == "swiglu":
        h = (_silu(mm(x, g("w_gate"), c("w_gate")))
             * mm(x, g("w_up"), c("w_up")))
    else:
        h = jax.nn.gelu(mm(x, g("w_up"), c("w_up")))
    h = shard(h, ctx, "batch", "seq", "act_mlp")
    return mm(h, g("w_down"), c("w_down"))


# ----------------------------- attention ---------------------------------

def attn_apply(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
               positions: jax.Array, cache: dict | None,
               prefix: str = "", cim: dict | None = None,
               read_key: jax.Array | None = None):
    g = lambda n: p[prefix + n]
    c = lambda n: None if cim is None else cim.get(prefix + n)
    B, S, D = x.shape

    def qkv_proj(name):
        w, dep = g(name), c(name)
        if dep is None:
            return jnp.einsum("bsd,dhk->bshk", x, w)
        return _cim_matmul(x, w, dep,
                           read_key=read_key).reshape(B, S, *w.shape[-2:])

    q = qkv_proj("wq")
    k = qkv_proj("wk")
    v = qkv_proj("wv")
    if cfg.qkv_bias:
        q, k, v = q + g("bq"), k + g("bk"), v + g("bv")
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    H = q.shape[2]
    tp = dict(ctx.mesh.shape).get("model", 1) if ctx.mesh else 1
    if cfg.attn_fallback_shard == "query" and H % tp != 0:
        # Heads can't take the TP axis: shard queries instead of the QK
        # contraction dim (head_dim) — scores stay shard-local.
        q = shard(q, ctx, "batch", "act_seq_q", None, None)
        k = shard(k, ctx, "batch", None, None, None)
        v = shard(v, ctx, "batch", None, None, None)
    else:
        q = shard(q, ctx, "batch", "seq", "act_heads", "act_head_dim")
        k = shard(k, ctx, "batch", "seq", "act_kv", "act_head_dim")
        v = shard(v, ctx, "batch", "seq", "act_kv", "act_head_dim")

    new_cache = None
    if cache is None:
        k_all, v_all, k_pos = k, v, positions
    elif positions.ndim == 2:
        # Slot-pool decode: every batch lane carries its own position
        # clock, so the cache write is a per-lane scatter and ``kpos``
        # is per-lane (B, C).  Evicted lanes hold EMPTY_POS everywhere
        # and mask themselves out of attention entirely.
        C = cache["k"].shape[1]
        Sw = min(S, C)
        kw, vw, pw = k[:, S - Sw:], v[:, S - Sw:], positions[:, S - Sw:]
        idx = pw % C                                   # (B, Sw)
        b = jnp.arange(idx.shape[0])[:, None]
        ck = cache["k"].at[b, idx].set(kw.astype(cache["k"].dtype))
        cv = cache["v"].at[b, idx].set(vw.astype(cache["v"].dtype))
        cp = cache["kpos"].at[b, idx].set(pw)
        new_cache = {"k": ck, "v": cv, "kpos": cp}
        k_all, v_all, k_pos = ck, cv, cp
    else:
        C = cache["k"].shape[1]
        Sw = min(S, C)
        kw, vw, pw = k[:, S - Sw:], v[:, S - Sw:], positions[S - Sw:]
        if cfg.cache_update == "dus" and not cfg.sliding_window:
            # No ring wraparound without a window (C >= max position):
            # one contiguous dynamic-update-slice keeps the cache write
            # shard-local (the index-array scatter below replicates the
            # cache under SPMD — the dominant prefill collective).
            start = pw[0]
            ck = jax.lax.dynamic_update_slice(
                cache["k"], kw.astype(cache["k"].dtype), (0, start, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], vw.astype(cache["v"].dtype), (0, start, 0, 0))
            cp = jax.lax.dynamic_update_slice(cache["kpos"], pw, (start,))
        else:
            idx = pw % C
            ck = cache["k"].at[:, idx].set(kw.astype(cache["k"].dtype))
            cv = cache["v"].at[:, idx].set(vw.astype(cache["v"].dtype))
            cp = cache["kpos"].at[idx].set(pw)
        new_cache = {"k": ck, "v": cv, "kpos": cp}
        k_all, v_all, k_pos = ck, cv, cp

    if cfg.attn_impl == "pallas":
        from repro.kernels.flash_attention import flash_attention_tpu
        out = flash_attention_tpu(q, k_all, v_all, q_positions=positions,
                                  k_positions=k_pos,
                                  window=cfg.sliding_window)
    else:
        out = flash_attention(q, k_all, v_all, q_positions=positions,
                              k_positions=k_pos, window=cfg.sliding_window,
                              chunk=cfg.attn_chunk,
                              gqa_broadcast=cfg.gqa_broadcast,
                              remat_chunk=cfg.attn_remat_chunk)
    if c("wo") is None:
        y = jnp.einsum("bshk,hkd->bsd", out, g("wo"))
    else:
        y = _cim_matmul(out.reshape(B, S, -1), g("wo"), c("wo"),
                        read_key=read_key)
    return y, new_cache


# --------------------------- block dispatch ------------------------------

def block_apply(bt: str, p: dict, x: jax.Array, cfg: ModelConfig,
                ctx: ShardingCtx, positions: jax.Array,
                state: dict | None, decode: bool,
                cim: dict | None = None,
                read_key: jax.Array | None = None):
    """Apply one block. Returns (x, new_state_slice, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_state: dict = {}
    h = rmsnorm(x, p["norm"], cfg.norm_eps)

    if bt == "attn":
        y, cache = attn_apply(p, h, cfg, ctx, positions,
                              None if state is None else state, cim=cim,
                              read_key=read_key)
        if cache is not None:
            new_state = cache
    elif bt == "hybrid":
        cache_in = None if state is None else \
            {k: state[k] for k in ("k", "v", "kpos")}
        y_attn, cache = attn_apply(p, h, cfg, ctx, positions, cache_in,
                                   prefix="attn_", cim=cim,
                                   read_key=read_key)
        ssm_in = None if state is None else (state["conv"], state["ssm"])
        if decode:
            y_ssm, (cs, hs) = mamba_decode(p, h, ssm_in, prefix="ssm_")
        else:
            y_ssm, (cs, hs) = mamba_mixer(p, h, ssm_in,
                                          chunk=cfg.ssm_chunk, prefix="ssm_")
        y = 0.5 * (y_attn + y_ssm)
        if state is not None:
            new_state = dict(cache, conv=cs, ssm=hs)
    elif bt == "mamba":
        ssm_in = None if state is None else (state["conv"], state["ssm"])
        if decode:
            y, (cs, hs) = mamba_decode(p, h, ssm_in)
        else:
            y, (cs, hs) = mamba_mixer(p, h, ssm_in, chunk=cfg.ssm_chunk)
        if state is not None:
            new_state = {"conv": cs, "ssm": hs}
    elif bt == "mlstm":
        st = None if state is None else (state["S"], state["n"])
        if decode:
            y, (Sm, nv) = mlstm_decode(p, h, st)
        else:
            y, (Sm, nv) = mlstm_mixer(p, h, st, chunk=cfg.mlstm_chunk)
        if state is not None:
            new_state = {"S": Sm, "n": nv}
    elif bt == "slstm":
        st = None if state is None else (state["h"], state["c"])
        y, (hh, cc) = slstm_mixer(p, h, st, ctx=ctx, tp=cfg.slstm_tp)
        if state is not None:
            new_state = {"h": hh, "c": cc}
    else:
        raise ValueError(f"unknown block type {bt}")

    x = x + y
    x = shard(x, ctx, "batch", "seq", "act_embed")

    if bt in ("attn", "hybrid") and cfg.mlp_type != "none":
        hf = rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
        if cfg.n_experts:
            yf, aux = moe_ffn(p, hf, cfg, ctx, cim=cim, read_key=read_key)
        else:
            yf = dense_mlp(p, hf, cfg, ctx, cim=cim, read_key=read_key)
        x = x + yf
        x = shard(x, ctx, "batch", "seq", "act_embed")
    return x, new_state, aux


# ----------------------------- full model --------------------------------

def _remat_wrap(fn, cfg: ModelConfig, train: bool):
    if not train or cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # "full": recompute everything


def apply_model(params: dict, cfg: ModelConfig, ctx: ShardingCtx, *,
                tokens: jax.Array | None = None,
                embeds: jax.Array | None = None,
                state: ModelState | None = None,
                decode: bool = False,
                return_hidden: bool = False,
                cim: dict | None = None,
                read_key: jax.Array | None = None):
    """Returns (logits_or_hidden, new_state, aux_loss).

    ``cim``: optional per-slot CimDeployment tree (stacked over pattern
    repeats) routing projection matmuls through the crossbar path.
    ``read_key``: optional PRNG key for per-read crossbar conductance
    noise (one key per forward pass; each deployment decorrelates via
    its stacked per-repeat ``noise_tag``, so the shared key is safe to
    closure-capture across the layer scan).  None = noiseless serving.
    """
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    B, S = x.shape[0], x.shape[1]
    x = shard(x, ctx, "batch", "seq", "act_embed")

    pos0 = jnp.zeros((), jnp.int32) if state is None else state["pos"]
    if jnp.ndim(pos0) == 0:
        positions = pos0 + jnp.arange(S, dtype=jnp.int32)
    else:
        # Per-slot decode state (``init_decode_state(per_slot=True)``):
        # pos is (B,) and every lane gets its own absolute positions.
        positions = pos0[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]

    pattern = cfg.block_pattern
    slot_names = [f"slot{i}_{bt}" for i, bt in enumerate(pattern)]
    xs: dict = {"params": {n: params[n] for n in slot_names}}
    if state is not None:
        xs["state"] = {n: state[n] for n in slot_names}
    if cim is not None:
        xs["cim"] = {n: cim.get(n, {}) for n in slot_names}

    train = state is None

    def repeat_body(carry, xs_t):
        x, aux = carry
        new_states = {}
        for i, bt in enumerate(pattern):
            n = slot_names[i]
            st = xs_t["state"][n] if state is not None else None
            ci = xs_t["cim"][n] if cim is not None else None
            x, ns, a = block_apply(bt, xs_t["params"][n], x, cfg, ctx,
                                   positions, st, decode, cim=ci,
                                   read_key=read_key)
            new_states[n] = ns
            aux = aux + a
        return (x, aux), new_states

    body = _remat_wrap(repeat_body, cfg, train)
    (x, aux), ys = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    new_state = None
    if state is not None:
        new_state = dict(ys, pos=pos0 + S)

    if return_hidden:
        return x, new_state, aux

    logits = lm_logits(params, cfg, ctx, x)
    return logits, new_state, aux


def lm_logits(params: dict, cfg: ModelConfig, ctx: ShardingCtx,
              hidden: jax.Array) -> jax.Array:
    logits = jnp.einsum("bsd,dv->bsv", hidden,
                        params["lm_head"]).astype(jnp.float32)
    logits = shard(logits, ctx, "batch", "seq", "act_vocab")
    if cfg.padded_vocab > cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = logits - 1e9 * pad_mask.astype(jnp.float32)
    return logits


# ------------------------------- state -----------------------------------

def _state_defs(cfg: ModelConfig, batch: int, cache_len: int,
                per_slot: bool = False):
    """shape/dtype/logical-dims/fill for every decode-state tensor."""
    R = cfg.pattern_repeats
    Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    H = cfg.n_heads
    Di = cfg.d_model * cfg.ssm_expand
    N, K = cfg.ssm_state, cfg.ssm_conv
    C = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    dt = jnp.dtype(cfg.dtype)

    def attn_defs():
        # ``per_slot``: every batch lane keeps its own ring occupancy
        # (slot-pool serving — lanes join/evict independently), so
        # ``kpos`` grows a batch axis.
        kpos = (((R, batch, C), jnp.int32,
                 ("layers", "cache_batch", None), int(EMPTY_POS))
                if per_slot else
                ((R, C), jnp.int32, ("layers", None), int(EMPTY_POS)))
        return {
            "k": ((R, batch, C, Hkv, Dh), dt,
                  ("layers", "cache_batch", "cache_seq", "cache_kv",
                   "cache_head_dim"), 0),
            "v": ((R, batch, C, Hkv, Dh), dt,
                  ("layers", "cache_batch", "cache_seq", "cache_kv",
                   "cache_head_dim"), 0),
            "kpos": kpos,
        }

    def mamba_defs():
        return {
            "conv": ((R, batch, K - 1, Di), dt,
                     ("layers", "cache_batch", None, "inner"), 0),
            "ssm": ((R, batch, Di, N), jnp.float32,
                    ("layers", "cache_batch", "inner", "state"), 0),
        }

    defs: dict = {}
    for i, bt in enumerate(cfg.block_pattern):
        n = f"slot{i}_{bt}"
        if bt == "attn":
            defs[n] = attn_defs()
        elif bt == "hybrid":
            defs[n] = dict(attn_defs(), **mamba_defs())
        elif bt == "mamba":
            defs[n] = mamba_defs()
        elif bt == "mlstm":
            Dhm = (cfg.d_model * cfg.ssm_expand) // H
            defs[n] = {
                "S": ((R, batch, H, Dhm, Dhm), jnp.float32,
                      ("layers", "cache_batch", "heads", "head_dim", None), 0),
                "n": ((R, batch, H, Dhm), jnp.float32,
                      ("layers", "cache_batch", "heads", "head_dim"), 0),
            }
        elif bt == "slstm":
            Dhs = cfg.d_model // H
            defs[n] = {
                "h": ((R, batch, H, Dhs), jnp.float32,
                      ("layers", "cache_batch", "heads", "head_dim"), 0),
                "c": ((R, batch, H, Dhs), jnp.float32,
                      ("layers", "cache_batch", "heads", "head_dim"), 0),
            }
    return defs


def _map_state(defs: dict, fn):
    out = {}
    for slot, d in defs.items():
        out[slot] = {k: fn(*v) for k, v in d.items()}
    return out


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                      abstract: bool = False,
                      per_slot: bool = False) -> ModelState:
    """Fresh decode state. ``kpos`` slots start at EMPTY_POS (self-masking).

    Attention caches per slot are (R, B, C, Hkv, Dh) ring buffers with
    C = min(cache_len, sliding_window or cache_len).

    ``per_slot=True`` builds the slot-pool layout the continuous-batching
    tier serves from: ``pos`` is (B,) and ``kpos`` is (R, B, C), so every
    batch lane advances its own position clock and ring occupancy —
    lanes join/evict by index update, never by reshape.
    """
    defs = _state_defs(cfg, batch, cache_len, per_slot=per_slot)
    pos_shape = (batch,) if per_slot else ()
    if abstract:
        st = _map_state(defs, lambda sh, dt, dims, fill:
                        jax.ShapeDtypeStruct(sh, dt))
        st["pos"] = jax.ShapeDtypeStruct(pos_shape, jnp.int32)
        return st
    st = _map_state(defs, lambda sh, dt, dims, fill:
                    jnp.full(sh, fill, dt))
    st["pos"] = jnp.zeros(pos_shape, jnp.int32)
    return st


def state_partition_specs(cfg: ModelConfig, ctx: ShardingCtx, batch: int,
                          cache_len: int, per_slot: bool = False):
    defs = _state_defs(cfg, batch, cache_len, per_slot=per_slot)
    specs = _map_state(defs, lambda sh, dt, dims, fill:
                       logical_spec(sh, dims, ctx.mesh, ctx.rules))
    from jax.sharding import PartitionSpec as P
    specs["pos"] = P()
    return specs


# ------------------------------- params ----------------------------------

def init_params(cfg: ModelConfig, key: jax.Array, dtype=None):
    return sch.materialize(cfg, key, dtype)


def param_partition_specs(cfg: ModelConfig, ctx: ShardingCtx):
    return sch.partition_specs(cfg, ctx)


# -------------------------------- loss -----------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Token-mean CE; labels < 0 are masked."""
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * valid
    return ce.sum() / jnp.maximum(valid.sum(), 1)


def train_loss(params: dict, cfg: ModelConfig, ctx: ShardingCtx,
               batch: dict) -> tuple[jax.Array, dict]:
    """batch: {"tokens": (B, S+1)} or {"embeds": (B,S,D), "labels": (B,S)}."""
    if "embeds" in batch:
        embeds, labels = batch["embeds"], batch["labels"]
        hidden, _, aux = apply_model(params, cfg, ctx, embeds=embeds,
                                     return_hidden=True)
    else:
        toks = batch["tokens"]
        hidden, _, aux = apply_model(params, cfg, ctx, tokens=toks[:, :-1],
                                     return_hidden=True)
        labels = toks[:, 1:]

    if cfg.loss_chunk and hidden.shape[1] % cfg.loss_chunk == 0:
        nc = hidden.shape[1] // cfg.loss_chunk
        B = hidden.shape[0]
        hs = hidden.reshape(B, nc, cfg.loss_chunk, -1).swapaxes(0, 1)
        ls = labels.reshape(B, nc, cfg.loss_chunk).swapaxes(0, 1)

        @jax.checkpoint
        def chunk_ce(h_c, l_c):
            lg = lm_logits(params, cfg, ctx, h_c)
            valid = l_c >= 0
            safe = jnp.maximum(l_c, 0)
            logz = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
            return ((logz - gold) * valid).sum(), valid.sum()

        def body(carry, xs):
            tot, cnt = carry
            s, c = chunk_ce(*xs)
            return (tot + s, cnt + c), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
            (hs, ls))
        ce = tot / jnp.maximum(cnt, 1)
    else:
        logits = lm_logits(params, cfg, ctx, hidden)
        ce = cross_entropy(logits, labels)

    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}
