"""Sort-based Mixture-of-Experts with capacity buckets.

Dispatch uses argsort + bounded-capacity scatter (the production pattern)
rather than GShard one-hot einsums — one-hot dispatch costs
O(T * E * C * D) matmul FLOPs, which for 60-expert configs exceeds the
expert FLOPs themselves by an order of magnitude.  Overflowing tokens are
dropped into a trash slot (standard capacity-factor semantics) and keep
their residual path.

Expert weights are TP-sharded inside each expert ("mlp" -> model axis)
and FSDP-sharded over "embed"; the optional "expert" rule set shards the
expert dim itself when E divides the mesh axis (true EP).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingCtx, shard


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _expert_mm(xe: jax.Array, w: jax.Array, dep, expert_axis: int,
               read_key: jax.Array | None = None):
    """Per-expert matmul, through deployed crossbars when available.

    ``xe``: activations with the expert dim at ``expert_axis``; ``dep``
    a CimDeployment stacked over experts (leading axis), or None for
    the plain einsum.  ``w``: (E, in, out).  vmapping the
    backend-dispatched ``cim_mvm`` over the expert axis keeps every
    expert on its own tile grid — the expert-partitioned deployment of
    ``repro.deploy`` (pipeline ``partition=expert``).

    Experts whose deployment is ``degraded`` (line-open faults past the
    spare-line budget) fall back to the digital matmul per expert —
    ``jnp.where`` on the per-expert scalar, since under vmap a
    ``lax.cond`` would lower to the same both-branches select.
    ``read_key`` threads per-read conductance noise (per-expert
    ``noise_tag``s keep the draws independent).
    """
    if dep is None:
        eq = ("ecd,edf->ecf" if expert_axis == 0 else "becd,edf->becf")
        return jnp.einsum(eq, xe, w)
    from repro.kernels.cim_mvm.ops import cim_mvm

    def one_expert(a, d, we):
        y = cim_mvm(a, d, read_key=read_key)
        if d.degraded is not None:
            dig = (a.astype(jnp.float32)
                   @ we.reshape(d.in_dim, d.out_dim).astype(jnp.float32))
            y = jnp.where(d.degraded > 0, dig, y)
        return y

    y = jax.vmap(one_expert,
                 in_axes=(expert_axis, 0, 0),
                 out_axes=expert_axis)(xe, dep, w)
    return y.astype(xe.dtype)


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
            prefix: str = "ffn_", cim: dict | None = None,
            read_key: jax.Array | None = None):
    """x: (B, S, D) -> (y (B, S, D), aux_loss scalar).

    ``cim``: optional per-slot CimDeployment dict; expert banks deploy
    under keys ``ffn_we_{gate,up,down}`` with the expert axis stacked
    (see ``repro.deploy.deploy_model_params`` with an expert-axis
    partition pipeline), routing the expert matmuls through ``cim_mvm``.
    Routing, gating and shared experts stay digital.
    """
    if cfg.moe_dispatch == "grouped":
        return moe_ffn_grouped(p, x, cfg, ctx, prefix, cim=cim,
                               read_key=read_key)
    g = lambda n: p[prefix + n]
    c = lambda n: None if cim is None else cim.get(prefix + n)
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.n_experts_per_token
    xt = x.reshape(T, D)

    logits = (xt @ g("router")).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, K)                # (T, K)
    topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch/Mixtral form).
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(topk_idx, E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(dispatch_frac * jnp.mean(probs, axis=0))

    cap = int(((K * T * cfg.capacity_factor / E) // 128 + 1) * 128)
    cap = min(cap, T * K)

    e_flat = topk_idx.reshape(-1)                             # (T*K,)
    tok_flat = jnp.arange(T * K, dtype=jnp.int32) // K
    w_flat = topk_w.reshape(-1)

    order = jnp.argsort(e_flat, stable=True)
    e_s, tok_s, w_s = e_flat[order], tok_flat[order], w_flat[order]
    counts = jnp.bincount(e_flat, length=E)
    offsets = jnp.cumsum(counts) - counts                     # exclusive
    pos = jnp.arange(T * K, dtype=jnp.int32) - offsets[e_s]
    keep = pos < cap
    pos_safe = jnp.where(keep, pos, cap)                      # trash slot

    buf = jnp.zeros((E, cap + 1, D), x.dtype)
    buf = buf.at[e_s, pos_safe].set(xt[tok_s])
    xe = shard(buf[:, :cap], ctx, "experts", "batch", "act_embed")

    h = _silu(_expert_mm(xe, g("we_gate"), c("we_gate"), 0, read_key))
    h = h * _expert_mm(xe, g("we_up"), c("we_up"), 0, read_key)
    h = shard(h, ctx, "experts", "batch", "act_mlp")
    ye = _expert_mm(h, g("we_down"), c("we_down"), 0, read_key)
    ye = shard(ye, ctx, "experts", "batch", "act_embed")

    y_tok = ye[e_s, pos_safe] * (keep * w_s)[:, None].astype(ye.dtype)
    out = jnp.zeros((T, D), ye.dtype).at[tok_s].add(y_tok)

    if cfg.n_shared_experts:
        hs = _silu(xt @ g("ws_gate")) * (xt @ g("ws_up"))
        ys = hs @ g("ws_down")
        gate = jax.nn.sigmoid((xt @ g("shared_gate")).astype(jnp.float32))
        out = out + ys * gate.astype(ys.dtype)

    return out.reshape(B, S, D).astype(x.dtype), aux


def moe_ffn_grouped(p: dict, x: jax.Array, cfg: ModelConfig,
                    ctx: ShardingCtx, prefix: str = "ffn_",
                    cim: dict | None = None,
                    read_key: jax.Array | None = None):
    """Group-local sort-based dispatch (§Perf optimisation).

    The global variant sorts all B*S tokens in one index space, so every
    dispatch gather/scatter mixes data across the batch-sharded axis and
    SPMD must replicate (T, D)-sized tensors and all-reduce them — the
    dominant collective in the MoE train cells (2.1 PB/step for mixtral).
    Here each *batch group* (one sequence) routes its own S tokens into a
    per-group capacity buffer: every dispatch tensor keeps the leading
    B dim, which stays sharded over ("pod","data"), and dispatch becomes
    entirely shard-local.  Capacity is per-group (K*S*cf/E, rounded to 8)
    — physically equivalent to per-DP-shard capacity in production MoE.
    """
    g = lambda n: p[prefix + n]
    c = lambda n: None if cim is None else cim.get(prefix + n)
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.n_experts_per_token

    logits = (x @ g("router")).astype(jnp.float32)            # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, K)                # (B, S, K)
    topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-9)

    dispatch_frac = jnp.mean(
        jax.nn.one_hot(topk_idx, E, dtype=jnp.float32), axis=(0, 1, 2))
    aux = E * jnp.sum(dispatch_frac * jnp.mean(probs, axis=(0, 1)))

    cap = int(((K * S * cfg.capacity_factor / E) // 8 + 1) * 8)
    cap = min(cap, S * K)

    e_flat = topk_idx.reshape(B, S * K)                       # (B, SK)
    tok_flat = jnp.broadcast_to(
        (jnp.arange(S * K, dtype=jnp.int32) // K)[None], (B, S * K))
    w_flat = topk_w.reshape(B, S * K)

    order = jnp.argsort(e_flat, axis=-1, stable=True)         # (B, SK)
    e_s = jnp.take_along_axis(e_flat, order, axis=-1)
    tok_s = jnp.take_along_axis(tok_flat, order, axis=-1)
    w_s = jnp.take_along_axis(w_flat, order, axis=-1)

    # per-group exclusive offsets of each expert bucket
    counts = jnp.sum(jax.nn.one_hot(e_flat, E, dtype=jnp.int32),
                     axis=1)                                  # (B, E)
    offsets = jnp.cumsum(counts, axis=-1) - counts            # (B, E)
    pos = jnp.arange(S * K, dtype=jnp.int32)[None] \
        - jnp.take_along_axis(offsets, e_s, axis=-1)
    keep = pos < cap
    pos_safe = jnp.where(keep, pos, cap)

    x_tok = jnp.take_along_axis(x, tok_s[..., None], axis=1)  # (B, SK, D)
    buf = jnp.zeros((B, E, cap + 1, D), x.dtype)
    buf = buf.at[jnp.arange(B)[:, None], e_s, pos_safe].set(x_tok)
    xe = shard(buf[:, :, :cap], ctx, "batch", "experts", None, "act_embed")

    h = _silu(_expert_mm(xe, g("we_gate"), c("we_gate"), 1, read_key))
    h = h * _expert_mm(xe, g("we_up"), c("we_up"), 1, read_key)
    h = shard(h, ctx, "batch", "experts", None, "act_mlp")
    ye = _expert_mm(h, g("we_down"), c("we_down"), 1, read_key)
    ye = shard(ye, ctx, "batch", "experts", None, "act_embed")

    y_tok = ye[jnp.arange(B)[:, None], e_s, pos_safe] \
        * (keep * w_s)[..., None].astype(ye.dtype)            # (B, SK, D)
    out = jnp.zeros((B, S, D), ye.dtype)
    out = out.at[jnp.arange(B)[:, None], tok_s].add(y_tok)

    if cfg.n_shared_experts:
        hs = _silu(x @ g("ws_gate")) * (x @ g("ws_up"))
        ys = hs @ g("ws_down")
        gate = jax.nn.sigmoid((x @ g("shared_gate")).astype(jnp.float32))
        out = out + ys * gate.astype(ys.dtype)

    return out.astype(x.dtype), aux
