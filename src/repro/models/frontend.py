"""Modality frontend STUBS for [vlm]/[audio] architectures.

Per the assignment, the transformer BACKBONE is the deliverable; the
modality frontend (InternViT for internvl2, EnCodec for musicgen) is a
stub whose contract is: ``input_specs()`` provides *precomputed*
patch/frame embeddings of backbone width.  These helpers generate
deterministic synthetic embeddings with realistic statistics for smoke
tests and examples; the dry-run uses ShapeDtypeStructs only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def synthetic_embeddings(cfg: ModelConfig, batch: int, seq: int,
                         key: jax.Array, dtype=None) -> jax.Array:
    """Stand-in for frontend output: unit-variance (B, S, D) embeddings."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    return jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32) \
        .astype(dtype)


def embedding_spec(cfg: ModelConfig, batch: int, seq: int,
                   dtype=None) -> jax.ShapeDtypeStruct:
    dtype = dtype or jnp.dtype(cfg.dtype)
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dtype)
