"""Parameter schema: single source of truth for shapes, logical sharding
dims and initialisation of every parameter in the zoo.

A schema is a nested dict mirroring the parameter pytree whose leaves are
:class:`ParamSpec`.  From it we derive (a) initialised parameters,
(b) PartitionSpecs for pjit, (c) abstract ShapeDtypeStructs for the
dry-run — guaranteeing the three can never drift apart.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingCtx, logical_spec


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    dims: tuple                  # logical dim names (len == len(shape))
    init: str = "normal"         # normal | zeros | ones
    scale: float = 0.0           # stddev; 0 -> 1/sqrt(fan_in as shape[0] prod)

    def stddev(self) -> float:
        if self.scale:
            return self.scale
        fan_in = self.shape[0] if len(self.shape) == 1 else 1
        if len(self.shape) >= 2:
            fan_in = 1
            for s in self.shape[:-1]:
                fan_in *= s
            # for 3-D projections (D,H,K) fan-in is D only
            if len(self.shape) == 3:
                fan_in = self.shape[0]
        return fan_in ** -0.5


def _stack(spec: ParamSpec, repeats: int) -> ParamSpec:
    """Prepend the scanned-layers dim."""
    return ParamSpec((repeats,) + spec.shape, ("layers",) + spec.dims,
                     spec.init, spec.scale)


# --------------------------- block schemas -------------------------------

def attn_schema(cfg: ModelConfig) -> dict:
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s = {
        "norm": ParamSpec((D,), (None,), "ones"),
        "wq": ParamSpec((D, H, Dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D, Hkv, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((D, Hkv, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, Dh, D), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H, Dh), ("heads", "head_dim"), "zeros")
        s["bk"] = ParamSpec((Hkv, Dh), ("kv_heads", "head_dim"), "zeros")
        s["bv"] = ParamSpec((Hkv, Dh), ("kv_heads", "head_dim"), "zeros")
    return s


def mlp_schema(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    s = {
        "norm": ParamSpec((D,), (None,), "ones"),
        "w_up": ParamSpec((D, F), ("embed", "mlp")),
        "w_down": ParamSpec((F, D), ("mlp", "embed")),
    }
    if cfg.mlp_type == "swiglu":
        s["w_gate"] = ParamSpec((D, F), ("embed", "mlp"))
    return s


def moe_schema(cfg: ModelConfig) -> dict:
    D, E = cfg.d_model, cfg.n_experts
    Fe = cfg.moe_d_ff or cfg.d_ff
    s = {
        "norm": ParamSpec((D,), (None,), "ones"),
        "router": ParamSpec((D, E), ("embed", "experts")),
        "we_gate": ParamSpec((E, D, Fe), ("experts", "embed", "mlp")),
        "we_up": ParamSpec((E, D, Fe), ("experts", "embed", "mlp")),
        "we_down": ParamSpec((E, Fe, D), ("experts", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        F = cfg.d_ff  # fused shared-expert width
        s["ws_gate"] = ParamSpec((D, F), ("embed", "mlp"))
        s["ws_up"] = ParamSpec((D, F), ("embed", "mlp"))
        s["ws_down"] = ParamSpec((F, D), ("mlp", "embed"))
        s["shared_gate"] = ParamSpec((D, 1), ("embed", None))
    return s


def mamba_schema(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    Di = D * cfg.ssm_expand
    N = cfg.ssm_state
    return {
        "norm": ParamSpec((D,), (None,), "ones"),
        "w_in": ParamSpec((D, 2 * Di), ("embed", "inner")),
        "conv_w": ParamSpec((cfg.ssm_conv, Di), ("conv", "inner"),
                            "normal", 0.5),
        "conv_b": ParamSpec((Di,), ("inner",), "zeros"),
        "w_dt": ParamSpec((Di, Di), ("inner", None), "normal", 1e-3),
        "b_dt": ParamSpec((Di,), ("inner",), "ones"),
        "w_bc": ParamSpec((Di, 2 * N), ("inner", "state")),
        "a_log": ParamSpec((Di, N), ("inner", "state"), "zeros"),
        "d_skip": ParamSpec((Di,), ("inner",), "ones"),
        "w_out": ParamSpec((Di, D), ("inner", "embed")),
    }


def mlstm_schema(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    Di = D * cfg.ssm_expand
    H = cfg.n_heads
    Dh = Di // H
    return {
        "norm": ParamSpec((D,), (None,), "ones"),
        "w_up": ParamSpec((D, 2 * Di), ("embed", "inner")),
        "wq": ParamSpec((Di, H, Dh), ("inner", "heads", "head_dim")),
        "wk": ParamSpec((Di, H, Dh), ("inner", "heads", "head_dim")),
        "wv": ParamSpec((Di, H, Dh), ("inner", "heads", "head_dim")),
        "w_if": ParamSpec((Di, 2 * H), ("inner", None), "normal", 0.01),
        "b_if": ParamSpec((2 * H,), (None,), "zeros"),
        "w_down": ParamSpec((Di, D), ("inner", "embed")),
    }


def slstm_schema(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    H = cfg.n_heads
    Dh = D // H
    if cfg.slstm_tp == "replicate":
        # Input projection stays TP-sharded (computed once, gx
        # all-gathered once per layer in the mixer); the small recurrence
        # itself is replicated across the model axis — no per-step
        # collectives.
        gd = ("embed", "heads", "head_dim")
        rd, bd = (None, None, None), (None, None)
        od = ("embed", "mlp")
    else:
        gd = ("embed", "heads", "head_dim")
        rd = ("heads", "head_dim", None)
        bd = ("heads", "head_dim")
        od = ("embed", "embed2")
    return {
        "norm": ParamSpec((D,), (None,), "ones"),
        "w_gates": ParamSpec((D, H, 4 * Dh), gd),
        "r_gates": ParamSpec((H, Dh, 4 * Dh), rd, "normal", 0.02),
        "b_gates": ParamSpec((H, 4 * Dh), bd, "zeros"),
        "w_out": ParamSpec((D, D), od),
    }


def hybrid_schema(cfg: ModelConfig) -> dict:
    """Hymba-style parallel attention + mamba heads sharing one block."""
    s = {f"attn_{k}": v for k, v in attn_schema(cfg).items() if k != "norm"}
    s.update({f"ssm_{k}": v for k, v in mamba_schema(cfg).items()
              if k != "norm"})
    s["norm"] = ParamSpec((cfg.d_model,), (None,), "ones")
    return s


_BLOCK_SCHEMAS = {
    "attn": attn_schema,
    "mamba": mamba_schema,
    "mlstm": mlstm_schema,
    "slstm": slstm_schema,
    "hybrid": hybrid_schema,
}


def block_schema(cfg: ModelConfig, block_type: str) -> dict:
    s = dict(_BLOCK_SCHEMAS[block_type](cfg))
    # FFN attachment: attn/hybrid blocks carry an MLP or MoE; recurrent
    # xLSTM blocks are self-contained (d_ff == 0).
    if block_type in ("attn", "hybrid") and cfg.mlp_type != "none":
        ffn = moe_schema(cfg) if cfg.n_experts else mlp_schema(cfg)
        s.update({f"ffn_{k}": v for k, v in ffn.items()})
    return s


def model_schema(cfg: ModelConfig) -> dict:
    """Full parameter schema. Blocks are stacked over pattern repeats."""
    V, D = cfg.padded_vocab, cfg.d_model
    reps = cfg.pattern_repeats
    schema: dict = {
        "embed": ParamSpec((V, D), ("vocab", "embed"), "normal", 0.02),
        "final_norm": ParamSpec((D,), (None,), "ones"),
        "lm_head": ParamSpec((D, V), ("embed", "vocab")),
    }
    for i, bt in enumerate(cfg.block_pattern):
        slot = {k: _stack(v, reps) for k, v in block_schema(cfg, bt).items()}
        schema[f"slot{i}_{bt}"] = slot
    return schema


# ------------------------ schema consumers -------------------------------

def materialize(cfg: ModelConfig, key: jax.Array, dtype=None):
    """Initialised parameter pytree matching :func:`model_schema`."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    schema = model_schema(cfg)

    def is_spec(x):
        return isinstance(x, ParamSpec)

    leaves = jax.tree_util.tree_leaves_with_path(schema, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    key_of = {jax.tree_util.keystr(p): k for (p, _), k in zip(leaves, keys)}

    def build(path, spec: ParamSpec):
        k = key_of[jax.tree_util.keystr(path)]
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        return (jax.random.normal(k, spec.shape, jnp.float32)
                * spec.stddev()).astype(dtype)

    return jax.tree_util.tree_map_with_path(build, schema, is_leaf=is_spec)


def abstract_params(cfg: ModelConfig, dtype=None):
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        model_schema(cfg), is_leaf=lambda x: isinstance(x, ParamSpec))


def partition_specs(cfg: ModelConfig, ctx: ShardingCtx):
    """PartitionSpec pytree matching the parameter pytree."""
    return jax.tree_util.tree_map(
        lambda s: logical_spec(s.shape, s.dims, ctx.mesh, ctx.rules),
        model_schema(cfg), is_leaf=lambda x: isinstance(x, ParamSpec))
