"""Chunked flash attention (pure JAX, O(seq) memory) with GQA, RoPE,
sliding windows and ring-buffer KV-cache decode.

The KV sequence is scanned in fixed chunks with an online-softmax
accumulator (running max / denominator / weighted sum), so no (Sq, Skv)
score matrix is ever materialised — prefill at 32k and the 80-layer
dry-runs stay linear in sequence length.  Numerics: f32 accumulation.

Masking is position-based: both query and key carry *absolute* token
positions, so the same code path serves training (k_positions = arange),
full-cache decode, and sliding-window ring buffers (k_positions follows
the ring; empty slots hold EMPTY_POS and mask themselves out).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30
# np, not jnp: a module-level jnp constant would initialise the backend
# and transfer at import time (reprolint RPL005); jnp ops accept the
# numpy scalar and it stays int32 under weak typing.
EMPTY_POS = np.int32(2 ** 30)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, Dh); positions: (S,) shared across
    the batch, or (B, S) per-sequence (slot-pool decode, where every
    lane sits at its own absolute position)."""
    Dh = x.shape[-1]
    half = Dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[None, :, None, None].astype(jnp.float32) * freqs
    else:
        ang = positions[:, :, None, None].astype(jnp.float32) * freqs
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    q_positions: jax.Array, k_positions: jax.Array,
                    window: int = 0, chunk: int = 512,
                    gqa_broadcast: str = "repeat",
                    remat_chunk: bool = False) -> jax.Array:
    """q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh) -> (B, Sq, H, Dh).

    Causal: key position must be <= query position (absolute positions);
    with ``window`` > 0 additionally q_pos - k_pos < window.
    ``q_positions`` / ``k_positions`` are (Sq,) / (Skv,) shared across
    the batch, or (B, Sq) / (B, Skv) per-sequence — the slot-pool decode
    path, where each batch lane carries its own position clock and ring
    occupancy.  The 1-D form normalises to a broadcast batch dim of 1,
    so the shared-positions path computes bit-identically to before.

    GQA is handled by broadcasting KV heads to the full H inside each
    chunk (transient, chunk-sized) rather than reshaping H -> (Hkv, G):
    splitting the head dim would leave no dimension divisible by the TP
    mesh axis and forces the SPMD partitioner into full replication of
    every attention intermediate (observed as "involuntary full
    rematerialization" warnings and ~100x inflated HBM traffic).
    Keeping H intact keeps every (B, *, H, *) tensor TP-sharded.
    """
    B, Sq, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = Dh ** -0.5
    chunk = min(chunk, Skv)
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    # Normalise positions to (b, S) with b in {1, B}; the b=1 path is
    # the historical shared-positions computation, unchanged.
    qp = q_positions if q_positions.ndim == 2 else q_positions[None]
    kp = k_positions if k_positions.ndim == 2 else k_positions[None]
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(kp, ((0, 0), (0, pad)), constant_values=EMPTY_POS)

    qf = q.astype(jnp.float32)
    k_chunks = k.reshape(B, n_chunks, chunk, Hkv, Dh).swapaxes(0, 1)
    v_chunks = v.reshape(B, n_chunks, chunk, Hkv, Dh).swapaxes(0, 1)
    p_chunks = kp.reshape(kp.shape[0], n_chunks, chunk).swapaxes(0, 1)

    init = (jnp.full((B, Sq, H), NEG_INF, jnp.float32),
            jnp.zeros((B, Sq, H), jnp.float32),
            jnp.zeros((B, Sq, H, Dh), jnp.float32))

    # "take": a static gather along the head axis produces the (B,c,H,Dh)
    # tensor directly — the H-dim output shards on the TP axis, whereas
    # "repeat"'s broadcast+reshape goes through a (B,c,Hkv,G,Dh)
    # intermediate with no TP-divisible dim, forcing SPMD replication of
    # every attention chunk tensor (§Perf iteration 1).
    head_map = jnp.arange(H, dtype=jnp.int32) // G if G > 1 else None

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        k_c, v_c, k_pos = xs
        if G > 1:  # broadcast KV heads to H (chunk-transient)
            if gqa_broadcast == "take":
                k_c = jnp.take(k_c, head_map, axis=2)
                v_c = jnp.take(v_c, head_map, axis=2)
            else:
                k_c = jnp.repeat(k_c, G, axis=2)
                v_c = jnp.repeat(v_c, G, axis=2)
        s = jnp.einsum("bqhd,bchd->bqhc", qf,
                       k_c.astype(jnp.float32)) * scale
        valid = k_pos[:, None, :] <= qp[:, :, None]       # (b, Sq, C)
        if window:
            valid &= (qp[:, :, None] - k_pos[:, None, :]) < window
        s = jnp.where(valid[:, :, None, :], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.maximum(m_new, NEG_INF / 2)          # fully-masked guard
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0))
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqhc,bchd->bqhd", p, v_c.astype(jnp.float32))
        return (m_new, l_new, acc), None

    if remat_chunk:
        # Backward recomputes each chunk's score/softmax tensors from
        # (q, k_c, v_c) instead of saving them stacked over chunks.
        body = jax.checkpoint(body)
    (m, l, acc), _ = jax.lax.scan(body, init, (k_chunks, v_chunks, p_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
