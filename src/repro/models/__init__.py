from repro.models.model import (  # noqa: F401
    ModelState,
    apply_model,
    init_decode_state,
    init_params,
    param_partition_specs,
    state_partition_specs,
    train_loss,
)
