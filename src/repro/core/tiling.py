"""Mapping DNN weight matrices onto bit-sliced crossbar tiles.

A weight matrix W of shape (in_dim, out_dim) deploys onto a grid of
physical crossbar tiles of ``spec.rows`` rows x ``spec.cols`` columns.
Each weight occupies ``spec.n_bits`` adjacent columns (its fractional-bit
slice, high-order bit first under conventional dataflow), so one tile
holds ``spec.cols // spec.n_bits`` output columns of W and ``spec.rows``
input rows.  This mirrors the paper's setup ("a 128x128 crossbar with 16
multipliers ... each row stores eight different weight values") and its
experiments (crossbars in 64x64 tiles).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class CrossbarSpec(NamedTuple):
    """Physical crossbar tile + device parameters (paper §III-B / §V)."""

    rows: int = 64
    cols: int = 64
    n_bits: int = 8
    r: float = 2.5          # parasitic wire resistance per segment [ohm]
    r_on: float = 300e3     # active-cell resistance [ohm]
    r_off: float = 3e6      # inactive-cell resistance [ohm]
    v_read: float = 0.2     # row read voltage [V]

    @property
    def weights_per_tile(self) -> int:
        if self.cols % self.n_bits:
            raise ValueError(f"cols={self.cols} not divisible by n_bits={self.n_bits}")
        return self.cols // self.n_bits

    @property
    def nf_unit(self) -> float:
        """r / R_on — the NF slope of the Manhattan Hypothesis."""
        return self.r / self.r_on

    def grid(self, in_dim: int, out_dim: int) -> tuple[int, int]:
        """(row_tiles, col_tiles) needed for an (in_dim, out_dim) matrix."""
        return (math.ceil(in_dim / self.rows),
                math.ceil(out_dim / self.weights_per_tile))


def pad_to_tiles(bits: jax.Array, spec: CrossbarSpec) -> jax.Array:
    """Zero-pad a (I, N, K) bit tensor so I, N fill whole tiles."""
    I, N, K = bits.shape
    ti, tn = spec.grid(I, N)
    pad_i = ti * spec.rows - I
    pad_n = tn * spec.weights_per_tile - N
    if pad_i or pad_n:
        bits = jnp.pad(bits, ((0, pad_i), (0, pad_n), (0, 0)))
    return bits


def tile_masks(bits: jax.Array, spec: CrossbarSpec) -> jax.Array:
    """Arrange bit planes into physical tile activity masks.

    bits: (I, N, K) uint8 bit-planes of |W| (K = spec.n_bits, plane 0 is
    the 2^-1 high-order bit).
    Returns (Ti, Tn, rows, cols) uint8 masks in *conventional* dataflow
    layout: inside each weight's K-column group the high-order bit sits at
    the smallest column index (closest to the input rail).
    """
    K = bits.shape[-1]
    if K != spec.n_bits:
        raise ValueError(f"bit planes {K} != spec.n_bits {spec.n_bits}")
    bits = pad_to_tiles(bits, spec)
    I, N = bits.shape[0], bits.shape[1]
    ti, tn = I // spec.rows, N // spec.weights_per_tile
    # (ti, rows, tn, wpt, K) -> (ti, tn, rows, wpt*K)
    m = bits.reshape(ti, spec.rows, tn, spec.weights_per_tile, K)
    m = m.transpose(0, 2, 1, 3, 4)
    return m.reshape(ti, tn, spec.rows, spec.cols)


def untile_masks(masks: jax.Array, in_dim: int, out_dim: int,
                 spec: CrossbarSpec) -> jax.Array:
    """Inverse of :func:`tile_masks`; crops padding. Returns (I, N, K)."""
    ti, tn = masks.shape[0], masks.shape[1]
    K = spec.n_bits
    m = masks.reshape(ti, tn, spec.rows, spec.weights_per_tile, K)
    m = m.transpose(0, 2, 1, 3, 4)
    m = m.reshape(ti * spec.rows, tn * spec.weights_per_tile, K)
    return m[:in_dim, :out_dim]


def reverse_dataflow(masks: jax.Array) -> jax.Array:
    """Mirror tile columns: the low-order (dense) bits move next to the
    input rail (paper MDM step 1).  Pure relabelling of the physical
    column order — arithmetic is untouched because every bit column is
    sensed independently and shift-added digitally."""
    return masks[..., ::-1]
