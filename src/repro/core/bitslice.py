"""Bit-sliced weight decomposition for memristive crossbars.

Paper §II-A: each weight ``w`` is mapped across ``K`` fractional-bit columns,

    w = sign(w) * scale * sum_{k=1..K} b_k(w) 2^{-k}

where ``b_k`` is the k-th fractional bit of the magnitude normalised to
[0, 1).  Bit index ``k`` runs 1..K from high-order (2^-1) to low-order
(2^-K); in array layouts we store bits along the last axis with position
``k-1`` (0 = highest order).

Sign is tracked digitally (standard sign-magnitude CIM deployment); the
crossbar stores magnitudes only, matching the paper's nonnegative-W model.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SlicedWeights(NamedTuple):
    """Bit-sliced representation of a weight tensor.

    bits:  uint8, shape ``w.shape + (K,)``; bits[..., 0] is the 2^-1 plane.
    sign:  int8, shape ``w.shape``; +1 / -1 (0 maps to +1).
    scale: f32 scalar (or per-axis) normalisation so |w|/scale in [0, 1).
    """

    bits: jax.Array
    sign: jax.Array
    scale: jax.Array

    @property
    def n_bits(self) -> int:
        return self.bits.shape[-1]


def magnitude_scale(w: jax.Array, n_bits: int) -> jax.Array:
    """Default quantisation scale so |w|/scale lands in [0, 1).

    The headroom factor 2^K/(2^K - 1) makes the max magnitude land exactly
    on the all-ones code, keeping round-off within 1/2 LSB everywhere.
    Factored out so deployment planners can precompute the scale with the
    exact op sequence this module uses (a re-derived max can differ by an
    ulp under a different reduction fusion, shifting rounding boundaries).
    """
    levels = (1 << n_bits) - 1
    return (jnp.max(jnp.abs(w)) * ((1 << n_bits) / levels)
            * (1.0 + 1e-6) + 1e-30)


def magnitude_scale_host(w, n_bits: int):
    """Host (numpy) mirror of :func:`magnitude_scale`, bit-identical.

    Each step reproduces the jnp chain above under f32 weak-scalar
    promotion (max is rounding-free, the scalar constants are rounded
    to f32 before each op, exactly as XLA does) — keep the two in
    lockstep if the formula ever changes.  Lets deployment planners
    bit-slice whole models on the host with zero device dispatches.
    """
    import numpy as np

    levels = (1 << n_bits) - 1
    s = np.float32(np.max(np.abs(np.asarray(w, np.float32))))
    s = np.float32(s * np.float32((1 << n_bits) / levels))
    s = np.float32(s * np.float32(1.0 + 1e-6))
    return np.float32(s + np.float32(1e-30))


def quantize_magnitude(w: jax.Array, n_bits: int, scale: jax.Array | None = None):
    """Normalise |w| by ``scale`` and quantise to ``n_bits`` fractional bits.

    Returns (codes, sign, scale) where codes are integer levels in
    [0, 2^n_bits - 1] such that |w| ~= scale * codes * 2^-n_bits.
    """
    mag = jnp.abs(w)
    if scale is None:
        scale = magnitude_scale(w, n_bits)
    levels = (1 << n_bits) - 1
    codes = jnp.clip(jnp.round(mag / scale * (1 << n_bits)), 0, levels)
    codes = codes.astype(jnp.uint32)
    sign = jnp.where(w < 0, -1, 1).astype(jnp.int8)
    return codes, sign, jnp.asarray(scale, jnp.float32)


def codes_to_bits(codes: jax.Array, n_bits: int) -> jax.Array:
    """Expand integer codes into bit-planes, high-order first.

    bits[..., k] = bit (n_bits-1-k) of code  ==  b_{k+1} (the 2^-(k+1) plane).
    """
    shifts = jnp.arange(n_bits - 1, -1, -1, dtype=jnp.uint32)
    bits = (codes[..., None] >> shifts) & jnp.uint32(1)
    return bits.astype(jnp.uint8)


def bitslice(w: jax.Array, n_bits: int, scale: jax.Array | None = None) -> SlicedWeights:
    """Decompose a weight tensor into its bit-sliced crossbar form."""
    codes, sign, scale = quantize_magnitude(w, n_bits, scale)
    return SlicedWeights(bits=codes_to_bits(codes, n_bits), sign=sign, scale=scale)


def bits_to_codes(bits: jax.Array) -> jax.Array:
    n_bits = bits.shape[-1]
    weights = (jnp.uint32(1) << jnp.arange(n_bits - 1, -1, -1, dtype=jnp.uint32))
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=-1)


def unbitslice(sliced: SlicedWeights) -> jax.Array:
    """Reconstruct the (quantised) weight tensor from its bit-sliced form."""
    codes = bits_to_codes(sliced.bits)
    mag = codes.astype(jnp.float32) * (sliced.scale / (1 << sliced.n_bits))
    return mag * sliced.sign.astype(jnp.float32)


def quantization_error_bound(scale: jax.Array, n_bits: int) -> jax.Array:
    """Max absolute rounding error of the bit-sliced representation."""
    return scale * 0.5 * 2.0 ** (-n_bits)


def column_density(bits: jax.Array) -> jax.Array:
    """Fraction of active cells per bit plane: p_k estimate, shape (K,).

    Theorem 1 predicts density increases with k (lower-order planes denser)
    and p_k < 1/2 for bell-shaped |w| distributions.
    """
    flat = bits.reshape(-1, bits.shape[-1])
    return jnp.mean(flat.astype(jnp.float32), axis=0)
