"""Position-dependent PR noise injection (paper Eq 17).

    w'_j = sum_{k<=K} b_{j,k}(w_j) 2^{-k} [1 + eta * delta_{j,k} * d(j,k)]

where d(j,k) = physical row position + physical column position of the
bit cell *after* the deployment plan (dataflow direction + row sort) is
applied.  This folds the analog distortion of a CIM deployment into an
effective dense weight matrix, so any model can be evaluated "as if" it
ran on PR-afflicted crossbars by swapping W -> noisy_weights(W, plan).

``eta`` is calibrated against the circuit-level solver (the paper uses
SPICE; we use ``repro.crossbar.solver``) such that the injected noise
matches the measured distortion at the spec's wire resistance.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bitslice import bitslice
from repro.core.mdm import MdmPlan, plan_from_bits
from repro.core.tiling import CrossbarSpec

# Paper's SPICE-calibrated value for r=2.5ohm, R_on=300kohm (§V-C).
PAPER_ETA = 2e-3


def _bit_weights(n_bits: int) -> jax.Array:
    """2^-(k+1) for plane k (plane 0 = 2^-1)."""
    return 2.0 ** -(1.0 + jnp.arange(n_bits, dtype=jnp.float32))


@partial(jax.jit, static_argnames=("spec",))
def noisy_magnitude(bits: jax.Array, scale: jax.Array, plan: MdmPlan,
                    spec: CrossbarSpec, eta: float | jax.Array) -> jax.Array:
    """Effective |W'| (I, N) after PR distortion under ``plan``.

    Split Eq 17 into a row term and a column term so no (I, N, K) tensor
    is materialised:

        |w'| = scale * [(1 + eta*p) * M0 + eta * M1]
        M0   = sum_k b_k 2^-(k+1)          (the clean magnitude)
        M1   = sum_k b_k 2^-(k+1) * c_k    (column-distance moment)
    """
    I, N, K = bits.shape
    rows, wpt = spec.rows, spec.weights_per_tile
    b = bits.astype(jnp.float32)
    bw = _bit_weights(K)

    # Physical column of bit plane k for output column n.
    slot = jnp.arange(N) % wpt
    col = slot[:, None] * K + jnp.arange(K)[None, :]          # (N, K)
    rev = jnp.asarray(plan.reversed_dataflow)
    col = jnp.where(rev, (spec.cols - 1) - col, col)

    # Physical row of input row i when feeding column-tile tn.
    ti = jnp.arange(I) // rows
    q = jnp.arange(I) % rows
    tn = jnp.arange(N) // wpt
    # (Ti, Tn, rows) -> (I, Tn) -> (I, N)
    pos_itn = plan.row_position[ti, :, q]                     # (I, Tn)
    p = pos_itn[:, tn].astype(jnp.float32)                    # (I, N)

    m0 = jnp.einsum("ink,k->in", b, bw)
    if plan.col_position is None:
        m1 = jnp.einsum("ink,nk->in", b, bw * col.astype(jnp.float32))
    else:
        # Column-permuted plan: bitline of (i, n, k) is per-tile.
        colp = plan.col_position[ti[:, None, None], tn[None, :, None],
                                 col[None, :, :]].astype(jnp.float32)
        m1 = jnp.einsum("ink,ink->in", b, bw * colp)
    return scale * ((1.0 + eta * p) * m0 + eta * m1)


def noisy_weights(w: jax.Array, spec: CrossbarSpec, mode="mdm",
                  eta: float | jax.Array = PAPER_ETA,
                  plan: MdmPlan | None = None) -> tuple[jax.Array, MdmPlan]:
    """Eq 17 end-to-end: bit-slice, plan, distort.

    ``mode`` is a ``repro.mapping.MappingPipeline`` or a named/legacy
    string (resolved by ``repro.mapping.resolve_pipeline``).

    Returns (W', plan).  With eta=0 this returns the plain bit-sliced
    quantisation of W — the semantics-preservation baseline.
    """
    sliced = bitslice(w, spec.n_bits)
    if plan is None:
        plan = plan_from_bits(sliced.bits, sliced.scale, spec, mode)
    mag = noisy_magnitude(sliced.bits, sliced.scale, plan, spec, eta)
    return mag * sliced.sign.astype(jnp.float32), plan


def calibrate_eta(spec: CrossbarSpec, key=None, n_tiles: int = 16,
                  sparsity: float = 0.8, precision=None) -> float:
    """Calibrate eta against the circuit-level solver (paper §V-C: the
    paper does this in SPICE, obtaining eta = 2e-3 for r = 2.5 ohm).

    Least-squares: match the Eq-17 predicted per-tile current deficit,
    sum_cells eta * d(j,k), to the circuit-measured |sum di| / i_cell on
    random tiles of the target sparsity.  All tiles are solved in one
    fused call to the batched engine (``repro.crossbar.batched``), so
    calibration cost is one PCG solve, not ``n_tiles`` of them.

    ``precision`` selects the engine arithmetic (a
    :class:`repro.crossbar.batched.SolverPrecision`, a policy name, or
    None = all-f64); the mixed f32/f64 policy matches the f64 oracle to
    ~1e-10 relative — far below the least-squares fit noise — at a
    fraction of the solve cost, so sweeps calibrating eta per device
    spec can safely run ``precision="mixed"``.
    """
    import jax as _jax
    import numpy as _np

    from repro.core import manhattan
    from repro.crossbar.batched import measured_nf_batched

    key = key if key is not None else _jax.random.PRNGKey(0)  # reprolint: disable=RPL003 -- documented deterministic calibration default; callers needing fresh tiles pass their own key
    masks = (_jax.random.uniform(
        key, (n_tiles, spec.rows, spec.cols)) < (1 - sparsity)
    ).astype(jnp.float32)
    res = measured_nf_batched(masks, spec, precision=precision)
    # per-cell-normalised measured deficit: |sum di| / (g_on * v_read)
    i_cell = spec.v_read / spec.r_on
    measured = _np.abs(_np.asarray(res.currents - res.ideal)).sum(-1) / i_cell
    predicted_d = _np.asarray(manhattan.aggregate_distance(masks))
    # measured ~= eta * predicted_d
    eta = float((measured * predicted_d).sum()
                / _np.maximum((predicted_d ** 2).sum(), 1e-30))
    return eta


def tree_noisy_weights(params, spec: CrossbarSpec, mode="mdm",
                       eta: float | jax.Array = PAPER_ETA, min_size: int = 1024):
    """Apply Eq 17 to every 2-D weight matrix in a pytree (>= min_size
    elements; biases/norms are left untouched — they stay digital)."""

    def visit(x):
        if isinstance(x, jax.Array) and x.ndim == 2 and x.size >= min_size:
            w, _ = noisy_weights(x, spec, mode, eta)
            return w.astype(x.dtype)
        if isinstance(x, jax.Array) and x.ndim == 3 and x.shape[1] * x.shape[2] >= min_size:
            # Stacked (layers, in, out) scan weights: vectorise over layers.
            def one(m):
                return noisy_weights(m, spec, mode, eta)[0]
            return jax.lax.map(one, x).astype(x.dtype)
        return x

    return jax.tree_util.tree_map(visit, params)
