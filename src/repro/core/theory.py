"""Theorem 1 machinery: bit-level structured sparsity of DNN weights.

For a nonnegative random variable W with continuous, strictly-decreasing
density f on [0, inf), the k-th fractional-bit activation probability

    p_k = P(b_k = 1),   b_k the 2^-k bit of W,

satisfies |p_k - 1/2| <= f(0) / 2^(2+k), with p_k < 1/2 for all k.

This module evaluates p_k exactly (quadrature over the bit indicator's
period structure) and empirically (sampling), and exposes the bound — the
property tests in ``tests/test_theory.py`` verify the theorem for several
bell-shaped families, and ``benchmarks/theorem1.py`` reproduces the
structured-sparsity premise on trained model weights.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.bitslice import bitslice

Density = Callable[[jax.Array], jax.Array]


def bit_indicator(w: jax.Array, k: int) -> jax.Array:
    """b_k(w): the 2^-k fractional bit of w (k >= 1), for w in [0, inf)."""
    return (jnp.floor(w * (2.0 ** k)) % 2).astype(jnp.int32)


def p_k_quadrature(f: Density, k: int, w_max: float = 32.0,
                   n_points: int = 2 ** 18) -> jax.Array:
    """P(b_k = 1) = integral of f over the half-periods where b_k = 1.

    Midpoint rule on a grid aligned to the bit period 2^-k so the
    indicator is constant within each cell.
    """
    period = 2.0 ** (-k)
    cell = period / 2.0
    sub = max(1, int(n_points * cell / w_max))
    n_cells = int(round(w_max / cell))
    edges = jnp.arange(n_cells) * cell
    offs = (jnp.arange(sub) + 0.5) * (cell / sub)
    pts = edges[:, None] + offs[None, :]
    mass = f(pts) * (cell / sub)
    ind = bit_indicator(pts, k)
    return jnp.sum(mass * ind) / jnp.sum(mass)  # normalised over [0, w_max]


def p_k_empirical(samples: jax.Array, k: int) -> jax.Array:
    return jnp.mean(bit_indicator(jnp.abs(samples), k).astype(jnp.float32))


def theorem1_bound(f0: float, k: int) -> float:
    """|p_k - 1/2| <= f(0) / 2^(1+k) for the standard 2^-k coefficient bit.

    Note on conventions: the paper's proof defines the indicator with
    period L = 2^-k (0 on the first half-period, 1 on the second), which
    is the *2^-(k+1)* coefficient in standard binary expansion — i.e.
    paper-b_k == standard-b_(k+1), and the paper's f(0)/2^(2+k) bound for
    its indicator is exactly f(0)/2^(1+k') for the standard bit k' = k+1.
    We index by the standard coefficient bit (consistent with
    ``repro.core.bitslice``), hence the 2^(1+k) denominator.  The
    telescoping argument is unchanged: Delta_k <= (period/2) * f(0).
    """
    return f0 / (2.0 ** (1 + k))


# --- Bell-shaped magnitude densities (|w| of common weight dists) --------

def half_normal(sigma: float) -> Density:
    c = jnp.sqrt(2.0 / jnp.pi) / sigma
    return lambda w: c * jnp.exp(-(w ** 2) / (2 * sigma ** 2))


def exponential(lam: float) -> Density:
    return lambda w: lam * jnp.exp(-lam * w)


def half_laplace(b: float) -> Density:
    return lambda w: (1.0 / b) * jnp.exp(-w / b)


def empirical_bit_densities(w: jax.Array, n_bits: int) -> jax.Array:
    """Observed per-plane density of a weight tensor after bit-slicing.

    Returns (n_bits,) with plane 0 = 2^-1.  Theorem 1 predicts a strictly
    sub-1/2, increasing-in-k profile for bell-shaped weights — the
    structured sparsity MDM exploits.
    """
    sliced = bitslice(w, n_bits)
    flat = sliced.bits.reshape(-1, n_bits).astype(jnp.float32)
    return jnp.mean(flat, axis=0)
