"""Manhattan Distance Mapping (MDM) — the paper's core algorithm.

Post-training, semantics-preserving remap of DNN weights onto crossbar
tiles (paper §IV), generalised to a composable
:class:`repro.mapping.MappingPipeline` of registered passes:

  1. *Dataflow orientation* — mirror tile columns so the dense
     low-order bit planes sit closest to the input rail.
  2. *Column order* — optional per-tile bitline permutation
     (X-CHANGR-style; ``identity`` reproduces the paper).
  3. *Row order* — per-row Manhattan scoring + sort (``mdm``), its
     fault-aware / significance-weighted variants, or ``identity``.

The result is an :class:`MdmPlan`: per-tile row (and optionally
column) permutations plus the dataflow direction.  The plan is pure
bookkeeping — applying it and then inverting it digitally (input mux
per tile row, column mux per bitline) reproduces the original matmul
exactly; only the *physical positions* (and hence the parasitic-
resistance exposure) change.

The legacy ``mode`` strings ("baseline"/"reverse"/"sort"/"mdm") are a
deprecation shim resolved by :func:`repro.mapping.resolve_pipeline`;
they produce bit-identical plans and identical plan-cache keys to the
pre-pipeline planner (pinned in tests/test_mapping.py).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import manhattan
from repro.core.bitslice import bitslice
from repro.core.tiling import CrossbarSpec, reverse_dataflow, tile_masks
from repro.mapping import MappingPipeline, resolve_pipeline

MODES = ("baseline", "reverse", "sort", "mdm")  # legacy shim names


class MdmPlan(NamedTuple):
    """Deployment plan for one weight matrix.

    row_perm:     (Ti, Tn, rows) int32 — physical row p of tile (ti,tn)
                  hosts original (tile-local) weight row ``row_perm[ti,tn,p]``.
    row_position: (Ti, Tn, rows) int32 — inverse: physical position of
                  tile-local original row q.
    reversed_dataflow: python bool (static).
    nf_before / nf_after: (Ti, Tn) f32 per-tile NF (Manhattan model).
    scale: f32 quantisation scale of the bit-sliced weights.
    col_perm:     (Ti, Tn, cols) int32 — physical bitline p hosts
                  dataflow-layout column ``col_perm[ti,tn,p]`` — or
                  None (identity column strategies; the pre-pipeline
                  plan layout).
    col_position: (Ti, Tn, cols) int32 inverse of ``col_perm``, or None.
    """

    row_perm: jax.Array
    row_position: jax.Array
    reversed_dataflow: jax.Array  # bool scalar (pytree leaf; use jnp.where)
    nf_before: jax.Array
    nf_after: jax.Array
    scale: jax.Array
    col_perm: jax.Array | None = None
    col_position: jax.Array | None = None

    @property
    def nf_reduction(self) -> jax.Array:
        """Fractional NF reduction, aggregated over all tiles."""
        b, a = jnp.sum(self.nf_before), jnp.sum(self.nf_after)
        return (b - a) / jnp.maximum(b, 1e-30)


def physical_column_significance(spec: CrossbarSpec, reversed_df: bool,
                                 col_perm: jax.Array | None = None,
                                 n_tiles: int = 1) -> jax.Array:
    """Per-physical-column bit significance 2^-(k+1), (T, cols) f32.

    ``k`` is the bit plane hosted at each physical bitline after the
    dataflow orientation and (optionally) a per-tile column permutation
    ``col_perm`` ((T, cols): physical position -> dataflow-layout
    column).
    """
    K = spec.n_bits
    k_of = jnp.arange(spec.cols, dtype=jnp.int32) % K
    if reversed_df:
        k_of = (K - 1) - k_of
    sig = 2.0 ** -(1.0 + k_of.astype(jnp.float32))
    if col_perm is None:
        return jnp.broadcast_to(sig, (n_tiles, spec.cols))
    return sig[col_perm]


@partial(jax.jit, static_argnames=("spec", "mode"))
def plan_tile_population(masks: jax.Array, spec: CrossbarSpec,
                         mode: str | MappingPipeline = "mdm",
                         fault_maps: jax.Array | None = None
                         ) -> tuple[jax.Array, jax.Array,
                                    jax.Array | None, jax.Array | None,
                                    jax.Array, jax.Array]:
    """Fused planning core over a flat tile population (T, rows, cols).

    Scoring, sorting and NF bookkeeping are vmapped over the whole
    population in one jit — the tiles may come from one layer's grid or
    from every layer of a model at once (``repro.deploy.planner``
    amortises planning this way, the same trick the batched circuit
    solver uses for its tile populations).

    ``mode`` is a :class:`repro.mapping.MappingPipeline` (or a named /
    legacy string resolved through ``repro.mapping.resolve_pipeline``).
    ``fault_maps`` (optional, (T, rows, cols) int8 physical cell states
    — see ``repro.nonideal.models``) feeds the fault-aware row
    strategies; the maps live in *physical* tile coordinates and are
    never dataflow-reversed or column-permuted.  Pipelines whose row
    pass does not consume faults ignore the argument (matching the
    legacy no-op for unsorted modes).

    Returns (row_perm, row_position, col_perm, col_position, nf_before,
    nf_after); the col entries are None for identity column strategies.
    """
    pipe = resolve_pipeline(mode, fault_maps is not None)
    T, rows = masks.shape[0], masks.shape[1]
    nf_before = manhattan.nonideality_factor(masks, spec.r, spec.r_on)

    placed = reverse_dataflow(masks) if pipe.reversed_dataflow else masks
    stuck = (fault_maps
             if (pipe.rows.uses_faults or pipe.cols.uses_faults)
             else None)

    # Pre-permutation significance: which bit plane each dataflow-layout
    # column *hosts* — the cols pass is choosing where those planes
    # land, so its significance grid is keyed by identity column order.
    pre_sig = None
    if pipe.cols.uses_col_significance:
        pre_sig = physical_column_significance(
            spec, pipe.reversed_dataflow, None, T)
    col_perm = pipe.cols.order_tiles(placed, stuck, pre_sig, spec)
    col_position = None
    if col_perm is not None:
        col_perm = col_perm.astype(jnp.int32)
        col_position = jnp.argsort(col_perm, axis=-1).astype(jnp.int32)
        placed = jnp.take_along_axis(placed, col_perm[:, None, :], axis=-1)

    col_sig = None
    if pipe.rows.uses_col_significance:
        col_sig = physical_column_significance(
            spec, pipe.reversed_dataflow, col_perm, T)

    perm = pipe.rows.order_tiles(placed, stuck, col_sig, spec)
    if perm is None:
        perm = jnp.broadcast_to(jnp.arange(rows, dtype=jnp.int32), (T, rows))
    else:
        perm = perm.astype(jnp.int32)
        placed = jnp.take_along_axis(placed, perm[..., None], axis=-2)

    position = jnp.argsort(perm, axis=-1).astype(jnp.int32)
    nf_after = manhattan.nonideality_factor(placed, spec.r, spec.r_on)
    return perm, position, col_perm, col_position, nf_before, nf_after


def plan_from_masks(masks: jax.Array, scale: jax.Array, spec: CrossbarSpec,
                    mode: str | MappingPipeline = "mdm",
                    fault_maps: jax.Array | None = None) -> MdmPlan:
    """Build an MDM plan from tile activity masks (Ti, Tn, rows, cols).

    The front door for callers that already hold the physical tile
    layout (``deploy()`` computes it once and shares it with
    ``placed_masks``, instead of re-deriving the bit planes twice).
    ``fault_maps`` ((Ti, Tn, rows, cols) int8 physical cell states)
    feeds the fault-aware row strategies.
    """
    pipe = resolve_pipeline(mode, fault_maps is not None)
    ti, tn, rows, cols = masks.shape
    flat = masks.reshape(ti * tn, rows, cols)
    if fault_maps is not None:
        fault_maps = fault_maps.reshape(ti * tn, rows, cols)
    perm, position, col_perm, col_position, nf_before, nf_after = \
        plan_tile_population(flat, spec, pipe, fault_maps)
    return MdmPlan(perm.reshape(ti, tn, rows),
                   position.reshape(ti, tn, rows),
                   jnp.asarray(pipe.reversed_dataflow),
                   nf_before.reshape(ti, tn),
                   nf_after.reshape(ti, tn), scale,
                   None if col_perm is None
                   else col_perm.reshape(ti, tn, cols),
                   None if col_position is None
                   else col_position.reshape(ti, tn, cols))


@partial(jax.jit, static_argnames=("spec", "mode"))
def plan_from_bits(bits: jax.Array, scale: jax.Array, spec: CrossbarSpec,
                   mode: str | MappingPipeline = "mdm",
                   fault_maps: jax.Array | None = None) -> MdmPlan:
    """Build an MDM plan from bit-sliced weights (I, N, K)."""
    return plan_from_masks(tile_masks(bits, spec), scale, spec, mode,
                           fault_maps)


def plan_layer(w: jax.Array, spec: CrossbarSpec,
               mode: str | MappingPipeline = "mdm",
               fault_maps: jax.Array | None = None) -> MdmPlan:
    """Bit-slice a weight matrix and build its deployment plan.

    ``fault_maps`` ((Ti, Tn, rows, cols) int8 physical cell states)
    folds known stuck cells into the row sort (fault-aware MDM).
    """
    if w.ndim != 2:
        raise ValueError("plan_layer expects a 2-D (in_dim, out_dim) matrix")
    sliced = bitslice(w, spec.n_bits)
    return plan_from_bits(sliced.bits, sliced.scale, spec, mode, fault_maps)


def placed_masks(bits: jax.Array, plan: MdmPlan, spec: CrossbarSpec,
                 masks: jax.Array | None = None) -> jax.Array:
    """Physical tile activity masks under a plan (for solver validation).

    Pass ``masks`` to reuse an already-derived ``tile_masks(bits, spec)``
    layout instead of recomputing the bit-plane arrangement.
    """
    if masks is None:
        masks = tile_masks(bits, spec)
    masks = jnp.where(jnp.asarray(plan.reversed_dataflow),
                      reverse_dataflow(masks), masks)
    if plan.col_perm is not None:
        masks = jnp.take_along_axis(masks, plan.col_perm[..., None, :],
                                    axis=-1)
    return jnp.take_along_axis(masks, plan.row_perm[..., None], axis=-2)


def permute_inputs(x_tile: jax.Array, plan: MdmPlan, ti: int, tn: int) -> jax.Array:
    """Digital input mux: reorder the activation slice feeding tile (ti,tn).

    x_tile: (..., rows) activations for the tile's input rows in original
    order; returns them in physical-row order.  Because summation over
    rows is permutation-invariant, the tile's column outputs are unchanged
    — this is the semantics-preservation guarantee of MDM.
    """
    return jnp.take(x_tile, plan.row_perm[ti, tn], axis=-1)
