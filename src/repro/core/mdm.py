"""Manhattan Distance Mapping (MDM) — the paper's core algorithm.

Post-training, semantics-preserving remap of DNN weights onto crossbar
tiles (paper §IV), in three steps:

  1. *Dataflow reversal* — mirror tile columns so the dense low-order bit
     planes sit closest to the input rail.
  2. *Row scoring* — per-row Manhattan exposure score of active cells.
  3. *Row sorting* — permute rows so high-score (dense) rows occupy the
     positions closest to the I/O rails.

The result is an :class:`MdmPlan`: per-tile row permutations plus the
dataflow direction.  The plan is pure bookkeeping — applying it and then
inverting it digitally (input mux per tile) reproduces the original
matmul exactly; only the *physical positions* (and hence the parasitic-
resistance exposure) change.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import manhattan
from repro.core.bitslice import bitslice
from repro.core.tiling import CrossbarSpec, reverse_dataflow, tile_masks

MODES = ("baseline", "reverse", "sort", "mdm")  # mdm = reverse + sort


class MdmPlan(NamedTuple):
    """Deployment plan for one weight matrix.

    row_perm:     (Ti, Tn, rows) int32 — physical row p of tile (ti,tn)
                  hosts original (tile-local) weight row ``row_perm[ti,tn,p]``.
    row_position: (Ti, Tn, rows) int32 — inverse: physical position of
                  tile-local original row q.
    reversed_dataflow: python bool (static).
    nf_before / nf_after: (Ti, Tn) f32 per-tile NF (Manhattan model).
    scale: f32 quantisation scale of the bit-sliced weights.
    """

    row_perm: jax.Array
    row_position: jax.Array
    reversed_dataflow: jax.Array  # bool scalar (pytree leaf; use jnp.where)
    nf_before: jax.Array
    nf_after: jax.Array
    scale: jax.Array

    @property
    def nf_reduction(self) -> jax.Array:
        """Fractional NF reduction, aggregated over all tiles."""
        b, a = jnp.sum(self.nf_before), jnp.sum(self.nf_after)
        return (b - a) / jnp.maximum(b, 1e-30)


def _identity_perms(ti: int, tn: int, rows: int) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(rows, dtype=jnp.int32), (ti, tn, rows))


@partial(jax.jit, static_argnames=("spec", "mode"))
def plan_from_bits(bits: jax.Array, scale: jax.Array, spec: CrossbarSpec,
                   mode: str = "mdm") -> MdmPlan:
    """Build an MDM plan from bit-sliced weights (I, N, K)."""
    if mode not in MODES:
        raise ValueError(f"mode={mode!r} not in {MODES}")
    masks = tile_masks(bits, spec)                       # (Ti, Tn, R, C)
    ti, tn, rows, _ = masks.shape
    nf_before = manhattan.nonideality_factor(masks, spec.r, spec.r_on)

    rev = mode in ("reverse", "mdm")
    placed = reverse_dataflow(masks) if rev else masks

    if mode in ("sort", "mdm"):
        perm = jax.vmap(jax.vmap(manhattan.optimal_row_order))(placed)
        perm = perm.astype(jnp.int32)
        placed = jnp.take_along_axis(placed, perm[..., None], axis=-2)
    else:
        perm = _identity_perms(ti, tn, rows)

    position = jnp.argsort(perm, axis=-1).astype(jnp.int32)
    nf_after = manhattan.nonideality_factor(placed, spec.r, spec.r_on)
    return MdmPlan(perm, position, jnp.asarray(rev), nf_before, nf_after, scale)


def plan_layer(w: jax.Array, spec: CrossbarSpec, mode: str = "mdm") -> MdmPlan:
    """Bit-slice a weight matrix and build its MDM deployment plan."""
    if w.ndim != 2:
        raise ValueError("plan_layer expects a 2-D (in_dim, out_dim) matrix")
    sliced = bitslice(w, spec.n_bits)
    return plan_from_bits(sliced.bits, sliced.scale, spec, mode)


def placed_masks(bits: jax.Array, plan: MdmPlan, spec: CrossbarSpec) -> jax.Array:
    """Physical tile activity masks under a plan (for solver validation)."""
    masks = tile_masks(bits, spec)
    masks = jnp.where(jnp.asarray(plan.reversed_dataflow),
                      reverse_dataflow(masks), masks)
    return jnp.take_along_axis(masks, plan.row_perm[..., None], axis=-2)


def permute_inputs(x_tile: jax.Array, plan: MdmPlan, ti: int, tn: int) -> jax.Array:
    """Digital input mux: reorder the activation slice feeding tile (ti,tn).

    x_tile: (..., rows) activations for the tile's input rows in original
    order; returns them in physical-row order.  Because summation over
    rows is permutation-invariant, the tile's column outputs are unchanged
    — this is the semantics-preservation guarantee of MDM.
    """
    return jnp.take(x_tile, plan.row_perm[ti, tn], axis=-1)
