"""Manhattan Distance Mapping (MDM) — the paper's core algorithm.

Post-training, semantics-preserving remap of DNN weights onto crossbar
tiles (paper §IV), in three steps:

  1. *Dataflow reversal* — mirror tile columns so the dense low-order bit
     planes sit closest to the input rail.
  2. *Row scoring* — per-row Manhattan exposure score of active cells.
  3. *Row sorting* — permute rows so high-score (dense) rows occupy the
     positions closest to the I/O rails.

The result is an :class:`MdmPlan`: per-tile row permutations plus the
dataflow direction.  The plan is pure bookkeeping — applying it and then
inverting it digitally (input mux per tile) reproduces the original
matmul exactly; only the *physical positions* (and hence the parasitic-
resistance exposure) change.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import manhattan
from repro.core.bitslice import bitslice
from repro.core.tiling import CrossbarSpec, reverse_dataflow, tile_masks

MODES = ("baseline", "reverse", "sort", "mdm")  # mdm = reverse + sort


class MdmPlan(NamedTuple):
    """Deployment plan for one weight matrix.

    row_perm:     (Ti, Tn, rows) int32 — physical row p of tile (ti,tn)
                  hosts original (tile-local) weight row ``row_perm[ti,tn,p]``.
    row_position: (Ti, Tn, rows) int32 — inverse: physical position of
                  tile-local original row q.
    reversed_dataflow: python bool (static).
    nf_before / nf_after: (Ti, Tn) f32 per-tile NF (Manhattan model).
    scale: f32 quantisation scale of the bit-sliced weights.
    """

    row_perm: jax.Array
    row_position: jax.Array
    reversed_dataflow: jax.Array  # bool scalar (pytree leaf; use jnp.where)
    nf_before: jax.Array
    nf_after: jax.Array
    scale: jax.Array

    @property
    def nf_reduction(self) -> jax.Array:
        """Fractional NF reduction, aggregated over all tiles."""
        b, a = jnp.sum(self.nf_before), jnp.sum(self.nf_after)
        return (b - a) / jnp.maximum(b, 1e-30)


@partial(jax.jit, static_argnames=("spec", "mode"))
def plan_tile_population(masks: jax.Array, spec: CrossbarSpec,
                         mode: str = "mdm",
                         fault_maps: jax.Array | None = None
                         ) -> tuple[jax.Array, jax.Array,
                                    jax.Array, jax.Array]:
    """Fused planning core over a flat tile population (T, rows, cols).

    Scoring, lexsort and NF bookkeeping are vmapped over the whole
    population in one jit — the tiles may come from one layer's grid or
    from every layer of a model at once (``repro.deploy.planner``
    amortises planning this way, the same trick the batched circuit
    solver uses for its tile populations).

    ``fault_maps`` (optional, (T, rows, cols) int8 physical cell states
    — see ``repro.nonideal.models``) switches the sorting modes to
    fault-aware placement (:func:`repro.core.manhattan
    .fault_aware_row_order`): known stuck cells steer dense rows away
    from fault-heavy physical rows.  The maps live in *physical* tile
    coordinates and are never dataflow-reversed.

    Returns (row_perm, row_position, nf_before, nf_after), each with a
    leading T dim.
    """
    if mode not in MODES:
        raise ValueError(f"mode={mode!r} not in {MODES}")
    T, rows = masks.shape[0], masks.shape[1]
    nf_before = manhattan.nonideality_factor(masks, spec.r, spec.r_on)

    rev = mode in ("reverse", "mdm")
    placed = reverse_dataflow(masks) if rev else masks

    if mode in ("sort", "mdm"):
        if fault_maps is None:
            perm = jax.vmap(manhattan.optimal_row_order)(placed)
        else:
            perm = jax.vmap(manhattan.fault_aware_row_order,
                            in_axes=(0, 0, None))(placed, fault_maps,
                                                  spec.nf_unit)
        perm = perm.astype(jnp.int32)
        placed = jnp.take_along_axis(placed, perm[..., None], axis=-2)
    else:
        perm = jnp.broadcast_to(jnp.arange(rows, dtype=jnp.int32), (T, rows))

    position = jnp.argsort(perm, axis=-1).astype(jnp.int32)
    nf_after = manhattan.nonideality_factor(placed, spec.r, spec.r_on)
    return perm, position, nf_before, nf_after


def plan_from_masks(masks: jax.Array, scale: jax.Array, spec: CrossbarSpec,
                    mode: str = "mdm",
                    fault_maps: jax.Array | None = None) -> MdmPlan:
    """Build an MDM plan from tile activity masks (Ti, Tn, rows, cols).

    The front door for callers that already hold the physical tile
    layout (``deploy()`` computes it once and shares it with
    ``placed_masks``, instead of re-deriving the bit planes twice).
    ``fault_maps`` ((Ti, Tn, rows, cols) int8 physical cell states)
    makes the sorting modes fault-aware.
    """
    if mode not in MODES:
        raise ValueError(f"mode={mode!r} not in {MODES}")
    ti, tn, rows, cols = masks.shape
    flat = masks.reshape(ti * tn, rows, cols)
    if fault_maps is not None:
        fault_maps = fault_maps.reshape(ti * tn, rows, cols)
    perm, position, nf_before, nf_after = plan_tile_population(
        flat, spec, mode, fault_maps)
    rev = mode in ("reverse", "mdm")
    return MdmPlan(perm.reshape(ti, tn, rows),
                   position.reshape(ti, tn, rows),
                   jnp.asarray(rev),
                   nf_before.reshape(ti, tn),
                   nf_after.reshape(ti, tn), scale)


@partial(jax.jit, static_argnames=("spec", "mode"))
def plan_from_bits(bits: jax.Array, scale: jax.Array, spec: CrossbarSpec,
                   mode: str = "mdm",
                   fault_maps: jax.Array | None = None) -> MdmPlan:
    """Build an MDM plan from bit-sliced weights (I, N, K)."""
    return plan_from_masks(tile_masks(bits, spec), scale, spec, mode,
                           fault_maps)


def plan_layer(w: jax.Array, spec: CrossbarSpec, mode: str = "mdm",
               fault_maps: jax.Array | None = None) -> MdmPlan:
    """Bit-slice a weight matrix and build its MDM deployment plan.

    ``fault_maps`` ((Ti, Tn, rows, cols) int8 physical cell states)
    folds known stuck cells into the row sort (fault-aware MDM).
    """
    if w.ndim != 2:
        raise ValueError("plan_layer expects a 2-D (in_dim, out_dim) matrix")
    sliced = bitslice(w, spec.n_bits)
    return plan_from_bits(sliced.bits, sliced.scale, spec, mode, fault_maps)


def placed_masks(bits: jax.Array, plan: MdmPlan, spec: CrossbarSpec,
                 masks: jax.Array | None = None) -> jax.Array:
    """Physical tile activity masks under a plan (for solver validation).

    Pass ``masks`` to reuse an already-derived ``tile_masks(bits, spec)``
    layout instead of recomputing the bit-plane arrangement.
    """
    if masks is None:
        masks = tile_masks(bits, spec)
    masks = jnp.where(jnp.asarray(plan.reversed_dataflow),
                      reverse_dataflow(masks), masks)
    return jnp.take_along_axis(masks, plan.row_perm[..., None], axis=-2)


def permute_inputs(x_tile: jax.Array, plan: MdmPlan, ti: int, tn: int) -> jax.Array:
    """Digital input mux: reorder the activation slice feeding tile (ti,tn).

    x_tile: (..., rows) activations for the tile's input rows in original
    order; returns them in physical-row order.  Because summation over
    rows is permutation-invariant, the tile's column outputs are unchanged
    — this is the semantics-preservation guarantee of MDM.
    """
    return jnp.take(x_tile, plan.row_perm[ti, tn], axis=-1)
