"""The Manhattan Hypothesis: analytical parasitic-resistance NF model.

Paper §III-B (Eq 16):

    NF ~= (r / R_on) * sum_{j,k} delta_{j,k} * (j + k)

where (j, k) are a cell's row/column indices *measured from the I/O rails*
(0 = closest).  Geometry convention used throughout this repo:

  * Activations drive rows from the column-0 side -> a cell's horizontal
    distance from the input rail is its column index in the stored array.
  * Column outputs are sensed at the row-0 side -> vertical distance from
    the output rail is the row index.
  * ``dataflow="reversed"`` mirrors the bit-column order inside every
    weight so the dense low-order planes sit at small column index
    (paper step 1); the physical array is unchanged, only the mapping is.

All functions operate on a *tile*: a 2-D 0/1 activity mask of shape
(rows, cols) = (J, K_total) where K_total = weights_per_row * bits_per_weight.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def distance_grid(rows: int, cols: int, dtype=jnp.float32) -> jax.Array:
    """Manhattan distance d(j,k) = j + k of every cell from the I/O corner."""
    j = jnp.arange(rows, dtype=dtype)[:, None]
    k = jnp.arange(cols, dtype=dtype)[None, :]
    return j + k


def aggregate_distance(active: jax.Array) -> jax.Array:
    """sum_{j,k} delta_{j,k} (j+k) for one tile (or batch of tiles).

    ``active`` has shape (..., J, K); returns shape (...).
    """
    J, K = active.shape[-2], active.shape[-1]
    d = distance_grid(J, K)
    return jnp.sum(active.astype(jnp.float32) * d, axis=(-2, -1))


def nonideality_factor(active: jax.Array, r: float, r_on: float) -> jax.Array:
    """Eq 16: NF of a tile under the Manhattan Hypothesis."""
    return (r / r_on) * aggregate_distance(active)


def row_scores(active: jax.Array) -> jax.Array:
    """Per-row Manhattan exposure score (paper step 2).

    score_j = sum_k delta_{j,k} * (1 + k): each active cell contributes its
    column distance plus one unit of row exposure, so the score rises with
    both row density and low-order concentration.  Shape (..., J).
    """
    K = active.shape[-1]
    col = 1.0 + jnp.arange(K, dtype=jnp.float32)
    return jnp.sum(active.astype(jnp.float32) * col, axis=-1)


def row_counts(active: jax.Array) -> jax.Array:
    """Number of active cells per row, shape (..., J)."""
    return jnp.sum(active.astype(jnp.float32), axis=-1)


def placement_cost(active: jax.Array) -> jax.Array:
    """Total NF-proportional cost of the *current* row placement.

    cost = sum_j j * n_j + sum_j s0_j  with  n_j = row count and
    s0_j = sum_k delta_{j,k} k (placement-independent).  Identical to
    ``aggregate_distance`` but split to expose the permutable term.
    """
    return aggregate_distance(active)


def optimal_row_order(active: jax.Array) -> jax.Array:
    """Row permutation minimising the Manhattan-model NF (paper step 3).

    Under Eq 16 the only placement-dependent term is sum_j pos_j * n_j,
    so by the rearrangement inequality the optimum assigns the densest
    rows the smallest positions: sort by active count, descending.
    Ties are broken by the Manhattan row score (denser-low-order first),
    making the order deterministic.

    Returns ``perm`` such that ``active[perm]`` is the remapped tile.
    Works on a single tile (J, K) only; vmap for batches.
    """
    n = row_counts(active)
    s = row_scores(active)
    # Collision-free composite sort: lexsort's last key is primary, and
    # stability supplies the index tiebreak.  (A packed float key
    # ``n * C + s / (s.max() + 1)`` cannot work for wide tiles: once
    # ``n * C`` outgrows the f32 mantissa the sub-1 score term is
    # rounded away entirely and ties fall back to index order.)
    return jnp.lexsort((-s, -n))


def antidiagonal_mirror(active: jax.Array) -> jax.Array:
    """Reflect a square tile across its main diagonal: (j,k) -> (k,j).

    This reflection maps every anti-diagonal j+k = const onto itself, so two
    configurations related by it have identical aggregate Manhattan distance
    and hence identical NF under Eq 16 — the "anti-diagonal symmetry" of
    Fig 2, corroborated there by SPICE and here by ``repro.crossbar.solver``.
    """
    return jnp.swapaxes(active, -1, -2)
