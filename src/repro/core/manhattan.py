"""The Manhattan Hypothesis: analytical parasitic-resistance NF model.

Paper §III-B (Eq 16):

    NF ~= (r / R_on) * sum_{j,k} delta_{j,k} * (j + k)

where (j, k) are a cell's row/column indices *measured from the I/O rails*
(0 = closest).  Geometry convention used throughout this repo:

  * Activations drive rows from the column-0 side -> a cell's horizontal
    distance from the input rail is its column index in the stored array.
  * Column outputs are sensed at the row-0 side -> vertical distance from
    the output rail is the row index.
  * ``dataflow="reversed"`` mirrors the bit-column order inside every
    weight so the dense low-order planes sit at small column index
    (paper step 1); the physical array is unchanged, only the mapping is.

All functions operate on a *tile*: a 2-D 0/1 activity mask of shape
(rows, cols) = (J, K_total) where K_total = weights_per_row * bits_per_weight.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def distance_grid(rows: int, cols: int, dtype=jnp.float32) -> jax.Array:
    """Manhattan distance d(j,k) = j + k of every cell from the I/O corner."""
    j = jnp.arange(rows, dtype=dtype)[:, None]
    k = jnp.arange(cols, dtype=dtype)[None, :]
    return j + k


def aggregate_distance(active: jax.Array) -> jax.Array:
    """sum_{j,k} delta_{j,k} (j+k) for one tile (or batch of tiles).

    ``active`` has shape (..., J, K); returns shape (...).
    """
    J, K = active.shape[-2], active.shape[-1]
    d = distance_grid(J, K)
    return jnp.sum(active.astype(jnp.float32) * d, axis=(-2, -1))


def nonideality_factor(active: jax.Array, r: float, r_on: float) -> jax.Array:
    """Eq 16: NF of a tile under the Manhattan Hypothesis."""
    return (r / r_on) * aggregate_distance(active)


def row_scores(active: jax.Array) -> jax.Array:
    """Per-row Manhattan exposure score (paper step 2).

    score_j = sum_k delta_{j,k} * (1 + k): each active cell contributes its
    column distance plus one unit of row exposure, so the score rises with
    both row density and low-order concentration.  Shape (..., J).
    """
    K = active.shape[-1]
    col = 1.0 + jnp.arange(K, dtype=jnp.float32)
    return jnp.sum(active.astype(jnp.float32) * col, axis=-1)


def row_counts(active: jax.Array) -> jax.Array:
    """Number of active cells per row, shape (..., J)."""
    return jnp.sum(active.astype(jnp.float32), axis=-1)


def placement_cost(active: jax.Array) -> jax.Array:
    """Total NF-proportional cost of the *current* row placement.

    cost = sum_j j * n_j + sum_j s0_j  with  n_j = row count and
    s0_j = sum_k delta_{j,k} k (placement-independent).  Identical to
    ``aggregate_distance`` but split to expose the permutable term.
    """
    return aggregate_distance(active)


def optimal_row_order(active: jax.Array) -> jax.Array:
    """Row permutation minimising the Manhattan-model NF (paper step 3).

    Under Eq 16 the only placement-dependent term is sum_j pos_j * n_j,
    so by the rearrangement inequality the optimum assigns the densest
    rows the smallest positions: sort by active count, descending.
    Ties are broken by the Manhattan row score (denser-low-order first),
    making the order deterministic.

    Returns ``perm`` such that ``active[perm]`` is the remapped tile.
    Works on a single tile (J, K) only; vmap for batches.
    """
    K = active.shape[-1]
    # Packed single-key sort: both keys are integers on a binary mask
    # (count n <= K, score s <= K(K+1)/2), so ``n * (s_max+1) + s`` is a
    # collision-free int32 composite whenever it fits — one stable
    # argsort instead of lexsort's two.  (A packed *float* key cannot
    # work for wide tiles: once ``n * C`` outgrows the f32 mantissa the
    # score term is rounded away entirely — see the wide-tile regression
    # in tests/test_manhattan.py, which exercises the fallback.)
    s_max = K * (K + 1) // 2
    if (K + 1) * (s_max + 1) - 1 < 2 ** 31:
        a = (active > 0).astype(jnp.int32)
        n = jnp.sum(a, axis=-1)
        s = jnp.sum(a * (1 + jnp.arange(K, dtype=jnp.int32)), axis=-1)
        return jnp.argsort(-(n * (s_max + 1) + s), stable=True)
    n = row_counts(active)
    s = row_scores(active)
    # Wide-tile fallback: lexsort's last key is primary, and stability
    # supplies the index tiebreak.
    return jnp.lexsort((-s, -n))


def optimal_col_order(active: jax.Array) -> jax.Array:
    """Column permutation minimising the Manhattan-model NF.

    The column-placement term of Eq 16, ``sum_c pos_c * m_c`` (``m_c``
    = active cells of column c), is independent of the row term, so the
    rearrangement inequality applies column-wise exactly as it does
    row-wise: sort columns by active count descending (ties by column
    Manhattan score, then index — the transpose of
    :func:`optimal_row_order`, packed key and wide-tile fallback
    included).  Any bitline order preserves the matmul — columns are
    sensed independently and shift-added digitally through the column
    mux — so this is the X-CHANGR-style remapping freedom expressed in
    the Manhattan model.

    Returns ``perm`` such that ``active[:, perm]`` is the remapped
    tile.  Single tile (J, K) only; vmap for batches.
    """
    return optimal_row_order(jnp.swapaxes(active, -1, -2))


def fault_aware_row_order(active: jax.Array, stuck: jax.Array,
                          nf_unit: float | jax.Array,
                          col_weights: jax.Array | None = None,
                          open_penalty: float = 0.0,
                          line_weights: jax.Array | None = None,
                          off_current: float = 0.0) -> jax.Array:
    """Row permutation minimising Manhattan NF *plus* expected fault loss.

    ``active`` is the tile's (J, K) logical row masks in physical column
    layout (i.e. after any dataflow reversal); ``stuck`` is the tile's
    (J, K) *physical* cell-state map (``repro.nonideal.models``: 0 =
    healthy, 1 = stuck-OFF, 2 = stuck-ON) — a property of the hardware,
    fixed in physical coordinates while the mapping chooses which
    logical row lands on which physical row.

    Model: hosting a row with ``n_j`` active cells at physical position
    ``p`` costs an expected per-tile current deficit of

        n_j * [ nf_unit * p  +  (|S_p| - |O_p|) / K ]   (+ row/pos consts)

    where ``|S_p|``/``|O_p|`` count the stuck-OFF/ON cells of physical
    row ``p``: a stuck-OFF cell kills a whole active-cell current (one
    deficit unit, vs ``nf_unit * d`` per parasitic unit) with overlap
    probability ``n_j / K``, while a stuck-ON cell adds spurious current
    only under the row's *inactive* cells, so dense rows neutralise it.
    Both factor as ``n_j * phi_p``, so the rearrangement inequality
    applies to the combined objective exactly as in
    :func:`optimal_row_order`: assign rows by descending density to
    positions by ascending penalty ``phi_p``.  (The expected-overlap
    approximation is what keeps the assignment a product form — exact
    per-row/per-position overlap costs would need a Hungarian solve.)

    ``col_weights`` (optional, (K,) f32) generalises the fault currency
    from "one stuck cell = one unit" to a per-physical-column weight —
    the significance-weighted strategy passes the hosted bit plane's
    shift-add weight 2^-(k+1), so positions whose stuck columns carry
    high-order planes read as more expensive.  ``None`` keeps the exact
    uniform-currency arithmetic (``w_c = 1`` reduces to it
    analytically: ``(sum w off - sum w on) / sum w = (n_off - n_on) /
    K``).

    ``line_weights`` (optional, (J,) f32) weights the *logical* lines
    being placed: line j's placement importance becomes
    ``w_j * (n_j + (K - n_j) * off_current)`` instead of the bare
    active count — its total line current in active-cell units, scaled
    by its significance.  ``off_current`` is the inactive-cell current
    ratio ``g_off / g_on`` (= ``r_on / r_off``): a severed or
    attenuated line loses its *whole* current, off-cells included, so
    with a realistic on/off ratio a nearly-empty high-order bit plane
    is *more* expensive to lose than a dense LSB plane (64 cells at
    2^-8 < 6.4 off-cell units at 2^-1) — exactly the case the bare
    ``w_j * n_j`` ranking gets backwards.  The product form is
    preserved exactly — hosting line j at position p costs
    ``w_j * I_j * phi_p`` with ``I_j`` the line current — so the
    weighted sort is still the optimum of the weighted objective.
    This is how :func:`fault_aware_col_order` folds per-bit-plane
    significance into column steering.  ``None`` keeps the historical
    density ranking (``optimal_row_order``), bit-exactly.

    With no stuck cells ``phi_p`` is strictly increasing in ``p`` and
    the result equals :func:`optimal_row_order` exactly.  Single tile
    only; vmap for batches (``repro.core.mdm.plan_tile_population``).

    Cells on OPEN lines (code 3, line-open faults) conduct nothing and
    count as stuck-OFF in the penalty; ``open_penalty`` adds an extra
    per-open-cell surcharge on top.  A fully-open wordline then carries
    the maximum penalty, so the assignment naturally shunts it the
    sparsest (ideally all-zero *spare*) logical row — the
    ``spare_line`` mapping pass drives this.
    """
    J, K = active.shape[-2], active.shape[-1]
    if line_weights is None:
        row_rank = optimal_row_order(active)
    else:
        # Weighted rank: significance x total line current descending,
        # Manhattan score then index as tiebreaks (float keys force the
        # lexsort path — the packed-int trick of optimal_row_order does
        # not apply).
        a = (active > 0).astype(jnp.float32)
        n = jnp.sum(a, axis=-1)
        s = jnp.sum(a * (1.0 + jnp.arange(K, dtype=jnp.float32)),
                    axis=-1)
        cur = n + (K - n) * jnp.float32(off_current)
        wn = jnp.asarray(line_weights, jnp.float32) * cur
        row_rank = jnp.lexsort((-s, -wn))
    # Codes per repro.nonideal.models: 1 = stuck-OFF, 2 = stuck-ON,
    # 3 = OPEN (dead line — off-like, optionally surcharged).
    off_like = (stuck == 1) | (stuck == 3)
    if col_weights is None:
        n_off = jnp.sum(off_like.astype(jnp.float32), axis=-1)
        n_on = jnp.sum((stuck == 2).astype(jnp.float32), axis=-1)
        pen = (n_off - n_on) / K
    else:
        w = jnp.asarray(col_weights, jnp.float32)
        w_off = jnp.sum(w * off_like.astype(jnp.float32), axis=-1)
        w_on = jnp.sum(w * (stuck == 2).astype(jnp.float32), axis=-1)
        pen = (w_off - w_on) / jnp.maximum(jnp.sum(w), 1e-30)
    if open_penalty:
        pen = pen + (jnp.float32(open_penalty)
                     * jnp.sum((stuck == 3).astype(jnp.float32), axis=-1)
                     / K)
    phi = (jnp.asarray(nf_unit, jnp.float32)
           * jnp.arange(J, dtype=jnp.float32) + pen)
    pos_rank = jnp.argsort(phi, stable=True)
    # perm[p] = logical row hosted at physical position p: the r-th
    # densest row goes to the r-th cheapest position.
    return (jnp.zeros((J,), jnp.int32)
            .at[pos_rank].set(row_rank.astype(jnp.int32)))


def fault_aware_col_order(active: jax.Array, stuck: jax.Array,
                          nf_unit: float | jax.Array,
                          col_weights: jax.Array | None = None,
                          open_penalty: float = 0.0,
                          off_current: float = 0.0) -> jax.Array:
    """Column permutation steering logical columns off faulty bitlines.

    The column twin of :func:`fault_aware_row_order` (the transpose
    argument — column placement cost factors as ``m_c * phi_p`` exactly
    like the row term): logical columns ranked by descending active
    count are assigned to physical bitlines ranked by ascending
    parasitic+fault penalty, so an OPEN bitline ends up hosting the
    sparsest (ideally spare all-zero) logical column instead of a dense
    low-order bit plane.  Any bitline order preserves the matmul —
    columns are sensed independently (the X-CHANGR freedom).

    ``col_weights`` (optional, (K,) f32) is the *logical* columns' bit
    significance (2^-(k+1) of the plane each dataflow-layout column
    hosts): the ranking becomes significance-weighted — each column
    ranked by significance x total column current, with ``off_current``
    (the ``g_off / g_on`` ratio) pricing in the inactive cells a
    severed bitline also silences — so the steering protects the
    columns whose loss costs the most shift-added output error.  A
    sparse MSB plane outranks a dense LSB plane once its off-current
    floor is priced; the cheap sacrifice for a dead bitline is the
    *lowest-significance* plane, not merely the emptiest one.  ``None``
    keeps the historical density-only ranking bit-exactly.

    Returns ``perm`` such that ``active[:, perm]`` is the remapped
    tile.  Single tile only; vmap for batches.
    """
    return fault_aware_row_order(jnp.swapaxes(active, -1, -2),
                                 jnp.swapaxes(stuck, -1, -2),
                                 nf_unit, open_penalty=open_penalty,
                                 line_weights=col_weights,
                                 off_current=off_current)


def antidiagonal_mirror(active: jax.Array) -> jax.Array:
    """Reflect a square tile across its main diagonal: (j,k) -> (k,j).

    This reflection maps every anti-diagonal j+k = const onto itself, so two
    configurations related by it have identical aggregate Manhattan distance
    and hence identical NF under Eq 16 — the "anti-diagonal symmetry" of
    Fig 2, corroborated there by SPICE and here by ``repro.crossbar.solver``.
    """
    return jnp.swapaxes(active, -1, -2)
