"""MDM core: bit-sliced crossbar mapping, Manhattan NF model, PR noise."""
from repro.core.bitslice import SlicedWeights, bitslice, unbitslice  # noqa: F401
from repro.core.manhattan import (  # noqa: F401
    aggregate_distance,
    antidiagonal_mirror,
    distance_grid,
    nonideality_factor,
    optimal_row_order,
    row_counts,
    row_scores,
)
from repro.core.mdm import (  # noqa: F401
    MODES,
    MdmPlan,
    plan_from_bits,
    plan_from_masks,
    plan_layer,
    plan_tile_population,
)
from repro.core.noise import PAPER_ETA, noisy_weights, tree_noisy_weights  # noqa: F401
from repro.core.tiling import CrossbarSpec, tile_masks  # noqa: F401
