"""Monte-Carlo NF / degradation engine over fault+variation ensembles.

The circuit-calibrated point estimates of :mod:`repro.crossbar` answer
"what does *this* crossbar do"; under stochastic device nonidealities
the quantity of interest is a **distribution** over fault/variation
realisations.  This engine produces it without ever looping over
samples in Python:

1. ``n_samples`` :class:`repro.nonideal.models.CellSample` draws are
   taken by ``jax.vmap`` over split PRNG keys — one fused sampling
   program for the whole ``(S, T, rows, cols)`` ensemble;
2. the perturbed conductance fields are folded into the solver's tile
   axis (``(S, T) -> S*T``): the batched/sharded PCG engine is already
   embarrassingly parallel over tiles, so the sample axis rides the
   same fused loop (``repro.crossbar.batched
   .measured_nf_conductances``) or the same device mesh
   (``repro.distributed.solver_shard
   .measured_nf_conductances_sharded``) — the solver *is* the vmap;
3. per-sample NF and significance-weighted degradation come back with
   the ``(S, ...)`` axes restored; :func:`summarize` reduces them to
   mean/std/p95.

:func:`mc_nf_oracle` is the small-case parity reference: the identical
per-sample computation as an explicit Python loop over single-sample
solves (pinned in ``tests/test_nonideal.py``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as tm
from repro.core.tiling import CrossbarSpec
from repro.crossbar.batched import (
    measured_nf_conductances,
    measured_nf_conductances_checked,
)
from repro.nonideal.models import (
    NonidealModel,
    apply_to_conductances,
    conductances_from_masks,
    sample_cell_state,
)


_H_MC_SWEEP = tm.histogram(
    "repro_mc_sweep_seconds", "Wall time of one mc_nf ensemble solve.")
_C_MC_SAMPLES = tm.counter(
    "repro_mc_samples_total", "Monte-Carlo samples solved (S x tiles).")
_C_MC_UNCONV = tm.counter(
    "repro_mc_unconverged_total",
    "Ensemble tiles unconverged after escalation.")
_G_MC_NF_MEAN = tm.gauge(
    "repro_mc_nf_mean", "Mean NF of the most recent mc_nf sweep.")
_G_MC_NF_P95 = tm.gauge(
    "repro_mc_nf_p95", "95th-percentile NF of the most recent sweep.")


class McNfResult(NamedTuple):
    """Per-sample, per-tile Monte-Carlo solve results.

    nf_total:     (S, ...) aggregate |sum di| / sum i0 per tile.
    weighted_err: (S, ...) column-weighted relative error
                  ``sum_c w_c |di_c| / sum_c w_c i0_c`` — with uniform
                  weights a cancellation-free NF, with bit-significance
                  weights the accuracy-degradation proxy (what the
                  digital shift-add actually accumulates).
    residual:     (S, ...) final relative CG residual per tile.
    iterations:   () shared iteration count of the fused loop.
    unconverged:  () tiles that missed tol or produced non-finite
                  output (NaN/Inf-aware — a diverged circuit counts as
                  unconverged, never as a silent zero).
    report:       the solver watchdog's :class:`repro.crossbar.batched
                  .SolverReport` (converged mask, escalations), or None
                  for the oracle path.
    """

    nf_total: jax.Array
    weighted_err: jax.Array
    residual: jax.Array
    iterations: jax.Array
    unconverged: jax.Array
    report: object = None


def summarize(x) -> dict:
    """Distribution summary the benchmarks record: mean / std / p95
    over the whole (samples x tiles) ensemble."""
    x = np.asarray(x, np.float64)
    return {
        "mean": float(np.mean(x)),
        "std": float(np.std(x)),
        "p95": float(np.percentile(x, 95.0)),
    }


def _weighted_err(currents, ideal, col_weights):
    """Column-weighted error; ``col_weights`` may be one global
    ``(cols,)`` vector or per-tile ``(..., cols)`` weights (the
    ``physical_column_significance`` grid of a column-permuted plan —
    it broadcasts against the ``(S, ..., cols)`` currents)."""
    di = jnp.abs(currents - ideal)
    if col_weights is not None:
        w = jnp.asarray(col_weights, di.dtype)
        di = di * w
        ideal = ideal * w
    return jnp.sum(di, axis=-1) / jnp.maximum(
        jnp.sum(ideal, axis=-1), 1e-30)


def mc_samples(key: jax.Array, masks: jax.Array, spec: CrossbarSpec,
               model: NonidealModel, n_samples: int,
               stuck: jax.Array | None = None):
    """(perturbed g (S, ..., J, K), clean g (..., J, K)) for ``masks``.

    One vmapped sampling program over the split per-sample keys — the
    per-sample draws are bit-identical to calling
    :func:`repro.nonideal.models.sample_cell_state` with each key in a
    loop (the oracle does exactly that).  ``stuck`` pins a known
    physical fault map shared by every sample (the fault-aware-mapping
    scenario); variation and read noise stay per-sample.
    """
    keys = jax.random.split(key, n_samples)
    g_clean = conductances_from_masks(masks, spec)
    samples = jax.vmap(
        lambda k: sample_cell_state(k, masks.shape, model, stuck))(keys)
    return apply_to_conductances(masks, samples, spec, model), g_clean


def mc_nf(masks: jax.Array, spec: CrossbarSpec, model: NonidealModel,
          n_samples: int, key: jax.Array, *,
          stuck: jax.Array | None = None,
          precision="mixed",
          ctx=None,
          col_weights: jax.Array | None = None,
          maxiter: int = 4000,
          chain_impl: str = "lax") -> McNfResult:
    """NF / degradation distribution of a tile population under ``model``.

    ``masks``: (..., J, K) clean activity masks with arbitrary leading
    tile dims.  Fully vectorised: sampling is one vmap, and the
    ``(n_samples, T)`` ensemble is folded into the solver's tile axis —
    one fused PCG call on a single device, or one sharded call over the
    logical "tiles" mesh when ``ctx`` is given (each device then solves
    its slice of the sample x tile ensemble).  Returns per-sample
    per-tile distributions; reduce with :func:`summarize`.

    ``col_weights`` may be global ``(cols,)`` or per-tile ``(...,
    cols)`` matching the mask batch dims (required for correctness
    under column-permuted pipelines, where bit significance varies per
    tile).  Every solve runs under the convergence watchdog: failed
    tiles are escalated (f64 / Jacobi reruns) and the surviving
    failures are reported in ``unconverged`` / ``report`` — a
    non-converged circuit never masquerades as a good NF number.
    """
    t0 = tm.monotonic()
    with tm.span("nonideal/mc_nf", samples=n_samples):
        batch_shape = masks.shape[:-2]
        flat = masks.reshape((-1,) + masks.shape[-2:])
        if stuck is not None:
            stuck = jnp.asarray(stuck, jnp.int8).reshape(flat.shape)
        if col_weights is not None:
            col_weights = jnp.asarray(col_weights)
            if col_weights.ndim > 1:
                col_weights = col_weights.reshape(
                    (-1, col_weights.shape[-1]))
        g, g_ref = mc_samples(key, flat, spec, model, n_samples, stuck)

        if ctx is not None:
            from repro.distributed.solver_shard import (
                measured_nf_conductances_sharded_checked,
            )
            res, report = measured_nf_conductances_sharded_checked(
                g, spec, g_ref=g_ref, maxiter=maxiter,
                precision=precision, ctx=ctx, chain_impl=chain_impl)
            unconverged = res.unconverged
        else:
            res, report = measured_nf_conductances_checked(
                g, spec, g_ref=g_ref, maxiter=maxiter,
                precision=precision, chain_impl=chain_impl)
            unconverged = report.n_failed.astype(jnp.int32)

        werr = _weighted_err(res.currents, res.ideal, col_weights)
        shape = (n_samples,) + batch_shape
        out = McNfResult(res.nf_total.reshape(shape),
                         werr.reshape(shape), res.residual.reshape(shape),
                         res.iterations, unconverged, report)
        if tm.enabled():
            # np.asarray blocks on the device values — telemetry-only
            # syncs; the computed numbers are untouched.
            nf = np.asarray(out.nf_total, np.float64)
            _C_MC_SAMPLES.inc(nf.size)
            _C_MC_UNCONV.inc(int(unconverged))
            _G_MC_NF_MEAN.set(float(nf.mean()))
            _G_MC_NF_P95.set(float(np.percentile(nf, 95.0)))
    _H_MC_SWEEP.observe(tm.monotonic() - t0)
    return out


def mc_nf_oracle(masks: jax.Array, spec: CrossbarSpec,
                 model: NonidealModel, n_samples: int, key: jax.Array, *,
                 stuck: jax.Array | None = None,
                 precision="mixed",
                 col_weights: jax.Array | None = None,
                 maxiter: int = 4000) -> McNfResult:
    """Per-sample reference: identical math as an explicit Python loop.

    Small cases only — this pays one solver dispatch per sample, which
    is exactly the cost structure :func:`mc_nf` exists to remove.  The
    engine must match it bit-for-bit on the sampled conductances and to
    solver tolerance on the currents (``tests/test_nonideal.py``).
    """
    batch_shape = masks.shape[:-2]
    flat = masks.reshape((-1,) + masks.shape[-2:])
    if stuck is not None:
        stuck = jnp.asarray(stuck, jnp.int8).reshape(flat.shape)
    keys = jax.random.split(key, n_samples)
    g_clean = conductances_from_masks(flat, spec)
    nf, werr, resid = [], [], []
    iters = 0
    for s in range(n_samples):
        sample = sample_cell_state(keys[s], flat.shape, model, stuck)
        g = apply_to_conductances(flat, sample, spec, model)
        res = measured_nf_conductances(g, spec, g_ref=g_clean,
                                       maxiter=maxiter,
                                       precision=precision)
        nf.append(np.asarray(res.nf_total))
        werr.append(np.asarray(
            _weighted_err(res.currents, res.ideal, col_weights)))
        resid.append(np.asarray(res.residual))
        iters = max(iters, int(res.iterations))
    # Host-side stacking: jnp.stack would canonicalise the f64 solver
    # outputs back to f32 outside the enable_x64 scope.
    shape = (n_samples,) + batch_shape
    resid = np.stack(resid).reshape(shape)
    # ~(resid <= tol) instead of (resid > tol): NaN residuals must
    # count as unconverged, not slip through a False comparison.
    return McNfResult(np.stack(nf).reshape(shape),
                      np.stack(werr).reshape(shape), resid,
                      np.int64(iters), int((~(resid <= 1e-12)).sum()))
