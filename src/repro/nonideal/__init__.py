"""Device-nonideality subsystem: fault/variation models, Monte-Carlo
NF engine, and deployment-level fault injection.

The paper's pitch is parasitic-resistance resilience; real crossbars
additionally suffer stuck-at faults, line-open (wordline/bitline)
structural failures, programming variation — i.i.d. and spatially
correlated — read noise and conductance drift (Bhattacharjee et al.;
PRUNIX).  This package makes those scenarios first-class across every
layer of the simulator (taxonomy and degradation semantics in
``docs/nonideal.md``):

==========================  ============================================
layer                       entry points
==========================  ============================================
device models               :mod:`repro.nonideal.models` —
                            :class:`NonidealModel`, PRNG-keyed
                            :func:`sample_cell_state`, conductance /
                            cell-value application
Monte-Carlo engine          :mod:`repro.nonideal.montecarlo` —
                            :func:`mc_nf` folds an ``(S, T)`` sample x
                            tile ensemble into the batched/sharded PCG
                            solver's tile axis (no Python loop over
                            samples); :func:`mc_nf_oracle` is the
                            per-sample parity reference
effective-weight evaluator  :mod:`repro.nonideal.weights` — Eq 17
                            generalised to analog cell values, gathered
                            physical -> logical through the plan
deployment injection        :mod:`repro.nonideal.inject` — stuck bits
                            fold *exactly* into the int16 deployment
                            codes, variation/drift into a per-weight
                            gain, so ``cim_mvm`` serves under injected
                            faults unchanged
fault-aware planning        :func:`repro.core.manhattan
                            .fault_aware_row_order` via the
                            ``fault_maps`` argument of
                            ``repro.core.mdm`` / ``repro.deploy``
==========================  ============================================

**Composition contract.**  A :class:`NonidealModel` is a frozen record
of independent terms; every term defaults to "off" and any subset
composes.  Application order is fixed by the physics and identical in
all three consumers (conductances, cell values, deployment codes):
drift scales the programmed ON-state, log-normal variation spreads it
(the i.i.d. and spatially-correlated terms multiply — two independent
Gaussian terms of ``ln g``), stuck-at faults override everything (a
pinned device never saw the programming pulse, so it carries no
variation or drift), read noise perturbs the read-back value last, and
line-open faults sever their cells entirely (zero conduction — they
override even stuck-at states and read noise on the same line).  Fault
maps always live in **physical** tile coordinates ``(Ti, Tn, rows,
cols)`` — defects belong to the hardware — and are mapped into logical
weight-bit layout only through a deployment plan (row permutation +
dataflow direction).

**PRNG-key discipline.**  Every sampler takes an explicit key and
derives one sub-key per term with fixed ``jax.random.fold_in`` tags
(stuck = 0, programming = 1, read = 2, line opens = 3, correlated
variation = 4).  Consequences callers may rely
on: (a) enabling or disabling one term never reshuffles another term's
draws under the same key; (b) the Monte-Carlo engine's per-sample keys
are ``jax.random.split(key, n_samples)``, so sample ``s`` of a vmapped
ensemble is bit-identical to a standalone call with ``keys[s]`` (this
is what the oracle parity test pins); (c) whole-checkpoint deployment
sampling draws one fused population keyed by a single model-level key —
per-matrix maps are slices in traversal order, deterministic given
(key, checkpoint structure, model).  Never reuse a key across terms or
samples; derive, don't recycle.
"""
from repro.nonideal.models import (
    HEALTHY,
    OPEN,
    STUCK_OFF,
    STUCK_ON,
    CellSample,
    NonidealModel,
    apply_to_conductances,
    cell_values,
    conductances_from_masks,
    sample_cell_state,
    sample_corr_field,
    sample_line_open,
    sample_stuck,
)
from repro.nonideal.montecarlo import (
    McNfResult,
    mc_nf,
    mc_nf_oracle,
    mc_samples,
    summarize,
)
from repro.nonideal.weights import (
    gather_physical,
    nonideal_magnitude,
    nonideal_weights,
)

__all__ = [
    "HEALTHY", "OPEN", "STUCK_OFF", "STUCK_ON",
    "CellSample", "NonidealModel",
    "apply_to_conductances", "cell_values", "conductances_from_masks",
    "sample_cell_state", "sample_corr_field", "sample_line_open",
    "sample_stuck",
    "McNfResult", "mc_nf", "mc_nf_oracle", "mc_samples", "summarize",
    "gather_physical", "nonideal_magnitude", "nonideal_weights",
]
