"""Host-side fault/variation injection into CIM deployments.

``repro.deploy`` packages whole checkpoints on the host (numpy) to keep
deployment free of per-matrix device dispatches; this module injects
device nonidealities at the same level so serving under faults costs
nothing extra at generation time:

* **stuck-at faults fold into the int16 codes exactly** — a stuck cell
  pins one bit of one weight's magnitude, so ``(code | on) & ~off`` is
  a bit-exact model and the perturbed deployment flows through the
  *unchanged* backend-dispatched ``cim_mvm`` (Pallas / XLA /
  interpret);
* **programming variation / drift fold into a per-weight gain**:
  ``gain = M0' / M0`` (the perturbed over clean magnitude moment) is
  exact for the dominant clean-magnitude term of Eq 17 and carries an
  O(eta * sigma) approximation on the parasitic column-moment term —
  the :mod:`repro.nonideal.weights` evaluator is the exact reference.
  The gain rides the deployment as an optional (I_pad, N_pad) field
  consumed by the fused XLA kernel
  (:mod:`repro.kernels.cim_mvm.xla`);
* **read noise has no deployment-level analogue** (it is per-read) —
  the Monte-Carlo engine samples it per cell; the serving path draws a
  fresh weight-level aggregate per forward call through ``cim_mvm``'s
  ``read_key`` hook (``CimDeployment.sigma_read`` / ``noise_tag``);
* **line opens can exhaust the mapping's spare capacity** — when
  programmed active bits survive on OPEN cells after the remap
  (:func:`open_bit_overlap_host`), the deployment is marked
  ``degraded`` and the model layer demotes it to the digital fallback.

All functions mirror :func:`repro.nonideal.weights.gather_physical` in
numpy: nonideality fields live in physical tile coordinates and are
pulled into logical weight-bit layout through the deployment plan.
"""
from __future__ import annotations

from typing import Mapping, NamedTuple

import jax
import numpy as np

from repro.core.tiling import CrossbarSpec
from repro.nonideal.models import (
    HEALTHY,
    OPEN,
    STUCK_OFF,
    STUCK_ON,
    NonidealModel,
    sample_cell_state,
)


class HostCells(NamedTuple):
    """One matrix's sampled physical cell state, host-resident.

    stuck: (Ti, Tn, rows, cols) int8 cell codes, or None (no faults).
    gamma: (Ti, Tn, rows, cols) f32 programming gains, or None.
    relax: (Ti, Tn, rows, cols) f32 unit-normal relaxation draws, or
           None — the fixed per-cell draw the ``relax_sigma_at(age)``
           envelope scales as the deployment ages.
    """

    stuck: np.ndarray | None
    gamma: np.ndarray | None
    relax: np.ndarray | None = None


def sample_deployment_cells(key: jax.Array,
                            grids: Mapping[str, tuple[int, int]],
                            spec: CrossbarSpec,
                            model: NonidealModel
                            ) -> dict[str, HostCells]:
    """Sample the physical cell state of a whole checkpoint at once.

    One fused draw over the concatenated ``(sum Ti*Tn, rows, cols)``
    tile population (the deployment engine's amortisation pattern),
    sliced back per matrix in ``grids``'s iteration order — so the
    fault map of each matrix is a deterministic function of (key,
    traversal order, model).
    """
    total = sum(ti * tn for ti, tn in grids.values())
    sample = sample_cell_state(key, (total, spec.rows, spec.cols), model)
    has_faults = (model.p_stuck_off > 0.0 or model.p_stuck_on > 0.0
                  or model.has_line_opens)
    has_gain = (model.sigma_program > 0.0 or model.drift_factor != 1.0
                or model.sigma_corr > 0.0 or model.has_aging)
    has_relax = model.sigma_relax > 0.0
    stuck = np.asarray(sample.stuck) if has_faults else None
    gamma = np.asarray(sample.gamma) if has_gain else None
    relax = np.asarray(sample.relax) if has_relax else None
    out: dict[str, HostCells] = {}
    off = 0
    for name, (ti, tn) in grids.items():
        nt = ti * tn
        shape = (ti, tn, spec.rows, spec.cols)
        out[name] = HostCells(
            stuck[off:off + nt].reshape(shape) if has_faults else None,
            gamma[off:off + nt].reshape(shape) if has_gain else None,
            relax[off:off + nt].reshape(shape) if has_relax else None)
        off += nt
    return out


def gather_physical_host(field: np.ndarray, row_position: np.ndarray,
                         reversed_df: bool, spec: CrossbarSpec,
                         col_position: np.ndarray | None = None
                         ) -> np.ndarray:
    """Numpy mirror of :func:`repro.nonideal.weights.gather_physical`
    over the full padded (I_pad, N_pad, K) logical layout.

    ``col_position`` ((Ti, Tn, cols) int32, optional) remaps dataflow
    columns through a per-tile bitline permutation (column-permuting
    mapping pipelines)."""
    ti_n, tn_n = field.shape[0], field.shape[1]
    rows, wpt, K = spec.rows, spec.weights_per_tile, spec.n_bits
    i_pad, n_pad = ti_n * rows, tn_n * wpt
    ti = np.arange(i_pad) // rows
    q = np.arange(i_pad) % rows
    tn = np.arange(n_pad) // wpt
    slot = np.arange(n_pad) % wpt
    p = np.asarray(row_position)[ti, :, q][:, tn]             # (I, N)
    col = slot[:, None] * K + np.arange(K)[None, :]           # (N, K)
    if reversed_df:
        col = (spec.cols - 1) - col
    if col_position is None:
        return field[ti[:, None, None], tn[None, :, None],
                     p[:, :, None], col[None, :, :]]          # (I, N, K)
    colp = np.asarray(col_position)[ti[:, None, None],
                                    tn[None, :, None],
                                    col[None, :, :]]          # (I, N, K)
    return field[ti[:, None, None], tn[None, :, None],
                 p[:, :, None], colp]


def perturb_codes_host(codes: np.ndarray, stuck_log: np.ndarray,
                       n_bits: int) -> np.ndarray:
    """Apply stuck bits to (I_pad, N_pad) uint32 magnitude codes.

    ``stuck_log``: (I_pad, N_pad, K) logical-layout cell codes.  Bit
    plane k is code bit ``n_bits - 1 - k`` (high-order first) — exact:
    a stuck-ON cell reads as a programmed 1, a stuck-OFF cell as a 0,
    and a cell on an OPEN line contributes nothing (reads as 0 too).
    """
    shifts = np.uint32(n_bits - 1) - np.arange(n_bits, dtype=np.uint32)
    on = np.bitwise_or.reduce(
        (stuck_log == STUCK_ON).astype(np.uint32) << shifts, axis=-1)
    off = np.bitwise_or.reduce(
        ((stuck_log == STUCK_OFF) | (stuck_log == OPEN)
         ).astype(np.uint32) << shifts, axis=-1)
    return (codes | on) & ~off


def open_bit_overlap_host(codes: np.ndarray, stuck_log: np.ndarray,
                          n_bits: int) -> int:
    """Programmed active bits landing on OPEN (line-open) cells.

    Counts, over the logical layout, magnitude bits that are 1 *and*
    sit on a severed line — the current the crossbar physically cannot
    deliver.  Zero means the mapping (e.g. the ``spare_line`` pipeline)
    absorbed every open line with spare/zero rows and columns; a
    positive count means spares ran out and the deployment engine
    demotes the matrix to the digital fallback (``CimDeployment
    .degraded``).  Evaluate *before* :func:`perturb_codes_host`, which
    clears exactly these bits.
    """
    shifts = np.uint32(n_bits - 1) - np.arange(n_bits, dtype=np.uint32)
    bits = ((codes[..., None] >> shifts) & 1).astype(bool)
    return int((bits & (stuck_log == OPEN)).sum())


def variation_gain_host(codes: np.ndarray, stuck_log: np.ndarray | None,
                        gamma_log: np.ndarray, n_bits: int,
                        drift_factor: float = 1.0) -> np.ndarray:
    """Per-weight gain folding programming variation + drift into W'.

    ``gain = M0' / M0`` with ``M0' = sum_k gamma_eff_k b_k 2^-(k+1)``
    over the (already stuck-perturbed) bits; stuck cells carry gain 1 —
    a pinned device never saw the programming pulse.  Exact for the
    clean-magnitude term of Eq 17; the O(eta) column-moment term reuses
    the same gain (documented approximation, reference evaluator in
    :mod:`repro.nonideal.weights`).
    """
    shifts = np.uint32(n_bits - 1) - np.arange(n_bits, dtype=np.uint32)
    bits = ((codes[..., None] >> shifts) & 1).astype(np.float32)
    bw = (2.0 ** -(1.0 + np.arange(n_bits))).astype(np.float32)
    g_eff = np.asarray(gamma_log, np.float32) * np.float32(drift_factor)
    if stuck_log is not None:
        g_eff = np.where(stuck_log != HEALTHY, np.float32(1.0), g_eff)
    m0 = (bits * bw).sum(-1)
    m0p = (bits * g_eff * bw).sum(-1)
    return np.where(m0 > 0, m0p / np.maximum(m0, 1e-30),
                    np.float32(1.0)).astype(np.float32)


def aged_gain_host(codes: np.ndarray, stuck_log: np.ndarray | None,
                   gamma_log: np.ndarray | None,
                   relax_log: np.ndarray | None, n_bits: int,
                   model: NonidealModel, age: float) -> np.ndarray:
    """Per-weight gain of a deployment evaluated at runtime ``age``.

    Re-derives :func:`variation_gain_host` with the time-dependent
    terms moved onto the age clock: power-law drift becomes
    ``drift_factor_at(age)`` and the stochastic relaxation draw is
    scaled by its deterministic ``relax_sigma_at(age)`` envelope before
    folding into the per-cell gamma.  Because the relaxation draw is
    fixed per cell, calling this twice with a larger ``age`` widens the
    same trajectory — it never reshuffles which cells drifted — which
    is exactly the fold_in-tag composition contract extended in time.
    """
    g = (np.ones(codes.shape + (n_bits,), np.float32)
         if gamma_log is None else np.asarray(gamma_log, np.float32))
    s_relax = model.relax_sigma_at(age)
    if relax_log is not None and s_relax > 0.0:
        g = g * np.exp(np.float32(s_relax)
                       * np.asarray(relax_log, np.float32))
    return variation_gain_host(codes, stuck_log, g, n_bits,
                               model.drift_factor_at(age))
