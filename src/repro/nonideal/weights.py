"""Eq-17 effective weights under device nonidealities.

Generalises :func:`repro.core.noise.noisy_magnitude` from binary bits to
*analog cell values* (:func:`repro.nonideal.models.cell_values`): a
stuck or variation-afflicted cell contributes ``c_k`` instead of
``b_k`` to the shift-add,

    |w'| = scale * sum_k c_k 2^{-(k+1)} [1 + eta * (p + col_k)]
         = scale * [(1 + eta p) M0' + eta M1'],

so any model can be evaluated "as if" it ran on a faulty,
variation-spread crossbar by swapping W -> nonideal_weights(...).

Coordinate contract: the nonideality fields (``stuck``, ``gamma``) live
in **physical** tile coordinates ``(Ti, Tn, rows, cols)`` — defects are
a property of the hardware — and are gathered into logical weight-bit
layout *through the deployment plan* (row permutation + dataflow
direction).  This is what makes the evaluator sensitive to the mapping:
fault-aware MDM steers dense rows away from stuck-OFF-heavy physical
rows, and the same fault field then intersects fewer programmed bits.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bitslice import bitslice
from repro.core.mdm import MdmPlan, plan_from_bits
from repro.core.noise import PAPER_ETA, _bit_weights
from repro.core.tiling import CrossbarSpec
from repro.nonideal.models import NonidealModel, cell_values


def gather_physical(field: jax.Array, plan: MdmPlan,
                    spec: CrossbarSpec, I: int, N: int) -> jax.Array:
    """Gather a physical (Ti, Tn, rows, cols) cell field into logical
    (I, N, K) weight-bit layout under ``plan``.

    Logical bit (i, n, k) sits at physical row
    ``plan.row_position[i // rows, n // wpt, i % rows]`` and physical
    column ``slot * K + k`` (mirrored when the dataflow is reversed,
    then remapped through ``plan.col_position`` when the plan carries a
    bitline permutation).
    """
    rows, wpt, K = spec.rows, spec.weights_per_tile, spec.n_bits
    ti = jnp.arange(I) // rows
    q = jnp.arange(I) % rows
    tn = jnp.arange(N) // wpt
    slot = jnp.arange(N) % wpt
    p = plan.row_position[ti, :, q][:, tn]                    # (I, N)
    col = slot[:, None] * K + jnp.arange(K)[None, :]          # (N, K)
    col = jnp.where(jnp.asarray(plan.reversed_dataflow),
                    (spec.cols - 1) - col, col)
    if plan.col_position is None:
        return field[ti[:, None, None], tn[None, :, None],
                     p[:, :, None], col[None, :, :]]          # (I, N, K)
    colp = plan.col_position[ti[:, None, None], tn[None, :, None],
                             col[None, :, :]]                 # (I, N, K)
    return field[ti[:, None, None], tn[None, :, None],
                 p[:, :, None], colp]


@partial(jax.jit, static_argnames=("spec", "model"))
def nonideal_magnitude(bits: jax.Array, scale: jax.Array, plan: MdmPlan,
                       spec: CrossbarSpec, eta: float | jax.Array,
                       stuck: jax.Array | None = None,
                       gamma: jax.Array | None = None,
                       model: NonidealModel | None = None) -> jax.Array:
    """Effective |W'| (I, N) under PR distortion *and* cell nonidealities.

    ``stuck`` / ``gamma`` are physical (Ti, Tn, rows, cols) fields (or
    None for the ideal term); with both None this reduces exactly to
    :func:`repro.core.noise.noisy_magnitude`.
    """
    I, N, K = bits.shape
    rows, wpt = spec.rows, spec.weights_per_tile

    stuck_log = (jnp.zeros((1, 1, 1), jnp.int8) if stuck is None
                 else gather_physical(stuck, plan, spec, I, N))
    gamma_log = (jnp.ones((1, 1, 1), jnp.float32) if gamma is None
                 else gather_physical(gamma, plan, spec, I, N))
    c = cell_values(bits, stuck_log, gamma_log, model)        # (I, N, K)

    bw = _bit_weights(K)
    slot = jnp.arange(N) % wpt
    col = slot[:, None] * K + jnp.arange(K)[None, :]
    col = jnp.where(jnp.asarray(plan.reversed_dataflow),
                    (spec.cols - 1) - col, col)

    ti = jnp.arange(I) // rows
    q = jnp.arange(I) % rows
    tn = jnp.arange(N) // wpt
    p = plan.row_position[ti, :, q][:, tn].astype(jnp.float32)

    m0 = jnp.einsum("ink,k->in", c, bw)
    if plan.col_position is None:
        m1 = jnp.einsum("ink,nk->in", c, bw * col.astype(jnp.float32))
    else:
        colp = plan.col_position[ti[:, None, None], tn[None, :, None],
                                 col[None, :, :]].astype(jnp.float32)
        m1 = jnp.einsum("ink,ink->in", c, bw * colp)
    return scale * ((1.0 + eta * p) * m0 + eta * m1)


def nonideal_weights(w: jax.Array, spec: CrossbarSpec, mode="mdm",
                     eta: float | jax.Array = PAPER_ETA,
                     stuck: jax.Array | None = None,
                     gamma: jax.Array | None = None,
                     model: NonidealModel | None = None,
                     plan: MdmPlan | None = None,
                     fault_aware: bool = False
                     ) -> tuple[jax.Array, MdmPlan]:
    """End-to-end: bit-slice, plan, distort under faults + variation.

    Returns (W', plan).  ``fault_aware=True`` folds the known ``stuck``
    map into the planning itself (:func:`repro.core.manhattan
    .fault_aware_row_order`); otherwise the plan ignores it and only the
    evaluation sees the faults — the {MDM, fault-aware MDM} comparison
    of ``benchmarks/fault_tolerance.py``.
    """
    sliced = bitslice(w, spec.n_bits)
    if plan is None:
        plan = plan_from_bits(sliced.bits, sliced.scale, spec, mode,
                              stuck if fault_aware else None)
    mag = nonideal_magnitude(sliced.bits, sliced.scale, plan, spec, eta,
                             stuck, gamma, model)
    return mag * sliced.sign.astype(jnp.float32), plan
