"""Composable device-nonideality models for memristive bit cells.

Every nonideality the subsystem knows about is a *perturbation of the
per-cell conductance field* of a deployed tile population — the
representation shared by the circuit solver (conductances in Siemens),
the Eq-17 effective-weight evaluator (normalised cell values) and the
deployment code injector:

* **stuck-at faults** — a cell is pinned to the ON (LRS) or OFF (HRS)
  conductance regardless of the programmed bit (Bhattacharjee et al.:
  the dominant accuracy killer for sparse mappings);
* **programming variation** — log-normal multiplicative spread of the
  programmed conductance, ``g -> g * exp(sigma_program * N(0, 1))``;
* **read noise** — zero-mean additive conductance noise per read,
  ``g -> g + sigma_read * g_on * N(0, 1)``;
* **conductance drift** — deterministic power-law decay of the ON-state
  conductance, ``g_on -> g_on * drift_time ** -drift_nu``.  At serving
  time the exponent is evaluated against a *runtime age clock* instead
  of the static ``drift_time`` (``drift_factor_at``) — the lifetime
  machinery in :mod:`repro.health` advances the clock as the engine
  serves;
* **stochastic relaxation** — a per-cell random walk of ln g whose
  spread grows as ``sigma_relax * sqrt(ln t)`` (log-time diffusion, the
  empirical retention-loss envelope of metal-oxide cells): each cell
  carries one *fixed* unit-normal draw scaled by the deterministic
  envelope, so re-evaluating the same deployment at a later age widens
  the spread without reshuffling which cells drifted up or down;
* **line-open faults** — a whole wordline (row) or bitline (column) is
  electrically disconnected; every cell on it conducts nothing
  regardless of its programmed or stuck state (the structural
  non-ideality the Yale sparse-DNN study finds dominates accuracy
  loss — arXiv:2201.05229);
* **correlated programming variation** — a spatially-smooth log-normal
  gain field over each tile (Gaussian-blurred white noise, unit
  marginal variance), modelling wafer-/array-level process gradients
  that i.i.d. cell draws cannot express.

All samplers are PRNG-keyed and fully vectorised over arbitrary leading
batch dims; the key/composition contract is documented in
:mod:`repro.nonideal` (the package docstring).
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.tiling import CrossbarSpec

# Cell-state codes of a fault map (int8).  Fault maps live in *physical*
# tile coordinates (ti, tn, row, col) — a property of the hardware,
# independent of which logical weight the mapping lands on a cell.
# OPEN marks a cell on an open (disconnected) wordline or bitline: it
# conducts *nothing* — below even the HRS leakage a STUCK_OFF cell
# keeps — and overrides any per-cell stuck state.
HEALTHY, STUCK_OFF, STUCK_ON, OPEN = 0, 1, 2, 3

# Fixed fold_in tags deriving the per-term sub-keys (see package
# docstring: enabling one term must never reshuffle another's draws).
_TAG_STUCK, _TAG_PROGRAM, _TAG_READ = 0, 1, 2
_TAG_LINE, _TAG_CORR, _TAG_RELAX = 3, 4, 5


@dataclasses.dataclass(frozen=True)
class NonidealModel:
    """One composable device-nonideality scenario (hashable/jit-static).

    Every field defaults to "off", so ``NonidealModel()`` is the ideal
    device and any subset of terms composes by construction.
    """

    p_stuck_off: float = 0.0    # stuck-at-OFF (HRS) cell rate
    p_stuck_on: float = 0.0     # stuck-at-ON (LRS) cell rate
    sigma_program: float = 0.0  # log-normal programming spread (of ln g)
    sigma_read: float = 0.0     # additive read noise, in units of g_on
    drift_nu: float = 0.0       # power-law ON-conductance drift exponent
    drift_time: float = 1.0     # read time / programming time t0
    p_open_wordline: float = 0.0  # whole-row (wordline) open rate
    p_open_bitline: float = 0.0   # whole-column (bitline) open rate
    sigma_corr: float = 0.0     # correlated log-normal spread (of ln g)
    corr_length: float = 4.0    # Gaussian correlation length, in cells
    sigma_relax: float = 0.0    # relaxation spread of ln g per sqrt(ln t)

    def __post_init__(self):
        # Fail at construction with a named field, not as NaNs three
        # layers down: a negative rate silently flips `uniform < p`
        # comparisons and a non-positive drift_time makes the power law
        # complex-valued.
        for name in ("p_stuck_off", "p_stuck_on", "p_open_wordline",
                     "p_open_bitline"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(
                    f"{name}={p!r} must be a probability in [0, 1]")
        for name in ("sigma_program", "sigma_read", "sigma_corr",
                     "sigma_relax", "drift_nu"):
            s = getattr(self, name)
            if not s >= 0.0:   # rejects negatives *and* NaN
                raise ValueError(f"{name}={s!r} must be >= 0")
        if self.p_stuck_off + self.p_stuck_on > 1.0:
            raise ValueError("p_stuck_off + p_stuck_on > 1")
        if not self.drift_time > 0.0:
            raise ValueError(
                f"drift_time={self.drift_time!r} must be > 0 "
                "(time in units of the programming time t0)")
        if self.corr_length < 1.0:
            # Sub-cell correlation lengths collapse the Gaussian filter
            # to (numerically) white noise while the normalisation
            # divides by a vanishing row norm.
            raise ValueError(
                f"corr_length={self.corr_length!r} must be >= 1 cell")

    @property
    def drift_factor(self) -> float:
        """Multiplier on the ON-state conductance at ``drift_time``."""
        return self.drift_factor_at(self.drift_time)

    def drift_factor_at(self, age: float) -> float:
        """Power-law ON-conductance multiplier at runtime ``age``.

        ``age`` is the time since (re)programming in units of t0; ages
        below 1 clamp to 1 — the power law describes decay *after* the
        programming pulse settles, and a freshly reprogrammed cell must
        restart from the undrifted conductance.
        """
        if self.drift_nu == 0.0:
            return 1.0
        return float(max(float(age), 1.0) ** -self.drift_nu)

    def relax_sigma_at(self, age: float) -> float:
        """Spread of the relaxation term of ln g at runtime ``age``.

        The log-time diffusion envelope ``sigma_relax * sqrt(ln age)``
        (zero at age <= 1): scaling one fixed per-cell draw by this
        deterministic factor ages a deployment in place — the draw
        never reshuffles, only its amplitude grows.
        """
        if self.sigma_relax == 0.0:
            return 0.0
        return float(self.sigma_relax
                     * math.sqrt(max(math.log(float(age)), 0.0)))

    @property
    def has_aging(self) -> bool:
        """Does any term change as the runtime age clock advances?"""
        return self.drift_nu > 0.0 or self.sigma_relax > 0.0

    @property
    def has_line_opens(self) -> bool:
        return self.p_open_wordline > 0.0 or self.p_open_bitline > 0.0

    @property
    def is_ideal(self) -> bool:
        return (self.p_stuck_off == 0.0 and self.p_stuck_on == 0.0
                and self.sigma_program == 0.0 and self.sigma_read == 0.0
                and self.drift_nu == 0.0 and not self.has_line_opens
                and self.sigma_corr == 0.0 and self.sigma_relax == 0.0)


class CellSample(NamedTuple):
    """One drawn realisation of the per-cell device state.

    stuck: int8 cell-state codes (HEALTHY / STUCK_OFF / STUCK_ON).
    gamma: f32 multiplicative programming gain (1 where sigma = 0).
    read:  f32 standard-normal read-noise draw (0 where sigma = 0;
           scaled by ``sigma_read * g_on`` at application time).
    relax: f32 standard-normal relaxation draw, or None when
           ``sigma_relax = 0`` — scaled by the deterministic
           ``relax_sigma_at(age)`` envelope at application time, so one
           fixed draw serves every age.
    """

    stuck: jax.Array
    gamma: jax.Array
    read: jax.Array
    relax: jax.Array | None = None


def sample_stuck(key: jax.Array, shape: tuple[int, ...],
                 p_stuck_off: float, p_stuck_on: float) -> jax.Array:
    """Mutually exclusive stuck-at fault codes from one uniform draw."""
    u = jax.random.uniform(key, shape)
    return jnp.where(
        u < p_stuck_off, STUCK_OFF,
        jnp.where(u < p_stuck_off + p_stuck_on, STUCK_ON,
                  HEALTHY)).astype(jnp.int8)


def sample_line_open(key: jax.Array, shape: tuple[int, ...],
                     p_open_wordline: float,
                     p_open_bitline: float) -> jax.Array:
    """Line-granular OPEN codes for a (..., rows, cols) population.

    One uniform per wordline (row) and one per bitline (column), drawn
    per tile over the leading batch dims — every cell on an open line
    gets OPEN.  The two draws use fixed sub-tags (0: wordlines, 1:
    bitlines) off the term key, so enabling bitline opens never
    reshuffles the wordline draw.
    """
    rows, cols = shape[-2], shape[-1]
    wl = jax.random.uniform(jax.random.fold_in(key, 0),
                            shape[:-1]) < p_open_wordline
    bl = jax.random.uniform(jax.random.fold_in(key, 1),
                            shape[:-2] + (cols,)) < p_open_bitline
    open_ = wl[..., :, None] | bl[..., None, :]
    return jnp.where(open_, OPEN, HEALTHY).astype(jnp.int8)


def sample_corr_field(key: jax.Array, shape: tuple[int, ...],
                      corr_length: float) -> jax.Array:
    """Unit-variance Gaussian field, smooth over each tile's (J, K).

    White noise filtered with a separable Gaussian of length-scale
    ``corr_length`` cells along rows and columns; the filter matrices
    are L2-row-normalised, so every output cell stays exactly N(0, 1)
    marginally while neighbouring cells within ~``corr_length`` are
    strongly correlated.  Leading batch dims (tiles, samples) get
    independent fields.
    """
    rows, cols = shape[-2], shape[-1]
    eps = jax.random.normal(key, shape)

    def smooth_matrix(n: int) -> jax.Array:
        d = jnp.arange(n, dtype=jnp.float32)
        a = jnp.exp(-0.5 * ((d[:, None] - d[None, :])
                            / jnp.float32(corr_length)) ** 2)
        return a / jnp.sqrt(jnp.sum(a * a, axis=1, keepdims=True))

    return jnp.einsum("Jj,...jk,Kk->...JK", smooth_matrix(rows), eps,
                      smooth_matrix(cols))


def sample_cell_state(key: jax.Array, shape: tuple[int, ...],
                      model: NonidealModel,
                      stuck: jax.Array | None = None) -> CellSample:
    """Draw one :class:`CellSample` for a cell population of ``shape``.

    Sub-keys are derived with fixed ``fold_in`` tags per term, so the
    draws of one term are invariant to every other term's rate (the
    composition contract).  Terms with zero rate/spread skip their draw
    and return the identity field.  Pass ``stuck`` to pin a *known*
    fault map (the fault-aware-planning scenario) while variation and
    read noise remain sampled; a pinned map pins the *whole* structural
    state — line opens are then the caller's responsibility (overlay
    :func:`sample_line_open` codes before pinning), not re-drawn here.
    """
    if stuck is None:
        if model.p_stuck_off > 0.0 or model.p_stuck_on > 0.0:
            stuck = sample_stuck(jax.random.fold_in(key, _TAG_STUCK),
                                 shape, model.p_stuck_off,
                                 model.p_stuck_on)
        else:
            stuck = jnp.zeros(shape, jnp.int8)
        if model.has_line_opens:
            # Line opens sever the cell from the array: they override
            # any per-cell stuck state on the same line.
            line = sample_line_open(jax.random.fold_in(key, _TAG_LINE),
                                    shape, model.p_open_wordline,
                                    model.p_open_bitline)
            stuck = jnp.where(line == OPEN, line, stuck)
    else:
        stuck = jnp.broadcast_to(jnp.asarray(stuck, jnp.int8), shape)
    if model.sigma_program > 0.0:
        gamma = jnp.exp(model.sigma_program * jax.random.normal(
            jax.random.fold_in(key, _TAG_PROGRAM), shape))
    else:
        gamma = jnp.ones(shape, jnp.float32)
    if model.sigma_corr > 0.0:
        # Correlated variation composes multiplicatively with the
        # i.i.d. programming spread: ln g picks up two independent
        # Gaussian terms, one white and one spatially smooth.
        gamma = gamma * jnp.exp(model.sigma_corr * sample_corr_field(
            jax.random.fold_in(key, _TAG_CORR), shape,
            model.corr_length))
    if model.sigma_read > 0.0:
        read = jax.random.normal(jax.random.fold_in(key, _TAG_READ),
                                 shape)
    else:
        read = jnp.zeros(shape, jnp.float32)
    if model.sigma_relax > 0.0:
        relax = jax.random.normal(jax.random.fold_in(key, _TAG_RELAX),
                                  shape)
    else:
        relax = None
    return CellSample(stuck, gamma, read, relax)


def conductances_from_masks(active: jax.Array,
                            spec: CrossbarSpec) -> jax.Array:
    """Clean (intended) conductance field of activity masks, f32 [S]."""
    return jnp.where(active > 0, jnp.float32(1.0 / spec.r_on),
                     jnp.float32(1.0 / spec.r_off))


def apply_to_conductances(active: jax.Array, sample: CellSample,
                          spec: CrossbarSpec, model: NonidealModel,
                          age: float | None = None) -> jax.Array:
    """Perturbed conductance field of a tile population.

    ``active`` (..., J, K) holds the clean activity masks; the sample's
    fields broadcast against it (the Monte-Carlo engine passes
    (S, T, J, K) samples against (T, J, K) masks).  Composition order
    mirrors the physics: drift scales what was programmed, variation
    spreads it, stuck cells override everything (the device never left
    its pinned state, so it carries no programming terms), read noise
    perturbs whatever is read back.  Conductances are clipped at 0 to
    keep the solver's operator positive semi-definite.

    ``age`` evaluates the time-dependent terms (power-law drift and
    stochastic relaxation) at a runtime clock instead of the model's
    static ``drift_time`` — same sample, later point on its lifetime
    trajectory.
    """
    t = model.drift_time if age is None else age
    g_on = jnp.float32(1.0 / spec.r_on)
    g_off = jnp.float32(1.0 / spec.r_off)
    g = jnp.where(active > 0,
                  g_on * jnp.float32(model.drift_factor_at(t)), g_off)
    g = g * sample.gamma
    s_relax = model.relax_sigma_at(t)
    if sample.relax is not None and s_relax > 0.0:
        g = g * jnp.exp(jnp.float32(s_relax) * sample.relax)
    g = jnp.where(sample.stuck == STUCK_ON, g_on, g)
    g = jnp.where(sample.stuck == STUCK_OFF, g_off, g)
    if model.sigma_read > 0.0:
        g = g + jnp.float32(model.sigma_read) * g_on * sample.read
    g = jnp.maximum(g, 0.0)
    # An OPEN cell sits on a severed line: no conduction path at all,
    # not even HRS leakage or read noise.
    return jnp.where(sample.stuck == OPEN, 0.0, g)


def cell_values(bits: jax.Array, stuck: jax.Array, gamma: jax.Array,
                model: NonidealModel | None = None,
                age: float | None = None) -> jax.Array:
    """Analog cell values for the Eq-17 effective-weight evaluator.

    Maps programmed bits b in {0, 1} to the normalised conductance-level
    cell value the shift-add arithmetic sees: stuck-ON -> 1, stuck-OFF
    and OPEN -> 0, healthy -> ``drift * gamma * b``.  (Read noise has
    no weight-level analogue — it is a per-read term, modelled by the
    circuit-level Monte-Carlo engine and the serving-path read-noise
    hook.)  All arguments broadcast.  ``age`` evaluates drift at a
    runtime clock instead of the model's static ``drift_time``.
    """
    if model is None:
        drift = 1.0
    else:
        drift = model.drift_factor_at(
            model.drift_time if age is None else age)
    c = bits.astype(jnp.float32) * gamma * jnp.float32(drift)
    c = jnp.where(stuck == STUCK_ON, 1.0, c)
    return jnp.where((stuck == STUCK_OFF) | (stuck == OPEN), 0.0, c)
