"""The jitted training step: loss -> grads -> AdamW, with optional
gradient-accumulation microbatching and cross-pod int8 error-feedback
gradient compression (shard_map over the "pod" axis, other axes left to
SPMD auto partitioning).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.distributed.compression import psum_compressed
from repro.distributed.sharding import ShardingCtx
from repro.models.model import train_loss
from repro.optim.adamw import AdamWState, adamw_update
from repro.optim.schedule import cosine_schedule


def _split_micro(batch: dict, n: int):
    def r(x):
        B = x.shape[0]
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree_util.tree_map(r, batch)


def _grads_of(cfg: ModelConfig, ctx: ShardingCtx, tcfg: TrainConfig):
    """(params, batch) -> (grads, metrics), with microbatch accumulation."""

    def loss_fn(params, batch):
        return train_loss(params, cfg, ctx, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, dict(metrics, loss=loss)

    if tcfg.microbatches <= 1:
        return single

    def accumulated(params, batch):
        micro = _split_micro(batch, tcfg.microbatches)

        def body(carry, mb):
            g_acc, l_acc = carry
            (loss, metrics), g = grad_fn(params, mb)
            g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
            return (g_acc, l_acc + loss), metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, loss_sum), ms = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro)
        inv = 1.0 / tcfg.microbatches
        g = jax.tree_util.tree_map(lambda x: x * inv, g)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], ms)
        return g, dict(metrics, loss=loss_sum * inv)

    return accumulated


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, ctx: ShardingCtx):
    """Build the (params, opt_state, batch) -> (params, opt_state, metrics)
    step function (jit it with the shardings from launch/train.py)."""
    grads_of = _grads_of(cfg, ctx, tcfg)

    use_compression = (tcfg.grad_compression == "int8_ef" and ctx.mesh
                       is not None and "pod" in ctx.mesh.axis_names)

    def train_step(params, opt_state: AdamWState, batch):
        if use_compression:
            from jax.sharding import PartitionSpec as P

            def per_pod(params_l, ef_l, batch_l):
                g, metrics = grads_of(params_l, batch_l)
                # mean over pods with int8 error-feedback payload
                g, new_ef = psum_compressed(g, ef_l, "pod")
                npods = jax.lax.psum(jnp.ones((), jnp.float32), "pod")
                g = jax.tree_util.tree_map(lambda x: x / npods, g)
                metrics = jax.lax.pmean(metrics, "pod")
                return g, new_ef, metrics

            rep = jax.tree_util.tree_map(lambda _: P(), params)
            ef_spec = jax.tree_util.tree_map(lambda _: P(), opt_state.ef_error)
            bspec = jax.tree_util.tree_map(lambda _: P("pod"), batch)
            mspec = {"loss": P(), "ce": P(), "aux": P()}
            from repro.compat import shard_map
            grads, new_ef, metrics = shard_map(
                per_pod, mesh=ctx.mesh,
                in_specs=(rep, ef_spec, bspec),
                out_specs=(rep, ef_spec, mspec),
                axis_names=frozenset({"pod"}),  # other axes stay auto/SPMD
                check_vma=False,
            )(params, opt_state.ef_error, batch)
            opt_state = opt_state._replace(ef_error=new_ef)
        else:
            grads, metrics = grads_of(params, batch)

        lr = cosine_schedule(opt_state.step, peak_lr=tcfg.learning_rate,
                             warmup_steps=tcfg.warmup_steps,
                             total_steps=tcfg.total_steps)
        new_params, new_opt, om = adamw_update(
            grads, opt_state, params, lr=lr, beta1=tcfg.beta1,
            beta2=tcfg.beta2, weight_decay=tcfg.weight_decay,
            grad_clip=tcfg.grad_clip)
        return new_params, new_opt, dict(metrics, **om, lr=lr)

    return train_step
