"""Trainer: checkpointed, restartable training loop with straggler
watchdog and deterministic data.

Fault-tolerance model (single-controller JAX):
  * the data stream is a pure function of (seed, step) -> any restart
    from checkpoint replays the identical token stream;
  * checkpoints (params + full optimizer state + step) are atomic and
    mesh-agnostic -> restart may use a different mesh/device count
    (elastic) — restore resbards on load;
  * ``run()`` survives injected step failures: on exception it reloads
    the latest checkpoint and continues (bounded retries), which is the
    single-process analogue of a coordinator rescheduling a failed pod;
  * the watchdog tracks a step-time EMA and logs outliers (straggler
    surface; on real multi-host deployments this feeds the preemption/
    re-slice decision).
"""
from __future__ import annotations

import jax
import numpy as np

from repro import telemetry as tm
from repro.checkpoint.ckpt import CheckpointManager, latest_step, load_checkpoint
from repro.configs.base import ModelConfig, TrainConfig
from repro.distributed.sharding import ShardingCtx
from repro.models.model import init_params
from repro.optim.adamw import adamw_init
from repro.train.step import make_train_step


class Watchdog:
    """Step-time EMA; flags steps slower than ``threshold`` x EMA."""

    def __init__(self, threshold: float = 2.0, decay: float = 0.9):
        self.ema = None
        self.threshold = threshold
        self.decay = decay
        self.stragglers: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        flagged = self.ema is not None and dt > self.threshold * self.ema
        if flagged:
            self.stragglers.append((step, dt))
        self.ema = dt if self.ema is None else \
            self.decay * self.ema + (1 - self.decay) * dt
        return flagged


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, dataset,
                 ctx: ShardingCtx | None = None, donate: bool = True):
        self.cfg, self.tcfg, self.dataset = cfg, tcfg, dataset
        self.ctx = ctx or ShardingCtx()
        self.watchdog = Watchdog()
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      async_save=tcfg.async_checkpoint)
        step_fn = make_train_step(cfg, tcfg, self.ctx)
        self._step = jax.jit(step_fn,
                             donate_argnums=(0, 1) if donate else ())
        self.params = None
        self.opt_state = None
        self.step = 0
        self.metrics_log: list[dict] = []

    # ------------------------------------------------------------------
    def init_state(self):
        key = jax.random.PRNGKey(self.tcfg.seed)
        self.params = init_params(self.cfg, key)
        self.opt_state = adamw_init(
            self.params,
            use_error_feedback=self.tcfg.grad_compression == "int8_ef")
        self.step = 0

    def resume_or_init(self):
        last = latest_step(self.tcfg.checkpoint_dir)
        if last is None:
            self.init_state()
            return False
        self.init_state()  # build structure, then overwrite from disk
        state = {"params": self.params, "opt": self.opt_state}
        restored = load_checkpoint(self.tcfg.checkpoint_dir, last, state)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.step = last
        return True

    def save(self):
        self.ckpt.save(self.step, {"params": self.params,
                                   "opt": self.opt_state})

    # ------------------------------------------------------------------
    def _device_batch(self, step: int) -> dict:
        toks = self.dataset.batch_at(step)
        return {"tokens": jax.numpy.asarray(toks)}

    def run(self, n_steps: int | None = None, fail_at=None,
            max_retries: int = 2):
        """Train for n_steps (default tcfg.total_steps). ``fail_at`` is a
        test hook: a set of step numbers at which a simulated failure is
        raised *after* the forward/backward ran (pre-checkpoint)."""
        n_steps = n_steps or self.tcfg.total_steps
        retries = 0
        while self.step < n_steps:
            try:
                t0 = tm.monotonic()
                batch = self._device_batch(self.step)
                if fail_at and self.step in fail_at:
                    fail_at = set(fail_at) - {self.step}
                    raise RuntimeError(f"injected failure @ {self.step}")
                self.params, self.opt_state, metrics = self._step(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = tm.monotonic() - t0
                self.watchdog.observe(self.step, dt)
                self.step += 1
                if self.step % self.tcfg.log_every == 0 or \
                        self.step == n_steps:
                    m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    m["step"], m["dt"] = self.step, dt
                    self.metrics_log.append(m)
                if self.step % self.tcfg.checkpoint_every == 0:
                    self.save()
            except Exception:
                retries += 1
                if retries > max_retries:
                    raise
                # recovery: reload latest checkpoint (or reinit) and go on
                self.ckpt.wait()
                if not self.resume_or_init():
                    self.init_state()
        self.ckpt.wait()
        return self.metrics_log
