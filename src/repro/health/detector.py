"""EWMA + CUSUM/z-score drift detection with hysteresis.

One :class:`DriftDetector` watches one scalar error stream — the
per-matrix probe error the health monitor produces — and answers a
single question per observation: *has this matrix drifted away from its
healthy baseline?*  Three classical pieces compose:

* an **EWMA tracker** smooths the per-probe error (probe error is noisy
  under per-read conductance noise; a raw threshold on single probes
  would trip on noise spikes);
* a **z-score** of the EWMA against the learned baseline (mean + std
  of the first ``warmup`` probes, refined over a bounded healthy
  window — see below — with a floor on the std so a noiseless baseline
  does not make the detector infinitely sensitive) catches sustained
  level shifts;
* a **one-sided CUSUM** ``S = max(0, S + (err - mu0 - k*sigma0))``
  accumulates small persistent exceedances that never individually
  clear the z threshold — the classical drift (slow ramp) detector.

**Hysteresis contract.**  Trip and clear use *separated* thresholds:
the detector trips when ``z >= z_trip`` or ``S >= h * sigma0`` and,
once tripped, reports tripped until the EWMA z-score falls back below
``z_clear`` (``z_clear < z_trip``, enforced).  An error level that sits
exactly at the trip threshold therefore trips once and stays tripped —
it cannot flap trip/clear/trip — and a remediation that actually fixed
the matrix clears it promptly because the EWMA falls well below
``z_clear``.  After a remediation the controller calls :meth:`rearm`,
which zeroes the CUSUM and the trip latch but keeps the learned
baseline (the reference "healthy" level of this matrix does not change
when the device is refreshed).

**Bounded baseline refinement.**  A baseline frozen at ``warmup``
observations carries the warmup's sampling error forever: a mean
underestimated by half a sigma turns the CUSUM's negative drift into a
near-zero one and the in-control average run length collapses (false
trips on perfectly stationary streams).  The detector therefore keeps
folding *demonstrably healthy* observations (z below ``z_clear``,
CUSUM below half its threshold, not tripped) into the Welford baseline
until ``baseline_window * warmup`` total observations — long enough to
shrink the estimation error, bounded so a slow real drift cannot be
absorbed into the reference indefinitely.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    """Thresholds of one drift detector (hashable, shareable).

    All thresholds are in units of the baseline std ``sigma0``; the
    baseline itself is learned from the first ``warmup`` observations,
    during which the detector never trips.
    """

    ewma_alpha: float = 0.3    # EWMA smoothing (1 = raw errors)
    warmup: int = 8            # observations to learn (mu0, sigma0)
    z_trip: float = 8.0        # trip when EWMA z-score reaches this
    z_clear: float = 2.0       # clear only when z falls below this
    cusum_k: float = 1.0       # CUSUM slack, in sigma0
    cusum_h: float = 12.0      # CUSUM trip threshold, in sigma0
    min_sigma: float = 1e-4    # absolute floor on sigma0
    min_rel_sigma: float = 0.02  # floor on sigma0 relative to mu0
    baseline_window: int = 4   # refine baseline until this x warmup
                               # observations (1 = freeze at warmup)

    def __post_init__(self):
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.warmup < 2:
            raise ValueError("warmup must be >= 2")
        if self.baseline_window < 1:
            raise ValueError("baseline_window must be >= 1")
        if not self.z_clear < self.z_trip:
            raise ValueError(
                "hysteresis requires z_clear < z_trip (separated "
                "thresholds are what prevents trip/clear flapping)")


class DriftDetector:
    """Stateful per-matrix drift detector (see module docstring)."""

    def __init__(self, config: DetectorConfig | None = None):
        self.config = config or DetectorConfig()
        self.n = 0            # observations seen
        self.mu0 = 0.0        # baseline mean (Welford, healthy window)
        self._m2 = 0.0
        self._n_base = 0      # observations folded into the baseline
        self.sigma0 = 0.0
        self.ewma = 0.0
        self.cusum = 0.0
        self.tripped = False
        self.n_trips = 0      # trip *edges* (False -> True transitions)
        self.n_clears = 0     # clear edges (True -> False transitions)
        self._reinit_ewma = False

    @property
    def warmed_up(self) -> bool:
        return self.n >= self.config.warmup

    @property
    def z(self) -> float:
        """Current EWMA z-score against the warmup baseline."""
        if not self.warmed_up:
            return 0.0
        return (self.ewma - self.mu0) / self._sigma()

    def _sigma(self) -> float:
        c = self.config
        return max(self.sigma0, c.min_sigma,
                   c.min_rel_sigma * abs(self.mu0))

    def update(self, err: float) -> bool:
        """Observe one probe error; returns the post-update trip state."""
        err = float(err)
        c = self.config
        self.n += 1
        if self.n == 1 or self._reinit_ewma:
            self.ewma = err
            self._reinit_ewma = False
        else:
            self.ewma = (c.ewma_alpha * err
                         + (1.0 - c.ewma_alpha) * self.ewma)
        if self.n <= c.warmup:
            # Baseline learning (Welford); the detector cannot trip yet.
            self._fold_baseline(err)
            if self.n == c.warmup:
                self.sigma0 = (self._m2 / (self._n_base - 1)) ** 0.5
            return False
        sigma = self._sigma()
        z = (self.ewma - self.mu0) / sigma
        # Bounded refinement: demonstrably healthy observations keep
        # shrinking the warmup's estimation error (a frozen mu0 off by
        # half a sigma destroys the CUSUM's in-control run length).
        if (not self.tripped
                and self.n <= c.baseline_window * c.warmup
                and z < c.z_clear
                and self.cusum < 0.5 * c.cusum_h * sigma):
            self._fold_baseline(err)
            self.sigma0 = (self._m2 / (self._n_base - 1)) ** 0.5
            sigma = self._sigma()
            z = (self.ewma - self.mu0) / sigma
        self.cusum = max(
            0.0, self.cusum + (err - self.mu0 - c.cusum_k * sigma))
        if not self.tripped:
            if z >= c.z_trip or self.cusum >= c.cusum_h * sigma:
                self.tripped = True
                self.n_trips += 1
        else:
            if z <= c.z_clear:
                self.tripped = False
                self.n_clears += 1
                self.cusum = 0.0
        return self.tripped

    def _fold_baseline(self, err: float) -> None:
        self._n_base += 1
        d = err - self.mu0
        self.mu0 += d / self._n_base
        self._m2 += d * (err - self.mu0)

    def rearm(self) -> None:
        """Reset the trip latch + CUSUM after a remediation.

        The learned baseline is kept: remediation restores the device
        toward the healthy level the baseline describes, and relearning
        it from post-remediation probes would slowly ratchet the
        reference upward with every partially-successful repair.  The
        EWMA restarts from the next observation — the remediation
        changed the device, so smoothing the new error stream into the
        pre-repair level would hold the z-score high for several rounds
        and falsely re-trip a repair that worked.
        """
        self.tripped = False
        self.cusum = 0.0
        self._reinit_ewma = True
        # Rearming is a controller action, not a spontaneous clear —
        # it does not count toward the clear-edge counter the flapping
        # check audits.

    def state(self) -> dict:
        """Scrape-friendly counters/gauges for the health report."""
        return {
            "n": self.n,
            "ewma": self.ewma,
            "mu0": self.mu0,
            "sigma0": self._sigma() if self.warmed_up else None,
            "z": self.z,
            "cusum": self.cusum,
            "tripped": self.tripped,
            "n_trips": self.n_trips,
            "n_clears": self.n_clears,
        }
