"""Per-matrix calibration probes and the structured health report.

The in-band health signal is a **calibration probe**: a small fixed
batch of known vectors pushed through the *production* ``cim_mvm``
path of a deployed matrix and compared against the digital reference
``probes @ W``.  The relative L2 residual over the probe batch is the
scalar error stream the drift detector watches; the residual itself is
what the recalibration rung of the remediation ladder fits its
per-output-column gain correction from.

Probe vectors are deterministic per ``(probe_seed, noise_tag)`` — a
numpy ``default_rng`` seeded by the pair, so every matrix gets its own
fixed probe batch and re-creating a monitor reproduces it bit-exactly
(no jax PRNG involved: probes are calibration *constants*, not
stochastic draws).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.health.detector import DetectorConfig, DriftDetector


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Configuration of the serving-health subsystem.

    ``age_per_token`` converts served tokens into drift-clock time
    (t0 units) so ``ServeEngine.generate`` can advance the age from
    simulated reads; 0 leaves the clock under explicit
    ``advance(dt)`` control.
    """

    n_probes: int = 16          # probe vectors per matrix
    probe_seed: int = 0         # probe-constant seed (per-matrix mixed)
    detector: DetectorConfig = dataclasses.field(
        default_factory=DetectorConfig)
    max_reprograms: int = 1     # endurance budget per matrix
    age_per_token: float = 0.0  # simulated-read aging per served token
    recal_limit: float = 20.0   # clamp on the per-column correction

    def __post_init__(self):
        if self.n_probes < 1:
            raise ValueError("n_probes must be >= 1")
        if self.max_reprograms < 0:
            raise ValueError("max_reprograms must be >= 0")


def probe_vectors(cfg: HealthConfig, noise_tag: int,
                  in_dim: int) -> np.ndarray:
    """The fixed (n_probes, in_dim) probe batch of one matrix."""
    rng = np.random.default_rng((cfg.probe_seed, int(noise_tag)))
    return rng.standard_normal((cfg.n_probes, in_dim)).astype(np.float32)


def probe_error(y_cim: np.ndarray, y_ref: np.ndarray) -> float:
    """Relative L2 residual of a probe batch (scalar error signal)."""
    denom = float(np.linalg.norm(y_ref))
    return float(np.linalg.norm(y_cim - y_ref)) / max(denom, 1e-30)


def estimate_recal(y_cim: np.ndarray, y_ref: np.ndarray,
                   limit: float) -> np.ndarray:
    """Per-output-column least-squares gain correction from residuals.

    Fits ``alpha_j`` minimising ``||alpha_j * y_cim[:, j] -
    y_ref[:, j]||`` — the correction that, folded into the deployment's
    per-weight gain, undoes a (column-wise) multiplicative drift of the
    analog output.  Columns with no probe energy keep 1; corrections
    are clamped to ``[1/limit, limit]`` so a dead column cannot demand
    an unbounded gain.
    """
    num = (y_cim * y_ref).sum(axis=0)
    den = (y_cim * y_cim).sum(axis=0)
    alpha = np.where(den > 1e-30, num / np.maximum(den, 1e-30), 1.0)
    return np.clip(alpha, 1.0 / limit, limit).astype(np.float32)


class MatrixMonitor:
    """Probe constants + detector + ladder bookkeeping of one matrix."""

    def __init__(self, cfg: HealthConfig, noise_tag: int,
                 w: np.ndarray):
        self.probes = probe_vectors(cfg, noise_tag, w.shape[0])
        self.y_ref = (self.probes @ np.asarray(w, np.float32)).astype(
            np.float32)
        self.probes_dev = jnp.asarray(self.probes)
        self.detector = DriftDetector(cfg.detector)
        self.last_err: float | None = None

    def observe(self, y_cim: np.ndarray) -> bool:
        """Update the detector with one probe round's residual."""
        self.last_err = probe_error(y_cim, self.y_ref)
        return self.detector.update(self.last_err)


@dataclasses.dataclass
class HealthReport:
    """Structured snapshot of the serving-health subsystem.

    ``counters`` is scrape-friendly (monotonic ints); ``events`` is the
    append-only remediation log, each entry
    ``{"round", "matrix", "event", "detail"}`` with ``event`` one of
    ``trip | recalibrate | reprogram | demote | clear``.  ``flaps``
    counts *spontaneous* detector clear-edges (clears not caused by a
    remediation rearm) — the hysteresis contract says this stays 0 for
    a level signal sitting at the trip threshold.
    """

    rounds: int
    counters: dict[str, int]
    matrices: dict[str, dict[str, Any]]
    events: list[dict[str, Any]]

    @property
    def flaps(self) -> int:
        return self.counters.get("spontaneous_clears", 0)

    @property
    def tripped(self) -> list[str]:
        return [n for n, m in self.matrices.items() if m["tripped"]]
