"""Serving-lifetime health: monitoring, drift detection, self-healing.

Memristive conductances keep moving while the chip serves (power-law
drift, stochastic relaxation — :mod:`repro.nonideal.models`'s aging
clock), so a long-lived :class:`repro.serve.engine.ServeEngine`
silently degrades unless something watches the analog path in-band.
This package completes the degradation -> detection -> recovery loop:

==================  ===================================================
piece               entry points
==================  ===================================================
drift detection     :mod:`repro.health.detector` —
                    :class:`DriftDetector` (EWMA + CUSUM/z-score with
                    hysteresis: separated trip/clear thresholds, so no
                    flapping), :class:`DetectorConfig`
calibration probes  :mod:`repro.health.monitor` — fixed per-matrix
                    probe batches through the production ``cim_mvm``
                    vs. the digital reference; :class:`HealthConfig`,
                    :class:`HealthReport` (+ event log / counters)
remediation ladder  :mod:`repro.health.controller` —
                    :class:`HealthController`: on trip, recalibrate ->
                    reprogram (endurance-bounded) -> demote, over the
                    host lifetime state in
                    :mod:`repro.deploy.lifetime`
==================  ===================================================

The serving integration lives in ``repro.serve.engine``: pass
``health=HealthConfig(...)`` (with a ``nonideal`` model) to
``ServeEngine``, then drive ``engine.advance(dt)`` /
``engine.check_health()`` — deployments refresh by atomic hot-swap
(fresh cim-tree dicts, never in-place mutation), so generation in
flight keeps the bank it started with.
"""
from repro.health.controller import HealthController  # noqa: F401
from repro.health.detector import (  # noqa: F401
    DetectorConfig,
    DriftDetector,
)
from repro.health.monitor import (  # noqa: F401
    HealthConfig,
    HealthReport,
    MatrixMonitor,
    estimate_recal,
    probe_error,
    probe_vectors,
)

__all__ = [
    "DetectorConfig", "DriftDetector",
    "HealthConfig", "HealthReport", "MatrixMonitor",
    "HealthController",
    "estimate_recal", "probe_error", "probe_vectors",
]
