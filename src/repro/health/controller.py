"""The health controller: probe rounds, the remediation ladder, and
atomic hot-swap bookkeeping.

One :class:`HealthController` owns the lifetime state of a deployed
checkpoint (``repro.deploy.lifetime.MatrixLifetime`` per matrix) and
drives the degradation -> detection -> recovery loop:

* :meth:`advance` moves every matrix's age clock and re-derives the
  served deployments at the new age (the *physics*: aging happens
  whether or not anyone watches);
* :meth:`probe` pushes each matrix's calibration probes through the
  production ``cim_mvm``, feeds the residual to the per-matrix drift
  detector, and — on a trip — climbs the remediation ladder:

  1. **recalibrate**: fold the per-output-column least-squares gain
     correction estimated from this round's probe residuals into the
     deployment (cheap; fixes uniform/columnwise drift exactly);
  2. **reprogram**: re-inject with a fresh program-verify-style draw
     and reset the drift clock (bounded by the per-matrix endurance
     budget ``max_reprograms`` — real cells wear out);
  3. **demote**: the runtime ``degraded`` sentinel routes the matrix to
     the digital fallback for good.

Both methods return the set of ``(slot, pname)`` stacking groups whose
served deployments changed; the serving engine restacks exactly those
(:func:`repro.deploy.lifetime.restack_group`) and swaps them in by
building a *fresh* cim tree dict — never mutating the old one — so a
generation loop holding the previous tree keeps a consistent bank.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as tm
from repro.deploy.lifetime import MatrixLifetime, group_key
from repro.health.monitor import (
    HealthConfig,
    HealthReport,
    MatrixMonitor,
    estimate_recal,
)

_H_PROBE_ROUND = tm.histogram(
    "repro_health_probe_round_seconds",
    "Wall time of one full probe round (all live matrices).")
_C_PROBES = tm.counter(
    "repro_health_probes_total", "Per-matrix calibration probe reads.")
_C_EVENTS = tm.counter(
    "repro_health_events_total",
    "Health events by kind (trip/clear/recalibrate/reprogram/demote).",
    labels=("event",))


class HealthController:
    """Drives monitoring + self-healing over a deployed checkpoint."""

    def __init__(self, lifetimes: dict[str, MatrixLifetime],
                 cfg: HealthConfig | None = None):
        self.cfg = cfg or HealthConfig()
        self.lifetimes = lifetimes
        self.monitors = {
            name: MatrixMonitor(self.cfg, lt.noise_tag, lt.w)
            for name, lt in lifetimes.items()}
        self.rounds = 0
        self.events: list[dict] = []
        self.counters = {
            "probes": 0, "trips": 0, "spontaneous_clears": 0,
            "recalibrations": 0, "reprograms": 0, "demotions": 0}

    # -- aging ---------------------------------------------------------

    def advance(self, dt: float) -> set[tuple[str, str]]:
        """Advance every live matrix's age; returns dirty swap groups."""
        dirty: set[tuple[str, str]] = set()
        for name, lt in self.lifetimes.items():
            if lt.demoted:
                continue
            lt.advance(dt)
            if lt.model.has_aging:
                lt.refresh()
                dirty.add(group_key(name))
        return dirty

    # -- probing + remediation -----------------------------------------

    def probe(self, read_key: jax.Array | None = None
              ) -> set[tuple[str, str]]:
        """One probe round over every live matrix.

        ``read_key`` threads per-read conductance noise into the probe
        reads (the probes measure the same physical path generation
        uses, noise included); the per-deployment ``noise_tag`` keeps
        draws independent across matrices as usual.  Returns the dirty
        swap groups of every matrix a remediation refreshed.
        """
        t0 = tm.monotonic()
        with tm.span("health/probe_round", round=self.rounds + 1):
            self.rounds += 1
            live = [(name, lt) for name, lt in self.lifetimes.items()
                    if not lt.demoted]
            results = self._probe_reads(live, read_key)
            dirty: set[tuple[str, str]] = set()
            for name, lt in live:
                mon = self.monitors[name]
                y = results[name]
                self.counters["probes"] += 1
                _C_PROBES.inc()
                det = mon.detector
                clears_before = det.n_clears
                tripped = mon.observe(y)
                if det.n_clears > clears_before:
                    self.counters["spontaneous_clears"] += (
                        det.n_clears - clears_before)
                    _C_EVENTS.labels(event="clear").inc(
                        det.n_clears - clears_before)
                    self._log(name, "clear", f"z={det.z:.2f}")
                if tripped:
                    self.counters["trips"] += 1
                    _C_EVENTS.labels(event="trip").inc()
                    self._log(name, "trip",
                              f"err={mon.last_err:.4g} z={det.z:.2f} "
                              f"cusum={det.cusum:.4g}")
                    self._remediate(name, lt, mon, y)
                    dirty.add(group_key(name))
        _H_PROBE_ROUND.observe(tm.monotonic() - t0)
        return dirty

    def _probe_reads(self, live: list, read_key: jax.Array | None
                     ) -> dict[str, np.ndarray]:
        """Probe currents for every live matrix, batched per swap group.

        Matrices in one ``(slot, pname)`` stacking group share tile
        geometry by construction, so their probe reads run as a single
        vmapped ``cim_mvm`` over the tree_map-stacked deployments — one
        dispatch per group instead of one per matrix (the per-read
        noise stays per-matrix: ``noise_tag`` is a stacked data leaf).
        *Ragged* groups (a custom partition can produce unequal expert
        shapes) are zero-drive padded to the group-max tile grid
        (:func:`repro.deploy.lifetime.pad_host_deployment`) and ride
        the same vmapped round, the readback sliced at each member's
        true ``out_dim``; only groups whose static meta genuinely
        conflicts (dataflow direction, crossbar geometry, optional-leaf
        presence) fall back to the sequential per-matrix path, as do
        singleton groups.
        """
        from repro.kernels.cim_mvm.ops import cim_mvm

        groups: dict[tuple[str, str], list] = {}
        for name, lt in live:
            groups.setdefault(group_key(name), []).append((name, lt))
        results: dict[str, np.ndarray] = {}
        for members in groups.values():
            if len(members) > 1 and self._stackable(members):
                probes = jnp.stack(
                    [self.monitors[n].probes_dev for n, _ in members])
                deps = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs),
                    *[lt.dep for _, lt in members])
                ys = np.asarray(jax.vmap(
                    lambda p, d: cim_mvm(p, d, read_key=read_key)
                )(probes, deps))
                for (name, _), y in zip(members, ys):
                    results[name] = y
                continue
            if len(members) > 1:
                padded = self._padded_probe_reads(members, read_key)
                if padded is not None:
                    results.update(padded)
                    continue
            for name, lt in members:
                results[name] = np.asarray(
                    cim_mvm(self.monitors[name].probes_dev, lt.dep,
                            read_key=read_key))
        return results

    def _padded_probe_reads(self, members: list,
                            read_key: jax.Array | None
                            ) -> dict[str, np.ndarray] | None:
        """One vmapped probe read over a zero-drive-padded ragged group.

        Pads every member deployment to the group-max tile grid (zero
        codes contribute nothing — per-cell distortion model), pads the
        probe batches with zero drive on the extra input lanes, runs
        the single vmapped ``cim_mvm``, and slices each member's
        readback at its true ``out_dim``.  Noiseless reads match the
        unpadded per-matrix reads exactly; with per-read noise armed
        the iid draw covers the padded grid, so the samples differ from
        an unpadded read while keeping the same per-cell statistics
        (and stay deterministic per ``read_key``) — fine for drift
        residuals, which only see the noise variance.  Returns None
        when the group cannot be padded into one tree (static meta or
        optional-leaf presence conflicts, unequal crossbar geometry or
        probe counts) — the caller then takes the sequential path.
        """
        from repro.deploy.lifetime import pad_host_deployment
        from repro.kernels.cim_mvm.ops import cim_mvm

        deps = [lt.dep for _, lt in members]
        d0 = deps[0]
        meta = lambda d: (d.n_bits, d.wpt, d.cols, d.eta, d.reversed_df,
                          d.sigma_read)
        if any(meta(d) != meta(d0) for d in deps):
            return None
        for f in ("gain", "col_pos", "degraded", "noise_tag"):
            if len({getattr(d, f) is None for d in deps}) != 1:
                return None
        if len({lt.spec.rows for _, lt in members}) != 1:
            return None
        if len({self.monitors[n].probes_dev.shape[0]
                for n, _ in members}) != 1:
            return None
        rows = members[0][1].spec.rows
        i_pad = max(d.codes.shape[0] for d in deps)
        n_pad = max(d.codes.shape[1] for d in deps)
        in_dim = max(d.in_dim for d in deps)
        out_dim = max(d.out_dim for d in deps)
        padded = [pad_host_deployment(d, i_pad, n_pad, in_dim, out_dim,
                                      rows=rows) for d in deps]
        probes = jnp.stack([
            jnp.pad(self.monitors[n].probes_dev,
                    ((0, 0),
                     (0, in_dim - self.monitors[n].probes_dev.shape[1])))
            for n, _ in members])
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *padded)
        ys = np.asarray(jax.vmap(
            lambda p, d: cim_mvm(p, d, read_key=read_key)
        )(probes, stacked))
        return {name: ys[i][:, :lt.dep.out_dim]
                for i, (name, lt) in enumerate(members)}

    def _stackable(self, members: list) -> bool:
        """All group members share probe shape + deployment tree shape."""
        shapes = {np.shape(self.monitors[n].probes_dev)
                  for n, _ in members}
        if len(shapes) != 1:
            return False
        sigs = set()
        for _, lt in members:
            leaves, treedef = jax.tree_util.tree_flatten(lt.dep)
            sigs.add((treedef,
                      tuple(jnp.shape(leaf) for leaf in leaves)))
        return len(sigs) == 1

    def _remediate(self, name: str, lt: MatrixLifetime,
                   mon: MatrixMonitor, y_cim: np.ndarray) -> None:
        if lt.rung == 0:
            recal = estimate_recal(y_cim, mon.y_ref,
                                   self.cfg.recal_limit)
            lt.recalibrate(recal)
            self.counters["recalibrations"] += 1
            _C_EVENTS.labels(event="recalibrate").inc()
            self._log(name, "recalibrate",
                      f"median_alpha={float(np.median(recal)):.4f} "
                      f"age={lt.age:.3g}")
        elif lt.reprograms < self.cfg.max_reprograms:
            lt.reprogram()
            self.counters["reprograms"] += 1
            _C_EVENTS.labels(event="reprogram").inc()
            self._log(name, "reprogram",
                      f"epoch={lt.reprograms} clock_reset age=1")
        else:
            lt.demote()
            self.counters["demotions"] += 1
            _C_EVENTS.labels(event="demote").inc()
            self._log(name, "demote",
                      f"endurance_exhausted reprograms={lt.reprograms}"
                      f" -> digital fallback")
        mon.detector.rearm()

    def _log(self, matrix: str, event: str, detail: str) -> None:
        self.events.append({"round": self.rounds, "matrix": matrix,
                            "event": event, "detail": detail})

    # -- reporting -----------------------------------------------------

    def report(self) -> HealthReport:
        matrices = {}
        for name, lt in self.lifetimes.items():
            mon = self.monitors[name]
            matrices[name] = {
                **mon.detector.state(),
                "last_err": mon.last_err,
                "age": lt.age,
                "rung": lt.rung,
                "reprograms": lt.reprograms,
                "demoted": lt.demoted,
            }
        return HealthReport(rounds=self.rounds,
                            counters=dict(self.counters),
                            matrices=matrices,
                            events=list(self.events))
