"""Sharded, mesh-agnostic checkpointing with async save and
reshard-on-load restore (the elastic-scaling path).

Checkpoints are host numpy arrays, one file per pytree leaf plus an
``index.json`` (leaf paths, shapes, dtypes).  Because the on-disk format
carries no sharding, a checkpoint written on one mesh restores onto any
other mesh (or a different device count) — restore just ``device_put``s
each leaf with the *target* sharding.  Writes are atomic
(tmp-dir + rename) so a crash mid-save never corrupts the latest
checkpoint; ``CheckpointManager`` retains the newest K and can save
asynchronously on a background thread (snapshot taken synchronously,
I/O off the training thread).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import ml_dtypes
import numpy as np

# numpy can't round-trip ml_dtypes through .npy; store a same-width
# integer view and reinterpret on load (index.json keeps the true dtype).
_CUSTOM_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _encode(arr: np.ndarray) -> np.ndarray:
    ent = _CUSTOM_DTYPES.get(str(arr.dtype))
    return arr.view(ent[1]) if ent else arr


def _decode(arr: np.ndarray, dtype: str) -> np.ndarray:
    ent = _CUSTOM_DTYPES.get(dtype)
    return arr.view(ent[0]) if ent else arr


def _leaf_files(tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(p), leaf) for p, leaf in leaves]


def save_checkpoint(directory: str, step: int, tree) -> str:
    """Atomic synchronous save. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    index = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(_leaf_files(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), _encode(arr))
        index["leaves"].append(
            {"path": path, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, target):
    """Restore into the structure (and shardings) of ``target``.

    ``target`` may hold concrete arrays or ShapeDtypeStructs; if a leaf
    has a ``.sharding`` (or target entries are NamedSharding via
    ``shardings`` pytree), the loaded array is device_put with it —
    this is the cross-mesh / elastic restore path.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)
    by_path = {e["path"]: e for e in index["leaves"]}

    leaves = jax.tree_util.tree_leaves_with_path(
        target, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    out = []
    for p, tgt in leaves:
        entry = by_path[jax.tree_util.keystr(p)]
        arr = _decode(np.load(os.path.join(path, entry["file"])),
                      entry["dtype"])
        sharding = getattr(tgt, "sharding", None)
        if sharding is not None:
            out.append(jax.device_put(arr, sharding))
        else:
            out.append(jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(
        target, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Retention + async saves."""

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree):
        self.wait()
        # Snapshot on the caller thread (device_get) so training can
        # mutate state while I/O proceeds in the background.
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def _do():
            save_checkpoint(self.directory, step, host_tree)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, target):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, load_checkpoint(self.directory, step, target)
