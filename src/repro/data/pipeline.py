"""Data pipeline: deterministic synthetic tokens + memmapped corpora.

Determinism contract (the fault-tolerance keystone): ``batch_at(step)``
is a pure function of (seed, step, shape) — a restart from any
checkpoint reproduces the exact token stream of an uninterrupted run,
and a re-sharded (elastic) restart reproduces it too, because batches
are generated in *global* order and sliced per host afterwards.

The synthetic stream is a Zipf-ish Markov chain rather than uniform
noise so that small LMs actually have structure to learn in the
examples and the MDM accuracy benchmark (Fig-6 analogue) shows
meaningful degradation/recovery.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticTokenDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 2            # Markov order of the synthetic language

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def batch_at(self, step: int) -> np.ndarray:
        """(global_batch, seq_len + 1) int32 tokens, pure in (seed, step)."""
        rng = self._rng(step)
        B, S, V = self.global_batch, self.seq_len + 1, self.vocab_size
        # Deterministic "language": token ~ f(prev tokens) with Zipf bias.
        base = rng.zipf(1.5, size=(B, S)).astype(np.int64)
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = base[:, 0] % V
        mix_a, mix_b = 2654435761, 40503
        for t in range(1, S):
            prev = toks[:, t - 1]
            prev2 = toks[:, t - 2] if t >= 2 else prev
            det = (prev * mix_a + prev2 * mix_b) % V
            use_det = (base[:, t] % 4) != 0          # 75% predictable
            toks[:, t] = np.where(use_det, det, base[:, t] % V)
        return toks.astype(np.int32)


@dataclasses.dataclass
class MemmapTokenDataset:
    """Flat binary token file (uint16/uint32), random crops by step."""

    path: str
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    dtype: str = "uint16"

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        if len(self._data) < self.seq_len + 1:
            raise ValueError("token file shorter than one sequence")

    def batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        hi = len(self._data) - self.seq_len - 1
        starts = rng.integers(0, hi, size=self.global_batch)
        out = np.stack([np.asarray(
            self._data[s:s + self.seq_len + 1]) for s in starts])
        return (out.astype(np.int64) % self.vocab_size).astype(np.int32)


def make_dataset(kind: str, **kw):
    if kind == "synthetic":
        return SyntheticTokenDataset(**kw)
    if kind == "memmap":
        return MemmapTokenDataset(**kw)
    raise KeyError(kind)
