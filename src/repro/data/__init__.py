from repro.data.pipeline import (  # noqa: F401
    MemmapTokenDataset,
    SyntheticTokenDataset,
    make_dataset,
)
