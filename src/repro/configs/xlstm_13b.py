"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 —
alternating sLSTM + mLSTM blocks (d_ff=0: the blocks carry their own
up/down projections, no separate FFN).  [arXiv:2405.04517; unverified]
Long-context eligible: O(1) recurrent state, no KV cache.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    mlp_type="none",
    ssm_expand=2,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                       vocab_size=256, attn_chunk=16)
