"""Config system: model, CIM deployment, parallelism and run configs."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class CimConfig:
    """CIM deployment of matmuls onto memristive crossbars (the paper)."""

    enabled: bool = False
    # Mapping strategy: a named pipeline ("baseline" | "reverse" |
    # "sort" | "mdm" | "fault_aware" | "significance_weighted" |
    # "xchangr" | ...) or a "df=...,row=...,col=...,part=..." spec
    # string — resolved by repro.mapping.resolve_pipeline.  The first
    # four are the legacy mode strings (deprecation shim, identical
    # plans and cache keys).
    mode: str = "mdm"
    eta: float = 2e-3            # PR noise coefficient (Eq 17)
    rows: int = 64
    cols: int = 64
    n_bits: int = 8
    r: float = 2.5
    r_on: float = 300e3
    r_off: float = 3e6


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description covering the whole assigned pool.

    ``block_pattern`` is the repeating unit of per-layer block types
    ("attn", "mamba", "hybrid", "mlstm", "slstm"); n_layers must be a
    multiple of its length.  Layers are scanned over pattern repeats.
    """

    name: str = "model"
    family: str = "dense"        # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0            # 0 -> d_model // n_heads
    block_pattern: tuple = ("attn",)
    # attention
    rope_theta: float = 1e4
    qkv_bias: bool = False
    sliding_window: int = 0      # 0 = global attention
    attn_chunk: int = 512        # KV chunk of the flash-attention scan
    # "jax" = pure-JAX chunked scan (differentiable, runs anywhere);
    # "pallas" = VMEM-resident TPU kernel (inference paths; interpret
    # mode on CPU).
    attn_impl: str = "jax"
    # MoE
    n_experts: int = 0
    n_experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0            # routed-expert hidden width (0 -> d_ff)
    capacity_factor: float = 1.25
    # MoE dispatch strategy: "global" sorts the full token set (simple,
    # but index-dependent gathers replicate under SPMD); "grouped" sorts
    # per batch-group so every dispatch tensor keeps a sharded leading
    # dim (production setting — see EXPERIMENTS.md §Perf).
    moe_dispatch: str = "global"
    # GQA KV broadcast inside flash attention: "repeat" (reshape-based)
    # or "take" (static gather — keeps the H dim intact and TP-sharded).
    gqa_broadcast: str = "repeat"
    # Remat the flash-attention chunk body: backward recomputes the
    # (B,Sq,H,chunk) score tensors per chunk instead of saving them
    # stacked over chunks (§Perf).
    attn_remat_chunk: bool = False
    # KV-cache write: "scatter" (index-array .at[].set — general, but
    # SPMD replicates the cache for data-dependent indices) or "dus"
    # (contiguous dynamic-update-slice — shard-local; valid whenever the
    # cache has no ring wraparound, i.e. all non-sliding-window archs).
    cache_update: str = "scatter"
    # Attention activation sharding when heads don't divide the TP axis:
    # "head_dim" (contraction-sharded QK -> per-chunk score all-reduce)
    # or "query" (shard Sq over the model axis — attention is
    # embarrassingly parallel over queries; one activation gather per
    # layer instead). §Perf bonus iteration.
    attn_fallback_shard: str = "head_dim"
    # SSM / recurrent
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 1
    ssm_chunk: int = 64          # mamba chunked-scan length
    mlstm_chunk: int = 128       # mLSTM chunkwise length
    # sLSTM tensor-parallel strategy: "shard" puts the recurrent matmul's
    # contraction dim on the model axis (one tiny all-reduce per
    # *timestep* — latency-catastrophic at 4k steps); "replicate"
    # computes the small recurrence redundantly on every model shard and
    # keeps TP for the big input/output projections (§Perf).
    slstm_tp: str = "shard"
    # frontend stubs for [vlm]/[audio]: inputs are precomputed embeddings
    frontend: str = ""           # "" | "vision" | "audio"
    # misc
    mlp_type: str = "swiglu"     # swiglu | gelu | none
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: str = "full"          # full | dots | none
    logical_rules: str = "default"  # sharding rule set (perf hillclimb knob)
    loss_chunk: int = 0          # 0 = unchunked cross-entropy
    cim: CimConfig = field(default_factory=CimConfig)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_repeats(self) -> int:
        if self.n_layers % len(self.block_pattern):
            raise ValueError(f"{self.name}: n_layers={self.n_layers} not a "
                             f"multiple of pattern {self.block_pattern}")
        return self.n_layers // len(self.block_pattern)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 128 multiple so the TP axis always divides it
        (e.g. hymba's 32001 -> 32128); padded logits are masked in the loss."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def is_recurrent_only(self) -> bool:
        return all(b in ("mamba", "mlstm", "slstm") for b in self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """True if decode state is O(1) in context (SSM/recurrent archs) or
        attention is windowed — the long_500k eligibility rule."""
        has_global_attn = any(b in ("attn", "hybrid") for b in self.block_pattern)
        return (not has_global_attn) or self.sliding_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1        # grad-accumulation factor
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    grad_compression: str = ""   # "" | "int8_ef" (cross-pod error-feedback)
    log_every: int = 10


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    fsdp_pods: bool = False      # extend the FSDP axis over "pod"

    @property
    def shape(self) -> tuple:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> tuple:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")
