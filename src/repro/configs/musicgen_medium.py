"""musicgen-medium [audio]: 48L d_model=1536 24H (GQA kv=24) d_ff=6144
vocab=2048 — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284; hf]
The EnCodec frontend is a STUB: ``input_specs`` feeds precomputed frame
embeddings; decode operates on codec tokens (vocab 2048).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    block_pattern=("attn",),
    mlp_type="gelu",
    frontend="audio",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=48, n_heads=4, n_kv_heads=4,
                       d_ff=96, vocab_size=128, attn_chunk=16)
