"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + Mamba heads in the same
block (fused hybrid head), sliding-window attention on most layers.
[arXiv:2411.13676; hf]

vocab 32001 is padded to 32128 for the 16-way TP axis (logits masked).
Long-context eligible: SWA + O(1) Mamba state.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    block_pattern=("hybrid",),
    sliding_window=1024,
    ssm_state=16,
    ssm_conv=4,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=256, sliding_window=32,
                       ssm_state=4, attn_chunk=16)
