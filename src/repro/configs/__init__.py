"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    CimConfig,
    MeshConfig,
    ModelConfig,
    ShapeConfig,
    SHAPES,
    TrainConfig,
)

# arch id -> module name
ARCHS: dict[str, str] = {
    "internvl2-76b": "internvl2_76b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "phi3-mini-3.8b": "phi3_mini_38b",
    "internlm2-20b": "internlm2_20b",
    "qwen2.5-32b": "qwen25_32b",
    "hymba-1.5b": "hymba_15b",
    "musicgen-medium": "musicgen_medium",
    "xlstm-1.3b": "xlstm_13b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def arch_shape_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch x shape) cells, with long_500k eligibility
    resolved (ineligible archs are skipped per DESIGN.md)."""
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape == "long_500k" and not cfg.supports_long_context:
                continue
            cells.append((arch, shape))
    return cells
