"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
Routed-expert hidden width is 1408; the shared-expert path uses
4 * 1408 = 5632 (the HF shared_expert_intermediate_size).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5632,            # shared-expert path width (4 fused shared experts)
    vocab_size=151936,
    block_pattern=("attn",),
    qkv_bias=True,
    n_experts=60,
    n_experts_per_token=4,
    n_shared_experts=4,
    moe_d_ff=1408,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                       d_ff=96, vocab_size=256, n_experts=8,
                       n_experts_per_token=4, n_shared_experts=2,
                       moe_d_ff=32, attn_chunk=16)
