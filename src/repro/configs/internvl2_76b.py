"""internvl2-76b [vlm]: InternViT frontend (stub) + InternLM2-76B backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[arXiv:2404.16821; unverified]
The ViT frontend is a STUB: ``input_specs`` feeds precomputed patch
embeddings of width d_model (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=("attn",),
    frontend="vision",
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=256, attn_chunk=16)
