"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("attn",),
    sliding_window=4096,
    n_experts=8,
    n_experts_per_token=2,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=256, n_experts=4,
                       n_experts_per_token=2, sliding_window=32, attn_chunk=16)
