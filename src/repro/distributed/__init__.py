from repro.distributed.sharding import (  # noqa: F401
    RULE_SETS,
    ShardingCtx,
    logical_spec,
    shard,
)
from repro.distributed.solver_shard import (  # noqa: F401
    ShardedSolveResult,
    measured_nf_sharded,
    solve_crossbar_sharded,
    tile_mesh,
    tile_sharding_ctx,
)
