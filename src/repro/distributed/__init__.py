from repro.distributed.sharding import (  # noqa: F401
    RULE_SETS,
    ShardingCtx,
    logical_spec,
    shard,
)
