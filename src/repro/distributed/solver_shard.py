"""Device-sharded crossbar solver: the layer-scale NF sweep engine.

A real DNN layer shards into thousands of crossbar tiles (a ResNet
conv layer at 64x64 tiles is ~1k-10k of them), and the solver state is
embarrassingly parallel over the tile axis — so the fused batched PCG
of :mod:`repro.crossbar.batched` scales out by simply splitting the
tile batch across a device mesh:

* the tile batch is laid out over a 1-D ``"tiles"`` mesh (all local
  devices by default) or any :class:`repro.distributed.sharding
  .ShardingCtx` mesh whose rules resolve the logical ``"tiles"`` dim;
* each shard runs the *whole* fused CG loop (:func:`repro.crossbar.
  batched._solve_core`) on its local tile slice under
  :func:`repro.compat.shard_map` — there are **no collectives inside
  the iteration loop**, so every shard early-exits the moment its own
  tiles converge instead of spinning until the globally worst tile is
  done (per-shard early exit);
* the only cross-device communication is the **global convergence
  check after the loop**: one ``psum`` counts still-unconverged tiles
  across shards and one ``pmax`` reports the worst-shard iteration
  count, both replicated so the host reads them without a gather;
* the preconditioner kernel is selectable per call (``chain_impl``):
  the default ``"lax"`` scan is work-optimal and lets the concurrent
  shard programs hide its sequential-step latency across the host's
  cores; ``"assoc"`` (Thomas factorisation applied via log-depth
  associative scans, no backend-specific lowering needed) wins when
  shards run with idle compute to spare — isolated solves, or
  accelerators without a batched ``tridiagonal_solve`` lowering;
* batches that don't divide the shard count are padded with zero-drive
  tiles (``b = 0`` makes them converge at iteration 0) and unpadded on
  the way out;
* the mesh is an ordinary ``jax.sharding.Mesh``, so the same code is
  mesh-ready for multi-host: on a multi-process runtime the ``"tiles"``
  axis simply spans all processes' local devices.

Precision composes orthogonally: pass any
:class:`repro.crossbar.batched.SolverPrecision` (e.g. ``MIXED`` for
f32 CG + f64 polish) and each shard runs that policy locally.
Throughput rows for sharded/mixed configurations are recorded by
``benchmarks/solver_throughput.py``.
"""
from __future__ import annotations

from functools import lru_cache
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import enable_x64, shard_map
from repro.core.tiling import CrossbarSpec
from repro.crossbar.batched import (
    SolverPrecision,
    SolverReport,
    _escalate_failed,
    _ref_subset,
    _solve_core,
    _solve_core_g,
    record_solver_report,
    resolve_precision,
    solve_conductances_batched,
    tile_converged,
)
from repro.distributed.sharding import ShardingCtx, logical_spec

TILE_AXIS = "tiles"


class ShardedSolveResult(NamedTuple):
    """Per-tile results plus the post-loop global convergence check.

    The first six fields mirror
    :class:`repro.crossbar.batched.BatchedSolveResult` (consumers can
    treat the two interchangeably); ``iterations`` is the worst shard's
    count (pmax) and ``unconverged`` the psum-reduced number of tiles
    that hit ``maxiter`` without passing ``tol`` — 0 means the whole
    layer population converged.
    """

    currents: jax.Array
    ideal: jax.Array
    nf_cols: jax.Array
    nf_total: jax.Array
    residual: jax.Array
    iterations: jax.Array
    unconverged: jax.Array


def tile_mesh(n_devices: int | None = None) -> Mesh:
    """1-D mesh over the local devices with the canonical tile axis."""
    devs = jax.local_devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), (TILE_AXIS,))


def tile_sharding_ctx(n_devices: int | None = None) -> ShardingCtx:
    """ShardingCtx whose mesh shards the logical "tiles" dim locally."""
    return ShardingCtx(mesh=tile_mesh(n_devices))


def _tile_axes(mesh: Mesh, rules) -> tuple[str, ...]:
    """Mesh axes the logical "tiles" dim shards over (rule-resolved).

    The dummy size passed to :func:`logical_spec` is the mesh's total
    device count, which every candidate divides — actual divisibility
    is handled by padding in :func:`measured_nf_sharded`.
    """
    total = 1
    for s in mesh.shape.values():
        total *= s
    spec = logical_spec((total,), (TILE_AXIS,), mesh, rules)
    if not spec:
        # Rules resolved "tiles" to replicated (e.g. a model-only mesh):
        # run unsharded on one device rather than failing.
        return ()
    axes = spec[0]
    return (axes,) if isinstance(axes, str) else tuple(axes)


@lru_cache(maxsize=None)
def _sharded_solver(mesh: Mesh, axes: tuple[str, ...], maxiter: int,
                    tol: float, precision: SolverPrecision,
                    chain_impl: str):
    """Build + cache the jitted shard_mapped solve for one config.

    Cached on (mesh, axes, maxiter, tol, precision, chain_impl) so
    repeated sweep calls reuse the compiled executable instead of
    re-tracing.
    """

    def local(active, v_in, spec_arr):
        # Each shard solves its slice with local early exit; the loop
        # body contains no collectives by construction.
        res = _solve_core(active, v_in, spec_arr, maxiter, tol, precision,
                          chain_impl)
        # Global convergence check — the solve's only communication.
        # NaN/Inf-aware (tile_converged): ``residual > tol`` is False
        # for NaN, which would count a diverged tile as converged.
        unconverged = jax.lax.psum(
            jnp.sum((~tile_converged(res, tol)).astype(jnp.int32)), axes)
        iters = jax.lax.pmax(res.iterations, axes)
        return ShardedSolveResult(res.currents, res.ideal, res.nf_cols,
                                  res.nf_total, res.residual, iters,
                                  unconverged)

    tiled = P(axes)
    out = ShardedSolveResult(tiled, tiled, tiled, tiled, tiled, P(), P())
    # check_vma=False: per-shard trip counts are data-dependent by
    # design (that is the early-exit win), which the replication checker
    # cannot express; the replicated outputs are produced by explicit
    # collectives above.
    fn = shard_map(local, mesh=mesh,
                   in_specs=(tiled, tiled, P()), out_specs=out,
                   check_vma=False)
    return jax.jit(fn)


@lru_cache(maxsize=None)
def _sharded_solver_g(mesh: Mesh, axes: tuple[str, ...], maxiter: int,
                      tol: float, precision: SolverPrecision,
                      chain_impl: str):
    """Conductance-field variant of :func:`_sharded_solver`.

    Same shard layout and post-loop global check, but the per-shard body
    is :func:`repro.crossbar.batched._solve_core_g` over perturbed /
    reference conductance pairs — the scale-out tier of the Monte-Carlo
    nonideality engine (:mod:`repro.nonideal.montecarlo`), whose sample
    axis is folded into the sharded tile axis.
    """

    def local(g, g_ref, v_in, spec_arr):
        res = _solve_core_g(g, g_ref, v_in, spec_arr, maxiter, tol,
                            precision, chain_impl)
        unconverged = jax.lax.psum(
            jnp.sum((~tile_converged(res, tol)).astype(jnp.int32)), axes)
        iters = jax.lax.pmax(res.iterations, axes)
        return ShardedSolveResult(res.currents, res.ideal, res.nf_cols,
                                  res.nf_total, res.residual, iters,
                                  unconverged)

    tiled = P(axes)
    out = ShardedSolveResult(tiled, tiled, tiled, tiled, tiled, P(), P())
    fn = shard_map(local, mesh=mesh,
                   in_specs=(tiled, tiled, tiled, P()), out_specs=out,
                   check_vma=False)
    return jax.jit(fn)


def solve_crossbar_sharded(active: jax.Array, v_in: jax.Array,
                           spec_arr: jax.Array, mesh: Mesh,
                           axes: tuple[str, ...], maxiter: int = 4000,
                           tol: float = 1e-12,
                           precision: SolverPrecision | None = None,
                           chain_impl: str = "lax"
                           ) -> ShardedSolveResult:
    """Shard a (T, J, K) tile batch over ``axes`` of ``mesh`` and solve.

    ``T`` must already be a multiple of the sharded device count and
    ``v_in`` already broadcast to (T, J) — :func:`measured_nf_sharded`
    is the padding/broadcasting front door.  ``chain_impl`` picks the
    preconditioner kernel (see
    :func:`repro.crossbar.batched._line_preconditioner`): "lax" is
    work-optimal when the shards saturate the host, "assoc" is the
    portable log-depth kernel for backends without a batched
    ``tridiagonal_solve`` lowering.
    """
    precision = resolve_precision(precision)
    return _sharded_solver(mesh, tuple(axes), maxiter, float(tol),
                           precision, chain_impl)(active, v_in, spec_arr)


def measured_nf_sharded(active: jax.Array, spec: CrossbarSpec,
                        v_in: jax.Array | None = None,
                        maxiter: int = 4000,
                        precision: SolverPrecision | str | None = None,
                        ctx: ShardingCtx | None = None,
                        tol: float = 1e-12,
                        chain_impl: str = "lax") -> ShardedSolveResult:
    """Circuit-measured NF of a layer-scale tile population, sharded.

    Drop-in scale-out of :func:`repro.crossbar.batched
    .measured_nf_batched`: ``active`` is (..., J, K) with arbitrary
    leading batch dims; the result carries the same leading dims plus
    the global convergence fields.  ``ctx`` supplies the mesh (default:
    a fresh 1-D mesh over all local devices); the logical "tiles" dim
    is resolved through the ctx's sharding rules, so the same call
    works on a dedicated tile mesh or on the data axis of a training
    mesh.  Tile counts that don't divide the shard count are padded
    with zero-drive tiles (converged at iteration 0) and unpadded.
    """
    precision = resolve_precision(precision)
    if ctx is None or ctx.mesh is None:
        ctx = tile_sharding_ctx()
    mesh = ctx.mesh
    axes = _tile_axes(mesh, ctx.rules)
    if not axes:
        # Rules replicate "tiles" on this mesh: degrade to the fused
        # single-device engine, synthesising the global-check fields.
        from repro.crossbar.batched import measured_nf_batched
        res = measured_nf_batched(active, spec, v_in, maxiter, precision)
        return ShardedSolveResult(
            *res[:5], res.iterations,
            jnp.sum((~tile_converged(res, tol)).astype(jnp.int32)))
    n_shards = 1
    for a in axes:
        n_shards *= dict(mesh.shape)[a]

    with enable_x64():
        spec_arr = jnp.array([spec.r, spec.r_on, spec.r_off], jnp.float64)
        if v_in is None:
            v_in = jnp.full((active.shape[-2],), spec.v_read, jnp.float64)
        batch_shape = active.shape[:-2]
        flat = active.reshape((-1,) + active.shape[-2:])
        T, J = flat.shape[0], flat.shape[1]
        v = jnp.broadcast_to(
            v_in.astype(jnp.float64),
            (T, J) if v_in.ndim == 1 else v_in.shape
        ).reshape(T, J)

        pad = (-T) % n_shards
        if pad:
            flat = jnp.concatenate(
                [flat, jnp.zeros((pad,) + flat.shape[1:], flat.dtype)])
            v = jnp.concatenate([v, jnp.zeros((pad, J), v.dtype)])

        res = solve_crossbar_sharded(flat, v, spec_arr, mesh, axes,
                                     maxiter, tol, precision, chain_impl)
        if pad:
            res = ShardedSolveResult(
                *(f[:T] for f in res[:5]), res.iterations, res.unconverged)
        if batch_shape != (T,):
            res = ShardedSolveResult(
                *(f.reshape(batch_shape + f.shape[1:]) for f in res[:5]),
                res.iterations, res.unconverged)
        return res


def measured_nf_conductances_sharded(
        g: jax.Array, spec: CrossbarSpec,
        g_ref: jax.Array | None = None,
        v_in: jax.Array | None = None,
        maxiter: int = 4000,
        precision: SolverPrecision | str | None = None,
        ctx: ShardingCtx | None = None,
        tol: float = 1e-12,
        chain_impl: str = "lax") -> ShardedSolveResult:
    """Sharded circuit-measured NF of perturbed conductance fields.

    Scale-out twin of :func:`repro.crossbar.batched
    .measured_nf_conductances`: ``g`` is (..., J, K) per-cell
    conductances with arbitrary leading batch dims (the Monte-Carlo
    engine's ``(samples, tiles)`` axes land here flattened), ``g_ref``
    the matching clean conductances the NF is measured against.
    Non-divisible batches are padded with zero-drive tiles.
    """
    precision = resolve_precision(precision)
    if ctx is None or ctx.mesh is None:
        ctx = tile_sharding_ctx()
    mesh = ctx.mesh
    axes = _tile_axes(mesh, ctx.rules)
    if not axes:
        from repro.crossbar.batched import measured_nf_conductances
        res = measured_nf_conductances(g, spec, g_ref, v_in, maxiter,
                                       precision, chain_impl)
        return ShardedSolveResult(
            *res[:5], res.iterations,
            jnp.sum((~tile_converged(res, tol)).astype(jnp.int32)))
    n_shards = 1
    for a in axes:
        n_shards *= dict(mesh.shape)[a]

    with enable_x64():
        spec_arr = jnp.array([spec.r, spec.r_on, spec.r_off], jnp.float64)
        if v_in is None:
            v_in = jnp.full((g.shape[-2],), spec.v_read, jnp.float64)
        batch_shape = g.shape[:-2]
        flat = g.reshape((-1,) + g.shape[-2:]).astype(jnp.float64)
        # The reference field is materialised at the full ensemble shape
        # here (unlike the batched engine, which broadcasts inside its
        # jit): the shard_map in_specs slice ref and g along the same
        # flattened tile axis, and a per-shard (T, J, K) replica of an
        # unexpanded reference would cost *more* memory than the
        # ensemble slice whenever n_shards > n_samples.  Each device
        # ends up holding only its 1/n_shards slice.
        ref = flat if g_ref is None else jnp.broadcast_to(
            g_ref, g.shape).reshape(flat.shape).astype(jnp.float64)
        T, J = flat.shape[0], flat.shape[1]
        v = jnp.broadcast_to(
            v_in.astype(jnp.float64),
            (T, J) if v_in.ndim == 1 else v_in.shape
        ).reshape(T, J)

        pad = (-T) % n_shards
        if pad:
            zt = jnp.zeros((pad,) + flat.shape[1:], flat.dtype)
            flat = jnp.concatenate([flat, zt])
            ref = jnp.concatenate([ref, zt])
            v = jnp.concatenate([v, jnp.zeros((pad, J), v.dtype)])

        res = _sharded_solver_g(mesh, tuple(axes), maxiter, float(tol),
                                precision, chain_impl)(flat, ref, v,
                                                       spec_arr)
        if pad:
            res = ShardedSolveResult(
                *(f[:T] for f in res[:5]), res.iterations, res.unconverged)
        if batch_shape != (T,):
            res = ShardedSolveResult(
                *(f.reshape(batch_shape + f.shape[1:]) for f in res[:5]),
                res.iterations, res.unconverged)
        return res


def measured_nf_conductances_sharded_checked(
        g: jax.Array, spec: CrossbarSpec,
        g_ref: jax.Array | None = None,
        v_in: jax.Array | None = None,
        maxiter: int = 4000,
        precision: SolverPrecision | str | None = None,
        ctx: ShardingCtx | None = None,
        tol: float = 1e-12,
        chain_impl: str = "lax",
        escalate: bool = True):
    """:func:`measured_nf_conductances_sharded` + convergence watchdog.

    The sharded solve runs as-is (its post-loop psum already counts
    failures NaN-aware); any failed tiles are then escalated on the
    host through the single-device batched engine — the failure set is
    a handful of tiles by construction, so a sharded rerun would be all
    dispatch overhead.  Returns ``(ShardedSolveResult, SolverReport)``
    with escalated tiles patched in and the ``unconverged`` count
    recomputed.
    """
    precision = resolve_precision(precision)
    res = measured_nf_conductances_sharded(g, spec, g_ref, v_in, maxiter,
                                           precision, ctx, tol,
                                           chain_impl)
    with enable_x64():
        J, K = g.shape[-2], g.shape[-1]
        batch_shape = g.shape[:-2]
        flat = ShardedSolveResult(
            *(jnp.reshape(f, (-1,) + f.shape[len(batch_shape):])
              for f in res[:5]), res.iterations, res.unconverged)
        base = flat[:5] + (flat.iterations,)
        from repro.crossbar.batched import BatchedSolveResult
        bres = BatchedSolveResult(*base)
        if not escalate:
            conv = tile_converged(bres, tol)
            if len(batch_shape) != 1:
                conv = conv.reshape(batch_shape)
            report = SolverReport(conv, res.iterations, 0,
                                  jnp.sum(~conv))
            record_solver_report(report)
            return res, report

        spec_arr = jnp.array([spec.r, spec.r_on, spec.r_off],
                             jnp.float64)
        if v_in is None:
            v_in_eff = jnp.full((J,), spec.v_read, jnp.float64)
        else:
            v_in_eff = v_in
        flat_v = (v_in_eff.reshape((-1, v_in_eff.shape[-1]))
                  if v_in_eff.ndim > 1 else v_in_eff)
        g_flat = g.reshape(-1, J, K).astype(jnp.float64)
        g_ref_eff = g if g_ref is None else g_ref

        def rerun(idx, prec_e, chain_e, mi_e):
            v_e = flat_v[idx] if flat_v.ndim > 1 else flat_v
            return solve_conductances_batched(
                g_flat[idx], _ref_subset(g_ref_eff, g.shape, idx, J, K),
                v_e, spec_arr, mi_e, tol, precision=prec_e,
                chain_impl=chain_e)

        bres, report = _escalate_failed(bres, rerun, precision,
                                        chain_impl, maxiter, tol)
        res = ShardedSolveResult(
            *(f.reshape(batch_shape + f.shape[1:]) for f in bres[:5]),
            bres.iterations, report.n_failed.astype(jnp.int32))
        if len(batch_shape) != 1:
            report = report._replace(
                converged=report.converged.reshape(batch_shape))
        record_solver_report(report)
        return res, report
