"""Logical-axis sharding rules with divisibility fallback.

Every tensor dim carries a *logical* name ("embed", "heads", "mlp", ...).
A rule set maps each name to an ordered list of mesh-axis candidates; the
first candidate whose axes (a) exist in the mesh, (b) are not already
used by another dim of the same tensor, and (c) evenly divide the dim
size, wins.  This gives MaxText-style 2-D (FSDP x TP) weight sharding
that degrades gracefully for awkward dims — e.g. deepseek's 56 heads
don't divide a 16-way model axis, so the "heads" dim replicates and the
"head_dim" fallback picks up the model axis instead.

Rule sets are selectable per-config (``cfg.logical_rules``) — the
hillclimbing knob for §Perf.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# name -> ordered candidates; each candidate is a tuple of mesh axes
# (meaning "shard this dim over the product of these axes").
Rules = dict[str, list[tuple[str, ...]]]

_DEFAULT: Rules = {
    # crossbar solver: the embarrassingly-parallel tile batch axis
    # (repro.distributed.solver_shard); a dedicated "tiles" mesh wins,
    # else the data-parallel axes of a training mesh.
    "tiles":     [("tiles",), ("pod", "data"), ("data",)],
    # activations
    "batch":     [("pod", "data"), ("data",)],
    "seq":       [],                      # replicated (no sequence parallel)
    "act_embed": [],
    "act_mlp":   [("model",)],
    "act_heads": [("model",)],
    "act_kv":    [("model",)],
    "act_head_dim": [("model",)],         # fallback after act_heads/act_kv
    "act_seq_q": [("model",)],            # query-parallel attention
    "act_vocab": [("model",)],
    # weights: "embed" is the FSDP dim, feature dims take the TP axis
    "embed":     [("data",)],
    "mlp":       [("model",)],
    "heads":     [("model",)],
    "kv_heads":  [("model",)],
    "head_dim":  [("model",)],
    "vocab":     [("model",)],
    "experts":   [],                      # E rarely divides an axis; TP inside
    "inner":     [("model",)],
    "state":     [],
    "conv":      [],
    "layers":    [],
    # caches
    "cache_batch": [("pod", "data"), ("data",)],
    "cache_seq":   [],
    "cache_kv":    [("model",)],
    "cache_head_dim": [("model",)],
}

# FSDP extended over the pod axis (params sharded across pods too).
_FSDP_PODS: Rules = dict(_DEFAULT, embed=[("pod", "data"), ("data",)])

# Sequence-parallel activations: shard seq over "model" between blocks
# (norms/elementwise), gathered at attention/matmul boundaries by SPMD.
_SEQPAR: Rules = dict(_DEFAULT, seq=[("model",)])

# Expert-parallel MoE: shard the expert dim over the model axis when E
# divides it (falls back to TP-inside-expert otherwise, same as default).
_EXPERT: Rules = dict(_DEFAULT, experts=[("model",)])

RULE_SETS: dict[str, Rules] = {
    "default": _DEFAULT,
    "fsdp_pods": _FSDP_PODS,
    "seqpar": _SEQPAR,
    "expert": _EXPERT,
}


@dataclass(frozen=True)
class ShardingCtx:
    """Mesh + rule set threaded through model code. mesh=None => no-op
    (single-device smoke tests)."""

    mesh: Mesh | None = None
    rules_name: str = "default"

    @property
    def rules(self) -> Rules:
        return RULE_SETS[self.rules_name]


def logical_spec(shape: tuple[int, ...], dims: tuple[str | None, ...],
                 mesh: Mesh | None, rules: Rules) -> P:
    """Resolve logical dim names to a concrete PartitionSpec."""
    if mesh is None:
        return P()
    if len(shape) != len(dims):
        raise ValueError(f"shape {shape} vs dims {dims}")
    axis_sizes = dict(mesh.shape)  # works for Mesh and AbstractMesh
    used: set[str] = set()
    out: list = []
    for size, name in zip(shape, dims):
        picked = None
        for cand in (rules.get(name, []) if name else []):
            if not all(a in axis_sizes for a in cand):
                continue
            if any(a in used for a in cand):
                continue
            prod = 1
            for a in cand:
                prod *= axis_sizes[a]
            if size % prod == 0:
                picked = cand
                used.update(cand)
                break
        out.append(picked if picked is None else
                   (picked[0] if len(picked) == 1 else picked))
    # Trim trailing Nones for tidy specs.
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, ctx: ShardingCtx, *dims: str | None) -> jax.Array:
    """with_sharding_constraint by logical dim names (no-op without mesh)."""
    if ctx.mesh is None:
        return x
    spec = logical_spec(x.shape, tuple(dims), ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def named_sharding(ctx: ShardingCtx, shape: tuple[int, ...],
                   dims: tuple[str | None, ...]) -> NamedSharding | None:
    if ctx.mesh is None:
        return None
    return NamedSharding(ctx.mesh,
                         logical_spec(shape, dims, ctx.mesh, ctx.rules))
