"""Gradient compression for cross-pod all-reduce: int8 with error feedback.

At multi-pod scale the "pod" axis rides the slowest links (DCI/optical),
so the cross-pod gradient all-reduce is compressed: per-tensor-block
scaled int8 quantisation, summed in int32, dequantised, with the
quantisation residual fed back into the next step's gradient (error
feedback keeps the scheme unbiased-in-the-limit; convergence tested in
tests/test_compression.py).

Implemented with shard_map over the "pod" axis: inside the mapped
function the gradients are the per-pod partial sums; we quantise,
psum over "pod", and dequantise.  Intra-pod reductions stay full
precision (fast ICI), matching production practice.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(x: jax.Array):
    """Round-trip (for error-feedback accounting). Returns (xq, residual)."""
    q, s = quantize_int8(x)
    xq = dequantize_int8(q, s)
    return xq, x - xq


def psum_compressed(grads, error, axis_name: str = "pod"):
    """Error-feedback int8 psum over ``axis_name``.

    grads/error: pytrees of f32 per-shard partial gradients.  Returns
    (reduced_grads, new_error).  Must run inside shard_map with
    ``axis_name`` in scope.
    """

    def one(g, e):
        g = g + e                           # inject residual
        q, s = quantize_int8(g)
        # sum int8 payloads in int32; scales are tiny, psum them raw
        qs = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ssum = jax.lax.psum(s, axis_name)   # conservative shared scale
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        # dequantise with the mean scale (per-shard scales are close for
        # statistically homogeneous DP gradients)
        out = qs.astype(jnp.float32) * (ssum / n)
        local = dequantize_int8(q, s)
        return out, g - local               # residual of the local payload

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return red, new_e
