"""reprolint: repo-specific static analysis for the JAX/Pallas contracts.

Successive PRs of growth accreted engineering contracts that nothing
enforced; this package enforces them:

=======  ==========================  =====================================
code     name                        contract
=======  ==========================  =====================================
RPL001   compat-routing              version-sensitive JAX APIs
                                     (shard_map, AbstractMesh,
                                     enable_x64, capability probes) only
                                     through ``repro/compat.py``
RPL002   tracer-escape               no float()/int()/bool()/.item()/
                                     np.asarray inside jit/shard_map-
                                     decorated functions
RPL003   prng-key-discipline         no key reuse without split/fold_in;
                                     no literal-seed PRNGKey in library
                                     code
RPL004   interpret-test-only         ``interpret=True`` / interpret-
                                     default dispatch only under tests/
RPL005   import-time-jnp             no module-level jax.numpy
                                     computation
RPL006   telemetry-clock             no raw time.time()/perf_counter()/
                                     monotonic() in library code; route
                                     through ``repro.telemetry``
=======  ==========================  =====================================

Two tiers:

* the **AST linter** (:mod:`repro.analysis.core` +
  :mod:`repro.analysis.rules`, CLI in :mod:`repro.analysis.cli` /
  ``scripts/lint.py``) never imports the linted code — whole-``src/``
  runs are sub-second and jax-free;
* the **semantic auditor** (:mod:`repro.analysis.audit`) imports the
  live registries and checks what syntax can't see: every behavioral
  field of every registered mapping pass must reach the pipeline
  fingerprint *and* the plan-cache key (else the content-addressed
  ``PlanCache`` silently serves stale plans), and the benchmark
  registry must agree with the files on disk and with
  ``scripts/test_nightly.sh``.

``repro.analysis.audit`` is deliberately **not** imported here so that
``from repro.analysis import run_paths`` (and the lint CLI) stays
jax-free.

Suppression syntax (same line as the finding, justification after
``--``)::

    key = jax.random.PRNGKey(0)  # reprolint: disable=RPL003 -- why

See docs/lint.md for the full rule-by-rule rationale.
"""
from repro.analysis.core import (  # noqa: F401
    Finding,
    all_rules,
    classify_path,
    format_human,
    format_json,
    run_paths,
    run_source,
)
