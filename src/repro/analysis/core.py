"""reprolint core: AST file model, rule registry, suppressions, runners.

The linter is a plain ``ast`` walker — it never imports the code it
checks, so ``scripts/lint.py`` stays jax-free and a whole-``src/`` run
is a sub-second operation (the tier-1 gate in
``tests/test_lint_clean.py`` budgets 5 s including interpreter
startup).  Semantic checks that *do* need the live registries
(fingerprint/cache-key coverage, benchmark registration) live in
:mod:`repro.analysis.audit` instead.

Vocabulary:

* a **rule** is a subclass of :class:`Rule` registered under a stable
  ``RPLxxx`` code (see :mod:`repro.analysis.rules`);
* a **finding** is one rule violation at one source location;
* an inline ``# reprolint: disable=RPL001`` (comma-separated codes,
  optionally followed by ``-- justification``) on the *finding line*
  marks it suppressed: it still appears in the output (and JSON) but
  does not fail the run.

File *roles* scope the rules: the key-discipline and interpret rules
deliberately don't apply to tests, and the compat module is the one
place allowed to touch the version-sensitive JAX APIs.  The role is
derived from the path (:func:`classify_path`) and can be forced by
callers (the fixture tests lint ``tests/fixtures/lint/*.py`` *as if*
they were library code).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from typing import Iterable, Iterator

# Role of a linted file; rules consult these to decide applicability.
ROLE_LIBRARY = "library"      # shipping code under src/ (the contracts)
ROLE_TOOLS = "tools"          # benchmarks / examples / scripts
ROLE_TESTS = "tests"          # anything under tests/ or test_*.py
ROLE_COMPAT = "compat"        # repro/compat.py: owns the wrapped APIs
ROLES = (ROLE_LIBRARY, ROLE_TOOLS, ROLE_TESTS, ROLE_COMPAT)

_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*disable=([A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    code: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def format(self) -> str:
        tag = "  [suppressed]" if self.suppressed else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.code} {self.message}{tag}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FileContext:
    """Everything a rule needs to check one parsed file."""

    path: str
    source: str
    tree: ast.Module
    role: str
    # name -> fully dotted path for import aliases, e.g. jnp -> jax.numpy
    aliases: dict[str, str]

    @property
    def is_tests(self) -> bool:
        return self.role == ROLE_TESTS

    @property
    def is_compat(self) -> bool:
        return self.role == ROLE_COMPAT

    @property
    def is_library(self) -> bool:
        return self.role == ROLE_LIBRARY

    def expand(self, node: ast.AST) -> str | None:
        """Dotted path of a Name/Attribute with import aliases resolved.

        ``jnp.int32`` -> ``jax.numpy.int32`` when the file did
        ``import jax.numpy as jnp``; returns None for non-name
        expressions (calls, subscripts, ...).
        """
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        parts.reverse()
        head = self.aliases.get(parts[0], parts[0])
        return ".".join([head] + parts[1:])


class Rule:
    """One registered lint rule.  Subclasses set the class attributes
    and implement :meth:`check` yielding ``(line, col, message)``."""

    code: str = ""
    name: str = ""
    rationale: str = ""

    def check(self, ctx: FileContext) -> Iterator[tuple[int, int, str]]:
        raise NotImplementedError


_RULES: dict[str, Rule] = {}


def register_rule(cls: type[Rule]) -> type[Rule]:
    """Class decorator: register a rule under its ``code``."""
    if not re.fullmatch(r"RPL\d{3}", cls.code):
        raise ValueError(f"bad rule code {cls.code!r} on {cls.__name__}")
    if cls.code in _RULES:
        raise ValueError(f"rule {cls.code} already registered "
                         f"({type(_RULES[cls.code]).__name__})")
    _RULES[cls.code] = cls()
    return cls


def all_rules() -> dict[str, Rule]:
    """Registered rules by code (sorted) — importing :mod:`repro
    .analysis.rules` populates the registry."""
    from repro.analysis import rules  # noqa: F401  (registration side effect)

    return dict(sorted(_RULES.items()))


def classify_path(path: str) -> str:
    """Derive a file's role from its path (overridable by callers)."""
    parts = os.path.normpath(os.path.abspath(path)).split(os.sep)
    base = parts[-1]
    if base == "compat.py" and "repro" in parts:
        return ROLE_COMPAT
    if "tests" in parts or base.startswith("test_"):
        return ROLE_TESTS
    if {"benchmarks", "examples", "scripts"} & set(parts[:-1]):
        return ROLE_TOOLS
    return ROLE_LIBRARY


def build_alias_map(tree: ast.Module) -> dict[str, str]:
    """Import-alias table for the whole file.

    Late rebindings shadow earlier ones file-wide — fine for lint
    granularity (nobody re-aliases ``jnp`` mid-module).
    """
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                and node.module:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def suppressions(source: str) -> dict[int, set[str]]:
    """Per-line suppressed codes from ``# reprolint: disable=...``."""
    out: dict[int, set[str]] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if m:
                codes = {c.strip() for c in m.group(1).split(",")}
                out.setdefault(tok.start[0], set()).update(codes)
    except tokenize.TokenError:  # partial file: best-effort comments
        pass
    return out


def run_source(path: str, source: str, *, role: str | None = None,
               select: Iterable[str] | None = None) -> list[Finding]:
    """Lint one source blob; returns findings with suppression applied.

    A syntactically invalid file yields a single RPL000 parse finding
    (never an exception): the linter must not crash CI on a bad tree.
    """
    role = role or classify_path(path)
    if role not in ROLES:
        raise ValueError(f"role={role!r} not in {ROLES}")
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("RPL000", path, e.lineno or 1, e.offset or 0,
                        f"file does not parse: {e.msg}")]
    ctx = FileContext(path=path, source=source, tree=tree, role=role,
                      aliases=build_alias_map(tree))
    lines = suppressions(source)
    findings: list[Finding] = []
    seen: set[tuple[str, int, int]] = set()
    for code, rule in all_rules().items():
        if select is not None and code not in select:
            continue
        for line, col, message in rule.check(ctx):
            if (code, line, col) in seen:
                continue
            seen.add((code, line, col))
            findings.append(Finding(
                code, path, line, col, message,
                suppressed=code in lines.get(line, ())))
    findings.sort(key=lambda f: (f.line, f.col, f.code))
    return findings


def iter_python_files(paths: Iterable[str]) -> Iterator[str]:
    """Expand files/directories into a sorted .py file list."""
    out: set[str] = set()
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if not d.startswith(".")
                                 and d != "__pycache__")
                out.update(os.path.join(root, f) for f in files
                           if f.endswith(".py"))
        elif p.endswith(".py"):
            out.add(p)
    return iter(sorted(out))


def run_paths(paths: Iterable[str], *, role: str | None = None,
              select: Iterable[str] | None = None
              ) -> tuple[list[Finding], int]:
    """Lint files/dirs; returns (findings, files_checked)."""
    findings: list[Finding] = []
    n = 0
    for f in iter_python_files(paths):
        n += 1
        with open(f, encoding="utf-8") as fh:
            src = fh.read()
        findings.extend(run_source(f, src, role=role, select=select))
    return findings, n


def format_human(findings: list[Finding], files: int) -> str:
    lines = [f.format() for f in findings]
    unsup = sum(1 for f in findings if not f.suppressed)
    lines.append(f"reprolint: {len(findings)} finding(s) "
                 f"({unsup} unsuppressed) in {files} file(s)")
    return "\n".join(lines)


def format_json(findings: list[Finding], files: int) -> str:
    return json.dumps({
        "version": 1,
        "files": files,
        "unsuppressed": sum(1 for f in findings if not f.suppressed),
        "findings": [f.to_json() for f in findings],
    }, indent=1)
