"""reprolint command line: ``python scripts/lint.py [paths...]``.

Kept deliberately jax-free (the AST rules never import the linted
code), so a whole-``src/`` run costs well under a second including
interpreter startup.  ``--audit`` additionally runs the registry-level
semantic auditor (:mod:`repro.analysis.audit`), which *does* import
the live mapping/benchmark registries — and therefore jax.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.core import (
    all_rules,
    format_human,
    format_json,
    run_paths,
)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="reprolint",
        description="repo-specific static analysis for the JAX/Pallas "
                    "contracts (RPL001-RPL006)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output")
    ap.add_argument("--select", default="",
                    help="comma-separated rule codes to run "
                         "(default: all)")
    ap.add_argument("--rules", action="store_true",
                    help="list registered rules and exit")
    ap.add_argument("--audit", action="store_true",
                    help="also run the semantic registry auditor "
                         "(imports the live code, needs jax)")
    args = ap.parse_args(argv)

    if args.rules:
        for code, rule in all_rules().items():
            print(f"{code} {rule.name}: {rule.rationale}")
        return 0

    select = ({c.strip() for c in args.select.split(",") if c.strip()}
              or None)
    findings, files = run_paths(args.paths, select=select)
    failed = any(not f.suppressed for f in findings)

    audit_lines: list[str] = []
    if args.audit:
        from repro.analysis.audit import run_audit

        audit_findings = run_audit()
        audit_lines = [f.format() for f in audit_findings]
        failed = failed or bool(audit_findings)

    if args.json:
        print(format_json(findings, files))
        for line in audit_lines:
            print(line, file=sys.stderr)
    else:
        print(format_human(findings, files))
        for line in audit_lines:
            print(line)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
