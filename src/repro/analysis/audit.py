"""Registry-level semantic auditor (the dynamic half of reprolint).

The AST rules (:mod:`repro.analysis.rules`) check what the *source*
says; this module imports the **live** registries and checks what the
code actually *does* against the contracts the cache layer depends on:

AUD001  a registered strategy's params are not fully covered by its
        ``fingerprint()`` (or the strategy cannot be default-built /
        a field cannot be auto-perturbed, so coverage is unverifiable);
AUD002  a strategy param does not reach the *pipeline* fingerprint;
AUD003  a strategy param does not reach the :func:`plan_key` cache
        address — the stale-plan bug class: two semantically different
        deployments would hit the same ``PlanCache`` entry;
AUD004  cache-token integrity: two semantically distinct pipeline
        combinations share a token, or a legacy mode string no longer
        round-trips to its historical token (which would orphan every
        pre-redesign cache entry);
AUD005  a ``benchmarks/`` module exists with no entry in the
        ``benchmarks.run`` registry (or the registry names a module
        file that does not exist);
AUD006  ``scripts/test_nightly.sh`` invokes a ``--only`` token the
        registry cannot resolve — before the registry grew
        :func:`benchmarks.run.resolve_only`, such a typo silently ran
        *nothing* and exited 0;
AUD007  telemetry metric declarations disagree with the live default
        :class:`repro.telemetry.MetricsRegistry`: a metric declared
        with a non-literal name (unauditable), declared twice, declared
        but absent from the live registry, or live under the
        ``repro_`` namespace with no module-level declaration in
        ``src/repro`` — dashboards scrape names, so the set must be
        statically enumerable and collision-free.

The audit is **mechanical**: it default-constructs every registered
strategy, perturbs each dataclass field in place
(``dataclasses.replace``) and asserts the three identity layers all
move.  Strategies with no params (the current built-ins) are vacuously
covered — the audit exists so the *next* parametrised pass cannot ship
with a leaky fingerprint.  ``tests/test_analysis_audit.py`` proves the
teeth by registering a deliberately leaky strategy and watching the
audit catch it.

Unlike the AST linter this module imports jax (via the mapping and
benchmark registries) — it is reached only through ``--audit`` /
``run_audit`` so plain lint runs stay sub-second.
"""
from __future__ import annotations

import ast
import dataclasses
import importlib
import os
import re
import sys

from repro.analysis.core import FileContext, build_alias_map
from repro.core.tiling import CrossbarSpec
from repro.deploy.cache import plan_key
from repro.mapping.base import KINDS, available, get_strategy
from repro.mapping.columns import IdentityCols
from repro.mapping.pipeline import (
    LEGACY_MODES,
    MappingPipeline,
    resolve_pipeline,
)
from repro.mapping.rows import FaultAwareRows, MdmRows

_W_FP = "0" * 64  # fixed weight fingerprint: only the token may vary


@dataclasses.dataclass(frozen=True)
class AuditFinding:
    """One audited contract violation."""

    code: str
    subject: str
    message: str

    def format(self) -> str:
        return f"{self.code} [{self.subject}] {self.message}"


def _perturb(value):
    """A value guaranteed != the original, same general type.

    Returns None when the field type has no mechanical perturbation
    (the audit then reports the field as unverifiable rather than
    silently passing it).
    """
    if isinstance(value, bool):
        return not value
    if isinstance(value, int):
        return value + 1
    if isinstance(value, float):
        return value * 2.0 + 1.0
    if isinstance(value, str):
        return value + "_audit"
    if isinstance(value, tuple):
        return value + value[-1:] if value else (1,)
    return None


def _pipeline_for(kind: str, strategy) -> MappingPipeline:
    return MappingPipeline(**{kind: strategy})


def audit_fingerprint_coverage() -> list[AuditFinding]:
    """Perturb every dataclass field of every registered strategy.

    For each field of each registered pass, assert the perturbation is
    visible in (1) the strategy fingerprint, (2) the pipeline
    fingerprint, and — for ``rows``/``cols`` passes — (3) the
    :func:`plan_key` cache address.  ``partition`` passes are exempt
    from (3) by documented design: partitioning changes *which*
    matrices exist, and each produced matrix is content-addressed
    individually (see :meth:`MappingPipeline.cache_token`).
    """
    findings: list[AuditFinding] = []
    spec = CrossbarSpec()
    for kind in KINDS:
        for name in available(kind):
            subject = f"{kind}/{name}"
            try:
                base = get_strategy(kind, name)
            except Exception as e:
                findings.append(AuditFinding(
                    "AUD001", subject,
                    f"cannot default-construct registered strategy: "
                    f"{e!r} — fingerprint coverage unverifiable"))
                continue
            for field in dataclasses.fields(base):
                new = _perturb(getattr(base, field.name))
                if new is None:
                    findings.append(AuditFinding(
                        "AUD001", subject,
                        f"field {field.name!r} has unsupported type for "
                        f"auto-perturbation; cannot verify it reaches "
                        f"the fingerprint"))
                    continue
                try:
                    mutated = dataclasses.replace(
                        base, **{field.name: new})
                except Exception as e:
                    findings.append(AuditFinding(
                        "AUD001", subject,
                        f"field {field.name!r} rejects perturbed value "
                        f"{new!r}: {e!r} — coverage unverifiable"))
                    continue
                if mutated.fingerprint() == base.fingerprint():
                    findings.append(AuditFinding(
                        "AUD001", subject,
                        f"fingerprint() ignores field {field.name!r} "
                        f"({base.fingerprint()!r} unchanged)"))
                p0, p1 = (_pipeline_for(kind, s) for s in (base, mutated))
                if p1.fingerprint() == p0.fingerprint():
                    findings.append(AuditFinding(
                        "AUD002", subject,
                        f"pipeline fingerprint ignores field "
                        f"{field.name!r}"))
                if kind == "partition":
                    continue
                k0 = plan_key(_W_FP, spec, p0.cache_token())
                k1 = plan_key(_W_FP, spec, p1.cache_token())
                if k0 == k1:
                    findings.append(AuditFinding(
                        "AUD003", subject,
                        f"plan_key ignores field {field.name!r}: "
                        f"cache token {p0.cache_token()!r} does not "
                        f"move — stale PlanCache hits"))
    return findings


def _rows_equiv(rows) -> str:
    """Cache-equivalence class of a row pass.

    ``FaultAwareRows()`` deliberately shares the MDM token: it reduces
    exactly to :class:`MdmRows` without fault maps, and *with* maps the
    fault fingerprint enters :func:`plan_key` separately.  Everything
    else is its own class.
    """
    if rows == MdmRows() or rows == FaultAwareRows():
        return "mdm"
    return rows.fingerprint()


def audit_cache_tokens() -> list[AuditFinding]:
    """Token-collision + legacy-token stability audit (AUD004).

    Enumerates every (dataflow, registered rows, registered cols)
    combination, groups by ``cache_token()``, and requires each token
    to map to exactly one cache-equivalence class.  Also pins the four
    legacy mode strings to their historical tokens.
    """
    findings: list[AuditFinding] = []
    token_owners: dict[str, dict[str, str]] = {}
    for dataflow in ("conventional", "reversed"):
        for rname in available("rows"):
            for cname in available("cols"):
                try:
                    pipe = MappingPipeline(
                        dataflow=dataflow,
                        rows=get_strategy("rows", rname),
                        cols=get_strategy("cols", cname))
                except Exception:
                    continue  # reported by audit_fingerprint_coverage
                equiv = (f"df={dataflow};rows={_rows_equiv(pipe.rows)};"
                         f"cols={pipe.cols.fingerprint()}")
                label = f"df={dataflow},row={rname},col={cname}"
                owners = token_owners.setdefault(pipe.cache_token(), {})
                owners.setdefault(equiv, label)
    for token, owners in token_owners.items():
        if len(owners) > 1:
            findings.append(AuditFinding(
                "AUD004", "cache_token",
                f"token {token!r} is shared by semantically distinct "
                f"pipelines: {sorted(owners.values())}"))
    for mode in LEGACY_MODES:
        token = resolve_pipeline(mode).cache_token()
        if token != mode:
            findings.append(AuditFinding(
                "AUD004", f"legacy/{mode}",
                f"legacy mode {mode!r} now yields token {token!r}; "
                f"pre-redesign PlanCache entries become unreachable"))
    # The fault-aware shim upgrade must keep the legacy token too (its
    # key is distinguished by the fault fingerprint, not the token).
    up = resolve_pipeline("mdm", have_faults=True).cache_token()
    if up != "mdm":
        findings.append(AuditFinding(
            "AUD004", "legacy/mdm+faults",
            f"fault-upgraded 'mdm' yields token {up!r} (want 'mdm')"))
    return findings


_ONLY_RE = re.compile(r"--only[= ]+([\w.]+)")


def _repo_root() -> str:
    import repro

    # repro is a namespace package (no __init__.py), so __file__ is
    # None; __path__ still holds the src/repro directory.
    pkg_dir = (os.path.dirname(os.path.abspath(repro.__file__))
               if getattr(repro, "__file__", None)
               else os.path.abspath(list(repro.__path__)[0]))
    return os.path.dirname(os.path.dirname(pkg_dir))


def _import_run():
    try:
        import benchmarks.run as run
    except ImportError:
        sys.path.insert(0, _repo_root())
        import benchmarks.run as run
    return run


def audit_benchmark_registry(module_files=None, registry=None,
                             nightly_text=None) -> list[AuditFinding]:
    """Cross-check benchmark files x registry x nightly (AUD005/6).

    The three override parameters exist for the tests: by default the
    audit reads the real ``benchmarks/`` directory, the live
    ``benchmarks.run.BENCHES`` registry, and the real
    ``scripts/test_nightly.sh``.

    ``module_files``: iterable of module names present on disk;
    ``registry``: iterable of Bench-like objects with ``.name`` and
    ``.module``; ``nightly_text``: the nightly script's source.
    """
    findings: list[AuditFinding] = []
    root = _repo_root()
    try:
        run = _import_run()
    except Exception as e:
        return [AuditFinding(
            "AUD005", "benchmarks.run",
            f"cannot import the benchmark registry: {e!r}")]
    if registry is None:
        registry = run.BENCHES
    if module_files is None:
        bench_dir = os.path.join(root, "benchmarks")
        module_files = sorted(
            f[:-3] for f in os.listdir(bench_dir)
            if f.endswith(".py") and not f.startswith("_")
            and f != "run.py")
    if nightly_text is None:
        nightly = os.path.join(root, "scripts", "test_nightly.sh")
        try:
            with open(nightly) as f:
                nightly_text = f.read()
        except OSError as e:
            findings.append(AuditFinding(
                "AUD006", "scripts/test_nightly.sh",
                f"cannot read nightly script: {e!r}"))
            nightly_text = ""

    registered = {b.module for b in registry}
    by_token = {t for b in registry for t in (b.name, b.module)}
    for mod in module_files:
        if mod not in registered:
            findings.append(AuditFinding(
                "AUD005", f"benchmarks/{mod}.py",
                "module exists but has no Bench entry in "
                "benchmarks.run.BENCHES — it never runs"))
    known_mods = set(module_files)
    for b in registry:
        if b.module not in known_mods:
            findings.append(AuditFinding(
                "AUD005", f"bench/{b.name}",
                f"registry names module {b.module!r} but "
                f"benchmarks/{b.module}.py does not exist"))

    if nightly_text:
        if "benchmarks.run" not in nightly_text:
            findings.append(AuditFinding(
                "AUD006", "scripts/test_nightly.sh",
                "nightly script never invokes benchmarks.run"))
        for token in _ONLY_RE.findall(nightly_text):
            if token not in by_token:
                findings.append(AuditFinding(
                    "AUD006", "scripts/test_nightly.sh",
                    f"--only {token!r} does not resolve to any "
                    f"registered benchmark (known: "
                    f"{sorted(b.name for b in registry)})"))
    return findings


# Default-registry factory spellings the declaration scan recognises;
# metrics built any other way (local registries, loops over computed
# names) are invisible to dashboards and flagged below.
_TM_FACTORIES = frozenset(
    f"repro.telemetry.{tail}{kind}"
    for tail in ("", "metrics.")
    for kind in ("counter", "gauge", "histogram"))


def _module_of(path: str) -> str | None:
    """``.../src/repro/x/y.py`` -> ``repro.x.y`` (None off-tree)."""
    parts = os.path.normpath(os.path.abspath(path)).split(os.sep)
    if "repro" not in parts:
        return None
    rel = parts[parts.index("repro"):]
    if rel[-1] == "__init__.py":
        rel = rel[:-1]
    elif rel[-1].endswith(".py"):
        rel[-1] = rel[-1][:-3]
    return ".".join(rel)


def audit_metric_registry(src_files=None,
                          live_names=None) -> list[AuditFinding]:
    """Static metric declarations x live default registry (AUD007).

    Scans ``src/repro`` for module-level ``tm.counter/gauge/histogram``
    declarations (the only sanctioned idiom — a metric's name must be a
    string literal so dashboards can be audited without running the
    stack), then imports the declaring modules and compares against
    ``repro.telemetry.registry().names()``.

    Test overrides: ``src_files`` maps path -> source text;
    ``live_names`` supplies the registry contents directly (both given
    => no filesystem walk, no imports).
    """
    findings: list[AuditFinding] = []
    if src_files is None:
        src_files = {}
        src_dir = os.path.join(_repo_root(), "src", "repro")
        for dirpath, _, names in os.walk(src_dir):
            for fn in sorted(names):
                if fn.endswith(".py"):
                    p = os.path.join(dirpath, fn)
                    with open(p, encoding="utf-8") as f:
                        src_files[p] = f.read()

    declared: dict[str, str] = {}
    declaring: set[str] = set()
    for path in sorted(src_files):
        source = src_files[path]
        try:
            tree = ast.parse(source)
        except SyntaxError as e:
            findings.append(AuditFinding(
                "AUD007", path, f"unparseable source: {e!r}"))
            continue
        ctx = FileContext(path=path, source=source, tree=tree,
                          role="library",
                          aliases=build_alias_map(tree))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            if (ctx.expand(node.func) or "") not in _TM_FACTORIES:
                continue
            subject = f"{os.path.basename(path)}:{node.lineno}"
            arg = node.args[0] if node.args else None
            if not (isinstance(arg, ast.Constant)
                    and isinstance(arg.value, str)):
                findings.append(AuditFinding(
                    "AUD007", subject,
                    "telemetry metric declared with a non-literal "
                    "name; the metric set must be statically "
                    "enumerable"))
                continue
            if arg.value in declared:
                findings.append(AuditFinding(
                    "AUD007", subject,
                    f"metric {arg.value!r} already declared in "
                    f"{declared[arg.value]} — the default registry "
                    f"rejects duplicates at import"))
                continue
            declared[arg.value] = subject
            declaring.add(path)

    if live_names is None:
        for path in sorted(declaring):
            mod = _module_of(path)
            if mod is None:
                continue
            try:
                importlib.import_module(mod)
            except Exception as e:
                findings.append(AuditFinding(
                    "AUD007", mod,
                    f"cannot import metric-declaring module: {e!r}"))
        from repro import telemetry
        live_names = telemetry.registry().names()

    live = set(live_names)
    for name in sorted(set(declared) - live):
        findings.append(AuditFinding(
            "AUD007", name,
            f"declared at {declared[name]} but absent from the live "
            f"default registry (conditional declaration?)"))
    for name in sorted(live - set(declared)):
        if name.startswith("repro_"):
            findings.append(AuditFinding(
                "AUD007", name,
                "live registry holds a repro_* metric with no "
                "module-level declaration under src/repro — "
                "dashboards cannot discover it statically"))
    return findings


def run_audit() -> list[AuditFinding]:
    """Full semantic audit; empty list means every contract holds."""
    return (audit_fingerprint_coverage()
            + audit_cache_tokens()
            + audit_benchmark_registry()
            + audit_metric_registry())
