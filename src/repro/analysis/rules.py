"""reprolint rules RPL001-RPL006: this repo's JAX/Pallas contracts.

Each rule machine-enforces a convention the ROADMAP records (and PRs
1-5 paid for the hard way).  None of these misuses *crash* — they
silently corrupt numbers (stale plan-cache hits, reshuffled PRNG
draws, interpret-mode "serving") or regress startup — which is exactly
why they need a linter rather than a runtime check.  See docs/lint.md
for the rule-by-rule rationale and the suppression syntax.
"""
from __future__ import annotations

import ast
import os
from typing import Iterator

from repro.analysis.core import FileContext, Rule, register_rule

# --------------------------------------------------------------------------
# RPL001 — version-sensitive JAX APIs must route through repro.compat
# --------------------------------------------------------------------------

# Banned dotted path -> the compat entry point that replaces it.
_COMPAT_WRAPPED = {
    "jax.shard_map": "repro.compat.shard_map",
    "jax.experimental.shard_map": "repro.compat.shard_map",
    "jax.sharding.AbstractMesh": "repro.compat.make_abstract_mesh",
    "jax.experimental.enable_x64": "repro.compat.enable_x64",
    "jax.enable_x64": "repro.compat.enable_x64",
}

# Calling these inside a try/except is the capability-probe pattern;
# the probes are centralised (and cached, and trace-safe) in compat.
_PROBE_TARGETS = {
    "jax.lax.linalg.tridiagonal_solve":
        "repro.compat.has_batched_tridiagonal_solve",
    "pallas_call": "repro.compat.has_pallas_lowering",
}


def _banned_path(path: str | None) -> str | None:
    if path is None:
        return None
    for banned in _COMPAT_WRAPPED:
        if path == banned or path.startswith(banned + "."):
            return banned
    return None


@register_rule
class CompatRouting(Rule):
    code = "RPL001"
    name = "compat-routing"
    rationale = ("Version-sensitive JAX APIs (shard_map, AbstractMesh, "
                 "enable_x64, backend capability probes) are wrapped in "
                 "repro/compat.py; direct use reintroduces the exact "
                 "version breaks PR 1 fixed.")

    def check(self, ctx: FileContext) -> Iterator[tuple[int, int, str]]:
        if ctx.is_compat:
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    b = _banned_path(a.name)
                    if b:
                        yield (node.lineno, node.col_offset,
                               f"direct import of {a.name}; use "
                               f"{_COMPAT_WRAPPED[b]} instead")
            elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                    and node.module:
                for a in node.names:
                    full = (node.module if a.name == "*"
                            else f"{node.module}.{a.name}")
                    b = _banned_path(full) or _banned_path(node.module)
                    if b:
                        yield (node.lineno, node.col_offset,
                               f"direct import of {full}; use "
                               f"{_COMPAT_WRAPPED[b]} instead")
            elif isinstance(node, (ast.Attribute, ast.Name)):
                b = _banned_path(ctx.expand(node))
                if b and not self._is_sub_attribute(ctx, node):
                    yield (node.lineno, node.col_offset,
                           f"direct use of {b}; use "
                           f"{_COMPAT_WRAPPED[b]} instead")
            elif isinstance(node, ast.Try):
                yield from self._probe_findings(ctx, node)

    @staticmethod
    def _is_sub_attribute(ctx: FileContext, node: ast.AST) -> bool:
        # Suppress duplicate findings on the inner Name/Attribute parts
        # of one banned chain: only the *outermost* matching node (and
        # the import that bound it) gets reported.  Cheap check: a Name
        # whose bare id doesn't expand to a banned path by itself was
        # reached as part of a larger Attribute chain and is reported
        # there.
        if isinstance(node, ast.Name):
            return _banned_path(ctx.aliases.get(node.id)) is None
        return False

    @staticmethod
    def _probe_findings(ctx: FileContext,
                        try_node: ast.Try) -> Iterator[tuple[int, int, str]]:
        for stmt in try_node.body:
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                path = ctx.expand(node.func) or ""
                for target, wrap in _PROBE_TARGETS.items():
                    if path == target or path.endswith("." + target):
                        yield (node.lineno, node.col_offset,
                               f"hand-rolled backend capability probe "
                               f"({target} inside try/except); use "
                               f"{wrap} instead")


# --------------------------------------------------------------------------
# RPL002 — no tracer escapes inside jit/shard_map-decorated functions
# --------------------------------------------------------------------------

_ESCAPE_BUILTINS = {"float", "int", "bool"}
_ESCAPE_CALLS = {"numpy.asarray", "numpy.array"}


def _is_traced_decorator(ctx: FileContext, dec: ast.expr) -> bool:
    """Does this decorator jit- or shard_map-wrap the function?"""
    if isinstance(dec, ast.Call):
        path = ctx.expand(dec.func) or ""
        if path.split(".")[-1] in ("jit", "shard_map"):
            return True  # jax.jit(...) / compat.shard_map(...) factory
        if path.split(".")[-1] == "partial":
            return any(_is_traced_decorator(ctx, a) for a in dec.args)
        return False
    path = ctx.expand(dec) or ""
    return path.split(".")[-1] in ("jit", "shard_map")


def _constant_like(node: ast.expr) -> bool:
    """Literal-ish expressions a float()/int() cast may legally touch."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.UnaryOp):
        return _constant_like(node.operand)
    if isinstance(node, ast.BinOp):
        return _constant_like(node.left) and _constant_like(node.right)
    return False


@register_rule
class TracerEscape(Rule):
    code = "RPL002"
    name = "tracer-escape"
    rationale = ("float()/int()/bool()/.item()/np.asarray inside a "
                 "jit- or shard_map-decorated function forces a "
                 "concretization: TracerError at best, a silent "
                 "recompile-per-call at worst.")

    def check(self, ctx: FileContext) -> Iterator[tuple[int, int, str]]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if not any(_is_traced_decorator(ctx, d)
                       for d in node.decorator_list):
                continue
            yield from self._escapes(ctx, node)

    @staticmethod
    def _escapes(ctx: FileContext, fn: ast.AST
                 ) -> Iterator[tuple[int, int, str]]:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name) \
                    and node.func.id in _ESCAPE_BUILTINS:
                if len(node.args) == 1 and not node.keywords \
                        and not _constant_like(node.args[0]):
                    yield (node.lineno, node.col_offset,
                           f"{node.func.id}() on a non-literal inside a "
                           f"traced function escapes the tracer; compute "
                           f"in jnp or hoist to a static argument")
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                yield (node.lineno, node.col_offset,
                       ".item() inside a traced function escapes the "
                       "tracer; return the array and read it outside")
            else:
                path = ctx.expand(node.func)
                if path in _ESCAPE_CALLS:
                    yield (node.lineno, node.col_offset,
                           f"{path}() inside a traced function escapes "
                           f"the tracer; use jnp.asarray or move the "
                           f"conversion outside the jit")


# --------------------------------------------------------------------------
# RPL003 — PRNG key discipline (no reuse, no literal seeds in library)
# --------------------------------------------------------------------------

# jax.random callables that *derive* or *construct* keys rather than
# consuming entropy; everything else under jax.random consumes its key.
_KEY_DERIVERS = {"split", "fold_in", "PRNGKey", "key", "key_data",
                 "wrap_key_data", "clone", "key_impl"}
_KEY_MAKERS = {"jax.random.PRNGKey", "jax.random.key"}

_TERMINAL = (ast.Return, ast.Raise, ast.Break, ast.Continue)


def _sampling_key_arg(ctx: FileContext, call: ast.Call) -> ast.expr | None:
    """The key argument if ``call`` is a jax.random sampling call."""
    path = ctx.expand(call.func)
    if not path or not path.startswith("jax.random."):
        return None
    if path.rsplit(".", 1)[1] in _KEY_DERIVERS:
        return None
    for kw in call.keywords:
        if kw.arg == "key":
            return kw.value
    if call.args and not isinstance(call.args[0], ast.Starred):
        return call.args[0]
    return None


def _bound_names(stmt: ast.stmt) -> set[str]:
    """Names (re)bound by one statement, incl. tuple targets + walrus.

    Compound statements (nested loops, with, try) contribute the binds
    of their whole subtree — ``_KeyTracker._loop`` relies on this to
    see that ``k += 1`` inside an inner loop refreshes a
    ``fold_in(key, k)`` expression consumed there.  def/class/lambda
    bodies bind their own scope and are skipped (a def still binds its
    *name*).
    """
    out: set[str] = set()

    def targets(t):
        if isinstance(t, ast.Name):
            out.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                targets(e)
        elif isinstance(t, ast.Starred):
            targets(t.value)

    def visit(node):
        if isinstance(node, ast.Lambda):
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            out.add(node.name)
            return
        if isinstance(node, ast.Assign):
            for t in node.targets:
                targets(t)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets(node.target)
        elif isinstance(node, (ast.For, ast.AsyncFor, ast.NamedExpr,
                               ast.comprehension)):
            targets(node.target)
        elif isinstance(node, (ast.withitem,)) and node.optional_vars:
            targets(node.optional_vars)
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(stmt)
    return out


class _KeyTracker:
    """Per-function linear scan flagging same-key sampling reuse."""

    def __init__(self, ctx: FileContext):
        self.ctx = ctx
        self.findings: list[tuple[int, int, str]] = []

    # used: normalized key expression -> (referenced names, first line)
    def block(self, stmts: list[ast.stmt],
              used: dict[str, tuple[frozenset[str], int]]) -> bool:
        """Scan one statement list; returns True if it always exits."""
        terminated = False
        for stmt in stmts:
            if terminated:
                break  # dead code: don't analyze past a terminal stmt
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.block(stmt.body, {})  # fresh scope
                continue
            if isinstance(stmt, ast.ClassDef):
                self.block(stmt.body, {})
                continue
            if isinstance(stmt, _TERMINAL):
                self._uses(stmt, used)
                terminated = True
                continue
            if isinstance(stmt, ast.If):
                self._expr_uses(stmt.test, used)
                merged: dict[str, tuple[frozenset[str], int]] = {}
                exits = []
                for branch in (stmt.body, stmt.orelse):
                    if not branch:
                        exits.append(False)
                        continue
                    u = dict(used)
                    exits.append(self.block(branch, u))
                    if not exits[-1]:
                        merged.update({k: v for k, v in u.items()
                                       if k not in used})
                used.update(merged)
                terminated = bool(exits) and all(exits) \
                    and len(exits) == 2 and stmt.orelse
                continue
            if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
                self._loop(stmt, used)
                continue
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self._expr_uses(item.context_expr, used)
                self.block(stmt.body, used)
                continue
            if isinstance(stmt, ast.Try):
                u = dict(used)
                self.block(stmt.body, u)
                used.update({k: v for k, v in u.items() if k not in used})
                for h in stmt.handlers:
                    self.block(h.body, dict(used))
                self.block(stmt.orelse, used)
                self.block(stmt.finalbody, used)
                continue
            # simple statement: record uses, then apply rebinds
            self._uses(stmt, used)
            for name in _bound_names(stmt):
                for k in [k for k, (names, _) in used.items()
                          if name in names]:
                    del used[k]
        return terminated

    def _loop(self, stmt, used) -> None:
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._expr_uses(stmt.iter, used)
            loop_bound = _bound_names(ast.Assign(
                targets=[stmt.target], value=ast.Constant(value=None)))
        else:
            self._expr_uses(stmt.test, used)
            loop_bound = set()
        for s in stmt.body:
            loop_bound |= _bound_names(s)
        u = dict(used)
        self.block(stmt.body, u)
        fresh = {k: v for k, v in u.items() if k not in used}
        # A key consumed in the body whose expression is not refreshed
        # by anything the loop rebinds repeats identically every
        # iteration.
        for k, (names, line) in fresh.items():
            if not (names & loop_bound):
                self.findings.append((
                    line, 0,
                    f"PRNG key expression '{k}' is consumed on every "
                    f"loop iteration without an interleaving "
                    f"split/fold_in; derive a per-iteration subkey"))
        used.update(fresh)
        self.block(stmt.orelse, used)

    def _uses(self, stmt: ast.stmt, used) -> None:
        # Collect sampling calls in *this* scope only: a lambda's body
        # runs in its own scope (its key parameter shadows ours), so
        # each lambda is tracked separately with a fresh `used` map.
        calls: list[ast.Call] = []
        lambdas: list[ast.Lambda] = []
        stack: list[ast.AST] = list(ast.iter_child_nodes(stmt))
        while stack:
            node = stack.pop()
            if isinstance(node, ast.Lambda):
                lambdas.append(node)
                continue
            if isinstance(node, ast.Call):
                calls.append(node)
            stack.extend(ast.iter_child_nodes(node))
        for lam in lambdas:
            self._expr_uses(lam.body, {})
        calls.sort(key=lambda c: (c.lineno, c.col_offset))
        for call in calls:
            karg = _sampling_key_arg(self.ctx, call)
            if karg is None:
                continue
            ktext = " ".join(ast.unparse(karg).split())
            names = frozenset(n.id for n in ast.walk(karg)
                              if isinstance(n, ast.Name))
            if ktext in used:
                self.findings.append((
                    call.lineno, call.col_offset,
                    f"PRNG key expression '{ktext}' already consumed by "
                    f"a sampling call at line {used[ktext][1]}; "
                    f"split/fold_in a fresh subkey"))
            else:
                used[ktext] = (names, call.lineno)

    def _expr_uses(self, expr: ast.expr, used) -> None:
        self._uses(ast.Expr(value=expr), used)


@register_rule
class KeyDiscipline(Rule):
    code = "RPL003"
    name = "prng-key-discipline"
    rationale = ("Reusing a PRNG key correlates draws that the "
                 "nonideal-model contract promises are independent; "
                 "literal seeds in library code silently pin "
                 "'randomness' every caller believes is keyed.")

    def check(self, ctx: FileContext) -> Iterator[tuple[int, int, str]]:
        if ctx.is_tests:
            return
        tracker = _KeyTracker(ctx)
        tracker.block(ctx.tree.body, {})
        yield from tracker.findings
        if not ctx.is_library:
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) \
                    and ctx.expand(node.func) in _KEY_MAKERS \
                    and node.args \
                    and isinstance(node.args[0], ast.Constant) \
                    and isinstance(node.args[0].value, int):
                yield (node.lineno, node.col_offset,
                       f"literal-seed "
                       f"{ctx.expand(node.func).rsplit('.', 1)[1]}"
                       f"({node.args[0].value}) in library code; thread "
                       f"a caller-supplied key through instead")


# --------------------------------------------------------------------------
# RPL004 — interpret mode is test-only
# --------------------------------------------------------------------------


@register_rule
class InterpretTestOnly(Rule):
    code = "RPL004"
    name = "interpret-test-only"
    rationale = ("pallas_call(interpret=True) executes the kernel body "
                 "block-by-block in Python — orders of magnitude too "
                 "slow for anything but BlockSpec validation in tests; "
                 "an interpret default silently serves through it.")

    def check(self, ctx: FileContext) -> Iterator[tuple[int, int, str]]:
        if ctx.is_tests:
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "interpret" \
                            and isinstance(kw.value, ast.Constant) \
                            and kw.value.value is True:
                        yield (kw.value.lineno, kw.value.col_offset,
                               "interpret=True outside tests/; interpret "
                               "mode is test-only validation")
                    elif kw.arg == "impl" \
                            and isinstance(kw.value, ast.Constant) \
                            and kw.value.value == "interpret":
                        yield (kw.value.lineno, kw.value.col_offset,
                               'impl="interpret" outside tests/; '
                               "interpret dispatch is test-only")
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._interpret_defaults(node)

    @staticmethod
    def _interpret_defaults(fn) -> Iterator[tuple[int, int, str]]:
        a = fn.args
        pairs = list(zip(a.args[len(a.args) - len(a.defaults):],
                         a.defaults))
        pairs += [(arg, d) for arg, d in zip(a.kwonlyargs, a.kw_defaults)
                  if d is not None]
        for arg, default in pairs:
            if arg.arg != "interpret":
                continue
            if isinstance(default, ast.Constant) \
                    and default.value is False:
                continue
            yield (default.lineno, default.col_offset,
                   f"parameter interpret defaults to "
                   f"{ast.unparse(default)}; interpret dispatch must be "
                   f"an explicit test-only opt-in (default False)")


# --------------------------------------------------------------------------
# RPL005 — no module-level jnp computation
# --------------------------------------------------------------------------


@register_rule
class ImportTimeJnp(Rule):
    code = "RPL005"
    name = "import-time-jnp"
    rationale = ("A module-level jax.numpy call initialises the backend "
                 "and compiles at *import* time, taxing every consumer "
                 "(including the jax-free lint CLI and non-JAX tools).")

    def check(self, ctx: FileContext) -> Iterator[tuple[int, int, str]]:
        yield from self._scan_body(ctx, ctx.tree.body)

    def _scan_body(self, ctx, stmts) -> Iterator[tuple[int, int, str]]:
        for stmt in stmts:
            if isinstance(stmt, ast.ClassDef):
                yield from self._scan_body(ctx, stmt.body)
                continue
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # Defaults and decorators evaluate at import time; the
                # body does not.
                for node in (stmt.args.defaults
                             + [d for d in stmt.args.kw_defaults if d]
                             + stmt.decorator_list):
                    yield from self._calls(ctx, node)
                continue
            yield from self._calls(ctx, stmt)

    @classmethod
    def _calls(cls, ctx, root) -> Iterator[tuple[int, int, str]]:
        # Manual traversal instead of ast.walk: lambda/def bodies nested
        # in an import-time expression are deferred and must be skipped.
        # The root itself is tested too — a function *default* is handed
        # in directly and may itself be the offending Call.
        stack: list[ast.AST] = [root]
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue
            if isinstance(node, ast.Call):
                path = ctx.expand(node.func) or ""
                if path.startswith("jax.numpy."):
                    yield (node.lineno, node.col_offset,
                           f"module-level {path}() runs at import time "
                           f"(backend init + possible compile); use "
                           f"numpy for constants or build lazily")
            stack.extend(ast.iter_child_nodes(node))


# --------------------------------------------------------------------------
# RPL006 — library code times through repro.telemetry, not time.*
# --------------------------------------------------------------------------


@register_rule
class TelemetryClock(Rule):
    code = "RPL006"
    name = "telemetry-clock"
    rationale = ("Ad-hoc time.time()/perf_counter() calls scattered "
                 "through library code bypass the telemetry layer: their "
                 "readings reach no metric, no trace, and no report "
                 "schema.  repro.telemetry.monotonic/wall_time are the "
                 "same clocks behind one instrumentable front door.")

    BANNED = frozenset({
        "time.time", "time.perf_counter", "time.monotonic",
        "time.perf_counter_ns", "time.monotonic_ns",
        "time.process_time", "time.process_time_ns",
    })

    def check(self, ctx: FileContext) -> Iterator[tuple[int, int, str]]:
        if not ctx.is_library:
            return
        parts = os.path.normpath(os.path.abspath(ctx.path)).split(os.sep)
        if "telemetry" in parts and "repro" in parts:
            return  # the one module allowed to own the raw clocks
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            path = ctx.expand(node.func) or ""
            if path in self.BANNED:
                yield (node.lineno, node.col_offset,
                       f"{path}() in library code; use repro.telemetry"
                       f".monotonic() (durations) or .wall_time() "
                       f"(timestamps) so readings feed the metrics/"
                       f"trace layer")
