"""Structured trace spans: nested, monotonic, JSONL.

``with span("deploy/plan", matrices=12):`` times one phase of a run.
Spans nest through a thread-local stack (a span opened inside another
records it as its parent), carry JSON-serialisable attributes, and are
written to the sink **at exit** as one JSON line each::

    {"name": "deploy/plan", "id": 3, "parent": 2, "depth": 1,
     "t_start": 0.0123, "t_end": 0.8711, "dur": 0.8588,
     "attrs": {"matrices": 12}}

Timestamps are :func:`repro.telemetry.monotonic` reads relative to the
``trace_to`` call — monotonic by construction, never wall-clock.  Span
ids are sequential integers handed out under a lock: deterministic for
a deterministic call order, no PRNG contact (the determinism contract
telemetry shares with the code it instruments).

Spans are active only while a sink is open (:func:`trace_to`) *and*
telemetry is enabled; otherwise :func:`span` returns a shared no-op
context manager — no object allocated per call, nothing timed.  The
``REPRO_TRACE`` environment variable opens a sink at import time, so
``REPRO_TELEMETRY=1 REPRO_TRACE=out.jsonl python -m ...`` traces any
entry point without code changes.

``repro.telemetry.report`` aggregates a trace file into the per-phase
wall/self-time table behind ``scripts/trace_report.py``.
"""
from __future__ import annotations

import json
import os
import threading

from repro.telemetry.metrics import enabled, monotonic

_LOCK = threading.Lock()
_LOCAL = threading.local()


class _TraceState:
    __slots__ = ("sink", "path", "t0", "next_id")

    def __init__(self):
        self.sink = None
        self.path = None
        self.t0 = 0.0
        self.next_id = 0


_TRACE = _TraceState()


def _stack() -> list:
    st = getattr(_LOCAL, "stack", None)
    if st is None:
        st = _LOCAL.stack = []
    return st


def trace_to(path: str) -> str:
    """Open ``path`` as the JSONL span sink (replacing any prior one).

    Resets the relative clock and the span-id sequence, so every trace
    file starts at ``t_start ~ 0`` with ids from 0.  Returns the path.
    """
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    f = open(path, "w", encoding="utf-8")
    with _LOCK:
        old = _TRACE.sink
        _TRACE.sink = f
        _TRACE.path = path
        _TRACE.t0 = monotonic()
        _TRACE.next_id = 0
    if old is not None:
        old.close()
    return path


def trace_stop() -> str | None:
    """Close the sink; returns the finished trace's path (or None)."""
    with _LOCK:
        f, path = _TRACE.sink, _TRACE.path
        _TRACE.sink = None
        _TRACE.path = None
    if f is not None:
        f.close()
    return path


def tracing() -> bool:
    """Is a span sink currently open?"""
    return _TRACE.sink is not None


def trace_path() -> str | None:
    """Path of the open sink, or None."""
    return _TRACE.path


def _coerce(v):
    """Attribute values must be JSON-serialisable and deterministic."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    try:
        return float(v)  # host scalar (incl. 0-d device arrays)
    except (TypeError, ValueError):
        return str(v)


class _NoopSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP_SPAN = _NoopSpan()


class _Span:
    __slots__ = ("name", "attrs", "id", "parent", "depth", "t_start")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        stack = _stack()
        with _LOCK:
            self.id = _TRACE.next_id
            _TRACE.next_id += 1
        self.parent = stack[-1].id if stack else None
        self.depth = len(stack)
        stack.append(self)
        self.t_start = monotonic()
        return self

    def __exit__(self, *exc):
        t_end = monotonic()
        stack = _stack()
        if stack and stack[-1] is self:
            stack.pop()
        rec = {"name": self.name, "id": self.id, "parent": self.parent,
               "depth": self.depth,
               "t_start": round(self.t_start - _TRACE.t0, 9),
               "t_end": round(t_end - _TRACE.t0, 9),
               "dur": round(t_end - self.t_start, 9)}
        if self.attrs:
            rec["attrs"] = self.attrs
        line = json.dumps(rec) + "\n"
        with _LOCK:
            if _TRACE.sink is not None:
                _TRACE.sink.write(line)
        return False


def span(name: str, **attrs):
    """Context manager timing one named phase (no-op when inactive)."""
    if _TRACE.sink is None or not enabled():
        return _NOOP_SPAN
    return _Span(name, {k: _coerce(v) for k, v in attrs.items()})


_env_trace = os.environ.get("REPRO_TRACE", "")
if _env_trace:
    try:
        trace_to(_env_trace)
    except OSError:  # unwritable path must not break the import
        pass
