"""repro.telemetry — dependency-free observability for the CIM stack.

One lightweight layer carries all three observability signals across
the deploy -> solve -> serve -> heal pipeline (docs/observability.md):

* **metrics** — a process-global :class:`MetricsRegistry` of counters,
  gauges and histograms with label support and Prometheus-text / JSON
  exposition (:mod:`repro.telemetry.metrics`);
* **traces** — nested :func:`span` context managers emitted as JSONL,
  summarised by ``scripts/trace_report.py``
  (:mod:`repro.telemetry.trace` / :mod:`repro.telemetry.report`);
* **clocks** — :func:`monotonic` (durations) and :func:`wall_time`
  (timestamps), the only sanctioned time sources for library code
  (reprolint RPL006 bans direct ``time.*`` calls under ``src/repro``
  outside this package).

Collection is **off by default** and costs nothing while off: set
``REPRO_TELEMETRY=1`` (or call :func:`enable`) to collect, and
``REPRO_TRACE=path.jsonl`` (or :func:`trace_to`) to additionally
record spans.  Instrumented library code records only at host-side
boundaries — never inside jit-traced functions — and never touches a
PRNG, so enabling telemetry cannot change a single computed value.
"""
from repro.telemetry.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    counter,
    disable,
    enable,
    enabled,
    gauge,
    histogram,
    monotonic,
    registry,
    wall_time,
)
from repro.telemetry.trace import (  # noqa: F401
    span,
    trace_path,
    trace_stop,
    trace_to,
    tracing,
)
