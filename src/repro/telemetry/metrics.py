"""Process-global metrics: counters, gauges, histograms, exposition.

Dependency-free (stdlib only — no jax, no numpy): the lint CLI and
``scripts/trace_report.py`` import this module, and both carry a
jax-free speed contract.  Three design rules govern everything here:

1. **Disabled is free.**  Telemetry is off unless ``REPRO_TELEMETRY``
   is set truthy or :func:`enable` was called; the unlabeled
   ``inc()``/``observe()``/``set()`` fast path is then a single global
   flag test and an immediate return — no allocation, no lock, no dict
   touch (``tests/test_telemetry.py`` pins zero allocated blocks).
2. **Host boundaries only.**  Instrumented call sites live outside
   jit-traced functions; values arriving here are concrete Python/
   device scalars and the ``float()`` coercions below are ordinary
   host arithmetic (RPL006 machine-enforces the clock half of this).
3. **Deterministic.**  No PRNG, no wall-clock inside metric *values*
   (durations come from the caller's :func:`monotonic` reads), and
   both exposition formats sort by name and label values — the same
   run produces the same snapshot shape.

Metric names follow Prometheus conventions, prefixed ``repro_``:
``repro_<subsystem>_<what>_<unit>`` with ``_total`` for counters and
``_seconds`` for latency histograms (see docs/observability.md).  The
semantic auditor (AUD007) cross-checks every statically declared name
against the live default registry, so a dead or duplicated declaration
fails ``lint --audit``.
"""
from __future__ import annotations

import bisect
import json
import os
import re
import threading
import time

# The one sanctioned clock for library timing (RPL006): monotonic,
# high-resolution, unaffected by wall-clock jumps.  ``wall_time`` is
# for *timestamps* (benchmark start times), never for durations.
monotonic = time.perf_counter
wall_time = time.time


def _env_enabled() -> bool:
    v = os.environ.get("REPRO_TELEMETRY", "")
    return v.strip().lower() not in ("", "0", "false", "off", "no")


class _State:
    __slots__ = ("enabled",)

    def __init__(self):
        self.enabled = _env_enabled()


_STATE = _State()


def enabled() -> bool:
    """Is telemetry collection on for this process?"""
    return _STATE.enabled


def enable() -> None:
    """Turn collection on (overrides the ``REPRO_TELEMETRY`` env)."""
    _STATE.enabled = True


def disable() -> None:
    """Turn collection off; every record call becomes a no-op."""
    _STATE.enabled = False


_NAME_RE = re.compile(r"[a-z][a-z0-9_]*$")

# Latency buckets (seconds): geometric-ish 100us..60s, suiting both a
# sub-ms decode step and a multi-second cold deploy.
DEFAULT_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005,
                   0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
                   10.0, 30.0, 60.0)


class _NoopChild:
    """Shared do-nothing ``labels()`` result while telemetry is off."""

    __slots__ = ()

    def inc(self, v=1.0):
        pass

    def dec(self, v=1.0):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass


_NOOP = _NoopChild()


class _Bound:
    """One metric child bound to concrete label values."""

    __slots__ = ("_metric", "_key")

    def __init__(self, metric, key):
        self._metric = metric
        self._key = key

    def inc(self, v=1.0):
        if _STATE.enabled:
            self._metric._record(self._key, float(v))

    def dec(self, v=1.0):
        if _STATE.enabled:
            self._metric._record(self._key, -float(v))

    def set(self, v):
        if _STATE.enabled:
            self._metric._set(self._key, float(v))

    def observe(self, v):
        if _STATE.enabled:
            self._metric._record(self._key, float(v))


class _Metric:
    """Common shape: name, help, label schema, per-label-tuple state."""

    kind = ""

    def __init__(self, name: str, help: str = "",
                 labels: tuple[str, ...] = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r} (want "
                             f"lowercase [a-z0-9_], e.g. repro_x_total)")
        self.name = name
        self.help = help
        self.label_names = tuple(labels)
        self._lock = threading.Lock()
        self._children: dict[tuple, _Bound] = {}
        self._init_state()

    def _init_state(self):
        raise NotImplementedError

    def labels(self, **kv):
        """Child bound to one label-value combination.

        While disabled this returns a shared no-op child without
        touching any state — take labels at *use* time, not at import
        time, so a later :func:`enable` is honoured.
        """
        if not _STATE.enabled:
            return _NOOP
        if set(kv) != set(self.label_names):
            raise ValueError(f"{self.name}: labels {sorted(kv)} != "
                             f"declared {sorted(self.label_names)}")
        key = tuple(str(kv[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key,
                                                  _Bound(self, key))
        return child

    # -- state ops (post-enabled-check; subclasses fill in) ------------

    def _record(self, key, v):
        raise NotImplementedError

    def _set(self, key, v):
        raise NotImplementedError("only gauges support set()")


class Counter(_Metric):
    kind = "counter"

    def _init_state(self):
        self._values: dict[tuple, float] = (
            {(): 0.0} if not self.label_names else {})

    def inc(self, v=1.0):
        if not _STATE.enabled:
            return
        self._record((), float(v))

    def _record(self, key, v):
        if v < 0:
            raise ValueError(f"{self.name}: counters only go up")
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + v

    def _reset(self):
        with self._lock:
            self._values = {(): 0.0} if not self.label_names else {}


class Gauge(_Metric):
    kind = "gauge"

    def _init_state(self):
        self._values: dict[tuple, float] = (
            {(): 0.0} if not self.label_names else {})

    def set(self, v):
        if not _STATE.enabled:
            return
        self._set((), float(v))

    def inc(self, v=1.0):
        if not _STATE.enabled:
            return
        self._record((), float(v))

    def dec(self, v=1.0):
        if not _STATE.enabled:
            return
        self._record((), -float(v))

    def _record(self, key, v):
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + v

    def _set(self, key, v):
        with self._lock:
            self._values[key] = v

    def _reset(self):
        with self._lock:
            self._values = {(): 0.0} if not self.label_names else {}


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help="", labels=(), buckets=None):
        self.buckets = tuple(sorted(float(b) for b in
                                    (DEFAULT_BUCKETS if buckets is None
                                     else buckets)))
        if not self.buckets:
            raise ValueError(f"{name}: need at least one bucket bound")
        super().__init__(name, help, labels)

    def _init_state(self):
        # label key -> [per-bucket counts (+Inf last), sum, count]
        self._data: dict[tuple, list] = {}
        if not self.label_names:
            self._data[()] = self._fresh()

    def _fresh(self):
        return [[0] * (len(self.buckets) + 1), 0.0, 0]

    def observe(self, v):
        if not _STATE.enabled:
            return
        self._record((), float(v))

    def _record(self, key, v):
        with self._lock:
            st = self._data.get(key)
            if st is None:
                st = self._data[key] = self._fresh()
            st[0][bisect.bisect_left(self.buckets, v)] += 1
            st[1] += v
            st[2] += 1

    def _reset(self):
        with self._lock:
            self._data = {}
            if not self.label_names:
                self._data[()] = self._fresh()


def _fmt(v: float) -> str:
    """Prometheus sample value: integral floats render as integers."""
    return str(int(v)) if float(v).is_integer() else repr(float(v))


def _label_str(names, values, extra=()) -> str:
    pairs = [f'{n}="{v}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{v}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


class MetricsRegistry:
    """Named metric set with Prometheus-text and JSON exposition.

    Registration is strict: a name registers exactly once (AUD007
    builds on this), with the kind/labels fixed at declaration.  The
    process-global default registry lives in this module
    (:func:`registry`); tests construct their own instances.
    """

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _register(self, m: _Metric) -> _Metric:
        with self._lock:
            if m.name in self._metrics:
                raise ValueError(
                    f"metric {m.name!r} already registered as "
                    f"{self._metrics[m.name].kind}; metric names "
                    f"register exactly once (AUD007)")
            self._metrics[m.name] = m
        return m

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = ()) -> Counter:
        return self._register(Counter(name, help, tuple(labels)))

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = ()) -> Gauge:
        return self._register(Gauge(name, help, tuple(labels)))

    def histogram(self, name: str, help: str = "",
                  labels: tuple[str, ...] = (),
                  buckets=None) -> Histogram:
        return self._register(
            Histogram(name, help, tuple(labels), buckets))

    def get(self, name: str) -> _Metric | None:
        return self._metrics.get(name)

    def names(self) -> frozenset[str]:
        return frozenset(self._metrics)

    def reset(self) -> None:
        """Zero every value; registrations (and children) survive."""
        for m in self._metrics.values():
            m._reset()

    # -- exposition ----------------------------------------------------

    def to_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        out: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out.append(f"# HELP {name} {m.help}")
            out.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key in sorted(m._data):
                    counts, total, n = m._data[key]
                    cum = 0
                    for le, c in zip(m.buckets, counts):
                        cum += c
                        out.append(
                            f"{name}_bucket"
                            f"{_label_str(m.label_names, key, [('le', _fmt(le))])}"
                            f" {cum}")
                    out.append(
                        f"{name}_bucket"
                        f"{_label_str(m.label_names, key, [('le', '+Inf')])}"
                        f" {cum + counts[-1]}")
                    ls = _label_str(m.label_names, key)
                    out.append(f"{name}_sum{ls} {_fmt(total)}")
                    out.append(f"{name}_count{ls} {n}")
            else:
                for key in sorted(m._values):
                    out.append(f"{name}"
                               f"{_label_str(m.label_names, key)} "
                               f"{_fmt(m._values[key])}")
        return "\n".join(out) + "\n"

    def snapshot(self) -> dict:
        """Plain-JSON snapshot (what benchmarks/run.py attaches)."""
        out: dict = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            entry: dict = {"kind": m.kind, "help": m.help}
            if isinstance(m, Histogram):
                entry["buckets"] = list(m.buckets)
                entry["values"] = [
                    {"labels": dict(zip(m.label_names, key)),
                     "counts": list(m._data[key][0]),
                     "sum": m._data[key][1],
                     "count": m._data[key][2]}
                    for key in sorted(m._data)]
            else:
                entry["values"] = [
                    {"labels": dict(zip(m.label_names, key)),
                     "value": m._values[key]}
                    for key in sorted(m._values)]
            out[name] = entry
        return out

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), indent=1)


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global default registry."""
    return _REGISTRY


def counter(name: str, help: str = "",
            labels: tuple[str, ...] = ()) -> Counter:
    """Register a counter on the default registry (module-level use)."""
    return _REGISTRY.counter(name, help, labels)


def gauge(name: str, help: str = "",
          labels: tuple[str, ...] = ()) -> Gauge:
    """Register a gauge on the default registry (module-level use)."""
    return _REGISTRY.gauge(name, help, labels)


def histogram(name: str, help: str = "",
              labels: tuple[str, ...] = (), buckets=None) -> Histogram:
    """Register a histogram on the default registry."""
    return _REGISTRY.histogram(name, help, labels, buckets)
