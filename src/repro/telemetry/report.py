"""Trace aggregation: JSONL spans -> per-phase wall/self-time table.

The model is the classic profiler decomposition: a span's **total**
time is its own duration; its **self** time is the duration minus the
durations of its *direct* children.  Self-times telescope — summed over
every span in a properly nested trace they equal the root spans' total
wall time exactly — so the coverage figure below reads as "how much of
the run the named phases account for" (the ISSUE's >= 95% acceptance
gate holds by construction whenever a root span wraps the run).

Stdlib-only on purpose: ``scripts/trace_report.py`` fronts this module
and must stay importable without jax (same contract as the lint CLI).
"""
from __future__ import annotations

import json


def load_spans(path: str) -> list[dict]:
    """Parse one JSONL trace file into span records.

    Non-JSON and non-span lines are skipped (the format is append-only
    and a crashed run may leave a torn final line).
    """
    spans: list[dict] = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue
            if isinstance(rec, dict) and "name" in rec and "dur" in rec:
                spans.append(rec)
    return spans


def aggregate(spans: list[dict]) -> tuple[dict[str, dict], float]:
    """Per-phase stats + root wall time.

    Returns ``({name: {count, total, self, min, max}}, wall)`` where
    ``wall`` is the summed duration of parentless (root) spans.
    """
    child_dur: dict[int, float] = {}
    for s in spans:
        p = s.get("parent")
        if p is not None:
            child_dur[p] = child_dur.get(p, 0.0) + s["dur"]
    stats: dict[str, dict] = {}
    wall = 0.0
    for s in spans:
        st = stats.setdefault(s["name"], {
            "count": 0, "total": 0.0, "self": 0.0,
            "min": float("inf"), "max": 0.0})
        dur = float(s["dur"])
        st["count"] += 1
        st["total"] += dur
        st["self"] += dur - child_dur.get(s.get("id"), 0.0)
        st["min"] = min(st["min"], dur)
        st["max"] = max(st["max"], dur)
        if s.get("parent") is None:
            wall += dur
    return stats, wall


def coverage(spans: list[dict]) -> float:
    """Fraction of root wall time the per-phase self-times account for."""
    stats, wall = aggregate(spans)
    if wall <= 0.0:
        return 0.0
    return sum(st["self"] for st in stats.values()) / wall


def format_table(stats: dict[str, dict], wall: float) -> str:
    """Human per-phase table, widest self-time first."""
    rows = sorted(stats.items(), key=lambda kv: -kv[1]["self"])
    name_w = max([len("phase")] + [len(n) for n in stats])
    head = (f"{'phase':<{name_w}}  {'count':>5}  {'total_s':>9}  "
            f"{'self_s':>9}  {'self_%':>6}  {'min_s':>9}  {'max_s':>9}")
    lines = [head, "-" * len(head)]
    for name, st in rows:
        pct = 100.0 * st["self"] / wall if wall > 0 else 0.0
        lines.append(
            f"{name:<{name_w}}  {st['count']:>5}  {st['total']:>9.4f}  "
            f"{st['self']:>9.4f}  {pct:>6.1f}  {st['min']:>9.4f}  "
            f"{st['max']:>9.4f}")
    covered = sum(st["self"] for st in stats.values())
    pct = 100.0 * covered / wall if wall > 0 else 0.0
    lines.append(f"wall {wall:.4f}s; phase self-times cover "
                 f"{covered:.4f}s ({pct:.1f}%)")
    return "\n".join(lines)


def report(path: str) -> str:
    """One-call convenience: load, aggregate, format."""
    stats, wall = aggregate(load_spans(path))
    return format_table(stats, wall)
