"""Batched crossbar circuit-solver engine with precision policies.

The seed solver (:mod:`repro.crossbar.solver`) solves one tile per CG
invocation and walks batches with ``jax.lax.map`` — correct, but the
whole (Ti, Tn) tile grid of a layer pays one sequential CG per tile.
This module solves the *entire batch in one jitted call*:

* the preconditioned-CG state is stacked along a leading tile axis
  ``(T, 2, J, K)`` and every stencil matvec / axpy runs across all
  tiles at once (one fused XLA program instead of T dispatches);
* the preconditioner is a **line (tridiagonal) preconditioner**: the
  nodal matrix is two families of wire chains — wordline chains along
  ``k`` and bitline chains along ``j`` — coupled only through the
  memristor conductances, and ``g/cw ~ r/R_on ~ 1e-5`` makes that
  coupling weak.  Solving the per-chain tridiagonal systems exactly
  (batched ``jax.lax.linalg.tridiagonal_solve`` over T*J + T*K chains)
  leaves ``M^-1 A ~= I + O(g/cw)``, so CG converges in a handful of
  iterations where the seed's Jacobi preconditioner needs hundreds.
  Backends whose ``tridiagonal_solve`` lacks a batched lowering are
  detected by :func:`repro.compat.has_batched_tridiagonal_solve` and
  fall back to the Jacobi diagonal automatically;
* convergence is tracked **per tile**: a boolean ``done`` mask freezes a
  tile's iterates (its step sizes are zeroed) the moment its relative
  residual passes ``tol``, while the shared iteration loop keeps running
  the stragglers;
* the shared ``lax.while_loop`` exits early as soon as *all* tiles have
  converged, so a batch is never slower than its hardest member;
* **precision is a policy** (:class:`SolverPrecision`): the default
  :data:`F64` runs the classic all-float64 solve; :data:`MIXED` runs
  the CG iterations in float32 (half the memory traffic — the stencil
  matvec and chain solves are bandwidth-bound) and then *polishes* the
  promoted iterate with warm-started float64 CG.  Because the line
  preconditioner contracts the residual by ~``g/cw`` per iteration,
  the polish reaches the f64 fixed point in 1–2 iterations, so the
  mixed path matches the f64 oracle to ~1e-12 relative while doing
  most of its arithmetic in f32.  :data:`F32` (no polish) is the
  throwaway-accuracy screening mode.

float64 is obtained with the config-scoped
:func:`repro.compat.enable_x64` at trace time (the old
``jax.enable_x64`` context manager no longer exists in JAX >= 0.4.x).

The single-tile Jacobi-CG path in :mod:`repro.crossbar.solver` is kept
as the oracle; ``tests/test_solver.py`` pins this engine against both
that path and the dense nodal solve.  Device-sharded layer-scale solves
live in :mod:`repro.distributed.solver_shard`, which shard_maps the
same :func:`_solve_core` over a tile mesh.  Throughput is tracked by
``benchmarks/solver_throughput.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import telemetry as tm
from repro.compat import enable_x64, has_batched_tridiagonal_solve
from repro.core.tiling import CrossbarSpec
from repro.crossbar.solver import _jacobi_diag, _stencil_matvec


@dataclass(frozen=True)
class SolverPrecision:
    """How the batched PCG spends its flops (hashable => jit-static).

    ``cg_dtype``
        dtype of the main CG iteration ("float64" or "float32").
    ``coarse_tol``
        relative-residual target of a float32 main loop (float32 CG
        stalls near its ~1e-7 epsilon, so the final ``tol`` is not
        reachable there; ignored when ``cg_dtype`` is float64).  1e-5
        sits safely above the f32 floor — pushing it lower trades f64
        polish iterations for f32 ones only until the floor, after
        which the coarse loop just spins against the stall guard.
    ``coarse_maxiter``
        stall guard on the float32 loop — if ``coarse_tol`` undershoots
        the f32 floor for an ill-conditioned batch, the coarse phase
        hands over to the polish after this many iterations instead of
        spinning to the caller's ``maxiter``.
    ``polish``
        run warm-started float64 CG from the promoted f32 iterate down
        to the caller's ``tol``.  With the line preconditioner this
        costs 1–2 iterations (residual contracts by ~g/cw per step).
    ``polish_maxiter``
        safety cap on the polish loop.
    """

    cg_dtype: str = "float64"
    coarse_tol: float = 1e-5
    coarse_maxiter: int = 64
    polish: bool = False
    polish_maxiter: int = 64

    @property
    def is_f64(self) -> bool:
        return self.cg_dtype == "float64"


F64 = SolverPrecision()
MIXED = SolverPrecision(cg_dtype="float32", polish=True)
F32 = SolverPrecision(cg_dtype="float32", polish=False)

_POLICIES = {"f64": F64, "float64": F64, "mixed": MIXED,
             "f32": F32, "float32": F32}


def resolve_precision(
        precision: SolverPrecision | str | None) -> SolverPrecision:
    """None -> F64 oracle policy; strings name the canned policies."""
    if precision is None:
        return F64
    if isinstance(precision, str):
        try:
            return _POLICIES[precision.lower()]
        except KeyError:
            raise ValueError(
                f"unknown precision policy {precision!r}; "
                f"expected one of {sorted(_POLICIES)}") from None
    return precision


class BatchedSolveResult(NamedTuple):
    """Per-tile solve results, leading axes = tile batch.

    Identical field layout to :class:`repro.crossbar.solver.SolveResult`
    (so consumers can treat the two interchangeably) plus the shared
    iteration count the early-exit loop actually ran (main + polish).
    """

    currents: jax.Array    # (..., K) actual column currents under PR
    ideal: jax.Array       # (..., K) ideal currents (r = 0)
    nf_cols: jax.Array     # (..., K) per-column |di/i0|
    nf_total: jax.Array    # (...,)  aggregate |sum di| / sum i0
    residual: jax.Array    # (...,)  final per-tile relative CG residual
    iterations: jax.Array  # ()      shared CG iterations until all done


class SolverReport(NamedTuple):
    """Convergence-watchdog verdict of a (possibly escalated) solve.

    Produced by the ``*_checked`` front doors: a per-tile health mask a
    caller can trust even when the PCG silently hit its iteration cap
    or produced NaN/Inf iterates — a non-converged circuit must never
    masquerade as a good NF number.
    """

    converged: jax.Array   # (...,) per-tile: finite AND residual <= tol
    iterations: jax.Array  # ()     total shared iterations, all stages
    escalations: int       #        escalation stages actually run
    n_failed: jax.Array    # ()     tiles still unconverged at the end

    @property
    def all_converged(self) -> bool:
        return bool(jnp.all(self.converged))


_C_SOLVES = tm.counter(
    "repro_solver_solves_total",
    "Checked batched circuit solves (one per *_checked call).")
_C_SOLVE_ITERS = tm.counter(
    "repro_solver_iterations_total",
    "Shared PCG iterations across all solve stages.")
_C_SOLVE_ESC = tm.counter(
    "repro_solver_escalations_total",
    "Watchdog escalation rungs actually run.")
_C_SOLVE_FAILED = tm.counter(
    "repro_solver_failed_tiles_total",
    "Tiles still unconverged after the full escalation ladder.")


def record_solver_report(report: SolverReport) -> None:
    """Fold one watchdog verdict into the solver counters.

    Called only by the ``*_checked`` front doors (here and in
    :mod:`repro.distributed.solver_shard`) — never by the inner stages,
    so escalated reruns are not double-counted.  The ``int()``
    coercions block on the device values, which is why the whole body
    is gated on :func:`repro.telemetry.enabled`: with telemetry off the
    solve stays fully async.
    """
    if not tm.enabled():
        return
    _C_SOLVES.inc()
    _C_SOLVE_ITERS.inc(int(report.iterations))
    _C_SOLVE_ESC.inc(int(report.escalations))
    _C_SOLVE_FAILED.inc(int(report.n_failed))


# The stencil physics lives once, in the oracle (solver.py); the batched
# matvec is its vmap over the leading tile axis: g (T,J,K), x (T,2,J,K).
_stencil_matvec_batched = jax.vmap(_stencil_matvec, in_axes=(0, None, 0))
_jacobi_diag_batched = jax.vmap(_jacobi_diag, in_axes=(0, None))


def _dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Per-tile inner product over the (2, J, K) node axes."""
    return jnp.sum(a * b, axis=(1, 2, 3))


def _thomas_factor(lo: jax.Array, d: jax.Array, hi: jax.Array):
    """Thomas (LU) factorisation of batched tridiagonal chains.

    ``lo``/``d``/``hi``: (..., M) with the chain along the last axis
    (``lo[..., 0]`` and ``hi[..., M-1]`` ignored/zero).  Returns the
    eliminated superdiagonal ``c`` and pivots ``denom``; runs once per
    preconditioner *construction* (a single 2M-step scan), after which
    every application is two log-depth associative scans.  The chains
    are strictly diagonally dominant (wire Laplacian + g), so no
    pivoting is needed.
    """

    def step(c_prev, x):
        lo_i, d_i, hi_i = x
        denom = d_i - lo_i * c_prev
        c = hi_i / denom
        return c, (c, denom)

    xs = (jnp.moveaxis(lo, -1, 0), jnp.moveaxis(d, -1, 0),
          jnp.moveaxis(hi, -1, 0))
    _, (c, denom) = jax.lax.scan(step, jnp.zeros_like(lo[..., 0]), xs)
    return jnp.moveaxis(c, 0, -1), jnp.moveaxis(denom, 0, -1)


def _affine_scan(alpha: jax.Array, beta: jax.Array,
                 reverse: bool = False) -> jax.Array:
    """Solve y_i = alpha_i * y_(i-1) + beta_i along the last axis via a
    log-depth associative scan (the affine maps compose associatively).
    Stable here because diagonal dominance keeps |alpha| < 1."""

    def comb(a, b):
        return (a[0] * b[0], a[1] * b[0] + b[1])

    ax = alpha.ndim - 1
    return jax.lax.associative_scan(comb, (alpha, beta), axis=ax,
                                    reverse=reverse)[1]


def _thomas_apply(lo: jax.Array, c: jax.Array, denom: jax.Array,
                  r: jax.Array) -> jax.Array:
    """Forward/back substitution with a precomputed factorisation, each
    sweep a log-depth associative scan instead of an M-step sequential
    scan — the latency-optimal shape for the sharded engine's small
    per-shard batches (and for accelerators without a batched
    ``tridiagonal_solve`` lowering)."""
    y = _affine_scan(-lo / denom, r / denom)
    return _affine_scan(-c, y, reverse=True)


def _line_preconditioner(g: jax.Array, cw: jax.Array,
                         chain_impl: str = "lax"):
    """Exact per-chain solver for the block-diagonal part of A.

    M = blockdiag(Dw + diag(g), Db + diag(g)) where Dw couples each
    wordline chain along k and Db each bitline chain along j; both are
    SPD tridiagonal, so M is a valid SPD preconditioner and captures
    everything except the weak W<->B memristor coupling.

    ``chain_impl`` picks the chain-solver kernel by regime:

    * ``"lax"`` — batched ``jax.lax.linalg.tridiagonal_solve``, one call
      per family (the two calls are independent so XLA overlaps their
      sequential scans across the intra-op pool; a merged (T, J+K)
      batch serialises the doubled per-step work and measures ~1.6x
      slower on CPU).  Bandwidth-optimal for wide single-device
      batches.  Requires a batched lowering on the active backend —
      probed via :func:`repro.compat.has_batched_tridiagonal_solve`,
      with a Jacobi-diagonal fallback where it is missing.
    * ``"assoc"`` — Thomas factorisation applied via log-depth
      associative scans (:func:`_thomas_apply`): latency-optimal for
      the sharded engine's small per-shard batches (~3-4x over the lax
      scan at 64 tiles/shard) and portable to every backend, since it
      uses only elementwise ops and ``lax.associative_scan``.
    * ``"jacobi"`` — the diagonal alone (the seed preconditioner).

    Degenerate geometries (rows or cols < 3) always use Jacobi — the
    chains are too short to matter and ``tridiagonal_solve`` rejects
    them.
    """
    T, J, K = g.shape
    dt = g.dtype
    diag = _jacobi_diag_batched(g, cw)                      # (T, 2, J, K)
    if (min(J, K) < 3 or chain_impl == "jacobi"
            or (chain_impl == "lax"
                and not has_batched_tridiagonal_solve())):
        return lambda r: r / diag
    dW = diag[:, 0]                                         # (T, J, K)
    dBt = diag[:, 1].transpose(0, 2, 1)                     # (T, K, J)
    lo_k = jnp.broadcast_to(
        jnp.where(jnp.arange(K) > 0, -cw, 0.0).astype(dt), (T, J, K))
    hi_k = jnp.broadcast_to(
        jnp.where(jnp.arange(K) < K - 1, -cw, 0.0).astype(dt), (T, J, K))
    lo_j = jnp.broadcast_to(
        jnp.where(jnp.arange(J) > 0, -cw, 0.0).astype(dt), (T, K, J))
    hi_j = jnp.broadcast_to(
        jnp.where(jnp.arange(J) < J - 1, -cw, 0.0).astype(dt), (T, K, J))

    if chain_impl == "assoc":
        cW, denW = _thomas_factor(lo_k, dW, hi_k)
        cB, denB = _thomas_factor(lo_j, dBt, hi_j)

        def pre(r):
            zW = _thomas_apply(lo_k, cW, denW, r[:, 0])
            zBt = _thomas_apply(lo_j, cB, denB,
                                r[:, 1].transpose(0, 2, 1))
            return jnp.stack([zW, zBt.transpose(0, 2, 1)], axis=1)

        return pre

    def pre(r):
        zW = jax.lax.linalg.tridiagonal_solve(
            lo_k, dW, hi_k, r[:, 0][..., None])[..., 0]
        zBt = jax.lax.linalg.tridiagonal_solve(
            lo_j, dBt, hi_j, r[:, 1].transpose(0, 2, 1)[..., None])[..., 0]
        return jnp.stack([zW, zBt.transpose(0, 2, 1)], axis=1)

    return pre


def _pcg_loop(g: jax.Array, cw: jax.Array, b: jax.Array,
              x0: jax.Array | None, tol, maxiter: int,
              chain_impl: str = "lax"):
    """Fused preconditioned-CG over a (T, 2, J, K) state stack.

    Runs in the dtype of ``g``; per-tile freeze + shared early exit.
    ``x0=None`` starts from zero (saves the warm-start matvec).
    Returns (x, residual_vec, iterations).
    """
    dtype = g.dtype
    mv = lambda x: _stencil_matvec_batched(g, cw, x)
    pre = _line_preconditioner(g, cw, chain_impl)

    b_norm2 = jnp.maximum(_dot(b, b), jnp.finfo(dtype).tiny)
    tol2 = jnp.asarray(tol, dtype) ** 2

    if x0 is None:
        x0 = jnp.zeros_like(b)
        r0 = b
    else:
        r0 = b - mv(x0)
    z0 = pre(r0)
    rz0 = _dot(r0, z0)
    done0 = _dot(r0, r0) <= tol2 * b_norm2

    def cond(state):
        k, _, _, _, _, done = state
        return (k < maxiter) & ~jnp.all(done)

    def body(state):
        k, x, res, p, rz, done = state
        Ap = mv(p)
        pAp = _dot(p, Ap)
        # Frozen (done) tiles and degenerate directions take a zero step.
        ok = ~done & (pAp > 0)
        alpha = jnp.where(ok, rz / jnp.where(ok, pAp, 1.0), 0.0)
        a4 = alpha[:, None, None, None]
        x = x + a4 * p
        res = res - a4 * Ap
        z = pre(res)
        rz_new = _dot(res, z)
        beta = jnp.where(ok, rz_new / jnp.where(rz > 0, rz, 1.0), 0.0)
        p = jnp.where(done[:, None, None, None], p,
                      z + beta[:, None, None, None] * p)
        done = done | (_dot(res, res) <= tol2 * b_norm2)
        return k + 1, x, res, p, jnp.where(ok, rz_new, rz), done

    k, x, res, _, _, _ = jax.lax.while_loop(
        cond, body, (jnp.asarray(0), x0, r0, z0, rz0, done0))
    return x, res, k


def _solve_core(active: jax.Array, v_in: jax.Array, spec_arr: jax.Array,
                maxiter: int, tol, precision: SolverPrecision,
                chain_impl: str = "lax") -> BatchedSolveResult:
    """Trace-level batched solve shared by the jitted single-device entry
    point below and the per-shard body in
    :mod:`repro.distributed.solver_shard` (which shard_maps it;
    ``chain_impl`` selects the preconditioner kernel per call)."""
    dtype = spec_arr.dtype
    active = active.astype(dtype)
    r_on, r_off = spec_arr[1], spec_arr[2]
    g = jnp.where(active > 0, 1.0 / r_on, 1.0 / r_off)
    return _solve_core_g(g, g, v_in, spec_arr, maxiter, tol, precision,
                         chain_impl)


def _solve_core_g(g: jax.Array, g_ref: jax.Array, v_in: jax.Array,
                  spec_arr: jax.Array, maxiter: int, tol,
                  precision: SolverPrecision,
                  chain_impl: str = "lax") -> BatchedSolveResult:
    """Batched solve over explicit per-cell conductances (T, J, K).

    The generalisation the device-nonideality subsystem
    (:mod:`repro.nonideal`) drives: faulted / variation-perturbed cells
    are no longer binary on/off, so the tile state is a real-valued
    conductance field ``g``.  ``g_ref`` holds the *intended* (clean)
    conductances: ideal currents — and hence NF — are measured against
    the programmer's intent, so the reported deficit includes both the
    parasitic-resistance error and the fault/variation error.  With
    ``g_ref is g`` this is exactly the classic mask solve."""
    dtype = spec_arr.dtype
    g = g.astype(dtype)
    g_ref = g_ref.astype(dtype)
    v_in = jnp.broadcast_to(v_in.astype(dtype),
                            g.shape[:1] + v_in.shape[-1:])
    r = spec_arr[0]
    cw = 1.0 / r
    T, J, K = g.shape

    bW = jnp.zeros((T, J, K), dtype).at[:, :, 0].set(cw * v_in)
    b = jnp.stack([bW, jnp.zeros((T, J, K), dtype)], axis=1)

    if precision.is_f64:
        x, res, iters = _pcg_loop(g, cw, b, None, tol, maxiter,
                                  chain_impl)
    else:
        # Coarse phase: all CG arithmetic in f32 (half the bytes moved).
        cdt = jnp.dtype(precision.cg_dtype)
        x32, _, k32 = _pcg_loop(g.astype(cdt), cw.astype(cdt),
                                b.astype(cdt), None,
                                max(float(tol), precision.coarse_tol),
                                min(maxiter, precision.coarse_maxiter),
                                chain_impl)
        x = x32.astype(dtype)
        iters = k32
        if precision.polish:
            # The polish loop recomputes the true f64 residual from its
            # warm start, so none is needed here.
            x, res, kp = _pcg_loop(g, cw, b, x, tol,
                                   precision.polish_maxiter, chain_impl)
            iters = iters + kp
        else:
            res = b - _stencil_matvec_batched(g, cw, x)  # true f64 resid

    b_norm2 = jnp.maximum(_dot(b, b), jnp.finfo(dtype).tiny)
    resid = jnp.sqrt(_dot(res, res) / b_norm2)
    currents = cw * x[:, 1, 0, :]               # (B[0,k] - 0) / r
    ideal = jnp.einsum("tjk,tj->tk", g_ref, v_in)
    di = currents - ideal
    nf_cols = jnp.abs(di) / jnp.maximum(ideal, 1e-30)
    nf_total = jnp.abs(jnp.sum(di, axis=-1)) / jnp.maximum(
        jnp.sum(ideal, axis=-1), 1e-30)
    return BatchedSolveResult(currents, ideal, nf_cols, nf_total, resid,
                              iters)


@partial(jax.jit,
         static_argnames=("maxiter", "tol", "precision", "chain_impl"))
def solve_crossbar_batched(active: jax.Array, v_in: jax.Array,
                           spec_arr: jax.Array, maxiter: int = 4000,
                           tol: float = 1e-12,
                           precision: SolverPrecision = F64,
                           chain_impl: str = "lax"
                           ) -> BatchedSolveResult:
    """Solve a (T, J, K) batch of tiles in one fused PCG loop.

    ``active``: (T, J, K) activity masks; ``v_in``: (J,) shared or
    (T, J) per-tile drive voltages; ``spec_arr`` = [r, r_on, r_off].
    Tiles that converge early are frozen (zero step) while the shared
    loop finishes the rest; the loop exits when every tile's relative
    residual is <= ``tol`` or at ``maxiter``.  ``precision`` selects
    the all-f64 path or the f32-CG + f64-polish mixed path;
    ``chain_impl`` the preconditioner kernel (see
    :func:`_line_preconditioner`).
    """
    return _solve_core(active, v_in, spec_arr, maxiter, tol, precision,
                       chain_impl)


@partial(jax.jit,
         static_argnames=("maxiter", "tol", "precision", "chain_impl"))
def solve_conductances_batched(g: jax.Array, g_ref: jax.Array,
                               v_in: jax.Array, spec_arr: jax.Array,
                               maxiter: int = 4000, tol: float = 1e-12,
                               precision: SolverPrecision = F64,
                               chain_impl: str = "lax"
                               ) -> BatchedSolveResult:
    """Solve a (..., J, K) batch of *conductance fields* in one fused PCG.

    The nonideality entry point: ``g`` carries the perturbed per-cell
    conductances (stuck faults, programming variation, read noise —
    :mod:`repro.nonideal.models`), ``g_ref`` the intended clean ones
    that define the ideal currents the NF is measured against.
    ``g_ref`` may have fewer leading dims than ``g`` (e.g. one (T, J, K)
    reference under an (S, T, J, K) Monte-Carlo ensemble): it is
    broadcast *inside* the jit, where XLA fuses it into the
    ideal-currents einsum instead of materialising S duplicate copies.
    Leading dims are flattened into the solver's tile axis; results come
    back flat (the front door below restores them).
    """
    J, K = g.shape[-2], g.shape[-1]
    g_ref = jnp.broadcast_to(g_ref, g.shape).reshape(-1, J, K)
    return _solve_core_g(g.reshape(-1, J, K), g_ref, v_in, spec_arr,
                         maxiter, tol, precision, chain_impl)


def measured_nf_conductances(g: jax.Array, spec: CrossbarSpec,
                             g_ref: jax.Array | None = None,
                             v_in: jax.Array | None = None,
                             maxiter: int = 4000,
                             precision: SolverPrecision | str | None = None,
                             chain_impl: str = "lax"
                             ) -> BatchedSolveResult:
    """Circuit-measured NF of perturbed conductance fields, one solve.

    ``g``: (..., J, K) per-cell conductances [S] with arbitrary leading
    batch dims (the Monte-Carlo engine folds its sample axis in here —
    the solver *is* the vmap); ``g_ref`` the matching clean conductances
    (default: ``g`` itself; may carry fewer leading dims — it broadcasts
    against ``g`` inside the jitted solve, so one (T, J, K) reference
    serves a whole (S, T, J, K) ensemble without duplication).  The
    result carries ``g``'s leading dims.
    """
    precision = resolve_precision(precision)
    with enable_x64():
        spec_arr = jnp.array([spec.r, spec.r_on, spec.r_off], jnp.float64)
        if v_in is None:
            v_in = jnp.full((g.shape[-2],), spec.v_read, jnp.float64)
        batch_shape = g.shape[:-2]
        flat_v = v_in.reshape((-1, v_in.shape[-1])) if v_in.ndim > 1 else v_in
        res = solve_conductances_batched(g, g if g_ref is None else g_ref,
                                         flat_v, spec_arr,
                                         maxiter, precision=precision,
                                         chain_impl=chain_impl)
        if len(batch_shape) != 1:
            res = BatchedSolveResult(
                *(f.reshape(batch_shape + f.shape[1:])
                  for f in res[:-1]), res.iterations)
        return res


def measured_nf_batched(active: jax.Array, spec: CrossbarSpec,
                        v_in: jax.Array | None = None,
                        maxiter: int = 4000,
                        precision: SolverPrecision | str | None = None,
                        chain_impl: str = "lax") -> BatchedSolveResult:
    """Circuit-measured NF of a batch of tiles in one jitted solve.

    ``active``: (..., J, K) with arbitrary leading batch dims (a single
    (J, K) tile becomes a batch of one); the result carries the same
    leading dims.  The f64 requirement is met with the config-scoped
    x64 flag at trace time (``jax.enable_x64`` no longer exists).
    ``precision`` (policy, name, or None=f64) picks the arithmetic —
    see :class:`SolverPrecision`.
    """
    precision = resolve_precision(precision)
    with enable_x64():
        spec_arr = jnp.array([spec.r, spec.r_on, spec.r_off], jnp.float64)
        if v_in is None:
            v_in = jnp.full((active.shape[-2],), spec.v_read, jnp.float64)
        batch_shape = active.shape[:-2]
        flat = active.reshape((-1,) + active.shape[-2:])
        flat_v = v_in.reshape((-1, v_in.shape[-1])) if v_in.ndim > 1 else v_in
        res = solve_crossbar_batched(flat, flat_v, spec_arr, maxiter,
                                     precision=precision,
                                     chain_impl=chain_impl)
        if batch_shape != flat.shape[:1]:
            res = BatchedSolveResult(
                *(f.reshape(batch_shape + f.shape[1:])
                  for f in res[:-1]), res.iterations)
        return res


# ------------------------- convergence watchdog ---------------------------

def tile_converged(res: BatchedSolveResult, tol: float) -> jax.Array:
    """NaN/Inf-aware per-tile convergence mask.

    The naive check ``residual > tol`` counts a NaN residual as
    *converged* (NaN comparisons are False) — the exact masquerade the
    watchdog exists to close.  The mask is therefore phrased
    positively: a tile is healthy iff its residual is a finite number
    ``<= tol`` AND every reported current (hence every NF it feeds) is
    finite.
    """
    finite = (jnp.all(jnp.isfinite(res.currents), axis=-1)
              & jnp.isfinite(res.residual) & jnp.isfinite(res.nf_total))
    return finite & (res.residual <= tol)


def _escalation_ladder(precision: SolverPrecision, chain_impl: str,
                       maxiter: int) -> list:
    """Bounded retry schedule for failed tiles, cheapest first.

    f32/mixed solves first get the full-f64 rerun (same
    preconditioner); whatever still fails gets a Jacobi-preconditioned
    f64 rerun with a doubled budget — the line preconditioner's chain
    solves are themselves a failure candidate on degenerate
    (zero-conductance) tiles, the plain diagonal never is.
    """
    ladder = []
    if not precision.is_f64:
        ladder.append((F64, chain_impl, maxiter))
    if not (precision.is_f64 and chain_impl == "jacobi"):
        ladder.append((F64, "jacobi", 2 * maxiter))
    else:
        ladder.append((F64, "jacobi", 4 * maxiter))
    return ladder


def _escalate_failed(res: BatchedSolveResult, rerun,
                     precision: SolverPrecision, chain_impl: str,
                     maxiter: int, tol: float):
    """Host-side watchdog: check, then rerun only the failed tiles.

    ``res`` is the flat (T leading) first-pass result; ``rerun(idx,
    precision, chain_impl, maxiter)`` solves just those tiles again.
    Runs outside jit on concrete arrays — the failure set is data-
    dependent, and re-solving a handful of tiles on the host beats
    paying a masked full-batch rerun inside the jitted program.
    Returns the patched result plus the :class:`SolverReport`.
    """
    converged = tile_converged(res, tol)
    escalations = 0
    for prec_e, chain_e, mi_e in _escalation_ladder(precision,
                                                    chain_impl, maxiter):
        if bool(jnp.all(converged)):
            break
        idx = jnp.nonzero(~converged)[0]
        sub = rerun(idx, prec_e, chain_e, mi_e)
        escalations += 1
        res = BatchedSolveResult(
            res.currents.at[idx].set(sub.currents),
            res.ideal.at[idx].set(sub.ideal),
            res.nf_cols.at[idx].set(sub.nf_cols),
            res.nf_total.at[idx].set(sub.nf_total),
            res.residual.at[idx].set(sub.residual),
            res.iterations + sub.iterations)
        converged = converged.at[idx].set(tile_converged(sub, tol))
    report = SolverReport(converged, res.iterations, escalations,
                          jnp.sum(~converged))
    return res, report


def _ref_subset(g_ref: jax.Array, g_shape: tuple, idx: jax.Array,
                J: int, K: int) -> jax.Array:
    """Rows of the broadcast clean reference for flat tile indices.

    ``g_ref`` may carry fewer leading dims than ``g`` (one (T, J, K)
    reference under an (S, T, J, K) ensemble); indexing it modulo its
    own flat tile count avoids materialising the S-fold broadcast just
    to escalate a handful of tiles.
    """
    if g_ref.shape == g_shape:
        return g_ref.reshape(-1, J, K)[idx]
    if g_ref.shape == g_shape[-g_ref.ndim:]:
        n_ref = 1
        for d in g_ref.shape[:-2]:
            n_ref *= d
        return g_ref.reshape(-1, J, K)[idx % max(n_ref, 1)]
    return jnp.broadcast_to(g_ref, g_shape).reshape(-1, J, K)[idx]


def measured_nf_conductances_checked(
        g: jax.Array, spec: CrossbarSpec,
        g_ref: jax.Array | None = None,
        v_in: jax.Array | None = None, maxiter: int = 4000,
        precision: SolverPrecision | str | None = None,
        chain_impl: str = "lax", tol: float = 1e-12,
        escalate: bool = True):
    """:func:`measured_nf_conductances` + the convergence watchdog.

    Returns ``(BatchedSolveResult, SolverReport)``: the result has the
    escalated reruns patched in per tile, the report says which tiles
    can be trusted.  ``escalate=False`` checks without retrying.
    """
    precision = resolve_precision(precision)
    with enable_x64():
        spec_arr = jnp.array([spec.r, spec.r_on, spec.r_off], jnp.float64)
        if v_in is None:
            v_in = jnp.full((g.shape[-2],), spec.v_read, jnp.float64)
        J, K = g.shape[-2], g.shape[-1]
        batch_shape = g.shape[:-2]
        flat_v = v_in.reshape((-1, v_in.shape[-1])) if v_in.ndim > 1 else v_in
        g_ref_eff = g if g_ref is None else g_ref
        res = solve_conductances_batched(g, g_ref_eff, flat_v, spec_arr,
                                         maxiter, tol,
                                         precision=precision,
                                         chain_impl=chain_impl)

        g_flat = g.reshape(-1, J, K)

        def rerun(idx, prec_e, chain_e, mi_e):
            v_e = flat_v[idx] if flat_v.ndim > 1 else flat_v
            return solve_conductances_batched(
                g_flat[idx], _ref_subset(g_ref_eff, g.shape, idx, J, K),
                v_e, spec_arr, mi_e, tol, precision=prec_e,
                chain_impl=chain_e)

        if escalate:
            res, report = _escalate_failed(res, rerun, precision,
                                           chain_impl, maxiter, tol)
        else:
            conv = tile_converged(res, tol)
            report = SolverReport(conv, res.iterations, 0,
                                  jnp.sum(~conv))
        if len(batch_shape) != 1:
            res = BatchedSolveResult(
                *(f.reshape(batch_shape + f.shape[1:])
                  for f in res[:-1]), res.iterations)
            report = report._replace(
                converged=report.converged.reshape(batch_shape))
        record_solver_report(report)
        return res, report


def measured_nf_batched_checked(
        active: jax.Array, spec: CrossbarSpec,
        v_in: jax.Array | None = None, maxiter: int = 4000,
        precision: SolverPrecision | str | None = None,
        chain_impl: str = "lax", tol: float = 1e-12,
        escalate: bool = True):
    """:func:`measured_nf_batched` + the convergence watchdog.

    Mask front door: builds the f64 conductance field exactly as
    :func:`_solve_core` does (bit-identical solve) and routes through
    the checked conductance entry.
    """
    with enable_x64():
        active = jnp.asarray(active)
        g = jnp.where(active > 0,
                      jnp.float64(1.0 / spec.r_on),
                      jnp.float64(1.0 / spec.r_off))
        if g.ndim == 2:
            g = g[None]
            res, report = measured_nf_conductances_checked(
                g, spec, g, v_in, maxiter, precision, chain_impl, tol,
                escalate)
            res = BatchedSolveResult(*(f[0] for f in res[:-1]),
                                     res.iterations)
            report = report._replace(converged=report.converged[0])
            return res, report
        return measured_nf_conductances_checked(
            g, spec, g, v_in, maxiter, precision, chain_impl, tol,
            escalate)
