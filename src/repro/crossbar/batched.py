"""Batched crossbar circuit-solver engine.

The seed solver (:mod:`repro.crossbar.solver`) solves one tile per CG
invocation and walks batches with ``jax.lax.map`` — correct, but the
whole (Ti, Tn) tile grid of a layer pays one sequential CG per tile.
This module solves the *entire batch in one jitted call*:

* the preconditioned-CG state is stacked along a leading tile axis
  ``(T, 2, J, K)`` and every stencil matvec / axpy runs across all
  tiles at once (one fused XLA program instead of T dispatches);
* the preconditioner is a **line (tridiagonal) preconditioner**: the
  nodal matrix is two families of wire chains — wordline chains along
  ``k`` and bitline chains along ``j`` — coupled only through the
  memristor conductances, and ``g/cw ~ r/R_on ~ 1e-5`` makes that
  coupling weak.  Solving the per-chain tridiagonal systems exactly
  (batched ``jax.lax.linalg.tridiagonal_solve`` over T*J + T*K chains)
  leaves ``M^-1 A ~= I + O(g/cw)``, so CG converges in a handful of
  iterations where the seed's Jacobi preconditioner needs hundreds;
* convergence is tracked **per tile**: a boolean ``done`` mask freezes a
  tile's iterates (its step sizes are zeroed) the moment its relative
  residual passes ``tol``, while the shared iteration loop keeps running
  the stragglers;
* the shared ``lax.while_loop`` exits early as soon as *all* tiles have
  converged, so a batch is never slower than its hardest member;
* float64 is obtained with the config-scoped
  :func:`repro.compat.enable_x64` at trace time (the old
  ``jax.enable_x64`` context manager no longer exists in JAX >= 0.4.x).

The single-tile Jacobi-CG path in :mod:`repro.crossbar.solver` is kept
as the oracle; ``tests/test_solver.py`` pins this engine against both
that path and the dense nodal solve.  Throughput is tracked by
``benchmarks/solver_throughput.py`` (the acceptance bar is >= 10x over
the seed ``lax.map`` path on a 64-tile batch).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.compat import enable_x64
from repro.core.tiling import CrossbarSpec
from repro.crossbar.solver import _jacobi_diag, _stencil_matvec


class BatchedSolveResult(NamedTuple):
    """Per-tile solve results, leading axes = tile batch.

    Identical field layout to :class:`repro.crossbar.solver.SolveResult`
    (so consumers can treat the two interchangeably) plus the shared
    iteration count the early-exit loop actually ran.
    """

    currents: jax.Array    # (..., K) actual column currents under PR
    ideal: jax.Array       # (..., K) ideal currents (r = 0)
    nf_cols: jax.Array     # (..., K) per-column |di/i0|
    nf_total: jax.Array    # (...,)  aggregate |sum di| / sum i0
    residual: jax.Array    # (...,)  final per-tile relative CG residual
    iterations: jax.Array  # ()      shared CG iterations until all done


# The stencil physics lives once, in the oracle (solver.py); the batched
# matvec is its vmap over the leading tile axis: g (T,J,K), x (T,2,J,K).
_stencil_matvec_batched = jax.vmap(_stencil_matvec, in_axes=(0, None, 0))
_jacobi_diag_batched = jax.vmap(_jacobi_diag, in_axes=(0, None))


def _dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Per-tile inner product over the (2, J, K) node axes."""
    return jnp.sum(a * b, axis=(1, 2, 3))


def _line_preconditioner(g: jax.Array, cw: jax.Array):
    """Exact per-chain solver for the block-diagonal part of A.

    M = blockdiag(Dw + diag(g), Db + diag(g)) where Dw couples each
    wordline chain along k and Db each bitline chain along j; both are
    SPD tridiagonal, so M is a valid SPD preconditioner and captures
    everything except the weak W<->B memristor coupling.

    ``jax.lax.linalg.tridiagonal_solve`` requires chains of length >= 3;
    degenerate geometries (rows or cols < 3) fall back to the Jacobi
    diagonal — at those sizes the chains are short enough that plain
    Jacobi CG converges quickly anyway.
    """
    T, J, K = g.shape
    dt = g.dtype
    diag = _jacobi_diag_batched(g, cw)                      # (T, 2, J, K)
    if min(J, K) < 3:
        return lambda r: r / diag
    dW = diag[:, 0]                                         # (T, J, K)
    dBt = diag[:, 1].transpose(0, 2, 1)                     # (T, K, J)
    lo_k = jnp.broadcast_to(
        jnp.where(jnp.arange(K) > 0, -cw, 0.0).astype(dt), (T, J, K))
    hi_k = jnp.broadcast_to(
        jnp.where(jnp.arange(K) < K - 1, -cw, 0.0).astype(dt), (T, J, K))
    lo_j = jnp.broadcast_to(
        jnp.where(jnp.arange(J) > 0, -cw, 0.0).astype(dt), (T, K, J))
    hi_j = jnp.broadcast_to(
        jnp.where(jnp.arange(J) < J - 1, -cw, 0.0).astype(dt), (T, K, J))

    def pre(r):
        zW = jax.lax.linalg.tridiagonal_solve(
            lo_k, dW, hi_k, r[:, 0][..., None])[..., 0]
        zBt = jax.lax.linalg.tridiagonal_solve(
            lo_j, dBt, hi_j, r[:, 1].transpose(0, 2, 1)[..., None])[..., 0]
        return jnp.stack([zW, zBt.transpose(0, 2, 1)], axis=1)

    return pre


@partial(jax.jit, static_argnames=("maxiter",))
def solve_crossbar_batched(active: jax.Array, v_in: jax.Array,
                           spec_arr: jax.Array, maxiter: int = 4000,
                           tol: float = 1e-12) -> BatchedSolveResult:
    """Solve a (T, J, K) batch of tiles in one fused PCG loop.

    ``active``: (T, J, K) activity masks; ``v_in``: (J,) shared or
    (T, J) per-tile drive voltages; ``spec_arr`` = [r, r_on, r_off].
    Tiles that converge early are frozen (zero step) while the shared
    loop finishes the rest; the loop exits when every tile's relative
    residual is <= ``tol`` or at ``maxiter``.
    """
    dtype = spec_arr.dtype
    active = active.astype(dtype)
    v_in = jnp.broadcast_to(v_in.astype(dtype),
                            active.shape[:1] + v_in.shape[-1:])
    r, r_on, r_off = spec_arr[0], spec_arr[1], spec_arr[2]
    g = jnp.where(active > 0, 1.0 / r_on, 1.0 / r_off)
    cw = 1.0 / r
    T, J, K = g.shape

    bW = jnp.zeros((T, J, K), dtype).at[:, :, 0].set(cw * v_in)
    b = jnp.stack([bW, jnp.zeros((T, J, K), dtype)], axis=1)
    mv = lambda x: _stencil_matvec_batched(g, cw, x)
    pre = _line_preconditioner(g, cw)

    b_norm2 = jnp.maximum(_dot(b, b), jnp.finfo(dtype).tiny)
    tol2 = jnp.asarray(tol, dtype) ** 2

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = pre(r0)
    rz0 = _dot(r0, z0)
    done0 = _dot(r0, r0) <= tol2 * b_norm2

    def cond(state):
        k, _, _, _, _, done = state
        return (k < maxiter) & ~jnp.all(done)

    def body(state):
        k, x, res, p, rz, done = state
        Ap = mv(p)
        pAp = _dot(p, Ap)
        # Frozen (done) tiles and degenerate directions take a zero step.
        ok = ~done & (pAp > 0)
        alpha = jnp.where(ok, rz / jnp.where(ok, pAp, 1.0), 0.0)
        a4 = alpha[:, None, None, None]
        x = x + a4 * p
        res = res - a4 * Ap
        z = pre(res)
        rz_new = _dot(res, z)
        beta = jnp.where(ok, rz_new / jnp.where(rz > 0, rz, 1.0), 0.0)
        p = jnp.where(done[:, None, None, None], p,
                      z + beta[:, None, None, None] * p)
        done = done | (_dot(res, res) <= tol2 * b_norm2)
        return k + 1, x, res, p, jnp.where(ok, rz_new, rz), done

    k, x, res, _, _, _ = jax.lax.while_loop(
        cond, body, (jnp.asarray(0), x0, r0, z0, rz0, done0))

    resid = jnp.sqrt(_dot(res, res) / b_norm2)
    currents = cw * x[:, 1, 0, :]               # (B[0,k] - 0) / r
    ideal = jnp.einsum("tjk,tj->tk", g, v_in)
    di = currents - ideal
    nf_cols = jnp.abs(di) / jnp.maximum(ideal, 1e-30)
    nf_total = jnp.abs(jnp.sum(di, axis=-1)) / jnp.maximum(
        jnp.sum(ideal, axis=-1), 1e-30)
    return BatchedSolveResult(currents, ideal, nf_cols, nf_total, resid, k)


def measured_nf_batched(active: jax.Array, spec: CrossbarSpec,
                        v_in: jax.Array | None = None,
                        maxiter: int = 4000) -> BatchedSolveResult:
    """Circuit-measured NF of a batch of tiles in one jitted solve.

    ``active``: (..., J, K) with arbitrary leading batch dims (a single
    (J, K) tile becomes a batch of one); the result carries the same
    leading dims.  The f64 requirement is met with the config-scoped
    x64 flag at trace time (``jax.enable_x64`` no longer exists).
    """
    with enable_x64():
        spec_arr = jnp.array([spec.r, spec.r_on, spec.r_off], jnp.float64)
        if v_in is None:
            v_in = jnp.full((active.shape[-2],), spec.v_read, jnp.float64)
        batch_shape = active.shape[:-2]
        flat = active.reshape((-1,) + active.shape[-2:])
        flat_v = v_in.reshape((-1, v_in.shape[-1])) if v_in.ndim > 1 else v_in
        res = solve_crossbar_batched(flat, flat_v, spec_arr, maxiter)
        if batch_shape != flat.shape[:1]:
            res = BatchedSolveResult(
                *(f.reshape(batch_shape + f.shape[1:])
                  for f in res[:-1]), res.iterations)
        return res
