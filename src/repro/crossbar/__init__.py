from repro.crossbar.solver import (  # noqa: F401
    SolveResult,
    column_currents_dense,
    ideal_currents,
    measured_nf,
    solve_crossbar,
)
