from repro.crossbar.batched import (  # noqa: F401
    F32,
    F64,
    MIXED,
    BatchedSolveResult,
    SolverPrecision,
    measured_nf_batched,
    resolve_precision,
    solve_crossbar_batched,
)
from repro.crossbar.solver import (  # noqa: F401
    SolveResult,
    column_currents_dense,
    ideal_currents,
    measured_nf,
    measured_nf_sequential,
    solve_crossbar,
)
