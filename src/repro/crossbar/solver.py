"""Circuit-level resistive-mesh solver — the repo's SPICE replacement.

Nodal analysis of a (J, K) memristive crossbar with parasitic wire
resistance ``r`` per segment (paper §III-B / Fig 2):

  * wordline nodes  W[j,k]; row j driven by V_in[j] through r into W[j,0]
  * bitline nodes   B[j,k]; column k sensed at virtual ground through r
    from B[0,k]  (row 0 is the side nearest the output rail, matching the
    Manhattan-distance convention of ``repro.core.manhattan``)
  * a memristor of conductance g[j,k] bridges W[j,k] <-> B[j,k]
    (1/R_on if the cell is active, 1/R_off otherwise)

The resulting SPD system is solved with Jacobi-preconditioned CG whose
matvec is a pure stencil (O(JK) per iteration); a dense nodal-matrix
``jnp.linalg.solve`` oracle validates it for small tiles.  Everything
runs in float64 (the NF signal is ~1e-3 relative).

This module is the *single-tile oracle path*.  Batches of tiles are
solved by :mod:`repro.crossbar.batched`, which runs one fused PCG loop
over the whole tile stack with per-tile convergence tracking —
``measured_nf`` transparently routes batched inputs there (and accepts
a :class:`~repro.crossbar.batched.SolverPrecision` policy for the
mixed f32-CG/f64-polish path).  Layer-scale tile populations shard
across local devices via :mod:`repro.distributed.solver_shard`.  The
sequential ``lax.map`` walk is kept as ``measured_nf_sequential`` so the
throughput benchmark (``benchmarks/solver_throughput.py``) and the
equivalence tests can compare the paths.

JAX-version pitfall: float64 is enabled with the config-scoped
``jax.experimental.enable_x64()`` (via :func:`repro.compat.enable_x64`)
around the *trace-time* call — the old ``jax.enable_x64`` context
manager was removed from the public namespace and dtypes are frozen
once a jit has been traced.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import enable_x64
from repro.core.tiling import CrossbarSpec


class SolveResult(NamedTuple):
    currents: jax.Array   # (K,) actual column currents under PR
    ideal: jax.Array      # (K,) ideal currents (r = 0)
    nf_cols: jax.Array    # (K,) per-column |di/i0| (i0 summed guard-eps)
    nf_total: jax.Array   # scalar aggregate |sum di| / sum i0
    residual: jax.Array   # final CG residual norm


def conductances(active: jax.Array, spec: CrossbarSpec) -> jax.Array:
    g_on, g_off = 1.0 / spec.r_on, 1.0 / spec.r_off
    return jnp.where(active > 0, g_on, g_off)


def ideal_currents(g: jax.Array, v_in: jax.Array) -> jax.Array:
    """Column currents for r = 0: i_k = sum_j g[j,k] v_in[j]."""
    return jnp.einsum("jk,j->k", g, v_in)


def _stencil_matvec(g: jax.Array, cw: jax.Array, x: jax.Array) -> jax.Array:
    """A @ x for the nodal system. x: (2, J, K) stacked [W, B] grids."""
    W, B = x[0], x[1]
    J, K = W.shape

    # Wordline: left tie is source (k=0) or neighbour; right tie if k<K-1.
    left = jnp.pad(W[:, :-1], ((0, 0), (1, 0)))            # neighbour W[:,k-1]
    right = jnp.pad(W[:, 1:], ((0, 0), (0, 1)))            # neighbour W[:,k+1]
    has_right = jnp.pad(jnp.ones((J, K - 1), x.dtype), ((0, 0), (0, 1)))
    degW = 1.0 + has_right                                  # left tie always
    yW = cw * (degW * W - left - right) + g * (W - B)

    # Bitline: down tie is ground (j=0) or neighbour; up tie if j<J-1.
    down = jnp.pad(B[:-1, :], ((1, 0), (0, 0)))            # neighbour B[j-1,:]
    up = jnp.pad(B[1:, :], ((0, 1), (0, 0)))               # neighbour B[j+1,:]
    has_up = jnp.pad(jnp.ones((J - 1, K), x.dtype), ((0, 1), (0, 0)))
    degB = 1.0 + has_up
    yB = cw * (degB * B - down - up) + g * (B - W)

    return jnp.stack([yW, yB])


def _rhs(v_in: jax.Array, cw: jax.Array, K: int) -> jax.Array:
    J = v_in.shape[0]
    bW = jnp.zeros((J, K), v_in.dtype).at[:, 0].set(cw * v_in)
    return jnp.stack([bW, jnp.zeros((J, K), v_in.dtype)])


def _jacobi_diag(g: jax.Array, cw: jax.Array) -> jax.Array:
    J, K = g.shape
    has_right = jnp.pad(jnp.ones((J, K - 1), g.dtype), ((0, 0), (0, 1)))
    has_up = jnp.pad(jnp.ones((J - 1, K), g.dtype), ((0, 1), (0, 0)))
    dW = cw * (1.0 + has_right) + g
    dB = cw * (1.0 + has_up) + g
    return jnp.stack([dW, dB])


@partial(jax.jit, static_argnames=("maxiter",))
def solve_crossbar(active: jax.Array, v_in: jax.Array, spec_arr: jax.Array,
                   maxiter: int = 4000) -> SolveResult:
    """Solve one tile. ``spec_arr`` = [r, r_on, r_off] (f64) so the same
    jitted solver serves sweeps over device parameters."""
    dtype = jnp.float64
    active = active.astype(dtype)
    v_in = v_in.astype(dtype)
    r, r_on, r_off = spec_arr[0], spec_arr[1], spec_arr[2]
    g = jnp.where(active > 0, 1.0 / r_on, 1.0 / r_off)
    cw = 1.0 / r
    J, K = g.shape

    b = _rhs(v_in, cw, K)
    diag = _jacobi_diag(g, cw)
    mv = lambda x: _stencil_matvec(g, cw, x)
    pre = lambda x: x / diag

    x, _ = jax.scipy.sparse.linalg.cg(mv, b, tol=1e-12, maxiter=maxiter, M=pre)
    resid = jnp.linalg.norm(mv(x) - b) / jnp.linalg.norm(b)

    currents = cw * x[1, 0, :]                 # (B[0,k] - 0) / r
    ideal = jnp.einsum("jk,j->k", g, v_in)
    di = currents - ideal
    nf_cols = jnp.abs(di) / jnp.maximum(ideal, 1e-30)
    nf_total = jnp.abs(jnp.sum(di)) / jnp.maximum(jnp.sum(ideal), 1e-30)
    return SolveResult(currents, ideal, nf_cols, nf_total, resid)


def measured_nf(active: jax.Array, spec: CrossbarSpec,
                v_in: jax.Array | None = None, maxiter: int = 4000,
                precision=None):
    """Circuit-measured NF of one tile (or a batch over leading dims).

    This is the quantity the paper probes in SPICE; comparing it against
    ``repro.core.manhattan.nonideality_factor`` is the Fig-4 experiment.
    Batched inputs are dispatched to the fused engine in
    :mod:`repro.crossbar.batched` (one jitted PCG over all tiles);
    single tiles take the oracle path below.

    ``precision`` (a :class:`repro.crossbar.batched.SolverPrecision`,
    a policy name, or None = all-f64) selects the engine arithmetic; a
    single tile under a non-default policy is routed through the batched
    engine as a batch of one and unwrapped back to a ``SolveResult``.
    """
    if active.ndim > 2:
        from repro.crossbar.batched import measured_nf_batched
        return measured_nf_batched(active, spec, v_in, maxiter, precision)
    if precision is not None:
        from repro.crossbar.batched import F64, measured_nf_batched, \
            resolve_precision
        if resolve_precision(precision) != F64:
            res = measured_nf_batched(active[None], spec, v_in, maxiter,
                                      precision)
            return SolveResult(*(f[0] for f in res[:5]))
    with enable_x64():
        spec_arr = jnp.array([spec.r, spec.r_on, spec.r_off], jnp.float64)
        if v_in is None:
            v_in = jnp.full((active.shape[-2],), spec.v_read, jnp.float64)
        return solve_crossbar(active, v_in, spec_arr, maxiter)


def measured_nf_checked(active: jax.Array, spec: CrossbarSpec,
                        v_in: jax.Array | None = None,
                        maxiter: int = 4000, precision=None,
                        tol: float = 1e-12, escalate: bool = True):
    """:func:`measured_nf` + the convergence watchdog.

    Routes every input shape through the checked batched engine
    (:func:`repro.crossbar.batched.measured_nf_batched_checked`) and
    returns ``(result, SolverReport)`` — a single (J, K) tile comes
    back as a :class:`SolveResult` with a scalar ``converged``.
    """
    from repro.crossbar.batched import measured_nf_batched_checked
    if active.ndim > 2:
        return measured_nf_batched_checked(active, spec, v_in, maxiter,
                                           precision, tol=tol,
                                           escalate=escalate)
    res, report = measured_nf_batched_checked(active, spec, v_in,
                                              maxiter, precision,
                                              tol=tol, escalate=escalate)
    return SolveResult(*res[:5]), report


def measured_nf_sequential(active: jax.Array, spec: CrossbarSpec,
                           v_in: jax.Array | None = None,
                           maxiter: int = 4000):
    """Seed behaviour: walk a tile batch with ``jax.lax.map``, one CG per
    tile.  Kept as the baseline for ``benchmarks/solver_throughput.py``
    and the batched-vs-sequential equivalence tests — use
    :func:`measured_nf` (batched engine) for real workloads.
    """
    with enable_x64():
        spec_arr = jnp.array([spec.r, spec.r_on, spec.r_off], jnp.float64)
        if v_in is None:
            v_in = jnp.full((active.shape[-2],), spec.v_read, jnp.float64)
        fn = lambda a: solve_crossbar(a, v_in, spec_arr, maxiter)
        batch_shape = active.shape[:-2]
        if batch_shape:
            flat = active.reshape((-1,) + active.shape[-2:])
            res = jax.lax.map(fn, flat)
            res = jax.tree_util.tree_map(
                lambda x: x.reshape(batch_shape + x.shape[1:]), res)
            return res
        return fn(active)


# ----------------------------- dense oracle ------------------------------

def _node_index(j: int, k: int, K: int, grid: int, JK: int) -> int:
    return grid * JK + j * K + k


def column_currents_dense(active: np.ndarray, v_in: np.ndarray,
                          spec: CrossbarSpec) -> np.ndarray:
    """Dense nodal-matrix solve (numpy, float64) — oracle for small tiles."""
    J, K = active.shape
    JK = J * K
    n = 2 * JK
    cw = 1.0 / spec.r
    g = np.where(active > 0, 1.0 / spec.r_on, 1.0 / spec.r_off)
    A = np.zeros((n, n))
    b = np.zeros(n)
    for j in range(J):
        for k in range(K):
            w = _node_index(j, k, K, 0, JK)
            bb = _node_index(j, k, K, 1, JK)
            # device
            A[w, w] += g[j, k]; A[bb, bb] += g[j, k]
            A[w, bb] -= g[j, k]; A[bb, w] -= g[j, k]
            # wordline left tie
            if k == 0:
                A[w, w] += cw; b[w] += cw * v_in[j]
            else:
                wl = _node_index(j, k - 1, K, 0, JK)
                A[w, w] += cw; A[wl, wl] += cw
                A[w, wl] -= cw; A[wl, w] -= cw
            # bitline down tie
            if j == 0:
                A[bb, bb] += cw  # to ground
            else:
                bd = _node_index(j - 1, k, K, 1, JK)
                A[bb, bb] += cw; A[bd, bd] += cw
                A[bb, bd] -= cw; A[bd, bb] -= cw
    x = np.linalg.solve(A, b)
    B0 = x[JK:].reshape(J, K)[0]
    return cw * B0
