"""Whole-model CIM deployment: model params -> routed CimDeployments.

Walks a model's parameter pytree, extracts every deployable projection
matrix (attention q/k/v/o and dense-MLP up/gate/down — the matmuls the
model zoo routes through ``cim_mvm`` when ``cfg.cim.enabled`` is set),
plans all of them in one fused pass (:mod:`repro.deploy.planner`,
through the persistent :class:`repro.deploy.cache.PlanCache`), and
packages per-slot stacks of :class:`CimDeployment` shaped for the
model's ``lax.scan`` over pattern repeats.

Embeddings, the LM head, norms/biases and recurrent/SSM state weights
stay digital (standard CIM practice: crossbars host the dense
projection GEMMs); MoE expert banks are skipped for now — their (E, I,
N) layout wants expert-axis-aware tiling, tracked in ROADMAP.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.bitslice import magnitude_scale_host
from repro.core.mdm import MdmPlan
from repro.core.tiling import CrossbarSpec
from repro.deploy.cache import PlanCache
from repro.deploy.planner import plan_matrices, quantize_codes_host
from repro.distributed.sharding import ShardingCtx
from repro.kernels.cim_mvm.ops import CimDeployment

# Projection parameters the serving path routes through cim_mvm, with
# the reshape that turns each per-layer tensor into a 2-D matmul weight.
_QKV_NAMES = ("wq", "wk", "wv", "attn_wq", "attn_wk", "attn_wv")
_OUT_NAMES = ("wo", "attn_wo")
_MLP_NAMES = ("ffn_w_gate", "ffn_w_up", "ffn_w_down")
DEPLOYABLE = _QKV_NAMES + _OUT_NAMES + _MLP_NAMES


def _as_matrix(name: str, w) -> np.ndarray:
    """Per-layer projection tensor -> its (in_dim, out_dim) matmul view."""
    if name in _QKV_NAMES:        # (D, H, Dh) -> (D, H*Dh)
        return w.reshape(w.shape[0], -1)
    if name in _OUT_NAMES:        # (H, Dh, D) -> (H*Dh, D)
        return w.reshape(-1, w.shape[-1])
    return w                      # MLP projections are already 2-D


def spec_from_config(cfg: ModelConfig) -> CrossbarSpec:
    c = cfg.cim
    return CrossbarSpec(rows=c.rows, cols=c.cols, n_bits=c.n_bits,
                        r=c.r, r_on=c.r_on, r_off=c.r_off)


def collect_projection_matrices(params: dict, cfg: ModelConfig
                                ) -> dict[str, np.ndarray]:
    """name "slot/param/repeat" -> 2-D f32 host matrix for every
    deployable projection in the model, in deterministic traversal
    order.

    Matrices land on the host (one device->host pull per stacked
    parameter): fingerprinting and the fused planner's bit-slicing are
    host-side anyway, so keeping a device-resident f32 copy would only
    add an upload plus two full download sweeps per deployment.
    bf16 -> f32 widening is exact, so the cast matches the device cast.
    """
    mats: dict[str, np.ndarray] = {}
    for i, bt in enumerate(cfg.block_pattern):
        slot = f"slot{i}_{bt}"
        slot_params = params.get(slot, {})
        for pname in DEPLOYABLE:
            if pname not in slot_params:
                continue
            stacked = np.asarray(slot_params[pname])  # (R, ...) layers
            for r in range(stacked.shape[0]):
                mats[f"{slot}/{pname}/{r}"] = np.asarray(
                    _as_matrix(pname, stacked[r]), np.float32)
    return mats


def package_deployment_host(w: np.ndarray, spec: CrossbarSpec, mode: str,
                            eta: float, plan: MdmPlan,
                            cells=None, nonideal=None) -> CimDeployment:
    """Host mirror of ``repro.kernels.cim_mvm.ops.deploy`` packaging.

    Quantises and lays out one planned matrix entirely in numpy —
    bit-identical to the device path (pinned in tests/test_deploy.py)
    but free of the ~10 eager device dispatches per matrix that a
    whole-checkpoint packaging loop would otherwise pay (the planner
    already amortised planning; packaging must not reintroduce the
    per-matrix cost structure).  The array leaves stay on host; the
    per-slot ``jnp.stack`` in :func:`deploy_model_params` uploads each
    stacked field once.

    ``cells`` (a :class:`repro.nonideal.inject.HostCells` sample, plus
    its :class:`repro.nonideal.models.NonidealModel` as ``nonideal``)
    injects device nonidealities at packaging time: stuck-at faults are
    folded bit-exactly into the int16 codes, programming variation /
    drift into the per-weight ``gain`` field — generation then runs
    under the injected faults through the unchanged ``cim_mvm``.
    """
    I, N = w.shape
    rev = mode in ("reverse", "mdm")
    scale = magnitude_scale_host(w, spec.n_bits)
    codes = quantize_codes_host(w, scale, spec.n_bits)
    sign = np.where(np.asarray(w, np.float32) < 0, -1, 1).astype(np.int32)

    ti, tn = spec.grid(I, N)
    rows, wpt = spec.rows, spec.weights_per_tile
    i_pad, n_pad = ti * rows, tn * wpt
    codes = np.pad(codes, ((0, i_pad - I), (0, n_pad - N)))
    sign = np.pad(sign, ((0, i_pad - I), (0, n_pad - N)),
                  constant_values=1)

    gain = None
    if cells is not None and (cells.stuck is not None
                              or cells.gamma is not None):
        from repro.nonideal.inject import (
            gather_physical_host,
            perturb_codes_host,
            variation_gain_host,
        )

        row_position = np.asarray(plan.row_position)
        stuck_log = None
        if cells.stuck is not None:
            stuck_log = gather_physical_host(cells.stuck, row_position,
                                             rev, spec)
            codes = perturb_codes_host(codes, stuck_log, spec.n_bits)
        if cells.gamma is not None:
            gamma_log = gather_physical_host(cells.gamma, row_position,
                                             rev, spec)
            drift = 1.0 if nonideal is None else nonideal.drift_factor
            gain = variation_gain_host(codes, stuck_log, gamma_log,
                                       spec.n_bits, drift)

    signed = (codes.astype(np.int32) * sign).astype(np.int16)

    qi = np.arange(i_pad) % rows
    tii = np.arange(i_pad) // rows
    pos = np.asarray(plan.row_position)[tii, :, qi].astype(np.int32)

    return CimDeployment(
        codes=signed, pos=pos, scale=np.float32(scale),
        n_bits=spec.n_bits, wpt=wpt, cols=spec.cols, eta=float(eta),
        reversed_df=rev, in_dim=I, out_dim=N, gain=gain)


def deploy_model_params(params: dict, cfg: ModelConfig,
                        cache: PlanCache | None = None,
                        ctx: ShardingCtx | None = None,
                        nonideal=None, nonideal_key=None,
                        fault_aware: bool = True) -> tuple[dict, dict]:
    """Deploy every projection matrix of a model onto crossbars.

    Returns (cim_tree, report): ``cim_tree[slot][param]`` is one
    :class:`CimDeployment` whose array leaves are stacked over the
    slot's pattern repeats — exactly the xs layout ``apply_model``'s
    layer scan consumes.  The report carries the fused-planning stats
    plus packaging wall-clock.

    ``nonideal`` (a :class:`repro.nonideal.models.NonidealModel`)
    deploys onto *imperfect* devices: one fused PRNG draw samples the
    physical cell state of the whole checkpoint (keyed by
    ``nonideal_key``, default key 0), known stuck cells steer the row
    sort when ``fault_aware`` is set (fault-aware MDM; the maps are
    fingerprinted into the plan-cache keys), and packaging folds the
    faults into the deployment codes / gain so generation runs under
    them end-to-end.
    """
    t0 = time.perf_counter()
    spec = spec_from_config(cfg)
    mode, eta = cfg.cim.mode, cfg.cim.eta

    mats = collect_projection_matrices(params, cfg)

    cells = fault_maps = None
    if nonideal is not None and not nonideal.is_ideal:
        from repro.nonideal.inject import sample_deployment_cells

        if nonideal_key is None:
            nonideal_key = jax.random.PRNGKey(0)
        elif isinstance(nonideal_key, int):
            nonideal_key = jax.random.PRNGKey(nonideal_key)
        grids = {name: spec.grid(*w.shape) for name, w in mats.items()}
        cells = sample_deployment_cells(nonideal_key, grids, spec,
                                        nonideal)
        if fault_aware:
            fault_maps = {name: c.stuck for name, c in cells.items()
                          if c.stuck is not None} or None

    plans, report = plan_matrices(mats, spec, mode, cache=cache, ctx=ctx,
                                  fault_maps=fault_maps)

    cim_tree: dict = {}
    for i, bt in enumerate(cfg.block_pattern):
        slot = f"slot{i}_{bt}"
        slot_deps: dict = {}
        for pname in DEPLOYABLE:
            if pname not in params.get(slot, {}):
                continue
            reps = params[slot][pname].shape[0]
            deps = [package_deployment_host(
                mats[f"{slot}/{pname}/{r}"], spec, mode, eta,
                plans[f"{slot}/{pname}/{r}"],
                cells=None if cells is None
                else cells[f"{slot}/{pname}/{r}"],
                nonideal=nonideal) for r in range(reps)]
            # One upload per stacked field (codes/pos/scale), not per
            # matrix: the stack is the device hand-off point.
            slot_deps[pname] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *deps)
        cim_tree[slot] = slot_deps

    report = dict(report)
    report["deploy_seconds"] = time.perf_counter() - t0
    report["n_slots"] = len(cim_tree)
    if cells is not None:
        report["nonideal"] = True
        report["fault_aware"] = bool(fault_maps)
        report["stuck_cells"] = int(sum(
            (c.stuck != 0).sum() for c in cells.values()
            if c.stuck is not None))
    return cim_tree, report


def deploy_matrices(mats: dict[str, jax.Array], spec: CrossbarSpec,
                    mode: str = "mdm", eta: float | None = None,
                    cache: PlanCache | None = None,
                    ctx: ShardingCtx | None = None
                    ) -> tuple[dict[str, CimDeployment], dict]:
    """Fused deployment of a plain named-matrix set (benchmarks/tools)."""
    from repro.core.noise import PAPER_ETA

    eta = PAPER_ETA if eta is None else eta
    plans, report = plan_matrices(mats, spec, mode, cache=cache, ctx=ctx)
    deps = {name: package_deployment_host(
        np.asarray(w, np.float32), spec, mode, eta, plans[name])
        for name, w in mats.items()}
    return deps, report
