"""Whole-model CIM deployment: model params -> routed CimDeployments.

Walks a model's parameter pytree, extracts every deployable projection
matrix (attention q/k/v/o, dense-MLP up/gate/down, and — under an
expert-axis partition pipeline — MoE expert banks), plans all of them
in one fused pass (:mod:`repro.deploy.planner`, through the persistent
:class:`repro.deploy.cache.PlanCache`), and packages per-slot stacks of
:class:`CimDeployment` shaped for the model's ``lax.scan`` over pattern
repeats.

Every parameter the walk does *not* deploy is recorded with a reason in
the collection summary (``report["matrices"]``) — nothing is silently
dropped.  Embeddings, the LM head, norms/biases and recurrent/SSM state
weights stay digital (standard CIM practice: crossbars host the dense
projection GEMMs); MoE expert banks deploy per-expert when the
pipeline's partition strategy is expert-axis-aware
(:class:`repro.mapping.ExpertPartition`) and are reported as skipped
otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry as tm
from repro.configs.base import ModelConfig
from repro.core.bitslice import magnitude_scale_host
from repro.core.mdm import MdmPlan
from repro.core.tiling import CrossbarSpec
from repro.deploy.cache import PlanCache
from repro.deploy.planner import plan_matrices, quantize_codes_host
from repro.distributed.sharding import ShardingCtx
from repro.kernels.cim_mvm.ops import CimDeployment
from repro.mapping import MappingPipeline, resolve_pipeline

# Projection parameters the serving path routes through cim_mvm, with
# the reshape that turns each per-layer tensor into a 2-D matmul weight.
_QKV_NAMES = ("wq", "wk", "wv", "attn_wq", "attn_wk", "attn_wv")
_OUT_NAMES = ("wo", "attn_wo")
_MLP_NAMES = ("ffn_w_gate", "ffn_w_up", "ffn_w_down")
DEPLOYABLE = _QKV_NAMES + _OUT_NAMES + _MLP_NAMES
# MoE expert banks: (R, E, D, F) stacks, deployable per expert when the
# pipeline partition is expert-axis-aware.
MOE_EXPERT_NAMES = ("ffn_we_gate", "ffn_we_up", "ffn_we_down")

_H_DEPLOY = tm.histogram(
    "repro_deploy_seconds",
    "End-to-end deploy_model_params wall time (collect+plan+package).")
_C_DEPLOY = tm.counter(
    "repro_deploy_matrices_total",
    "Model matrices per deployment outcome.", labels=("status",))


def _as_matrix(name: str, w) -> np.ndarray:
    """Per-layer projection tensor -> its (in_dim, out_dim) matmul view."""
    if name in _QKV_NAMES:        # (D, H, Dh) -> (D, H*Dh)
        return w.reshape(w.shape[0], -1)
    if name in _OUT_NAMES:        # (H, Dh, D) -> (H*Dh, D)
        return w.reshape(-1, w.shape[-1])
    return w                      # MLP projections are already 2-D


def spec_from_config(cfg: ModelConfig) -> CrossbarSpec:
    c = cfg.cim
    return CrossbarSpec(rows=c.rows, cols=c.cols, n_bits=c.n_bits,
                        r=c.r, r_on=c.r_on, r_off=c.r_off)


def _skip_reason(pname: str, expert_partition: bool) -> str:
    """Why a parameter stays digital (collection-summary bookkeeping)."""
    if pname in MOE_EXPERT_NAMES:
        return ("moe-expert-bank: select an expert-axis partition "
                "(e.g. pipeline 'mdm_expert') to deploy")
    if "norm" in pname or pname in ("bq", "bk", "bv"):
        return "norm/bias (digital)"
    if pname.startswith(("ffn_router", "ffn_shared", "ffn_ws")):
        return "moe routing / shared expert (digital)"
    if pname.startswith(("ssm_", "mlstm_", "slstm_", "conv_")) \
            or pname.startswith(("w_in", "w_x", "w_h", "a_log", "dt_")):
        return "recurrent/SSM state path (digital)"
    return "no crossbar mapping for this parameter"


def collect_model_matrices(params: dict, cfg: ModelConfig,
                           pipeline: MappingPipeline | str | None = None
                           ) -> tuple[dict[str, np.ndarray], dict]:
    """Extract every crossbar-deployable matrix, accounting for the rest.

    Returns ``(mats, summary)``: ``mats`` maps ``"slot/param/repeat"``
    (dense) or ``"slot/param/repeat/e{expert}"`` (expert-partitioned
    MoE banks) to 2-D f32 host matrices in deterministic traversal
    order; ``summary`` records the deployed names and — new with the
    pipeline API — every *skipped* parameter with a reason, so
    MoE/recurrent weights are never dropped silently
    (``{"deployed": [...], "skipped": {name: reason},
    "n_deployed": int, "n_skipped": int}``).

    Matrices land on the host (one device->host pull per stacked
    parameter): fingerprinting and the fused planner's bit-slicing are
    host-side anyway, so keeping a device-resident f32 copy would only
    add an upload plus two full download sweeps per deployment.
    bf16 -> f32 widening is exact, so the cast matches the device cast.
    """
    pipe = resolve_pipeline(pipeline if pipeline is not None
                            else cfg.cim.mode)
    expert = getattr(pipe.partition, "expert_axis", False)
    mats: dict[str, np.ndarray] = {}
    skipped: dict[str, str] = {}
    for top in params:
        if not top.startswith("slot"):
            skipped[top] = "embedding/head/final-norm (digital by design)"
    for i, bt in enumerate(cfg.block_pattern):
        slot = f"slot{i}_{bt}"
        slot_params = params.get(slot, {})
        # Deterministic traversal: DEPLOYABLE order first (the legacy
        # order — nonideal cell sampling slices the fused draw in mats
        # order, so this keeps fault maps stable per seed), then expert
        # banks, then the skip accounting.
        for pname in DEPLOYABLE:
            if pname not in slot_params:
                continue
            stacked = np.asarray(slot_params[pname])  # (R, ...) layers
            for r in range(stacked.shape[0]):
                mats[f"{slot}/{pname}/{r}"] = np.asarray(
                    _as_matrix(pname, stacked[r]), np.float32)
        for pname in MOE_EXPERT_NAMES:
            if pname not in slot_params or not expert:
                continue
            stacked = np.asarray(slot_params[pname])  # (R, E, D, F)
            for r in range(stacked.shape[0]):
                parts = pipe.partition.split(f"{slot}/{pname}/{r}",
                                             stacked[r])
                if parts is None:
                    skipped[f"{slot}/{pname}"] = (
                        f"partition {pipe.partition.name!r} cannot "
                        f"split shape {stacked[r].shape}")
                    break
                for sub, w2 in parts:
                    mats[sub] = np.asarray(w2, np.float32)
        for pname in slot_params:
            if pname in DEPLOYABLE or (pname in MOE_EXPERT_NAMES
                                       and expert):
                continue
            skipped[f"{slot}/{pname}"] = _skip_reason(pname, expert)
    summary = {"deployed": list(mats), "skipped": skipped,
               "n_deployed": len(mats), "n_skipped": len(skipped)}
    return mats, summary


def collect_projection_matrices(params: dict, cfg: ModelConfig
                                ) -> dict[str, np.ndarray]:
    """Back-compat wrapper: the deployable-matrix mapping only (dense
    partition semantics).  New code should use
    :func:`collect_model_matrices`, which also accounts for skipped
    parameters and honours the pipeline's partition strategy."""
    mats, _ = collect_model_matrices(params, cfg, "mdm")
    return mats


def package_deployment_host(w: np.ndarray, spec: CrossbarSpec, mode,
                            eta: float, plan: MdmPlan,
                            cells=None, nonideal=None,
                            noise_tag: int | None = None,
                            stats: dict | None = None,
                            capture: dict | None = None
                            ) -> CimDeployment:
    """Host mirror of ``repro.kernels.cim_mvm.ops.deploy`` packaging.

    Quantises and lays out one planned matrix entirely in numpy —
    bit-identical to the device path (pinned in tests/test_deploy.py)
    but free of the ~10 eager device dispatches per matrix that a
    whole-checkpoint packaging loop would otherwise pay (the planner
    already amortised planning; packaging must not reintroduce the
    per-matrix cost structure).  The array leaves stay on host; the
    per-slot ``jnp.stack`` in :func:`deploy_model_params` uploads each
    stacked field once.

    The physical layout (dataflow direction, column permutation) is
    read from ``plan`` itself; ``mode`` is retained for call
    compatibility only.

    ``cells`` (a :class:`repro.nonideal.inject.HostCells` sample, plus
    its :class:`repro.nonideal.models.NonidealModel` as ``nonideal``)
    injects device nonidealities at packaging time: stuck-at faults are
    folded bit-exactly into the int16 codes, programming variation /
    drift into the per-weight ``gain`` field — generation then runs
    under the injected faults through the unchanged ``cim_mvm``.

    When the fault map carries line opens, the pre-injection overlap of
    programmed bits with OPEN cells is recorded on the deployment as
    ``degraded`` (int32 count; > 0 = spares exhausted, the model layer
    demotes to the digital fallback) and in ``stats["open_bits"]`` when
    a ``stats`` dict is passed.  ``noise_tag`` (with
    ``nonideal.sigma_read > 0``) arms the per-read noise hook — a
    unique int per deployed matrix, folded into the serving read key.

    ``capture`` (a dict, filled in place) stashes the lifetime-state
    ingredients the health/remediation machinery needs to re-derive the
    gain at a later runtime age without re-planning: the post-stuck
    padded magnitude ``codes`` plus the gathered logical-layout
    ``stuck_log`` / ``gamma_log`` / ``relax_log`` fields.  A captured
    deployment also materialises ``gain`` (ones) and ``degraded`` (0)
    unconditionally, so hot-swapping a refreshed deployment later never
    changes the pytree structure the jitted serving graph traced.
    """
    del mode  # layout comes from the plan (kept for signature compat)
    I, N = w.shape
    rev = bool(plan.reversed_dataflow)
    col_position = (None if plan.col_position is None
                    else np.asarray(plan.col_position, np.int32))
    scale = magnitude_scale_host(w, spec.n_bits)
    codes = quantize_codes_host(w, scale, spec.n_bits)
    sign = np.where(np.asarray(w, np.float32) < 0, -1, 1).astype(np.int32)

    ti, tn = spec.grid(I, N)
    rows, wpt = spec.rows, spec.weights_per_tile
    i_pad, n_pad = ti * rows, tn * wpt
    codes = np.pad(codes, ((0, i_pad - I), (0, n_pad - N)))
    sign = np.pad(sign, ((0, i_pad - I), (0, n_pad - N)),
                  constant_values=1)

    gain = degraded = None
    stuck_log = gamma_log = relax_log = None
    if cells is not None and (cells.stuck is not None
                              or cells.gamma is not None
                              or cells.relax is not None):
        from repro.nonideal.inject import (
            aged_gain_host,
            gather_physical_host,
            open_bit_overlap_host,
            perturb_codes_host,
            variation_gain_host,
        )

        row_position = np.asarray(plan.row_position)
        if cells.stuck is not None:
            stuck_log = gather_physical_host(cells.stuck, row_position,
                                             rev, spec, col_position)
            open_bits = open_bit_overlap_host(codes, stuck_log,
                                              spec.n_bits)
            degraded = np.int32(open_bits)
            if stats is not None:
                stats["open_bits"] = open_bits
            codes = perturb_codes_host(codes, stuck_log, spec.n_bits)
        if cells.relax is not None:
            relax_log = gather_physical_host(cells.relax, row_position,
                                             rev, spec, col_position)
        if cells.gamma is not None:
            gamma_log = gather_physical_host(cells.gamma, row_position,
                                             rev, spec, col_position)
        if gamma_log is not None or relax_log is not None:
            if nonideal is None:
                gain = variation_gain_host(codes, stuck_log, gamma_log,
                                           spec.n_bits, 1.0)
            else:
                # Deploy-time gain = lifetime gain at the model's
                # static drift_time (bit-identical to the legacy
                # variation_gain_host path: relaxation is zero at
                # age <= 1 and drift_factor_at(drift_time) ==
                # drift_factor).
                gain = aged_gain_host(codes, stuck_log, gamma_log,
                                      relax_log, spec.n_bits, nonideal,
                                      nonideal.drift_time)

    if capture is not None:
        if gain is None:
            gain = np.ones_like(codes, np.float32)
        if degraded is None:
            degraded = np.int32(0)
        capture.update(codes=codes, stuck_log=stuck_log,
                       gamma_log=gamma_log, relax_log=relax_log)

    sigma_read = 0.0 if nonideal is None else float(nonideal.sigma_read)
    tag = (np.int32(noise_tag)
           if noise_tag is not None and sigma_read > 0.0 else None)

    signed = (codes.astype(np.int32) * sign).astype(np.int16)

    qi = np.arange(i_pad) % rows
    tii = np.arange(i_pad) // rows
    pos = np.asarray(plan.row_position)[tii, :, qi].astype(np.int32)

    return CimDeployment(
        codes=signed, pos=pos, scale=np.float32(scale),
        n_bits=spec.n_bits, wpt=wpt, cols=spec.cols, eta=float(eta),
        reversed_df=rev, in_dim=I, out_dim=N, gain=gain,
        col_pos=col_position, degraded=degraded, noise_tag=tag,
        sigma_read=sigma_read)


def deploy_model_params(params: dict, cfg: ModelConfig,
                        cache: PlanCache | None = None,
                        ctx: ShardingCtx | None = None,
                        nonideal=None, nonideal_key=None,
                        fault_aware: bool = True,
                        pipeline: MappingPipeline | str | None = None,
                        lifetime: dict | None = None,
                        verbose: bool = False) -> tuple[dict, dict]:
    """Deploy every projection matrix of a model onto crossbars.

    Returns (cim_tree, report): ``cim_tree[slot][param]`` is one
    :class:`CimDeployment` whose array leaves are stacked over the
    slot's pattern repeats (and, for expert-partitioned MoE banks, over
    the expert axis: leading dims ``(repeats, E)``) — exactly the xs
    layout ``apply_model``'s layer scan consumes.  The report carries
    the fused-planning stats, the collection summary (deployed vs.
    skipped matrices, with reasons) and packaging wall-clock.

    ``pipeline`` selects the mapping strategy
    (:class:`repro.mapping.MappingPipeline`, a named pipeline, or a
    spec string); it defaults to ``cfg.cim.mode``, where the legacy
    mode strings keep working through the deprecation shim.

    ``nonideal`` (a :class:`repro.nonideal.models.NonidealModel`)
    deploys onto *imperfect* devices: one fused PRNG draw samples the
    physical cell state of the whole checkpoint (keyed by
    ``nonideal_key``, default key 0), known stuck cells steer the row
    sort when ``fault_aware`` is set (fault-aware MDM; the maps are
    fingerprinted into the plan-cache keys), and packaging folds the
    faults into the deployment codes / gain so generation runs under
    them end-to-end.

    ``lifetime`` (a dict, filled in place) captures per-matrix
    :class:`repro.deploy.lifetime.MatrixLifetime` state — the host-side
    ingredients the health/remediation machinery
    (:mod:`repro.health`) needs to age, recalibrate, reprogram and
    hot-swap deployments at serving time.  Only meaningful together
    with a non-ideal model.
    """
    t0 = tm.monotonic()
    spec = spec_from_config(cfg)
    eta = cfg.cim.eta
    mode = pipeline if pipeline is not None else cfg.cim.mode

    with tm.span("deploy/collect"):
        mats, summary = collect_model_matrices(params, cfg, mode)

    cells = fault_maps = None
    if nonideal is not None and not nonideal.is_ideal:
        from repro.nonideal.inject import sample_deployment_cells

        if nonideal_key is None:
            nonideal_key = jax.random.PRNGKey(0)  # reprolint: disable=RPL003 -- documented "default key 0" fallback; deployments meant to differ pass nonideal_key
        elif isinstance(nonideal_key, int):
            nonideal_key = jax.random.PRNGKey(nonideal_key)
        grids = {name: spec.grid(*w.shape) for name, w in mats.items()}
        cells = sample_deployment_cells(nonideal_key, grids, spec,
                                        nonideal)
        if fault_aware:
            fault_maps = {name: c.stuck for name, c in cells.items()
                          if c.stuck is not None} or None

    if fault_maps is not None:
        # fault_aware=True must steer ANY sorting pipeline, not just the
        # legacy "sort"/"mdm" strings: upgrade plain-MDM rows to the
        # fault-aware pass (cache tokens are unchanged — FaultAwareRows
        # shares MdmRows' token, keyed by the fault-map fingerprint).
        # Identity-row pipelines stay identity (the legacy no-op for
        # unsorted modes) and fault-consuming rows pass through.
        from repro.mapping import FaultAwareRows, MdmRows

        pipe_eff = resolve_pipeline(mode, True)
        if isinstance(pipe_eff.rows, MdmRows):
            pipe_eff = pipe_eff.replace(rows=FaultAwareRows())
        mode = pipe_eff

    with tm.span("deploy/plan", matrices=len(mats)):
        plans, report = plan_matrices(mats, spec, mode, cache=cache,
                                      ctx=ctx, fault_maps=fault_maps)

    # Per-matrix PRNG tags for the per-read noise hook: unique over the
    # deterministic collection order, so one serving read key yields
    # independent noise per deployed matrix (and per repeat/expert).
    noise_tags = {name: t for t, name in enumerate(mats)}
    degraded: dict[str, int] = {}
    want_lifetime = lifetime is not None and cells is not None

    def _package(name):
        stats: dict = {}
        cap: dict | None = {} if want_lifetime else None
        dep = package_deployment_host(
            mats[name], spec, mode, eta, plans[name],
            cells=None if cells is None else cells[name],
            nonideal=nonideal, noise_tag=noise_tags[name], stats=stats,
            capture=cap)
        if stats.get("open_bits"):
            degraded[name] = stats["open_bits"]
        if cap is not None:
            from repro.deploy.lifetime import MatrixLifetime

            plan = plans[name]
            # Per-matrix reprogram key: a distinct fold_in branch (7 is
            # outside the sampler's term-tag range) off the deployment
            # key, then the matrix's unique tag — the n-th reprogram of
            # matrix m is a deterministic function of (seed, m, n).
            lifetime[name] = MatrixLifetime(
                name=name, noise_tag=noise_tags[name], spec=spec,
                model=nonideal, eta=eta, w=mats[name],
                row_position=np.asarray(plan.row_position),
                reversed_df=bool(plan.reversed_dataflow),
                col_position=(None if plan.col_position is None else
                              np.asarray(plan.col_position, np.int32)),
                stuck_phys=cells[name].stuck,
                codes=cap["codes"], stuck_log=cap["stuck_log"],
                gamma_log=cap["gamma_log"], relax_log=cap["relax_log"],
                dep=dep,
                key=jax.random.fold_in(
                    jax.random.fold_in(nonideal_key, 7),
                    noise_tags[name]),
                age=float(nonideal.drift_time))
        return dep

    cim_tree: dict = {}
    with tm.span("deploy/package", matrices=len(mats)):
        for i, bt in enumerate(cfg.block_pattern):
            slot = f"slot{i}_{bt}"
            slot_deps: dict = {}
            for pname in DEPLOYABLE:
                if pname not in params.get(slot, {}):
                    continue
                reps = params[slot][pname].shape[0]
                deps = [_package(f"{slot}/{pname}/{r}")
                        for r in range(reps)]
                # One upload per stacked field (codes/pos/scale), not
                # per matrix: the stack is the device hand-off point.
                slot_deps[pname] = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *deps)
            for pname in MOE_EXPERT_NAMES:
                if pname not in params.get(slot, {}):
                    continue
                reps = params[slot][pname].shape[0]
                # Sub-matrix names come from the partition pass's
                # split() output (collection order), not from a
                # hardcoded naming scheme — a custom partition strategy
                # packages the same way it collects.  Inner per-repeat
                # stack stays on host (numpy); the outer stack over
                # repeats is the single device upload per field.
                rows_ = []
                for r in range(reps):
                    prefix = f"{slot}/{pname}/{r}/"
                    subs = [n for n in mats if n.startswith(prefix)]
                    if not subs:
                        break
                    rows_.append(jax.tree_util.tree_map(
                        lambda *xs: np.stack(xs),
                        *[_package(n) for n in subs]))
                if len(rows_) == reps:
                    slot_deps[pname] = jax.tree_util.tree_map(
                        lambda *xs: jnp.stack(xs), *rows_)
            cim_tree[slot] = slot_deps

    report = dict(report)
    report["matrices"] = summary
    report["deploy_seconds"] = tm.monotonic() - t0
    report["n_slots"] = len(cim_tree)
    _H_DEPLOY.observe(report["deploy_seconds"])
    _C_DEPLOY.labels(status="deployed").inc(summary["n_deployed"])
    _C_DEPLOY.labels(status="skipped").inc(summary["n_skipped"])
    _C_DEPLOY.labels(status="degraded").inc(len(degraded))
    if cells is not None:
        report["nonideal"] = True
        # True only when planning actually consumed the fault maps
        # (identity-row pipelines sample cells for injection but never
        # steer — the legacy no-op for unsorted modes).
        report["fault_aware"] = bool(fault_maps) and resolve_pipeline(
            mode, fault_maps is not None).rows.uses_faults
        report["stuck_cells"] = int(sum(
            (c.stuck != 0).sum() for c in cells.values()
            if c.stuck is not None))
        # Graceful degradation accounting: matrices whose crossbars lose
        # programmed bits to open lines even after the remap (spares
        # exhausted) serve through the digital fallback; nothing is
        # demoted silently.
        report["degraded"] = {
            name: (f"degraded: {n} programmed bit(s) on open lines "
                   "after remap (spares exhausted); serving via "
                   "digital fallback")
            for name, n in sorted(degraded.items())}
        report["n_degraded"] = len(degraded)
    if verbose:
        print(f"deployed {summary['n_deployed']} matrices, skipped "
              f"{summary['n_skipped']} parameters:")
        for name, reason in summary["skipped"].items():
            print(f"  skip {name:40s} {reason}")
        for name, reason in report.get("degraded", {}).items():
            print(f"  demote {name:38s} {reason}")
    return cim_tree, report


def deploy_matrices(mats: dict[str, jax.Array], spec: CrossbarSpec,
                    mode="mdm", eta: float | None = None,
                    cache: PlanCache | None = None,
                    ctx: ShardingCtx | None = None
                    ) -> tuple[dict[str, CimDeployment], dict]:
    """Fused deployment of a plain named-matrix set (benchmarks/tools)."""
    from repro.core.noise import PAPER_ETA

    eta = PAPER_ETA if eta is None else eta
    plans, report = plan_matrices(mats, spec, mode, cache=cache, ctx=ctx)
    deps = {name: package_deployment_host(
        np.asarray(w, np.float32), spec, mode, eta, plans[name])
        for name, w in mats.items()}
    return deps, report
