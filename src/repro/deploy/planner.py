"""Fused whole-model MDM planning.

The per-layer entry point (``repro.core.mdm.plan_layer``) pays one jit
dispatch — and, for every distinct layer shape, one compile — per
matrix.  Real networks deploy hundreds of matrices spanning tens of
thousands of tiles, so this module amortises the whole model into a
constant number of device programs (the same trick the batched circuit
solver uses for its tile populations):

1. matrices are bit-sliced/tiled on the **host** (numpy) — with the
   scale fixed, quantisation and the tile reshuffle are pure
   elementwise/layout ops, bit-identical between numpy and XLA, so the
   whole extraction costs zero compiles and zero device dispatches (a
   vmapped jit here would pay one compile per distinct layer shape —
   exactly the per-layer path's cost structure — and even eager jnp
   ops pay per-shape mini-compiles);
2. every layer's tiles are flattened into a single (T, rows, cols)
   population and planned in **one** fused jit
   (:func:`repro.core.mdm.plan_tile_population`: score + lexsort + NF
   bookkeeping vmapped over all tiles of all layers at once),
   optionally sharded over the logical ``"tiles"`` mesh dim
   (``repro.distributed``);
3. per-matrix :class:`MdmPlan`\\ s are sliced back out of the
   population.

Because the fused path runs the identical per-tile computation as the
per-layer path (both call ``plan_tile_population``), the plans are
bit-identical — ``tests/test_deploy.py`` pins this.  A
:class:`repro.deploy.cache.PlanCache` short-circuits matrices whose
(weights, spec, mode) key was planned before.
"""
from __future__ import annotations

from functools import partial
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import telemetry as tm
from repro.core.bitslice import magnitude_scale_host
from repro.core.mdm import MdmPlan, plan_tile_population
from repro.core.tiling import CrossbarSpec
from repro.deploy.cache import (
    PlanCache,
    manifest_key,
    plan_key,
    weight_fingerprint,
)
from repro.distributed.sharding import ShardingCtx, logical_spec
from repro.mapping import resolve_pipeline

_H_PLAN = tm.histogram(
    "repro_plan_seconds",
    "Wall time of one fused plan_matrices pass (lookup + planning).")
_C_PLAN_TILES = tm.counter(
    "repro_plan_tiles_total",
    "Crossbar tiles planned by the fused jit (cache misses only).")


def quantize_codes_host(w: np.ndarray, scale: np.float32,
                        n_bits: int) -> np.ndarray:
    """Host mirror of ``quantize_magnitude``'s code rounding (uint32).

    ``scale`` must come from
    :func:`repro.core.bitslice.magnitude_scale_host` (bit-identical to
    the eager-jnp chain); with it fixed, the rounding below is pure
    elementwise IEEE arithmetic on which numpy and XLA agree
    bit-for-bit.
    """
    levels = (1 << n_bits) - 1
    mag = np.abs(np.asarray(w, np.float32))
    return np.clip(np.round(mag / scale * np.float32(1 << n_bits)),
                   np.float32(0), np.float32(levels)).astype(np.uint32)


def _matrix_tile_masks_host(w: np.ndarray, scale: np.float32,
                            spec: CrossbarSpec) -> np.ndarray:
    """Host bit-slice + tile of one matrix -> flat masks (Ti*Tn, R, C).

    Elementwise/layout mirror of ``quantize_magnitude`` ->
    ``codes_to_bits`` -> ``tile_masks``: the resulting plans are
    bit-identical to ``plan_layer``'s while costing zero compiles and
    zero device dispatches.
    """
    K = spec.n_bits
    codes = quantize_codes_host(w, scale, K)
    shifts = np.arange(K - 1, -1, -1, dtype=np.uint32)
    bits = ((codes[..., None] >> shifts) & np.uint32(1)).astype(np.uint8)

    I, N = w.shape
    ti, tn = spec.grid(I, N)
    rows, wpt = spec.rows, spec.weights_per_tile
    pad_i, pad_n = ti * rows - I, tn * wpt - N
    if pad_i or pad_n:
        bits = np.pad(bits, ((0, pad_i), (0, pad_n), (0, 0)))
    m = bits.reshape(ti, rows, tn, wpt, K).transpose(0, 2, 1, 3, 4)
    return m.reshape(ti * tn, rows, spec.cols)


def _population_sharding(ctx: ShardingCtx | None, n_tiles: int):
    """(NamedSharding, shard_count) for the tile population, or (None, 1).

    Resolves the logical ``"tiles"`` dim through the ctx's rules — the
    same resolution the sharded circuit solver uses — so the population
    lands on a dedicated tile mesh or the data axis of a training mesh.
    """
    if ctx is None or ctx.mesh is None:
        return None, 1
    axis_sizes = dict(ctx.mesh.shape)
    total = 1
    for s in axis_sizes.values():
        total *= s
    spec = logical_spec((total,), ("tiles",), ctx.mesh, ctx.rules)
    if not spec:
        return None, 1
    axes = spec[0]
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    n_shards = 1
    for a in axes:
        n_shards *= axis_sizes[a]
    sharding = NamedSharding(
        ctx.mesh, P(axes[0] if len(axes) == 1 else axes, None, None))
    return sharding, n_shards


def _flat_fault_map(name: str, fm, spec: CrossbarSpec,
                    ti: int, tn: int) -> np.ndarray:
    """Normalise one matrix's physical fault map to (Ti*Tn, R, C) int8."""
    fm = np.asarray(fm, np.int8)
    want = (ti * tn, spec.rows, spec.cols)
    if fm.shape == (ti, tn, spec.rows, spec.cols):
        fm = fm.reshape(want)
    if fm.shape != want:
        raise ValueError(
            f"{name}: fault map shape {fm.shape} != tile grid "
            f"{(ti, tn, spec.rows, spec.cols)}")
    return fm


def plan_matrices(mats: Mapping[str, jax.Array], spec: CrossbarSpec,
                  mode="mdm", cache: PlanCache | None = None,
                  ctx: ShardingCtx | None = None,
                  fault_maps: Mapping[str, np.ndarray] | None = None
                  ) -> tuple[dict[str, MdmPlan], dict]:
    """Plan every matrix of a model in one fused pass.

    mats: name -> (I, N) weight matrix (shapes may differ per matrix).
    ``mode`` is a :class:`repro.mapping.MappingPipeline` or a
    named/legacy string (``repro.mapping.resolve_pipeline``); the
    pipeline's cache token keys the plans, so legacy mode strings hit
    pre-redesign cache entries unchanged.  ``fault_maps`` (optional,
    name -> (Ti, Tn, rows, cols) int8 physical cell states —
    :mod:`repro.nonideal.models`) feeds fault-aware row strategies (the
    legacy "sort"/"mdm" strings auto-upgrade, matching the old
    side-channel semantics); the maps are fingerprinted into the cache
    keys so a changed fault map replans exactly like changed weights.
    Pipelines whose row *and* column passes both ignore faults drop
    the maps from both planning and keys.
    Returns ({name: MdmPlan}, report); the report records tile counts,
    cache hit/miss split (including whether the whole set resolved from
    one manifest read) and wall-clock of the fused planning pass.
    """
    t0 = tm.monotonic()
    pipe = resolve_pipeline(mode, fault_maps is not None)
    if not (pipe.rows.uses_faults or pipe.cols.uses_faults):
        fault_maps = None
    token = pipe.cache_token()
    plans: dict[str, MdmPlan] = {}
    keys: dict[str, str] = {}
    misses: list[str] = []
    manifest_hit = False
    for name, w in mats.items():
        if w.ndim != 2:
            raise ValueError(f"{name}: expected 2-D matrix, got {w.shape}")

    def key_of(name):
        ffp = (None if fault_maps is None or name not in fault_maps
               else weight_fingerprint(np.asarray(fault_maps[name],
                                                  np.int8)))
        return plan_key(weight_fingerprint(mats[name]), spec, token, ffp)

    with tm.span("deploy/plan_lookup", matrices=len(mats)):
        if cache is None:
            misses = list(mats)
        else:
            # Fingerprint + probe in a thread pool: blake2b and file
            # reads release the GIL, and the lookup pass is the whole
            # cost of a full cache hit.
            import os
            from concurrent.futures import ThreadPoolExecutor

            workers = max(1, min(os.cpu_count() or 1, len(mats)))
            with ThreadPoolExecutor(max_workers=workers) as ex:
                keys = dict(zip(mats, ex.map(key_of, mats)))
                # One manifest read resolves the whole checkpoint when
                # it was deployed before; otherwise fall back to
                # per-entry probes (covers partial hits after a few
                # matrices changed).
                hit_all = cache.get_manifest(keys)
                if hit_all is not None:
                    plans = hit_all
                    manifest_hit = True
                else:
                    for name, hit in zip(keys, ex.map(cache.get,
                                                      keys.values())):
                        if hit is not None:
                            plans[name] = hit
                        else:
                            misses.append(name)
    t_lookup = tm.monotonic() - t0

    total_tiles = 0
    if misses:
        # Host per-matrix bit-slice/tile (compile- and dispatch-free)...
        grids: dict[str, tuple[int, int]] = {}
        scales: dict[str, np.ndarray] = {}
        flat_chunks = []
        fault_chunks = [] if fault_maps is not None else None
        for name in misses:
            w = np.asarray(mats[name], np.float32)
            ti, tn = spec.grid(*w.shape)
            scale = magnitude_scale_host(w, spec.n_bits)
            flat_chunks.append(_matrix_tile_masks_host(w, scale, spec))
            if fault_chunks is not None:
                fm = fault_maps.get(name)
                fault_chunks.append(
                    np.zeros((ti * tn, spec.rows, spec.cols), np.int8)
                    if fm is None
                    else _flat_fault_map(name, fm, spec, ti, tn))
            grids[name] = (ti, tn)
            scales[name] = np.asarray(scale)
        order = misses

        # ...then one fused planning jit over the whole population.
        with tm.span("deploy/plan_fused", matrices=len(misses)):
            flat = np.concatenate(flat_chunks, axis=0)
            faults = (None if fault_chunks is None
                      else np.concatenate(fault_chunks, axis=0))
            total_tiles = flat.shape[0]
            sharding, n_shards = _population_sharding(ctx, total_tiles)
            pad = (-total_tiles) % n_shards
            if pad:  # zero-drive tiles plan to identity; dropped below
                flat = np.concatenate(
                    [flat,
                     np.zeros((pad,) + flat.shape[1:], flat.dtype)])
                if faults is not None:
                    faults = np.concatenate(
                        [faults, np.zeros((pad,) + faults.shape[1:],
                                          faults.dtype)])
            put = (jnp.asarray if sharding is None
                   else partial(jax.device_put, device=sharding))
            flat = put(flat)
            if faults is not None:
                faults = put(faults)
            pop = plan_tile_population(flat, spec, pipe, faults)
            # One transfer per field; slicing back per matrix is then
            # pure host views (an on-device slice would cost one
            # dispatch per matrix per field — most of the warm fused
            # wall-clock).
            perm, position, col_perm, col_position, nf_before, nf_after = (
                None if a is None else np.asarray(a) for a in pop)

        rev = np.bool_(pipe.reversed_dataflow)
        off = 0
        for name in order:
            ti, tn = grids[name]
            nt = ti * tn
            sl = slice(off, off + nt)
            plan = MdmPlan(
                row_perm=perm[sl].reshape(ti, tn, spec.rows),
                row_position=position[sl].reshape(ti, tn, spec.rows),
                reversed_dataflow=rev,
                nf_before=nf_before[sl].reshape(ti, tn),
                nf_after=nf_after[sl].reshape(ti, tn),
                scale=scales[name],
                col_perm=None if col_perm is None
                else col_perm[sl].reshape(ti, tn, spec.cols),
                col_position=None if col_position is None
                else col_position[sl].reshape(ti, tn, spec.cols))
            off += nt
            plans[name] = plan
            if cache is not None:
                cache.put(keys[name], plan)

    if cache is not None and not manifest_hit and plans:
        # Record the one-read manifest for this checkpoint's plan set
        # (also after partial hits: the set's manifest key is new).
        cache.put_manifest(keys, plans)

    report = {
        "n_matrices": len(mats),
        "cache_hits": len(mats) - len(misses),
        "cache_misses": len(misses),
        "manifest_hit": manifest_hit,
        "tiles_planned": int(total_tiles),
        "lookup_seconds": t_lookup,
        "total_seconds": tm.monotonic() - t0,
    }
    _H_PLAN.observe(report["total_seconds"])
    _C_PLAN_TILES.inc(total_tiles)
    return plans, report


def plan_model_tiles(mats: Mapping[str, jax.Array],
                     spec: CrossbarSpec) -> int:
    """Total crossbar tile count of a matrix set (planning workload size)."""
    total = 0
    for w in mats.values():
        ti, tn = spec.grid(*w.shape)
        total += ti * tn
    return total


def fingerprint_matrices(mats: Mapping[str, jax.Array],
                         spec: CrossbarSpec, mode) -> dict[str, str]:
    """Content-address every matrix (exposed for cache tooling/tests)."""
    token = resolve_pipeline(mode).cache_token()
    return {name: plan_key(weight_fingerprint(w), spec, token)
            for name, w in mats.items()}
