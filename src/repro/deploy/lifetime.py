"""Per-matrix lifetime state for serving-time aging and self-healing.

A deployment packaged by :func:`repro.deploy.engine.deploy_model_params`
is a snapshot of the device at programming time.  Real conductances keep
moving while the chip serves — power-law drift and stochastic
relaxation (:class:`repro.nonideal.models.NonidealModel`
``drift_factor_at`` / ``relax_sigma_at``) — so a long-lived engine needs
the *trajectory*, not the snapshot.  :class:`MatrixLifetime` keeps the
host-resident ingredients that trajectory is a deterministic function
of: the post-stuck codes, the gathered logical-layout variation /
relaxation draws, and a per-matrix age clock, so the deployment's gain
can be re-derived at any age (``repro.nonideal.inject.aged_gain_host``)
without re-planning or re-sampling.

The remediation ladder the health controller (:mod:`repro.health`)
climbs is implemented here as three state transitions:

* :meth:`MatrixLifetime.recalibrate` — fold a per-output-column gain
  correction (estimated from probe residuals) into the deployment;
* :meth:`MatrixLifetime.reprogram` — re-inject with a fresh
  program-verify-style variation/relaxation draw (stuck cells are
  hardware and stay pinned), reset the drift clock and drop the
  recalibration;
* :meth:`MatrixLifetime.demote` — mark the deployment ``degraded`` with
  the runtime sentinel (-1) so the model layer serves the digital
  fallback (PR-7's graceful-degradation machinery).

Everything here is host numpy; the single device hand-off point is
:func:`restack_group`, which rebuilds one ``(slot, pname)`` stacked
deployment from its refreshed host deployments — callers swap the
result into the serving tree atomically (fresh dict objects, never
in-place mutation), so generation in flight keeps the snapshot it
started with.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import CrossbarSpec
from repro.kernels.cim_mvm.ops import CimDeployment
from repro.nonideal.inject import (
    HostCells,
    aged_gain_host,
    gather_physical_host,
)
from repro.nonideal.models import NonidealModel, sample_cell_state

# Runtime-demotion sentinel for CimDeployment.degraded: negative so it
# never collides with the positive open-bit counts deploy-time demotion
# records (the model layer demotes on ``degraded != 0`` either way).
DEMOTED_RUNTIME = -1


@dataclasses.dataclass
class MatrixLifetime:
    """Host-side lifetime state of one deployed matrix.

    ``dep`` always holds the *currently served* host deployment (numpy
    leaves): :meth:`refresh` re-derives it from the captured draws at
    the current ``age``, the ladder transitions update it in place.
    ``age`` is time since (re)programming in units of the programming
    time t0 (1.0 = fresh).
    """

    name: str
    noise_tag: int
    spec: CrossbarSpec
    model: NonidealModel
    eta: float
    w: np.ndarray                      # (I, N) f32 source matrix
    row_position: np.ndarray
    reversed_df: bool
    col_position: np.ndarray | None
    stuck_phys: np.ndarray | None      # (Ti, Tn, rows, cols) int8
    codes: np.ndarray                  # (I_pad, N_pad) post-stuck codes
    stuck_log: np.ndarray | None
    gamma_log: np.ndarray | None
    relax_log: np.ndarray | None
    dep: CimDeployment
    key: jax.Array                     # per-matrix reprogram key base
    age: float = 1.0
    reprograms: int = 0
    rung: int = 0                      # 0 = fresh, 1 = recalibrated
    recal: np.ndarray | None = None    # (N_pad,) per-column correction
    demoted: bool = False

    # -- aging ---------------------------------------------------------

    def advance(self, dt: float) -> None:
        """Advance this matrix's age clock by ``dt`` (t0 units)."""
        self.age += float(dt)

    def refresh(self) -> CimDeployment:
        """Re-derive the served deployment at the current age.

        The gain is recomputed from the *fixed* captured draws with the
        time-dependent terms on the age clock, then any standing
        recalibration correction is re-applied on top — so a
        recalibrated matrix keeps its correction as it continues to
        age.  Demoted matrices are left untouched (the digital fallback
        does not age).
        """
        if self.demoted:
            return self.dep
        gain = aged_gain_host(self.codes, self.stuck_log, self.gamma_log,
                              self.relax_log, self.spec.n_bits,
                              self.model, self.age)
        if self.recal is not None:
            gain = gain * self.recal[None, :]
        self.dep = dataclasses.replace(self.dep, gain=gain)
        return self.dep

    # -- remediation ladder --------------------------------------------

    def recalibrate(self, recal: np.ndarray) -> CimDeployment:
        """Fold a per-output-column gain correction into the deployment.

        ``recal`` is the (out_dim,) least-squares rescaling estimated
        from probe residuals (``repro.health``); padding columns get
        1.  The correction persists across subsequent :meth:`refresh`
        calls until the next reprogram resets it.
        """
        n_pad = self.codes.shape[1]
        full = np.ones(n_pad, np.float32)
        full[:recal.shape[0]] = np.asarray(recal, np.float32)
        self.recal = full
        self.rung = 1
        return self.refresh()

    def reprogram(self) -> CimDeployment:
        """Re-inject with a fresh program-verify-style draw.

        Draws fresh variation/relaxation fields for this matrix only —
        keyed by ``fold_in(key, reprograms)``, so the n-th reprogram of
        a matrix is deterministic per deployment seed — while the stuck
        map stays pinned (defects are hardware; a reprogram does not
        heal them).  Resets the drift clock and the recalibration: the
        device is fresh again.
        """
        self.reprograms += 1
        ti, tn = self.stuck_phys.shape[:2] if self.stuck_phys is not None \
            else self.row_position.shape[:2]
        shape = (ti, tn, self.spec.rows, self.spec.cols)
        sample = sample_cell_state(
            jax.random.fold_in(self.key, self.reprograms), shape,
            self.model, stuck=self.stuck_phys)
        cells = HostCells(
            stuck=self.stuck_phys,
            gamma=np.asarray(sample.gamma),
            relax=(None if sample.relax is None
                   else np.asarray(sample.relax)))
        if cells.gamma is not None:
            self.gamma_log = gather_physical_host(
                cells.gamma, self.row_position, self.reversed_df,
                self.spec, self.col_position)
        if cells.relax is not None:
            self.relax_log = gather_physical_host(
                cells.relax, self.row_position, self.reversed_df,
                self.spec, self.col_position)
        self.age = 1.0
        self.recal = None
        self.rung = 0
        return self.refresh()

    def demote(self) -> CimDeployment:
        """Demote to the digital fallback (runtime ``degraded`` sentinel).

        The model layer (``repro.models.model._cim_matmul``) serves
        ``x @ w`` for any ``degraded != 0``; the negative sentinel
        distinguishes a health-controller demotion from deploy-time
        open-line counts in reports.
        """
        self.demoted = True
        self.dep = dataclasses.replace(
            self.dep, degraded=np.int32(DEMOTED_RUNTIME))
        return self.dep


def pad_host_deployment(dep: CimDeployment, i_pad: int, n_pad: int,
                        in_dim: int, out_dim: int, *,
                        rows: int) -> CimDeployment:
    """Zero-drive pad a host deployment to a larger tile grid.

    Grows ``codes`` to ``(i_pad, n_pad)`` with **zero codes** and the
    position tables with identity layouts, and rewrites the
    ``in_dim``/``out_dim`` meta to the targets — so deployments of
    *ragged* shapes inside one stacking group become tree-compatible
    and can ride a single vmapped ``cim_mvm`` (the health probe round's
    batched path).  Zero codes program no bits: in the parasitic
    distortion model every cell's effective weight is a function of its
    own code and position only, so padded tiles contribute exactly
    nothing to the original outputs — callers drive the padded input
    lanes with zeros and slice the readback at the true ``out_dim``
    (numerically equivalent to the unpadded read, up to f32 reduction
    order).  ``rows`` is the crossbar row count (``spec.rows``), needed
    to extend the physical row-position table; padding is in whole-tile
    units.
    """
    i0, n0 = dep.codes.shape
    tn0 = dep.pos.shape[1]
    if (i_pad - i0) % rows or (n_pad - n0) % dep.wpt:
        raise ValueError("padding must be whole tiles")
    tn = n_pad // dep.wpt
    codes = np.zeros((i_pad, n_pad), np.int16)
    codes[:i0, :n0] = np.asarray(dep.codes)
    pos = np.broadcast_to(
        (np.arange(i_pad, dtype=np.int32) % rows)[:, None],
        (i_pad, tn)).copy()
    pos[:i0, :tn0] = np.asarray(dep.pos)
    gain = dep.gain
    if gain is not None:
        g = np.ones((i_pad, n_pad), np.float32)
        g[:i0, :n0] = np.asarray(gain)
        gain = g
    col_pos = dep.col_pos
    if col_pos is not None:
        ti0, tn_c0 = np.asarray(col_pos).shape[:2]
        cp = np.broadcast_to(
            np.arange(dep.cols, dtype=np.int32),
            (i_pad // rows, tn, dep.cols)).copy()
        cp[:ti0, :tn_c0] = np.asarray(col_pos)
        col_pos = cp
    return dataclasses.replace(dep, codes=codes, pos=pos, gain=gain,
                               col_pos=col_pos, in_dim=in_dim,
                               out_dim=out_dim)


def group_key(name: str) -> tuple[str, str]:
    """(slot, pname) stacking group of a deployed-matrix name."""
    parts = name.split("/")
    return parts[0], parts[1]


def restack_group(lifetimes: dict[str, MatrixLifetime], slot: str,
                  pname: str) -> CimDeployment:
    """Rebuild one (slot, pname) stacked device deployment.

    Mirrors :func:`repro.deploy.engine.deploy_model_params`'s stacking
    exactly: dense parameters stack their repeats into the leading
    axis; expert-partitioned names (``slot/pname/r/e..``) stack experts
    per repeat on host first.  Returns a fully-built device deployment
    — the caller swaps it into a *fresh* serving dict in one
    assignment, which is what makes the hot-swap atomic: a generation
    loop that captured the previous dict never observes a half-updated
    bank.
    """
    mine = {n: lt for n, lt in lifetimes.items()
            if group_key(n) == (slot, pname)}
    by_rep: dict[int, list[MatrixLifetime]] = {}
    nested = False
    for n, lt in mine.items():
        parts = n.split("/")
        by_rep.setdefault(int(parts[2]), []).append(lt)
        nested = nested or len(parts) > 3
    reps = []
    for r in sorted(by_rep):
        deps = [lt.dep for lt in by_rep[r]]
        if nested:
            reps.append(jax.tree_util.tree_map(
                lambda *xs: np.stack(xs), *deps))
        else:
            assert len(deps) == 1
            reps.append(deps[0])
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *reps)
