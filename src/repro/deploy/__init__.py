"""Whole-model CIM deployment engine.

Fuses MDM planning across every layer of a model into a constant number
of device programs (``planner``), persists per-layer plans in a
content-addressed cache (``cache``), and packages model parameters into
the stacked :class:`CimDeployment` trees the serving path consumes
(``engine``).  ``ServeEngine`` calls :func:`deploy_model_params` at
init when ``cfg.cim.enabled`` is set; ``benchmarks/deploy_throughput``
records the fused-vs-per-layer planning and cache-hit redeploy wins.
"""
from repro.deploy.cache import (  # noqa: F401
    PLAN_CACHE_VERSION,
    CacheStats,
    PlanCache,
    default_cache_dir,
    manifest_key,
    plan_key,
    weight_fingerprint,
)
from repro.deploy.lifetime import (  # noqa: F401
    DEMOTED_RUNTIME,
    MatrixLifetime,
    pad_host_deployment,
    restack_group,
)
from repro.deploy.engine import (  # noqa: F401
    DEPLOYABLE,
    MOE_EXPERT_NAMES,
    collect_model_matrices,
    collect_projection_matrices,
    deploy_matrices,
    deploy_model_params,
    package_deployment_host,
    spec_from_config,
)
from repro.deploy.planner import (  # noqa: F401
    fingerprint_matrices,
    plan_matrices,
    plan_model_tiles,
)
