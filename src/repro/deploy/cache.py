"""Persistent, content-addressed MDM plan cache.

Planning a whole checkpoint is a one-off deployment cost, but it is paid
again on every engine restart unless the plans persist.  Each layer's
plan is content-addressed by (weight bytes, crossbar spec, mode, format
version): redeploying an unchanged checkpoint is a pure cache read
(~free), while any change to the weights, the device spec or the
deployment mode changes the key and forces a replan — there is no
staleness to manage.

Plans are stored one file per key under a two-level fan-out directory
in a fixed binary layout (17-byte header: flags, version, padding,
ti/tn/rows as u32-LE; then row_perm+row_position in the smallest uint
dtype that holds ``rows``, the two NF grids as f32, and the f32 scale).
A hit is one ``read()`` plus ``np.frombuffer`` views — zip-based
``.npz`` costs ~10ms of zipfile bookkeeping per entry and even raw
``.npy`` records pay a Python header parse each, which together
dominate a whole-model cache hit.  Loaded plans keep numpy leaves —
consumers touch them through jnp ops, which transfer on first use — so
a full-model cache hit does no device work at all.  The
default root sits next to the persistent JAX compilation cache when one
is configured (``.jax_cache/`` -> ``.mdm_plan_cache/``), mirroring how
compile artefacts already persist across runs; otherwise it falls back
to ``~/.cache/repro/mdm_plans``.  Writes are atomic (tmp +
``os.replace``), so a crash mid-write never corrupts an entry.

On top of the per-entry store, a **per-checkpoint manifest** packs a
whole model's plan set into one file keyed by the full ``{name: key}``
mapping (:func:`manifest_key`): an unchanged-checkpoint redeploy then
resolves every plan with a single read instead of one open per matrix.
Manifests are a read-path accelerator only — entries remain the source
of truth and any manifest mismatch falls back to per-entry probes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading

import jax
import numpy as np

from repro import telemetry as tm
from repro.core.mdm import MdmPlan
from repro.core.tiling import CrossbarSpec

# Host-boundary cache telemetry (docs/observability.md): mirrors the
# per-instance CacheStats onto the process-global registry so a serving
# deployment's cache traffic is scrapeable without plumbing the stats
# object out.  All record calls are no-ops while telemetry is disabled.
_M_PROBES = tm.counter(
    "repro_plan_cache_probes_total",
    "Plan-cache entry probes by result (hit/miss).", labels=("result",))
_M_MANIFEST_PROBES = tm.counter(
    "repro_plan_cache_manifest_probes_total",
    "Whole-checkpoint manifest probes by result (hit/miss).",
    labels=("result",))
_M_PUTS = tm.counter(
    "repro_plan_cache_puts_total", "Plan entries written.")
_M_READ_BYTES = tm.counter(
    "repro_plan_cache_read_bytes_total",
    "Bytes read by plan-cache hits (entries and manifests).")

# Bump when the MdmPlan layout or planning semantics change: old
# entries become unreachable (different keys) instead of wrongly hit.
PLAN_CACHE_VERSION = 1


def default_cache_dir() -> str:
    """Plan-cache root: next to the JAX compilation cache if configured."""
    jax_dir = jax.config.jax_compilation_cache_dir
    if jax_dir:
        parent = os.path.dirname(os.path.abspath(jax_dir))
        return os.path.join(parent, ".mdm_plan_cache")
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "mdm_plans")


def weight_fingerprint(w) -> str:
    """blake2b over the raw weight bytes + shape + dtype.

    blake2b digests ~2x faster than sha256 on large buffers, the array
    buffer is hashed zero-copy, and hashing releases the GIL — the
    fingerprint pass is most of a whole-model cache hit's cost, and the
    fused planner runs it from a thread pool.
    """
    arr = np.asarray(w)
    if not arr.flags.c_contiguous:
        arr = np.ascontiguousarray(arr)
    h = hashlib.blake2b(digest_size=32)
    h.update(repr((arr.shape, str(arr.dtype))).encode())
    h.update(arr.data)
    return h.hexdigest()


def plan_key(w_fingerprint: str, spec: CrossbarSpec, mode: str,
             fault_fingerprint: str | None = None) -> str:
    """Content address of one layer's plan.

    ``mode`` is the pipeline's cache token
    (:meth:`repro.mapping.MappingPipeline.cache_token`): the historical
    mode string for the canonical legacy pipelines — so shim-resolved
    deployments hit pre-redesign entries — and a ``"pipe:..."``
    strategy fingerprint otherwise.  ``fault_fingerprint`` (a
    :func:`weight_fingerprint` of the physical fault map) enters the
    key when the pipeline's row pass consumes fault maps — a changed
    fault map must invalidate the plan exactly like changed weights do.
    """
    payload = {
        "version": PLAN_CACHE_VERSION,
        "weights": w_fingerprint,
        "spec": list(spec),
        "mode": mode,
    }
    if fault_fingerprint is not None:
        payload["faults"] = fault_fingerprint
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()


def manifest_key(keys) -> str:
    """Content address of a whole checkpoint's plan set.

    Derived from the full ``{name: plan_key}`` mapping, so any change to
    any matrix's weights / spec / mode / fault map — or to the set of
    matrix names — changes the manifest key and the stale manifest
    simply becomes unreachable (same no-staleness property as the
    per-entry keys).
    """
    payload = json.dumps(sorted(dict(keys).items()))
    return hashlib.sha256(payload.encode()).hexdigest()


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    manifest_hits: int = 0
    manifest_misses: int = 0


class PlanCache:
    """Filesystem-backed MdmPlan store keyed by :func:`plan_key`.

    ``get``/``put`` are thread-safe (the fused planner probes entries
    from a thread pool); only the stats counters need the lock — file
    writes are already atomic via tmp + rename.
    """

    def __init__(self, root: str | None = None):
        self.root = root or default_cache_dir()
        self.stats = CacheStats()
        self._lock = threading.Lock()

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], key + ".mdmplan")

    def _manifest_path(self, mkey: str) -> str:
        return os.path.join(self.root, "manifest", mkey[:2],
                            mkey + ".mdmmanifest")

    @staticmethod
    def _perm_dtype(rows: int):
        # Permutation entries are < rows: the compact dtype cuts the
        # bytes a whole-model cache hit reads by up to 4x.
        return (np.uint8 if rows <= 256 else
                np.uint16 if rows <= 65536 else np.uint32)

    @classmethod
    def _encode_plan(cls, plan: MdmPlan) -> bytes:
        # Flags bit 0: reversed dataflow; bit 1: column-permuted plan
        # (a trailing [cols u4 header field + col_perm/col_position
        # block] follows the NF block).  Legacy entries have flags in
        # {0, 1} and no col block, so the format stays self-describing
        # at PLAN_CACHE_VERSION 1 and pre-pipeline entries still hit.
        perm = np.asarray(plan.row_perm)
        ti, tn, rows = perm.shape
        perm_dt = cls._perm_dtype(rows)
        has_cols = plan.col_perm is not None
        flags = int(bool(plan.reversed_dataflow)) | (2 if has_cols else 0)
        parts = [
            bytes([flags, PLAN_CACHE_VERSION, 0, 0, 0]),
            np.asarray([ti, tn, rows], "<u4").tobytes(),
        ]
        if has_cols:
            cols = np.asarray(plan.col_perm).shape[-1]
            parts.append(np.asarray([cols], "<u4").tobytes())
        parts += [
            np.stack([perm, np.asarray(plan.row_position)]
                     ).astype(perm_dt).tobytes(),
            np.concatenate([
                np.asarray(plan.nf_before, np.float32).ravel(),
                np.asarray(plan.nf_after, np.float32).ravel(),
                np.asarray(plan.scale, np.float32).reshape(1),
            ]).astype("<f4").tobytes(),
        ]
        if has_cols:
            parts.append(np.stack([np.asarray(plan.col_perm),
                                   np.asarray(plan.col_position)]
                                  ).astype(cls._perm_dtype(cols)).tobytes())
        return b"".join(parts)

    @classmethod
    def _decode_plan(cls, buf: bytes) -> MdmPlan:
        if len(buf) < 17 or buf[1] != PLAN_CACHE_VERSION:
            raise ValueError("bad plan entry header")
        flags = buf[0]
        has_cols = bool(flags & 2)
        ti, tn, rows = np.frombuffer(buf, "<u4", 3, offset=5)
        ti, tn, rows = int(ti), int(tn), int(rows)
        off = 17
        cols = 0
        if has_cols:
            cols = int(np.frombuffer(buf, "<u4", 1, offset=off)[0])
            off += 4
        perm_dt = cls._perm_dtype(rows)
        n_perm = 2 * ti * tn * rows
        perms = np.frombuffer(buf, perm_dt, n_perm, offset=off)
        off += n_perm * perms.itemsize
        nfs = np.frombuffer(buf, "<f4", 2 * ti * tn + 1, offset=off)
        off += nfs.size * 4
        col_perm = col_position = None
        if has_cols:
            col_dt = cls._perm_dtype(cols)
            cperms = np.frombuffer(buf, col_dt, 2 * ti * tn * cols,
                                   offset=off)
            off += cperms.size * cperms.itemsize
            cperms = cperms.astype(np.int32).reshape(2, ti, tn, cols)
            col_perm, col_position = cperms[0], cperms[1]
        if off != len(buf):
            # Exact-length contract: a short buffer already fails one of
            # the frombuffer reads above, but an entry whose header
            # promises more than its body holds (torn write on a
            # non-atomic filesystem, manual corruption) — or one with
            # trailing garbage — must be a miss, not a silent partial
            # decode.
            raise ValueError("plan entry length mismatch")
        perms = perms.astype(np.int32).reshape(2, ti, tn, rows)
        return MdmPlan(
            row_perm=perms[0], row_position=perms[1],
            reversed_dataflow=np.bool_(flags & 1),
            nf_before=nfs[:ti * tn].reshape(ti, tn),
            nf_after=nfs[ti * tn:2 * ti * tn].reshape(ti, tn),
            scale=np.float32(nfs[-1]),
            col_perm=col_perm, col_position=col_position)

    def get(self, key: str) -> MdmPlan | None:
        try:
            with open(self._path(key), "rb") as f:
                buf = f.read()
            plan = self._decode_plan(buf)
        except (FileNotFoundError, ValueError, OSError):
            with self._lock:
                self.stats.misses += 1
            _M_PROBES.labels(result="miss").inc()
            return None
        with self._lock:
            self.stats.hits += 1
        _M_PROBES.labels(result="hit").inc()
        _M_READ_BYTES.inc(len(buf))
        return plan

    def put(self, key: str, plan: MdmPlan) -> None:
        if not self._atomic_write(self._path(key),
                                  self._encode_plan(plan)):
            return
        with self._lock:
            self.stats.puts += 1
        _M_PUTS.inc()

    def _atomic_write(self, path: str, payload: bytes) -> bool:
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                       suffix=".tmp")
        except OSError:
            return False
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(payload)
                # Durability before visibility: rename-over without
                # fsync can surface a zero-length/truncated entry after
                # a power loss on journaled filesystems — exactly the
                # corruption class ``_decode_plan``'s length check turns
                # into a miss, but better never to publish it.
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except OSError:
            # Cache is best-effort: a full/read-only disk must not fail
            # the deployment itself.
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return False
        return True

    # ------------------------ checkpoint manifests ---------------------
    #
    # A whole checkpoint's plans in ONE file: header line of JSON
    # entry descriptors (name, per-entry key, offset, length), then the
    # concatenated per-entry binary blobs (the exact bytes the entry
    # files hold).  A full-checkpoint cache hit becomes a single read()
    # + frombuffer views instead of one file open per matrix — the
    # entry-probe pass is the whole cost of a hit redeploy.  Per-entry
    # files are still written (they are shared across checkpoints that
    # have matrices in common); the manifest is a pure read-path
    # accelerator, validated against the caller's expected keys and
    # falling back to per-entry probes on any mismatch or corruption.

    def get_manifest(self, keys) -> dict[str, MdmPlan] | None:
        """Resolve a whole ``{name: key}`` plan set from one file read.

        Returns the full ``{name: MdmPlan}`` mapping, or None if the
        manifest is absent, corrupt, or does not cover exactly the
        requested entries (the caller then falls back to per-entry
        probes).
        """
        keys = dict(keys)
        try:
            with open(self._manifest_path(manifest_key(keys)),
                      "rb") as f:
                buf = f.read()
            nl = buf.index(b"\n")
            head = json.loads(buf[:nl])
            if head.get("v") != PLAN_CACHE_VERSION:
                raise ValueError("manifest version mismatch")
            entries = head["entries"]
            if {e[0]: e[1] for e in entries} != keys:
                raise ValueError("manifest entry set mismatch")
            base = nl + 1
            plans = {name: self._decode_plan(buf[base + off:
                                                base + off + length])
                     for name, _, off, length in entries}
        except (FileNotFoundError, ValueError, KeyError, OSError):
            with self._lock:
                self.stats.manifest_misses += 1
            _M_MANIFEST_PROBES.labels(result="miss").inc()
            return None
        with self._lock:
            self.stats.manifest_hits += 1
        _M_MANIFEST_PROBES.labels(result="hit").inc()
        _M_READ_BYTES.inc(len(buf))
        return plans

    def put_manifest(self, keys, plans) -> None:
        """Write the one-read manifest for a ``{name: key}`` plan set."""
        keys = dict(keys)
        blobs, entries, off = [], [], 0
        for name, key in keys.items():
            blob = self._encode_plan(plans[name])
            entries.append([name, key, off, len(blob)])
            blobs.append(blob)
            off += len(blob)
        head = json.dumps({"v": PLAN_CACHE_VERSION,
                           "entries": entries}).encode() + b"\n"
        self._atomic_write(self._manifest_path(manifest_key(keys)),
                           head + b"".join(blobs))
