"""Trip-count-aware cost walker over optimized HLO text.

``compiled.cost_analysis()`` visits every computation exactly once, so a
``lax.scan`` over 80 layers reports 1/80th of the real FLOPs.  This
module re-derives FLOPs / bytes-accessed / collective bytes by walking
the HLO with loop multipliers taken from the ``known_trip_count``
backend_config that XLA attaches to while ops:

  * FLOPs: dots = 2 * result_elems * contracted_elems (shapes from the
    per-computation symbol table); elementwise/reduce ops = input elems.
  * bytes: per top-level op, operands + result (fusion bodies contribute
    FLOPs only — their memory traffic is the fusion call site's).
  * collectives: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (+ their async
    -start forms), scaled by the enclosing loop multipliers.

This is a structural model (no wall clock on CPU), but it is *consistent*
— the same workload change moves the same term — which is what the §Perf
hillclimb needs.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\]{},]+)\s+"
    r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_ELEMENTWISE_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "broadcast",
    "reshape", "custom-call", "infeed", "outfeed", "rng-bit-generator",
}
_NO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id"}


def _shape_info(s: str):
    """(total_bytes, dims_of_first_array) for a result type string."""
    total = 0
    dims0: list[int] | None = None
    for dt, dm in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in dm.split(",") if x.strip()]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        if dims0 is None:
            dims0 = dims
    return total, (dims0 or [])


@dataclass
class _Op:
    name: str
    opcode: str
    result: str
    operands: list[str]
    attrs: str


@dataclass
class _Comp:
    name: str
    ops: list[_Op] = field(default_factory=list)
    symtab: dict = field(default_factory=dict)   # op name -> result type str


def parse_computations(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    entry = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and _COMP_HDR.match(line) and \
                line.rstrip().endswith("{"):
            m = _COMP_HDR.match(line)
            cur = _Comp(m.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            # parameters: "  %p = f32[..] parameter(0)" matches _OP_RE;
            # non-op lines fall through here.
            continue
        name, result, opcode = m.group(1), m.group(2), m.group(3)
        rest = line[m.end():]
        close = rest.find(")")
        operand_str = rest[:close if close >= 0 else len(rest)]
        operands = _OPERAND_RE.findall(operand_str)
        cur.ops.append(_Op(name, opcode, result, operands, rest))
        cur.symtab[name] = result
    comps["__entry__"] = comps[entry]
    return comps


def _multipliers(comps: dict[str, _Comp]) -> dict[str, float]:
    """Execution-count multiplier per computation (while trip counts)."""
    mult: dict[str, float] = defaultdict(float)
    entry = comps["__entry__"].name
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # Build edges on demand (call graph is a DAG in HLO).
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps[cname]
        for op in comp.ops:
            edges: list[tuple[str, float]] = []
            if op.opcode == "while":
                t = _TRIP_RE.search(op.attrs)
                trips = float(t.group(1)) if t else 1.0
                for key in ("body", "condition"):
                    m = re.search(key + r"=%?([\w.\-]+)", op.attrs)
                    if m:
                        edges.append((m.group(1), trips))
            elif op.opcode in ("fusion", "call", "async-start"):
                m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.attrs)
                if m:
                    edges.append((m.group(1), 1.0))
            elif op.opcode == "conditional":
                for m in re.finditer(r"%([\w.\-]+)", op.attrs):
                    if m.group(1) in comps:
                        edges.append((m.group(1), 1.0))
            for child, k in edges:
                if child not in comps:
                    continue
                mult[child] += mult[cname] * k
                if child not in seen:
                    seen.add(child)
                    order.append(child)
    return mult


def _fusion_bodies(comps: dict[str, _Comp]) -> set[str]:
    bodies = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if m:
                    bodies.add(m.group(1))
    return bodies


# Ops that read only their *result*-sized window of a big operand.
_SLICE_LIKE = {"dynamic-slice", "gather", "slice"}


def _param_effective_bytes(comp: _Comp) -> dict[int, float]:
    """For a fusion body: param index -> effective bytes read, when every
    use of that parameter is slice-like (dynamic-slice of a scan input
    reads one step's window, not the whole stacked buffer)."""
    by_name = {op.name: op for op in comp.ops}
    uses: dict[str, list[_Op]] = defaultdict(list)
    for op in comp.ops:
        for o in op.operands:
            uses[o].append(op)
    out: dict[int, float] = {}
    for op in comp.ops:
        if op.opcode != "parameter":
            continue
        m = re.match(r"\s*(\d+)", op.attrs)
        if not m:
            continue
        idx = int(m.group(1))
        use_list = uses.get(op.name, [])
        if use_list and all(u.opcode in _SLICE_LIKE for u in use_list):
            out[idx] = sum(_shape_info(u.result)[0] for u in use_list)
    return out


def _root_op(comp: _Comp) -> _Op | None:
    return comp.ops[-1] if comp.ops else None


def _op_bytes(op: _Op, comp: _Comp, comps: dict,
              eff_cache: dict) -> float:
    """HBM traffic model for one top-level op."""
    res_bytes, _ = _shape_info(op.result)
    sym = comp.symtab

    def osize(name: str) -> float:
        return _shape_info(sym.get(name, ""))[0]

    if op.opcode in _SLICE_LIKE:
        # reads the sliced window (~= result) + writes result
        return 2.0 * res_bytes
    if op.opcode == "dynamic-update-slice":
        upd = osize(op.operands[1]) if len(op.operands) > 1 else res_bytes
        return 2.0 * upd       # read-modify-write of the update window
    if op.opcode == "scatter":
        idx = osize(op.operands[1]) if len(op.operands) > 1 else 0.0
        upd = osize(op.operands[2]) if len(op.operands) > 2 else res_bytes
        return idx + 2.0 * upd
    if op.opcode == "fusion":
        m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
        body = comps.get(m.group(1)) if m else None
        total = 0.0
        if body is not None:
            if m.group(1) not in eff_cache:
                eff_cache[m.group(1)] = _fusion_effective(body)
            eff, alias_res = eff_cache[m.group(1)]
            for i, o in enumerate(op.operands):
                total += eff.get(i, osize(o))
            return total + (0.0 if alias_res else res_bytes)
        total = sum(osize(o) for o in op.operands)
        return total + res_bytes
    return sum(osize(o) for o in op.operands) + res_bytes


def _fusion_effective(body: _Comp) -> tuple[dict[int, float], bool]:
    """(param index -> effective bytes, result_aliases_input).

    Two in-place patterns matter beyond plain slicing:
      * dynamic-update-slice of a parameter (scan output stacking / cache
        writes): traffic is 2x the update window, and the fusion result
        aliases the input buffer — charging the full carried buffer per
        step inflates an 80-layer scan by the buffer/step ratio (observed
        as 13 PB of phantom traffic on the xlstm cell).
      * scatter into a parameter: indices + 2x updates.
    """
    eff = _param_effective_bytes(body)
    by_name = {o.name: o for o in body.ops}
    param_idx = {}
    for o in body.ops:
        if o.opcode == "parameter":
            mi = re.match(r"\s*(\d+)", o.attrs)
            if mi:
                param_idx[o.name] = int(mi.group(1))

    def trace_param(name: str) -> int | None:
        seen = 0
        while name in by_name and seen < 8:
            o = by_name[name]
            if o.opcode == "parameter":
                return param_idx.get(name)
            # convert/copy included: XLA-CPU wraps loop-carried updates
            # in full-buffer dtype converts that the TPU pipeline sinks
            # into the update window — model the intended in-place op.
            if o.opcode in ("bitcast", "reshape", "transpose", "convert",
                            "copy") and o.operands:
                name = o.operands[0]
                seen += 1
                continue
            return None
        return param_idx.get(name)

    def obytes(name: str) -> float:
        return _shape_info(body.symtab.get(name, ""))[0]

    alias = False
    for o in body.ops:
        if o.opcode == "dynamic-update-slice" and len(o.operands) > 1:
            pi = trace_param(o.operands[0])
            upd = obytes(o.operands[1])
            if pi is not None:
                eff[pi] = 2.0 * upd
                alias = True
        elif o.opcode == "scatter" and len(o.operands) > 2:
            pi = trace_param(o.operands[0])
            cost = obytes(o.operands[1]) + 2.0 * obytes(o.operands[2])
            if pi is not None:
                eff[pi] = cost
                alias = True
    return eff, alias


def _dot_flops(op: _Op, comp: _Comp) -> float:
    res_bytes, res_dims = _shape_info(op.result)
    res_elems = 1
    for d in res_dims:
        res_elems *= d
    lhs = op.operands[0] if op.operands else None
    lhs_dims = _shape_info(comp.symtab.get(lhs, ""))[1] if lhs else []
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    contracted = 1
    if m and lhs_dims:
        for i in m.group(1).split(","):
            if i.strip():
                idx = int(i)
                if idx < len(lhs_dims):
                    contracted *= lhs_dims[idx]
    return 2.0 * res_elems * contracted


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    coll_breakdown: dict = field(default_factory=dict)
    loop_trip_counts: list = field(default_factory=list)
    top_coll_sites: list = field(default_factory=list)   # (bytes, desc)
    top_bytes_sites: list = field(default_factory=list)  # (bytes, desc)


def _op_meta(op: _Op) -> str:
    m = re.search(r'op_name="([^"]+)"', op.attrs)
    return m.group(1) if m else op.name


def analyze(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    mult = _multipliers(comps)
    fusion_bodies = _fusion_bodies(comps)
    out = HloCost()
    coll = defaultdict(float)
    coll_sites: list = []
    bytes_sites: list = []
    eff_cache: dict = {}

    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        k = mult.get(cname, 0.0)
        if k == 0.0:
            continue
        in_fusion = cname in fusion_bodies
        for op in comp.ops:
            res_bytes, res_dims = _shape_info(op.result)
            res_elems = 1
            for d in res_dims:
                res_elems *= d
            # ---- FLOPs
            if op.opcode == "dot":
                out.flops += k * _dot_flops(op, comp)
            elif op.opcode == "convolution":
                # treat like dot via operand kernel size (rare here)
                out.flops += k * 2.0 * res_elems
            elif op.opcode in ("reduce", "reduce-window"):
                opd = op.operands[0] if op.operands else None
                in_elems = 1
                for d in _shape_info(comp.symtab.get(opd, ""))[1]:
                    in_elems *= d
                out.flops += k * in_elems
            elif op.opcode not in _ELEMENTWISE_SKIP and \
                    op.opcode not in ("fusion", "while", "call",
                                      "conditional"):
                out.flops += k * res_elems
            # ---- collectives (operand bytes)
            base = op.opcode.removesuffix("-start").removesuffix("-done")
            if base in COLLECTIVE_KINDS and not op.opcode.endswith("-done"):
                ob = sum(_shape_info(comp.symtab.get(o, ""))[0]
                         for o in op.operands)
                coll[base] += k * ob
                out.collective_bytes += k * ob
                coll_sites.append(
                    (k * ob, f"{base} x{k:g} {op.result[:40]} "
                             f"[{_op_meta(op)[:90]}]"))
            # ---- bytes
            if not in_fusion and op.opcode not in _NO_BYTES and \
                    op.opcode not in ("while", "conditional"):
                b = _op_bytes(op, comp, comps, eff_cache)
                out.bytes_accessed += k * b
                bytes_sites.append(
                    (k * b,
                     f"{op.opcode} x{k:g} {op.result[:40]} "
                     f"[{_op_meta(op)[:90]}]"))

    coll_sites.sort(key=lambda t: -t[0])
    bytes_sites.sort(key=lambda t: -t[0])
    out.top_coll_sites = coll_sites[:20]
    out.top_bytes_sites = bytes_sites[:20]
    out.coll_breakdown = dict(coll)
    out.loop_trip_counts = [int(m.group(1))
                            for m in _TRIP_RE.finditer(hlo)]
    return out
