"""Serving launcher (smoke-scale batched generation).

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import telemetry as tm
from repro.configs import get_config
from repro.data import SyntheticTokenDataset
from repro.models.model import init_params
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_params(cfg, jax.random.PRNGKey(0))  # reprolint: disable=RPL003 -- serve smoke CLI: deterministic params make runs comparable
    eng = ServeEngine(cfg, params,
                      max_seq=args.prompt_len + args.gen + 1,
                      temperature=args.temperature)
    ds = SyntheticTokenDataset(cfg.vocab_size, args.prompt_len, args.batch)
    prompts = jax.numpy.asarray(ds.batch_at(0)[:, :args.prompt_len])
    if cfg.frontend:
        from repro.models.frontend import synthetic_embeddings
        prompts = synthetic_embeddings(cfg, args.batch, args.prompt_len,
                                       jax.random.PRNGKey(1))  # reprolint: disable=RPL003 -- serve smoke CLI: deterministic synthetic embeddings
    t0 = tm.monotonic()
    out = eng.generate(prompts, args.gen)
    dt = tm.monotonic() - t0
    toks = args.batch * args.gen
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print(np.asarray(out)[:2])


if __name__ == "__main__":
    main()
