import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/initialisation: jax locks the device
#   count on first init.  The dry-run (and only the dry-run) runs with
#   512 placeholder host devices so the production meshes materialise.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step for
train_4k, prefill for prefill_32k, one decode step for decode_32k /
long_500k), lowers it with ShapeDtypeStruct inputs (no allocation),
compiles it for the production mesh, and records:

  * memory_analysis()        — proves the cell fits per-device HBM
  * cost_analysis()          — raw XLA totals (loop bodies counted once)
  * hlo_cost.analyze()       — trip-count-aware FLOPs/bytes/collective
  * roofline terms           — §Roofline inputs

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod both] [--force]
Results land in results/dryrun/<arch>__<shape>__<mesh>[__tag].json.
"""
import argparse
import json
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import telemetry as tm
from repro.configs import SHAPES, arch_shape_cells, get_config
from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig, TrainConfig
from repro.distributed.sharding import ShardingCtx, logical_spec
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline
from repro.models import model as M
from repro.models import schema as sch
from repro.serve.engine import make_decode_step, make_prefill
from repro.train.step import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def param_counts(cfg: ModelConfig) -> dict:
    schema = sch.model_schema(cfg)
    leaves = jax.tree_util.tree_leaves_with_path(
        schema, is_leaf=lambda x: isinstance(x, sch.ParamSpec))
    total = expert = embed = 0
    for path, spec in leaves:
        n = 1
        for s in spec.shape:
            n *= s
        total += n
        key = jax.tree_util.keystr(path)
        if "ffn_we_" in key:
            expert += n
        if key.endswith("['embed']"):
            embed += n
    active = total - expert
    if cfg.n_experts:
        active += expert * cfg.n_experts_per_token / cfg.n_experts
    return {"total": total, "active": active, "embed": embed,
            "expert": expert}


def _ns(ctx: ShardingCtx, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(ctx.mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, ctx: ShardingCtx):
    """(abstract_batch, shardings) for a train batch."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend:
        batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        dims = {"embeds": ("batch", "seq", "act_embed"),
                "labels": ("batch", "seq")}
    else:
        batch = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
        dims = {"tokens": ("batch", "seq")}
    shardings = {
        k: NamedSharding(ctx.mesh, logical_spec(batch[k].shape, dims[k],
                                                ctx.mesh, ctx.rules))
        for k in batch}
    return batch, shardings


def build_cell(cfg: ModelConfig, shape: ShapeConfig, ctx: ShardingCtx,
               tcfg: TrainConfig):
    """Returns (fn, args, in_shardings, donate) ready for jit().lower()."""
    a_params = sch.abstract_params(cfg)
    p_specs = sch.partition_specs(cfg, ctx)
    p_ns = _ns(ctx, p_specs)

    if shape.kind == "train":
        from repro.optim.adamw import abstract_opt_state, optimizer_partition_specs
        a_opt = abstract_opt_state(
            a_params, tcfg.grad_compression == "int8_ef")
        o_specs = optimizer_partition_specs(p_specs)
        o_ns = jax.tree_util.tree_map(
            lambda s: NamedSharding(ctx.mesh, s), o_specs,
            is_leaf=lambda x: isinstance(x, P))
        if a_opt.ef_error is not None:
            o_ns = o_ns._replace(ef_error=p_ns)
        a_batch, b_ns = batch_specs(cfg, shape, ctx)
        fn = make_train_step(cfg, tcfg, ctx)
        return fn, (a_params, a_opt, a_batch), (p_ns, o_ns, b_ns), (0, 1)

    B, S = shape.global_batch, shape.seq_len
    a_state = M.init_decode_state(cfg, B, S, abstract=True)
    s_specs = M.state_partition_specs(cfg, ctx, B, S)
    s_ns = _ns(ctx, s_specs)

    if shape.kind == "prefill":
        dt = jnp.dtype(cfg.dtype)
        if cfg.frontend:
            a_in = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
            in_dims = ("batch", "seq", "act_embed")
        else:
            a_in = jax.ShapeDtypeStruct((B, S), jnp.int32)
            in_dims = ("batch", "seq")
        in_ns = NamedSharding(ctx.mesh, logical_spec(a_in.shape, in_dims,
                                                     ctx.mesh, ctx.rules))
        prefill = make_prefill(cfg, ctx)
        fn = lambda p, st, x: prefill(p, st, x, jax.random.PRNGKey(0))  # reprolint: disable=RPL003 -- dry-run traces shapes only; the key value is never sampled from
        return fn, (a_params, a_state, a_in), (p_ns, s_ns, in_ns), (1,)

    # decode: one new token against a seq_len-deep cache
    a_tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_ns = NamedSharding(ctx.mesh, logical_spec((B,), ("batch",),
                                                  ctx.mesh, ctx.rules))
    decode = make_decode_step(cfg, ctx)
    fn = lambda p, st, t: decode(p, st, t, jax.random.PRNGKey(0))  # reprolint: disable=RPL003 -- dry-run traces shapes only; the key value is never sampled from
    return fn, (a_params, a_state, a_tok), (p_ns, s_ns, tok_ns), (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules: str = "default", tag: str = "",
             overrides: dict | None = None, save_hlo: bool = False,
             out_dir: str = RESULTS_DIR) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = ShardingCtx(mesh=mesh, rules_name=rules)
    tcfg = TrainConfig()
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    chips = int(mesh.devices.size)

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "rules": rules, "tag": tag,
           "overrides": {k: str(v) for k, v in (overrides or {}).items()},
           "chips": chips}
    t0 = tm.monotonic()
    try:
        fn, args, in_sh, donate = build_cell(cfg, shape, ctx, tcfg)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              donate_argnums=donate).lower(*args)
            t_lower = tm.monotonic() - t0
            compiled = lowered.compile()
        t_compile = tm.monotonic() - t0 - t_lower
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        cost = hlo_cost.analyze(hlo)
        counts = param_counts(cfg)
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        n = counts["active"] - counts["embed"]
        mf = (6 if shape.kind == "train" else 2) * n * tokens
        # The compiled module is the post-SPMD per-device program; scale
        # by chip count to express global FLOPs/bytes (the roofline terms
        # divide by chips again, so per-device semantics are preserved).
        roof = Roofline(flops=cost.flops * chips,
                        bytes_accessed=cost.bytes_accessed * chips,
                        coll_bytes=cost.collective_bytes * chips,
                        chips=chips, model_flops=mf,
                        coll_breakdown={k: v * chips for k, v in
                                        cost.coll_breakdown.items()})
        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
                "code_bytes": getattr(mem,
                                      "generated_code_size_in_bytes", None),
            },
            "xla_cost_analysis": {k: ca.get(k) for k in
                                  ("flops", "bytes accessed",
                                   "transcendentals") if k in ca},
            "params": counts,
            "roofline": roof.as_dict(),
            "loop_trip_counts": cost.loop_trip_counts[:32],
        })
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            hp = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}"
                              + (f"__{tag}" if tag else "") + ".hlo")
            with open(hp, "w") as f:
                f.write(hlo)
    except Exception as e:  # a failing cell is a bug; record it loudly
        rec.update({"ok": False, "error": repr(e),
                    "traceback": traceback.format_exc()})
    rec["wall_s"] = round(tm.monotonic() - t0, 2)

    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}" \
        + (f"__{tag}" if tag else "") + ".json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"],
                    default="no")
    ap.add_argument("--rules", default="default")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    if args.all:
        cells = arch_shape_cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        cells = [(args.arch, args.shape)]

    pods = {"no": [False], "yes": [True], "both": [False, True]}[
        args.multi_pod]
    for arch, shape in cells:
        for mp in pods:
            mesh_name = "multipod_2x16x16" if mp else "pod_16x16"
            fname = os.path.join(
                args.out, f"{arch}__{shape}__{mesh_name}"
                + (f"__{args.tag}" if args.tag else "") + ".json")
            if os.path.exists(fname) and not args.force:
                print(f"[skip cached] {arch} {shape} {mesh_name}")
                continue
            rec = run_cell(arch, shape, mp, rules=args.rules, tag=args.tag,
                           save_hlo=args.save_hlo, out_dir=args.out)
            if rec["ok"]:
                r = rec["roofline"]
                print(f"[ok] {arch:20s} {shape:12s} {mesh_name:16s} "
                      f"compile={rec['compile_s']:7.1f}s "
                      f"peakMB={(rec['memory']['peak_bytes'] or 0)/1e6:9.1f} "
                      f"dom={r['dominant']:10s} "
                      f"roofline={r['roofline_fraction']:.3f}")
            else:
                print(f"[FAIL] {arch} {shape} {mesh_name}: {rec['error']}")


if __name__ == "__main__":
    main()
