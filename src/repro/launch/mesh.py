"""Production meshes.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model").

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    # more devices than the mesh needs (e.g. 512 placeholders, single-pod
    # mesh): use the first n.
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")):
    """1-device mesh with production axis names (smoke tests)."""
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]).reshape(shape), axes)
