"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --smoke --steps 100 --batch 8 --seq 128

Full-size runs target the production mesh (requires real devices or the
dry-run's forced host device count); --smoke runs the reduced config on
whatever devices exist (the end-to-end example path).
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data import SyntheticTokenDataset
from repro.distributed.sharding import ShardingCtx
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", default="",
                    choices=["", "int8_ef"])
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tcfg = TrainConfig(total_steps=args.steps, learning_rate=args.lr,
                       microbatches=args.microbatches,
                       checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=args.ckpt_every,
                       grad_compression=args.grad_compression)
    ds = SyntheticTokenDataset(cfg.vocab_size, args.seq, args.batch,
                               seed=tcfg.seed)
    tr = Trainer(cfg, tcfg, ds, ctx=ShardingCtx())
    if args.resume:
        tr.resume_or_init()
    else:
        tr.init_state()
    log = tr.run(args.steps)
    for m in log[-5:]:
        print({k: round(v, 4) if isinstance(v, float) else v
               for k, v in m.items()})
    if tr.watchdog.stragglers:
        print(f"watchdog: {len(tr.watchdog.stragglers)} straggler steps")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(log, f)


if __name__ == "__main__":
    main()
