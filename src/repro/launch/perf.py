import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede any jax import — see dryrun.py)

"""§Perf hillclimb driver: run tagged dry-run variants of the three
chosen cells and print the roofline-term deltas vs the untagged baseline.

    PYTHONPATH=src python -m repro.launch.perf --cell mixtral --iter 1
    PYTHONPATH=src python -m repro.launch.perf --all-iters
"""
import argparse
import json

from repro.launch.dryrun import RESULTS_DIR, run_cell

# cell -> list of (tag, overrides, hypothesis)
ITERATIONS = {
    "mixtral-8x7b|train_4k": [
        ("grouped_moe", {"moe_dispatch": "grouped"},
         "global-index dispatch replicates (T,D) f32 tensors and "
         "all-reduces them (2.1 PB/step); per-group dispatch keeps the "
         "batch dim sharded -> expect collective term to drop >4x"),
        ("grouped_rematchunk", {"moe_dispatch": "grouped",
                                "attn_remat_chunk": True},
         "flash-backward saves (n_chunks, B, S, H, c) score residuals; "
         "remat of the chunk body recomputes them -> memory term down"),
        ("grouped_rematchunk_c2k", {"moe_dispatch": "grouped",
                                    "attn_remat_chunk": True,
                                    "attn_chunk": 2048},
         "fewer chunk iterations -> fewer (m,l,acc) carry round-trips "
         "-> further memory-term reduction"),
    ],
    "internvl2-76b|train_4k": [
        ("gqa_take", {"gqa_broadcast": "take"},
         "kv=8 !| model=16: repeat's (B,c,8,8,Dh) intermediate forces "
         "SPMD replication of attention chunk tensors; take keeps H=64 "
         "TP-sharded -> expect memory term down"),
        ("chunk2k", {"attn_chunk": 2048},
         "8->2 chunk iterations: online-softmax carry (m,l,acc f32) "
         "r/w per iteration shrinks 4x -> memory term down"),
        ("chunk2k_rematchunk", {"attn_chunk": 2048,
                                "attn_remat_chunk": True},
         "drop the stacked per-chunk score residuals of the flash "
         "backward (2x f32[2,16,4096,4,2048] x160 sites) -> memory "
         "term down ~10-15%"),
        ("chunk2k_rematchunk_lc", {"attn_chunk": 2048,
                                   "attn_remat_chunk": True,
                                   "loss_chunk": 512},
         "chunked+remat CE avoids materialising (B,S,128k) f32 logits "
         "for backward -> memory term down"),
    ],
    "xlstm-1.3b|train_4k": [
        ("mlstm_chunk512", {"mlstm_chunk": 512},
         "mLSTM state (B,H,1024,1024) f32 carried r/w every chunk: "
         "32 -> 8 iterations cuts state traffic 4x"),
        ("slstm_replicate", {"slstm_tp": "replicate"},
         "sLSTM recurrence sharded on the contraction dim issues one "
         "tiny all-reduce per TIMESTEP (3x ~100-200 GB x98304 sites = "
         "~8s of the 11.3s collective term, latency-catastrophic on "
         "real ICI); replicating the small recurrence removes them at "
         "~0.5s extra (replicated) compute"),
        ("slstm_repl_mlstm512", {"slstm_tp": "replicate",
                                 "mlstm_chunk": 512},
         "combine both; expect collective-dominated -> memory-dominated "
         "with the residual memory term from mLSTM chunk tensors"),
    ],
}


def baseline_record(arch: str, shape: str) -> dict:
    p = os.path.join(RESULTS_DIR, f"{arch}__{shape}__pod_16x16.json")
    return json.load(open(p))


def show(rec: dict, base: dict):
    r, b = rec["roofline"], base["roofline"]
    for term in ("t_compute_s", "t_memory_s", "t_collective_s"):
        delta = r[term] / b[term] if b[term] else float("inf")
        print(f"    {term:16s} {b[term]:10.3g} -> {r[term]:10.3g} "
              f"(x{delta:.3f})")
    print(f"    dominant {b['dominant']} -> {r['dominant']}; roofline "
          f"fraction {b['roofline_fraction']:.4f} -> "
          f"{r['roofline_fraction']:.4f} "
          f"(x{r['roofline_fraction']/max(b['roofline_fraction'],1e-12):.2f})")
    pk = (rec["memory"]["peak_bytes"] or 0) / 1e9
    pb = (base["memory"]["peak_bytes"] or 0) / 1e9
    print(f"    peak HBM {pb:.2f} -> {pk:.2f} GB")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="")
    ap.add_argument("--iter", type=int, default=0)  # 1-based; 0 = all
    ap.add_argument("--all-iters", action="store_true")
    args = ap.parse_args()

    for cell, iters in ITERATIONS.items():
        arch, shape = cell.split("|")
        if args.cell and args.cell not in arch:
            continue
        base = baseline_record(arch, shape)
        for i, (tag, overrides, hypo) in enumerate(iters, 1):
            if args.iter and i != args.iter and not args.all_iters:
                continue
            print(f"== {arch} {shape} iter {i}: {tag}")
            print(f"   hypothesis: {hypo}")
            rec = run_cell(arch, shape, multi_pod=False, tag=tag,
                           overrides=overrides)
            if rec["ok"]:
                show(rec, base)
            else:
                print("   FAILED:", rec["error"])


if __name__ == "__main__":
    main()
