"""Roofline term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e targets):

    compute    = HLO_FLOPs   / (chips * 197e12  bf16 FLOP/s)
    memory     = HLO_bytes   / (chips * 819e9   HBM B/s)
    collective = coll_bytes  / (chips * 50e9    per-link ICI B/s)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
not reported there, so we parse the optimized HLO and sum the result
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (loop-body collectives are multiplied by the
enclosing while trip count when derivable from the scan length).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e hardware constants (per chip).
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.3 = f32[8,128]{1,0} all-reduce(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_TUPLE_ELT_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes per collective kind over the HLO module text."""
    totals = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        tuple_body, dtype, dims, kind = m.groups()
        if tuple_body is not None:
            b = sum(_shape_bytes(dt, dm)
                    for dt, dm in _TUPLE_ELT_RE.findall(tuple_body))
        else:
            b = _shape_bytes(dtype, dims)
        totals[kind] += b
    return totals


def loop_trip_counts(hlo_text: str) -> list[int]:
    """Trip counts of while loops, from trip_count annotations if present."""
    return [int(x) for x in re.findall(r'known_trip_count=\{"?(\d+)"?\}',
                                       hlo_text)]


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int
    model_flops: float = 0.0
    coll_breakdown: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term roofline that is useful model
        compute: (model_flops / peak) / bound_time."""
        if not self.model_flops or not self.bound_time:
            return 0.0
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.bound_time

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_breakdown": self.coll_breakdown,
        }


def model_flops(cfg, shape_cfg, n_params_active: int, n_params_embed: int):
    """6*N*D train FLOPs (2*N*D forward-only), N = active non-embedding
    params (MoE counts routed experts at top-k/E utilisation)."""
    tokens = shape_cfg.global_batch * (
        shape_cfg.seq_len if shape_cfg.kind != "decode" else 1)
    n = n_params_active - n_params_embed
    per_tok = 6 * n if shape_cfg.kind == "train" else 2 * n
    return float(per_tok) * tokens
