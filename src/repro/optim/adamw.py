"""AdamW with f32 master weights and fully-sharded state.

State tensors (m, v, master) inherit the parameter PartitionSpecs, which
under the default rules are 2-D sharded (FSDP x TP) — ZeRO-style: every
chip holds 1/(data*model) of the optimizer state.  Decoupled weight
decay, global-norm clipping, bf16 params with f32 masters.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict
    master: dict          # f32 master copy of params
    ef_error: dict | None  # error-feedback residual (grad compression)


def adamw_init(params, use_error_feedback: bool = False) -> AdamWState:
    # copy=True: when params are already f32, astype would alias the
    # param buffer and break donation (same buffer donated twice).
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.array(x, dtype=jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    ef = zeros(params) if use_error_feedback else None
    return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params),
                      f32(params), ef)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(grads, state: AdamWState, params, *, lr, beta1=0.9,
                 beta2=0.95, eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
    tmap = jax.tree_util.tree_map

    grads = tmap(lambda g: g.astype(jnp.float32) * clip, grads)
    m = tmap(lambda mu, g: beta1 * mu + (1 - beta1) * g, state.m, grads)
    v = tmap(lambda nu, g: beta2 * nu + (1 - beta2) * g * g, state.v, grads)
    bc1 = 1 - beta1 ** step.astype(jnp.float32)
    bc2 = 1 - beta2 ** step.astype(jnp.float32)

    def upd(master, mu, nu):
        update = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
        return master - lr * (update + weight_decay * master)

    master = tmap(upd, state.master, m, v)
    new_params = tmap(lambda w, ref: w.astype(ref.dtype), master, params)
    new_state = AdamWState(step, m, v, master, state.ef_error)
    return new_params, new_state, {"grad_norm": gnorm, "clip": clip}


def optimizer_partition_specs(param_specs):
    """State PartitionSpecs mirroring the parameter specs."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(
        step=P(), m=param_specs, v=param_specs, master=param_specs,
        ef_error=None)


def abstract_opt_state(abstract_params, use_error_feedback: bool = False):
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
    ef = f32(abstract_params) if use_error_feedback else None
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                      f32(abstract_params), f32(abstract_params),
                      f32(abstract_params), ef)
