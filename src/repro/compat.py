"""Version-compatibility shims for the JAX APIs this repo depends on.

Two JAX API moves bit this codebase (both are handled here so call sites
stay version-agnostic):

* ``jax.enable_x64`` — removed as a public context manager; the
  supported spelling is ``jax.experimental.enable_x64()`` (a
  config-scoped context manager that affects *tracing*, so wrap the
  jit'd call site, not the kernel body).  Use :func:`enable_x64`.
* ``jax.sharding.AbstractMesh`` — since JAX 0.4.35 the constructor
  takes a single ``shape_tuple`` of ``(name, size)`` pairs instead of
  the older ``(axis_sizes, axis_names)`` pair of tuples.  Use
  :func:`make_abstract_mesh` with the old-style arguments.
* ``jax.shard_map`` — newer JAX exposes it at top level with a
  ``check_vma`` kwarg; 0.4.x has ``jax.experimental.shard_map`` with
  ``check_rep``.  Use :func:`shard_map` (``check_vma`` spelling).

Backend capability probes also live here:

* :func:`has_batched_tridiagonal_solve` — whether
  ``jax.lax.linalg.tridiagonal_solve`` lowers (and executes) with
  leading batch dimensions on the active backend.  The batched crossbar
  engine's line preconditioner depends on it; backends without the
  batched lowering fall back to the Jacobi diagonal.
* :func:`has_pallas_lowering` — whether ``pallas_call`` compiles and
  runs natively (non-interpret) on the active backend.  The CIM matmul
  dispatch (``repro.kernels.cim_mvm.ops.cim_mvm``) uses it to pick the
  Pallas kernel where it lowers and the fused XLA fallback everywhere
  else, so interpret mode never lands on a hot path.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, Callable, ContextManager, Sequence

import jax


def enable_x64(enabled: bool = True) -> ContextManager:
    """Config-scoped float64 enablement across JAX versions.

    Prefer wrapping the *outermost* (trace-time) call: inside an already
    traced jit the dtypes are frozen and the flag has no effect.
    """
    try:  # JAX >= 0.4.x: the supported public location
        from jax.experimental import enable_x64 as _enable_x64
        return _enable_x64(enabled)
    except ImportError:  # pragma: no cover - very old JAX
        return jax.enable_x64(enabled)  # type: ignore[attr-defined]


def make_abstract_mesh(axis_sizes: Sequence[int],
                       axis_names: Sequence[str]):
    """Build an ``AbstractMesh`` from old-style (sizes, names) arguments.

    JAX >= 0.4.35 wants ``AbstractMesh((("data", 16), ("model", 16)))``;
    earlier releases wanted ``AbstractMesh((16, 16), ("data", "model"))``.
    """
    from jax.sharding import AbstractMesh

    if len(axis_sizes) != len(axis_names):
        raise ValueError(
            f"axis_sizes {tuple(axis_sizes)} and axis_names "
            f"{tuple(axis_names)} must have equal length")
    shape_tuple = tuple(zip(axis_names, axis_sizes))
    try:
        return AbstractMesh(shape_tuple)
    except TypeError:  # pragma: no cover - pre-0.4.35 signature
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))


def shard_map(f: Callable, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: bool = True,
              axis_names: frozenset | None = None) -> Callable:
    """``jax.shard_map`` across versions, with the new-style arguments.

    ``check_vma`` maps onto 0.4.x's ``check_rep``; ``axis_names`` (the
    set of *manual* mesh axes in the new API) maps onto 0.4.x's ``auto``
    (its complement: the mesh axes left automatic).
    """
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": axis_names}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma, **kw)


@lru_cache(maxsize=None)
def has_batched_tridiagonal_solve(platform: str | None = None) -> bool:
    """Probe: does ``tridiagonal_solve`` have a batched lowering here?

    The line preconditioner in :mod:`repro.crossbar.batched` solves
    ``(T, J)``-batched tridiagonal chains in one call; some backends
    (historically GPU's cusparse ``gtsv2`` path) reject leading batch
    dims or specific dtypes at lowering time.  This executes a tiny
    2-dim-batched solve on ``platform`` (default: the active backend)
    and reports whether it compiles *and* returns finite values, so the
    engine can decide between the line and Jacobi preconditioners
    without call-site version/backend guards.  Cached per platform —
    the probe runs at most once per process.
    """
    import threading

    # The probe is typically triggered at *trace time* (inside the
    # engine's jit).  JAX's ambient trace state is thread-local, so a
    # fresh worker thread is the one reliable way to run an independent
    # eager execution from inside a trace: jnp constants would become
    # tracers in the caller's trace, and ensure_compile_time_eval leaks
    # the eval trace into tridiagonal_solve's scan-based CPU lowering
    # (NotImplementedError: evaluation rule for 'empty').
    out: list[bool] = []
    t = threading.Thread(target=lambda: out.append(_probe_tridiagonal(
        platform)), daemon=True)
    t.start()
    t.join()
    return bool(out and out[0])


@lru_cache(maxsize=None)
def has_pallas_lowering(platform: str | None = None) -> bool:
    """Probe: does ``pallas_call`` lower natively on this backend?

    Executes a trivial Pallas kernel with ``interpret=False`` on
    ``platform`` (default: the active backend) and reports whether it
    compiles and returns the right answer.  TPU (Mosaic) passes; CPU/GPU
    builds without a Triton/Mosaic-GPU lowering raise at compile time
    and report False, routing callers to their fused XLA fallbacks.
    Runs in a worker thread for the same trace-escape reason as
    :func:`has_batched_tridiagonal_solve`; cached per platform.
    """
    import threading

    out: list[bool] = []
    t = threading.Thread(target=lambda: out.append(_probe_pallas(platform)),
                         daemon=True)
    t.start()
    t.join()
    return bool(out and out[0])


def _probe_pallas(platform: str | None) -> bool:
    try:
        import numpy as np
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...] + 1.0

        x = np.zeros((8, 128), np.float32)
        if platform:
            x = jax.device_put(x, jax.devices(platform)[0])
        out = np.asarray(pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((8, 128), np.float32),
            interpret=False)(x))
        return bool(np.all(out == 1.0))
    except Exception:  # no native lowering -> XLA fallback
        return False


def _probe_tridiagonal(platform: str | None) -> bool:
    try:
        import numpy as np

        m = 4
        dl = np.zeros((2, 3, m), np.float32)
        d = np.full((2, 3, m), 2.0, np.float32)
        du = np.zeros((2, 3, m), np.float32)
        b = np.ones((2, 3, m, 1), np.float32)
        args = (dl, d, du, b)
        if platform:  # jit follows input placement
            args = jax.device_put(args, jax.devices(platform)[0])
        out = np.asarray(
            jax.jit(jax.lax.linalg.tridiagonal_solve)(*args))
        return bool(np.all(np.isfinite(out)) and np.allclose(out, 0.5))
    except Exception:  # lowering/runtime rejection -> Jacobi fallback
        return False
