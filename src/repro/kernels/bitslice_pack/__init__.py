from repro.kernels.bitslice_pack.ops import bitslice_pack  # noqa: F401
