"""Pure-jnp oracle for the bit-plane expansion kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitslice import codes_to_bits


def bitslice_pack_ref(codes: jax.Array, n_bits: int,
                      reversed_df: bool = False) -> jax.Array:
    bits = codes_to_bits(jnp.abs(codes.astype(jnp.int32)).astype(jnp.uint32),
                         n_bits)
    return bits[..., ::-1] if reversed_df else bits
