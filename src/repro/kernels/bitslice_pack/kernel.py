"""Bit-plane expansion kernel: quantisation codes -> crossbar bit image.

Turns (I, N) integer codes into the (I, N, K) uint8 bit-plane tensor that
is the physical programming image of a bit-sliced crossbar (optionally
column-mirrored for reversed dataflow).  Used when exporting deployment
images and by the NF benchmarks; on TPU the expansion runs in VMEM so the
K-fold traffic blow-up happens on-chip, not over HBO->host DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(codes_ref, out_ref, *, n_bits: int, reversed_df: bool):
    c = jnp.abs(codes_ref[...].astype(jnp.int32)).astype(jnp.uint32)
    for k in range(n_bits):
        plane = ((c >> (n_bits - 1 - k)) & 1).astype(jnp.uint8)
        slot = (n_bits - 1 - k) if reversed_df else k
        out_ref[..., slot] = plane


def bitslice_pack_pallas(codes: jax.Array, *, n_bits: int, reversed_df: bool,
                         block_i: int, block_n: int, interpret: bool):
    I, N = codes.shape
    grid = (I // block_i, N // block_n)
    kernel = functools.partial(_pack_kernel, n_bits=n_bits,
                               reversed_df=reversed_df)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_i, block_n), lambda i, n: (i, n))],
        out_specs=pl.BlockSpec((block_i, block_n, n_bits),
                               lambda i, n: (i, n, 0)),
        out_shape=jax.ShapeDtypeStruct((I, N, n_bits), jnp.uint8),
        interpret=interpret,
    )(codes)
