"""Jit'd wrapper for the bit-plane expansion kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bitslice_pack.kernel import bitslice_pack_pallas
from repro.kernels.runtime import INTERPRET, round_up


@partial(jax.jit, static_argnames=("n_bits", "reversed_df", "interpret"))
def bitslice_pack(codes: jax.Array, n_bits: int, reversed_df: bool = False,
                  interpret: bool = INTERPRET) -> jax.Array:  # reprolint: disable=RPL004 -- validation wrapper: INTERPRET is False on every backend with a native lowering; hot path uses the fused XLA bit-slice
    """Expand (I, N) integer codes into (I, N, n_bits) uint8 bit planes."""
    I, N = codes.shape
    bi = min(256, round_up(I, 8))
    bn = min(128, round_up(N, 8))
    ip, np_ = round_up(I, bi), round_up(N, bn)
    padded = jnp.pad(codes, ((0, ip - I), (0, np_ - N)))
    out = bitslice_pack_pallas(padded, n_bits=n_bits, reversed_df=reversed_df,
                               block_i=bi, block_n=bn, interpret=interpret)
    return out[:I, :N]
