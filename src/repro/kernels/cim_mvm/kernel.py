"""Fused bit-sliced CIM matmul with parasitic-resistance distortion.

Computes  y = x @ W'  where W' is the PR-distorted effective weight of a
bit-sliced crossbar deployment (paper Eq 17):

    W'[i,n] = sign * scale * [ (1 + eta * p[i,n]) * M0 + eta * M1 ]
    M0      = code[i,n] * 2^-K                  (clean magnitude)
    M1      = sum_k bit_k(code) * 2^-(k+1) * col(n, k)

``p`` is the physical row position after the MDM plan, ``col(n,k)`` the
physical column of bit plane k (mirrored when the dataflow is reversed).

TPU adaptation (vs. the paper's PyTorch flow, which materialises K bit
planes in DRAM): the bit extraction, distortion and matmul are fused in
VMEM — weights travel HBM->VMEM once as int16 codes (2 bytes instead of
K bytes of bit planes + 4 bytes of float weights), the K-step bit loop is
fully unrolled over registers, and the final contraction feeds the MXU
directly at f32 accumulation.

Grid: (M/BM, N/BN, I/BI), accumulation over the last (fastest-varying)
axis so each output block stays resident in VMEM.  Block sizes are
MXU-aligned multiples of 128 (picked by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cim_mvm_kernel(x_ref, codes_ref, pos_ref, scale_ref, out_ref, *,
                    n_bits: int, wpt: int, cols: int, eta: float,
                    reversed_df: bool, block_n: int):
    """One (BM, BN) output block, accumulating one BI slab of the inner dim.

    x_ref:     (BM, BI)  activations
    codes_ref: (BI, BN)  signed quantisation codes (sign * magnitude code)
    pos_ref:   (BI, BN // wpt) physical row positions per column-tile
    scale_ref: (1, 1)    quantisation scale
    out_ref:   (BM, BN)  f32 accumulator
    """
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = codes_ref[...].astype(jnp.int32)
    mag = jnp.abs(c).astype(jnp.uint32)
    sign = jnp.where(c < 0, -1.0, 1.0)

    # Clean magnitude: sum_k b_k 2^-(k+1) == code * 2^-K, exactly.
    m0 = mag.astype(jnp.float32) * (2.0 ** -n_bits)

    # Column-distance moment: unrolled over the K bit planes (registers
    # only — no bit-plane tensor ever exists in memory).
    ni = pl.program_id(1)
    n_global = ni * block_n + jax.lax.broadcasted_iota(jnp.int32, mag.shape, 1)
    slot = n_global % wpt
    m1 = jnp.zeros_like(m0)
    for k in range(n_bits):
        bit = ((mag >> (n_bits - 1 - k)) & 1).astype(jnp.float32)
        col = slot * n_bits + k
        if reversed_df:
            col = (cols - 1) - col
        m1 = m1 + bit * (2.0 ** -(k + 1)) * col.astype(jnp.float32)

    # Physical row position p[i, n] = pos[i, n // wpt].
    p = jnp.repeat(pos_ref[...].astype(jnp.float32), wpt, axis=1)

    scale = scale_ref[0, 0]
    w_eff = sign * scale * ((1.0 + eta * p) * m0 + eta * m1)

    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jax.lax.dot_general(
        x, w_eff, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def cim_mvm_pallas(x: jax.Array, codes: jax.Array, pos: jax.Array,
                   scale: jax.Array, *, n_bits: int, wpt: int, cols: int,
                   eta: float, reversed_df: bool,
                   block_m: int, block_n: int, block_i: int,
                   interpret: bool) -> jax.Array:
    """Raw pallas_call; expects pre-padded block-aligned operands."""
    M, I = x.shape
    _, N = codes.shape
    grid = (M // block_m, N // block_n, I // block_i)

    kernel = functools.partial(
        _cim_mvm_kernel, n_bits=n_bits, wpt=wpt, cols=cols, eta=eta,
        reversed_df=reversed_df, block_n=block_n)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_i), lambda m, n, k: (m, k)),
            pl.BlockSpec((block_i, block_n), lambda m, n, k: (k, n)),
            pl.BlockSpec((block_i, block_n // wpt), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, 1), lambda m, n, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, codes, pos, scale)
