"""Fused XLA fallback for the CIM matmul (production non-TPU path).

Computes the same PR-distorted matmul as the Pallas kernel
(:mod:`repro.kernels.cim_mvm.kernel`) as a single fusible XLA graph:
the int16 signed codes are expanded to the effective weight matrix on
the fly — the K-step bit loop runs unrolled over (I, N) planes, so no
(I, N, K) bit tensor is ever materialised, mirroring the register-level
unroll of the kernel.  XLA fuses the expansion into one elementwise
pipeline feeding the matmul, keeping weight traffic at 2 B/weight
(measured against the paper's materialised-bit-plane flow in
``benchmarks/cim_traffic.py``).

This is the hot path on every backend where ``pallas_call`` has no
native lowering (``repro.compat.has_pallas_lowering``); interpret mode
is strictly a test/validation vehicle and is never dispatched from
serving code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cim_effective_weights(codes: jax.Array, pos: jax.Array,
                          scale: jax.Array, *, n_bits: int, wpt: int,
                          cols: int, eta: float, reversed_df: bool,
                          col_pos: jax.Array | None = None) -> jax.Array:
    """Effective PR-distorted weight matrix from signed codes.

    codes: (I, N) int16 signed quantisation codes (sign * magnitude).
    pos:   (I, N // wpt) int32 physical row positions per column-tile.
    scale: () f32 quantisation scale.
    col_pos: optional (Ti, Tn, cols) int32 per-tile physical bitline of
    each dataflow-layout column (column-permuting mapping pipelines);
    None keeps the fixed layout.
    Returns (I, N) f32 — Eq 17's W' with the same row/column split as
    the Pallas kernel:  W' = sign * scale * [(1 + eta*p) * M0 + eta*M1].
    """
    c = codes.astype(jnp.int32)
    mag = jnp.abs(c).astype(jnp.uint32)
    sign = jnp.where(c < 0, -1.0, 1.0)

    # Clean magnitude: code * 2^-K == sum_k b_k 2^-(k+1), exactly.
    m0 = mag.astype(jnp.float32) * (2.0 ** -n_bits)

    # Column-distance moment, unrolled over the K bit planes.
    N = codes.shape[1]
    slot = jnp.arange(N, dtype=jnp.int32) % wpt
    if col_pos is not None:
        # Tile coordinates of every (input row, output column) pair:
        # the bitline of bit k then resolves per tile through col_pos.
        rows = codes.shape[0] // col_pos.shape[0]
        tii = jnp.arange(codes.shape[0], dtype=jnp.int32) // rows
        tnn = jnp.arange(N, dtype=jnp.int32) // wpt
    m1 = jnp.zeros_like(m0)
    for k in range(n_bits):
        bit = ((mag >> (n_bits - 1 - k)) & 1).astype(jnp.float32)
        col = slot * n_bits + k
        if reversed_df:
            col = (cols - 1) - col
        if col_pos is None:
            colf = col.astype(jnp.float32)                     # (N,)
        else:
            colf = col_pos[tii[:, None], tnn[None, :],
                           col[None, :]].astype(jnp.float32)   # (I, N)
        m1 = m1 + bit * (2.0 ** -(k + 1)) * colf

    # Physical row position p[i, n] = pos[i, n // wpt].
    p = jnp.repeat(pos.astype(jnp.float32), wpt, axis=1)
    return sign * scale * ((1.0 + eta * p) * m0 + eta * m1)


def cim_mvm_xla(x: jax.Array, codes: jax.Array, pos: jax.Array,
                scale: jax.Array, *, n_bits: int, wpt: int, cols: int,
                eta: float, reversed_df: bool,
                gain: jax.Array | None = None,
                col_pos: jax.Array | None = None,
                read_key: jax.Array | None = None,
                sigma_read: float = 0.0) -> jax.Array:
    """y = x @ W' with on-the-fly code expansion; x: (M, I) f32.

    ``gain`` (optional, (I, N) f32 from ``repro.nonideal.inject``)
    multiplies the effective weights cell-wise — programming variation /
    drift folded per weight; it fuses into the same elementwise pipeline
    feeding the matmul, so the weight-traffic story is unchanged.
    ``col_pos`` (optional, (Ti, Tn, cols) int32) applies a per-tile
    bitline permutation to the column-distance moment (X-CHANGR-style
    mapping pipelines).
    ``read_key`` + ``sigma_read`` add fresh per-read weight noise: iid
    per-cell conductance noise of relative std ``sigma_read`` carries a
    per-bit value std of ``sigma_read * 2^-(k+1)``, which sums over the
    K independent bit planes to a per-weight std of
    ``scale * sigma_read * sqrt((1 - 4^-K) / 3)`` — the first-order
    weight-level aggregate (the per-cell reference is the sampled
    ``read`` field of :class:`repro.nonideal.models.CellSample`).  The
    noise term fuses into the same elementwise pipeline as the gain.
    """
    w_eff = cim_effective_weights(codes, pos, scale, n_bits=n_bits,
                                  wpt=wpt, cols=cols, eta=eta,
                                  reversed_df=reversed_df,
                                  col_pos=col_pos)
    if gain is not None:
        w_eff = w_eff * gain
    if read_key is not None and sigma_read > 0.0:
        agg = float(((1.0 - 4.0 ** -n_bits) / 3.0) ** 0.5)
        eps = jax.random.normal(read_key, w_eff.shape, jnp.float32)
        w_eff = w_eff + (sigma_read * agg) * scale * eps
    return jax.lax.dot_general(
        x.astype(jnp.float32), w_eff, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
