from repro.kernels.cim_mvm.ops import cim_mvm  # noqa: F401
