from repro.kernels.cim_mvm.ops import (  # noqa: F401
    CimDeployment,
    cim_mvm,
    deploy,
    resolve_impl,
)
