"""Pure-jnp oracle for the fused CIM matmul kernel.

Builds the effective PR-distorted weight matrix with the (independently
tested) ``repro.core.noise`` path and performs a plain matmul.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitslice import codes_to_bits
from repro.core.mdm import MdmPlan
from repro.core.noise import noisy_magnitude
from repro.core.tiling import CrossbarSpec


def cim_mvm_ref(x: jax.Array, codes_signed: jax.Array, plan: MdmPlan,
                spec: CrossbarSpec, eta: float) -> jax.Array:
    """y = x @ W' from signed codes (I, N) and an MDM plan."""
    mag = jnp.abs(codes_signed).astype(jnp.uint32)
    sign = jnp.where(codes_signed < 0, -1.0, 1.0).astype(jnp.float32)
    bits = codes_to_bits(mag, spec.n_bits)
    w_mag = noisy_magnitude(bits, plan.scale, plan, spec, eta)
    w_eff = sign * w_mag
    return jnp.dot(x.astype(jnp.float32), w_eff,
                   preferred_element_type=jnp.float32)
