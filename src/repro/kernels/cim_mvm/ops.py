"""Public jit'd wrapper for the fused CIM matmul, with backend dispatch.

``deploy()`` turns a dense weight matrix into a :class:`CimDeployment`
(signed quantisation codes + MDM physical-position table) once, at
deployment time; ``cim_mvm()`` then computes the PR-distorted matmul for
any activation batch.  When ``cfg.cim.enabled`` is set, the model zoo
(``repro.models.model``) routes attention/MLP projection matmuls through
``cim_mvm`` using deployments built by ``repro.deploy`` at engine init.

Dispatch (``impl``):

* ``"auto"`` (default) — the Pallas kernel on TPU where ``pallas_call``
  lowers natively (``repro.compat.has_pallas_lowering``; the kernel's
  grid-accumulation pattern assumes TPU's sequential grid, see
  :func:`resolve_impl`), the fused XLA fallback
  (:mod:`repro.kernels.cim_mvm.xla`) everywhere else.  Interpret mode
  is **never** selected automatically: it executes the kernel body
  block-by-block in Python and is orders of magnitude too slow for
  serving.
* ``"pallas"`` / ``"xla"`` — force one production path.
* ``"interpret"`` — the Pallas kernel under ``pallas_call(interpret=
  True)``; test/validation only (bit-faithful BlockSpec checking).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.compat import has_pallas_lowering
from repro.core.bitslice import codes_to_bits, quantize_magnitude
from repro.core.mdm import MdmPlan, plan_from_bits
from repro.core.noise import PAPER_ETA
from repro.core.tiling import CrossbarSpec
from repro.kernels.cim_mvm.kernel import cim_mvm_pallas
from repro.kernels.cim_mvm.xla import cim_mvm_xla
from repro.kernels.runtime import round_up
from repro.mapping import resolve_pipeline

IMPLS = ("auto", "pallas", "xla", "interpret")


@partial(jax.tree_util.register_dataclass,
         data_fields=("codes", "pos", "scale", "gain", "col_pos",
                      "degraded", "noise_tag"),
         meta_fields=("n_bits", "wpt", "cols", "eta", "reversed_df",
                      "in_dim", "out_dim", "sigma_read"))
@dataclasses.dataclass
class CimDeployment:
    """A weight matrix deployed onto bit-sliced crossbars.

    codes: (I_tiles*rows, N_tiles*wpt) int16 signed codes (sign*magnitude).
    pos:   (I_tiles*rows, N_tiles)     int32 physical row positions.
    scale: ()                          f32 quantisation scale.
    gain:  (I_tiles*rows, N_tiles*wpt) f32 per-weight conductance gain,
           or None (the ideal-device default).  Produced by
           ``repro.nonideal.inject`` to fold programming variation /
           drift into the deployment (stuck-at faults fold into the
           codes themselves); consumed by the fused XLA path only.
    col_pos: (I_tiles, N_tiles, cols) int32 physical bitline of each
           dataflow-layout column per tile, or None (identity column
           strategies — the pre-pipeline layout).  Produced by
           column-permuting mapping pipelines (e.g. the X-CHANGR-style
           bitline sort); consumed by the fused XLA path only.
    degraded: () int32 count of programmed active bits landing on OPEN
           (line-open) cells after the spare-line remap, or None (no
           fault injection).  ``degraded > 0`` means spare capacity ran
           out and this deployment's crossbar output is structurally
           wrong — the model layer (``repro.models.model._cim_matmul``)
           demotes such deployments to the digital matmul fallback.
    noise_tag: () int32 per-deployment PRNG tag (unique per deployed
           matrix), or None.  Folded into the caller-supplied read key
           so every deployment draws independent per-read noise from
           one shared key.
    sigma_read: relative per-read conductance noise std (static meta;
           the deployment-time :class:`repro.nonideal.models
           .NonidealModel.sigma_read`).  Applied by the fused XLA path
           only, and only when a read key is supplied to ``cim_mvm``.

    Registered as a pytree with the array fields as data, so stacked
    deployments (one per scanned model layer) thread through ``lax.scan``
    and ``jax.jit`` like any other parameter (a None gain/col_pos is an
    empty subtree and costs nothing).
    """

    codes: jax.Array
    pos: jax.Array
    scale: jax.Array
    n_bits: int
    wpt: int
    cols: int
    eta: float
    reversed_df: bool
    in_dim: int
    out_dim: int
    gain: jax.Array | None = None
    col_pos: jax.Array | None = None
    degraded: jax.Array | None = None
    noise_tag: jax.Array | None = None
    sigma_read: float = 0.0


def deploy(w: jax.Array, spec: CrossbarSpec, mode="mdm",
           eta: float = PAPER_ETA,
           plan: MdmPlan | None = None) -> tuple[CimDeployment, MdmPlan]:
    """Quantise, plan and package a weight matrix.

    ``mode`` is a :class:`repro.mapping.MappingPipeline` or a
    named/legacy string (``repro.mapping.resolve_pipeline``).  Pass
    ``plan`` (e.g. a cache hit or a slice of a fused whole-model plan
    from ``repro.deploy``) to skip the planning pass entirely; the bit
    planes are then never materialised — packaging needs only the int16
    codes and the plan's position tables.
    """
    if w.ndim != 2:
        raise ValueError("deploy expects (in_dim, out_dim)")
    I, N = w.shape
    codes, sign, scale = quantize_magnitude(w, spec.n_bits)
    if plan is None:
        plan = plan_from_bits(codes_to_bits(codes, spec.n_bits), scale,
                              spec, resolve_pipeline(mode))

    ti, tn = spec.grid(I, N)
    rows, wpt = spec.rows, spec.weights_per_tile
    i_pad, n_pad = ti * rows, tn * wpt
    signed = (codes.astype(jnp.int32) * sign.astype(jnp.int32)).astype(jnp.int16)
    signed = jnp.pad(signed, ((0, i_pad - I), (0, n_pad - N)))

    # pos[i, tn] = physical row position of input i in column-tile tn.
    qi = jnp.arange(i_pad) % rows
    tii = jnp.arange(i_pad) // rows
    pos = plan.row_position[tii, :, qi].astype(jnp.int32)      # (i_pad, tn)

    # The physical layout (dataflow direction, bitline permutation)
    # comes from the plan itself, so a supplied plan (cache hit / fused
    # whole-model slice) stays consistent even when ``mode`` disagrees.
    col_pos = (None if plan.col_position is None
               else plan.col_position.astype(jnp.int32))
    return CimDeployment(
        codes=signed, pos=pos, scale=scale, n_bits=spec.n_bits, wpt=wpt,
        cols=spec.cols, eta=float(eta),
        reversed_df=bool(plan.reversed_dataflow), in_dim=I, out_dim=N,
        col_pos=col_pos), plan


def _block_sizes(M: int, I: int, N: int, wpt: int) -> tuple[int, int, int]:
    bm = 128 if M >= 128 else round_up(M, 8)
    bi = 256 if I >= 256 else round_up(I, 8)
    n_unit = math.lcm(wpt, 8)
    bn = 128 if N >= 128 and 128 % n_unit == 0 else round_up(min(N, 128), n_unit)
    return bm, bi, bn


def resolve_impl(impl: str = "auto") -> str:
    """Resolve ``"auto"`` to the production path for the active backend.

    Never returns ``"interpret"`` — interpret mode must be requested
    explicitly (tests/validation only).  The Pallas path is gated on
    the TPU backend *and* the lowering probe: the kernel accumulates
    its output block across sequential grid steps (`out_ref[...] +=`
    with init at ki == 0), which is TPU grid semantics — on a GPU
    build where pallas_call happens to lower, parallel grid cells
    would race on that accumulator, so GPU stays on the fused XLA
    fallback until a revisiting-safe variant exists.
    """
    if impl not in IMPLS:
        raise ValueError(f"impl={impl!r} not in {IMPLS}")
    if impl == "auto":
        if jax.default_backend() == "tpu" and has_pallas_lowering():
            return "pallas"
        return "xla"
    return impl


@partial(jax.jit, static_argnames=("impl", "blocks"))
def cim_mvm(x: jax.Array, dep: CimDeployment,
            read_key: jax.Array | None = None, impl: str = "auto",
            blocks: tuple[int, int, int] | None = None) -> jax.Array:
    """y = x @ W_effective for a CIM-deployed weight matrix.

    x: (..., in_dim); returns (..., out_dim) f32.  ``impl`` picks the
    execution path (see module docstring); the default dispatches to the
    Pallas kernel or the fused XLA fallback, never to interpret mode.
    ``blocks`` tunes the Pallas/interpret grid only — the XLA fallback
    is a single fused program with no block structure to tune, so the
    argument has no effect there.

    ``read_key`` enables per-read conductance noise: when the
    deployment carries ``sigma_read > 0`` and a ``noise_tag``, the tag
    is folded into the key and fresh Gaussian weight noise is drawn for
    *this* read (decode steps pass a fresh key per step).  ``None``
    (the default) is bit-identical to the noiseless path.
    """
    requested = impl
    impl = resolve_impl(impl)
    noisy = (read_key is not None and dep.sigma_read > 0.0
             and dep.noise_tag is not None)
    if (dep.gain is not None or dep.col_pos is not None or noisy) \
            and impl != "xla":
        # Per-weight nonideality gain, per-tile column permutations and
        # per-read noise live in the fused XLA expansion only; the
        # Pallas kernel has none of these operands.  "auto" on TPU
        # legitimately lands here — degrade to the XLA path rather than
        # silently dropping the injected variation / bitline remap /
        # read noise.  An *explicit* pallas/interpret request must not
        # be silently rerouted (a TPU parity check would attribute XLA
        # numbers to the kernel), so surface the conflict instead.
        if requested != "auto":
            what = ("a deployment gain" if dep.gain is not None
                    else "a column-permuted deployment"
                    if dep.col_pos is not None else "per-read noise")
            raise ValueError(
                f"impl={requested!r} cannot apply {what}; "
                "use impl='xla' (or 'auto') for such deployments")
        impl = "xla"
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    M, I = x2.shape
    if I != dep.in_dim:
        raise ValueError(f"x feature dim {I} != deployed in_dim {dep.in_dim}")

    i_pad, n_pad = dep.codes.shape

    if impl == "xla":
        x2 = jnp.pad(x2, ((0, 0), (0, i_pad - I)))
        rk = (jax.random.fold_in(read_key, dep.noise_tag) if noisy
              else None)
        y = cim_mvm_xla(x2, dep.codes, dep.pos, dep.scale,
                        n_bits=dep.n_bits, wpt=dep.wpt, cols=dep.cols,
                        eta=dep.eta, reversed_df=dep.reversed_df,
                        gain=dep.gain, col_pos=dep.col_pos,
                        read_key=rk, sigma_read=dep.sigma_read)
        return y[:, :dep.out_dim].reshape(*batch_shape, dep.out_dim)

    bm, bi, bn = blocks or _block_sizes(M, i_pad, n_pad, dep.wpt)
    mp, ip, np_ = round_up(M, bm), round_up(i_pad, bi), round_up(n_pad, bn)
    x2 = jnp.pad(x2, ((0, mp - M), (0, ip - I)))
    codes = jnp.pad(dep.codes, ((0, ip - i_pad), (0, np_ - n_pad)))
    pos = jnp.pad(dep.pos, ((0, ip - i_pad), (0, (np_ - n_pad) // dep.wpt)))

    y = cim_mvm_pallas(
        x2, codes, pos, dep.scale.reshape(1, 1),
        n_bits=dep.n_bits, wpt=dep.wpt, cols=dep.cols, eta=dep.eta,
        reversed_df=dep.reversed_df, block_m=bm, block_n=bn, block_i=bi,
        interpret=impl == "interpret")
    return y[:M, :dep.out_dim].reshape(*batch_shape, dep.out_dim)
