"""Public jit'd wrapper for the fused CIM matmul kernel.

``deploy()`` turns a dense weight matrix into a :class:`CimDeployment`
(signed quantisation codes + MDM physical-position table) once, at
deployment time; ``cim_mvm()`` then computes the PR-distorted matmul for
any activation batch.  This is the layer the model zoo's ``cim.enabled``
mode routes matmuls through.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bitslice import quantize_magnitude
from repro.core.mdm import MdmPlan, plan_from_bits
from repro.core.bitslice import codes_to_bits
from repro.core.noise import PAPER_ETA
from repro.core.tiling import CrossbarSpec
from repro.kernels.cim_mvm.kernel import cim_mvm_pallas
from repro.kernels.runtime import INTERPRET, round_up


@partial(jax.tree_util.register_dataclass,
         data_fields=("codes", "pos", "scale"),
         meta_fields=("n_bits", "wpt", "cols", "eta", "reversed_df",
                      "in_dim", "out_dim"))
@dataclasses.dataclass
class CimDeployment:
    """A weight matrix deployed onto bit-sliced crossbars.

    codes: (I_tiles*rows, N_tiles*wpt) int16 signed codes (sign*magnitude).
    pos:   (I_tiles*rows, N_tiles)     int32 physical row positions.
    scale: ()                          f32 quantisation scale.
    """

    codes: jax.Array
    pos: jax.Array
    scale: jax.Array
    n_bits: int
    wpt: int
    cols: int
    eta: float
    reversed_df: bool
    in_dim: int
    out_dim: int


def deploy(w: jax.Array, spec: CrossbarSpec, mode: str = "mdm",
           eta: float = PAPER_ETA) -> tuple[CimDeployment, MdmPlan]:
    """Quantise, plan (MDM or ablation) and package a weight matrix."""
    if w.ndim != 2:
        raise ValueError("deploy expects (in_dim, out_dim)")
    I, N = w.shape
    codes, sign, scale = quantize_magnitude(w, spec.n_bits)
    bits = codes_to_bits(codes, spec.n_bits)
    plan = plan_from_bits(bits, scale, spec, mode)

    ti, tn = spec.grid(I, N)
    rows, wpt = spec.rows, spec.weights_per_tile
    i_pad, n_pad = ti * rows, tn * wpt
    signed = (codes.astype(jnp.int32) * sign.astype(jnp.int32)).astype(jnp.int16)
    signed = jnp.pad(signed, ((0, i_pad - I), (0, n_pad - N)))

    # pos[i, tn] = physical row position of input i in column-tile tn.
    qi = jnp.arange(i_pad) % rows
    tii = jnp.arange(i_pad) // rows
    pos = plan.row_position[tii, :, qi].astype(jnp.int32)      # (i_pad, tn)

    return CimDeployment(
        codes=signed, pos=pos, scale=scale, n_bits=spec.n_bits, wpt=wpt,
        cols=spec.cols, eta=float(eta),
        reversed_df=mode in ("reverse", "mdm"), in_dim=I, out_dim=N), plan


def _block_sizes(M: int, I: int, N: int, wpt: int) -> tuple[int, int, int]:
    bm = 128 if M >= 128 else round_up(M, 8)
    bi = 256 if I >= 256 else round_up(I, 8)
    n_unit = math.lcm(wpt, 8)
    bn = 128 if N >= 128 and 128 % n_unit == 0 else round_up(min(N, 128), n_unit)
    return bm, bi, bn


@partial(jax.jit, static_argnames=("interpret", "blocks"))
def cim_mvm(x: jax.Array, dep: CimDeployment,
            interpret: bool = INTERPRET,
            blocks: tuple[int, int, int] | None = None) -> jax.Array:
    """y = x @ W_effective for a CIM-deployed weight matrix.

    x: (..., in_dim); returns (..., out_dim) f32.
    """
    batch_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    M, I = x2.shape
    if I != dep.in_dim:
        raise ValueError(f"x feature dim {I} != deployed in_dim {dep.in_dim}")

    i_pad, n_pad = dep.codes.shape
    bm, bi, bn = blocks or _block_sizes(M, i_pad, n_pad, dep.wpt)

    mp, ip, np_ = round_up(M, bm), round_up(i_pad, bi), round_up(n_pad, bn)
    x2 = jnp.pad(x2, ((0, mp - M), (0, ip - I)))
    codes = jnp.pad(dep.codes, ((0, ip - i_pad), (0, np_ - n_pad)))
    pos = jnp.pad(dep.pos, ((0, ip - i_pad), (0, (np_ - n_pad) // dep.wpt)))

    y = cim_mvm_pallas(
        x2, codes, pos, dep.scale.reshape(1, 1),
        n_bits=dep.n_bits, wpt=dep.wpt, cols=dep.cols, eta=dep.eta,
        reversed_df=dep.reversed_df, block_m=bm, block_n=bn, block_i=bi,
        interpret=interpret)
    return y[:M, :dep.out_dim].reshape(*batch_shape, dep.out_dim)
