"""Per-tile Manhattan row scores + NF — the MDM planning reduction.

For a batch of tile activity masks (T, R, C) this computes, in one pass
over the (bandwidth-bound) mask data:

    scores[t, j] = sum_k m[t,j,k] * (1 + k)      (paper step-2 row score)
    counts[t, j] = sum_k m[t,j,k]                (row density, sort key)
    nf[t]        = unit * sum_{j,k} m[t,j,k] * (j + k)   (Eq 16)

On TPU the masks stream HBM->VMEM once; all three reductions reuse the
same VMEM-resident block (arithmetic intensity too low to ever be
compute-bound, so the win is purely the single pass + no intermediate
HBM traffic for the distance-weighted products).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(mask_ref, scores_ref, counts_ref, nf_ref, *, nf_unit: float):
    m = mask_ref[...].astype(jnp.float32)          # (BT, R, C)
    _, R, C = m.shape
    col = jax.lax.broadcasted_iota(jnp.float32, m.shape, 2)
    row = jax.lax.broadcasted_iota(jnp.float32, m.shape, 1)
    scores_ref[...] = jnp.sum(m * (1.0 + col), axis=2)
    counts_ref[...] = jnp.sum(m, axis=2)
    nf_ref[...] = nf_unit * jnp.sum(m * (row + col), axis=(1, 2), keepdims=True)[..., 0]


def manhattan_score_pallas(masks: jax.Array, *, nf_unit: float,
                           block_t: int, interpret: bool):
    T, R, C = masks.shape
    grid = (T // block_t,)
    kernel = functools.partial(_score_kernel, nf_unit=nf_unit)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, R, C), lambda t: (t, 0, 0))],
        out_specs=(
            pl.BlockSpec((block_t, R), lambda t: (t, 0)),
            pl.BlockSpec((block_t, R), lambda t: (t, 0)),
            pl.BlockSpec((block_t, 1), lambda t: (t, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((T, R), jnp.float32),
            jax.ShapeDtypeStruct((T, R), jnp.float32),
            jax.ShapeDtypeStruct((T, 1), jnp.float32),
        ),
        interpret=interpret,
    )(masks)
