from repro.kernels.manhattan_score.ops import manhattan_score  # noqa: F401
