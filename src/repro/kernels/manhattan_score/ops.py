"""Jit'd wrapper for the Manhattan score/NF reduction kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.manhattan_score.kernel import manhattan_score_pallas
from repro.kernels.runtime import INTERPRET, round_up


@partial(jax.jit, static_argnames=("nf_unit", "block_t", "interpret"))
def manhattan_score(masks: jax.Array, nf_unit: float = 1.0,
                    block_t: int = 8, interpret: bool = INTERPRET):  # reprolint: disable=RPL004 -- validation wrapper: INTERPRET is False on every backend with a native lowering; planning uses the fused XLA scorer
    """Row scores, row counts and per-tile NF for tile masks.

    masks: (..., R, C) activity masks (any integer/float 0-1 dtype).
    Returns (scores (..., R), counts (..., R), nf (...)).
    """
    batch = masks.shape[:-2]
    R, C = masks.shape[-2:]
    flat = masks.reshape(-1, R, C)
    T = flat.shape[0]
    bt = min(block_t, T) if T else 1
    tp = round_up(max(T, 1), bt)
    flat = jnp.pad(flat, ((0, tp - T), (0, 0), (0, 0)))
    scores, counts, nf = manhattan_score_pallas(
        flat, nf_unit=nf_unit, block_t=bt, interpret=interpret)
    return (scores[:T].reshape(*batch, R), counts[:T].reshape(*batch, R),
            nf[:T, 0].reshape(batch))
