"""Pure-jnp oracle for the Manhattan score/NF reduction kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import manhattan


def manhattan_score_ref(masks: jax.Array, nf_unit: float):
    """masks: (T, R, C). Returns (scores (T,R), counts (T,R), nf (T,))."""
    scores = manhattan.row_scores(masks)
    counts = manhattan.row_counts(masks)
    nf = nf_unit * manhattan.aggregate_distance(masks)
    return scores, counts, nf
