"""Kernel runtime switches."""
from __future__ import annotations

import jax

# pallas_call(interpret=True) on non-TPU backends: the kernel body runs
# block-by-block in the Python interpreter, giving bit-faithful validation
# of the BlockSpec tiling logic without TPU hardware.
INTERPRET: bool = jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
