from repro.kernels.slstm_scan.ops import slstm_scan  # noqa: F401
