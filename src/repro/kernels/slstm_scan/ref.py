"""Pure-jnp oracle: the sequential sLSTM scan (matches
repro.models.recurrent.slstm_mixer's recurrence exactly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slstm_scan_ref(gx: jax.Array, r_gates: jax.Array, h0: jax.Array,
                   c0: jax.Array):
    """gx: (B, T, H, 4Dh) f32; returns (hs (B,T,H,Dh) f32, hT, cT)."""

    def body(carry, g_t):
        h, c = carry
        pre = g_t.astype(jnp.float32) + jnp.einsum(
            "bhd,hdg->bhg", h, r_gates.astype(jnp.float32))
        i, f, z, o = jnp.split(pre, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(z)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    (hT, cT), hs = jax.lax.scan(body, (h0.astype(jnp.float32),
                                       c0.astype(jnp.float32)),
                                gx.swapaxes(0, 1))
    return hs.swapaxes(0, 1), hT, cT
