"""Pallas TPU sLSTM scan (beyond-paper §Perf kernel for xlstm-1.3b).

The sLSTM recurrence is strictly sequential (hidden-to-gate feedback),
so XLA lowers it to a 4096-iteration while loop whose body re-reads the
(H, Dh, 4Dh) recurrent weights from HBM and — when TP-sharded — issues a
tiny all-reduce *every timestep* (98k collectives per train step; the
dominant collective site of the xlstm train cell, and pure latency
poison on real ICI).

This kernel pins the recurrent weights and the (h, c) state in VMEM for
an entire time *chunk* (weights stream HBM->VMEM once per chunk instead
of once per step: a chunk=128 sweep cuts recurrent-weight traffic 128x),
and runs the recurrence replicated per shard — no per-step collectives.

Grid: (B/BB, T/chunk); T is the fastest-varying axis so the state
scratch persists across the whole sequence sweep of one batch block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slstm_kernel(gx_ref, r_ref, h0_ref, c0_ref, hs_ref, hT_ref, cT_ref,
                  h_s, c_s, *, chunk: int, n_t: int, t_valid: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)
        c_s[...] = c0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)                  # (H, Dh, 4Dh)

    def step(t, carry):
        h, c = carry
        g_t = gx_ref[:, t].astype(jnp.float32)          # (BB, H, 4Dh)
        pre = g_t + jax.lax.dot_general(
            h, r, (((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32).transpose(1, 0, 2)
        i, f, z, o = jnp.split(pre, 4, axis=-1)
        c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(z)
        h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
        # freeze the state on padded timesteps (T padded to the chunk)
        live = (ti * chunk + t) < t_valid
        c = jnp.where(live, c_new, c)
        h = jnp.where(live, h_new, h)
        hs_ref[:, t] = h_new.astype(hs_ref.dtype)
        return h, c

    h, c = jax.lax.fori_loop(0, chunk, step,
                             (h_s[...], c_s[...]), unroll=False)
    h_s[...] = h
    c_s[...] = c

    @pl.when(ti == n_t - 1)
    def _finish():
        hT_ref[...] = h.astype(hT_ref.dtype)
        cT_ref[...] = c.astype(cT_ref.dtype)


def slstm_scan_pallas(gx: jax.Array, r_gates: jax.Array, h0: jax.Array,
                      c0: jax.Array, *, block_b: int, chunk: int,
                      t_valid: int, interpret: bool):
    """gx: (B, T, H, 4Dh); r_gates: (H, Dh, 4Dh); h0/c0: (B, H, Dh).
    Returns (hs (B, T, H, Dh) f32, hT, cT)."""
    B, T, H, Dh4 = gx.shape
    Dh = Dh4 // 4
    grid = (B // block_b, T // chunk)
    kernel = functools.partial(_slstm_kernel, chunk=chunk,
                               n_t=T // chunk, t_valid=t_valid)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, chunk, H, Dh4),
                         lambda bi, ti: (bi, ti, 0, 0)),
            pl.BlockSpec((H, Dh, Dh4), lambda bi, ti: (0, 0, 0)),
            pl.BlockSpec((block_b, H, Dh), lambda bi, ti: (bi, 0, 0)),
            pl.BlockSpec((block_b, H, Dh), lambda bi, ti: (bi, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_b, chunk, H, Dh),
                         lambda bi, ti: (bi, ti, 0, 0)),
            pl.BlockSpec((block_b, H, Dh), lambda bi, ti: (bi, 0, 0)),
            pl.BlockSpec((block_b, H, Dh), lambda bi, ti: (bi, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, T, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Dh), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((block_b, H, Dh), jnp.float32),
            pltpu.VMEM((block_b, H, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(gx, r_gates, h0, c0)
