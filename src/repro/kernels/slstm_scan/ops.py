"""Jit'd wrapper for the Pallas sLSTM scan kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.runtime import INTERPRET, round_up
from repro.kernels.slstm_scan.kernel import slstm_scan_pallas


@partial(jax.jit, static_argnames=("block_b", "chunk", "interpret"))
def slstm_scan(gx: jax.Array, r_gates: jax.Array, h0: jax.Array,
               c0: jax.Array, block_b: int = 8, chunk: int = 128,
               interpret: bool = INTERPRET):  # reprolint: disable=RPL004 -- validation wrapper: INTERPRET is False on every backend with a native lowering; recurrent serving stays on the XLA scan
    """gx: (B, T, H, 4Dh); returns (hs (B,T,H,Dh) f32, hT, cT)."""
    B, T, H, Dh4 = gx.shape
    bb = min(block_b, B)
    ch = min(chunk, T)
    bp, tp = round_up(B, bb), round_up(T, ch)
    gx_p = jnp.pad(gx, ((0, bp - B), (0, tp - T), (0, 0), (0, 0)))
    pad_b = ((0, bp - B), (0, 0), (0, 0))
    h0_p, c0_p = jnp.pad(h0, pad_b), jnp.pad(c0, pad_b)
    hs, hT, cT = slstm_scan_pallas(gx_p, r_gates, h0_p, c0_p,
                                   block_b=bb, chunk=ch, t_valid=T,
                                   interpret=interpret)
    return hs[:B, :T], hT[:B], cT[:B]
