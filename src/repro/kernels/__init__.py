"""Pallas TPU kernels for the MDM/CIM hot paths.

Each kernel lives in its own subpackage with the canonical layout:

    kernels/<name>/kernel.py   pl.pallas_call + explicit BlockSpec tiling
    kernels/<name>/ops.py      jit'd public wrapper (padding, dtype mgmt)
    kernels/<name>/ref.py      pure-jnp oracle used by the allclose tests

Kernels target TPU (VMEM tiling, MXU-aligned blocks); on this CPU
container they are validated via ``interpret=True``, which executes the
kernel body per-block in Python.  ``repro.kernels.runtime.INTERPRET``
flips automatically based on the backend.
"""
from repro.kernels.runtime import INTERPRET  # noqa: F401
