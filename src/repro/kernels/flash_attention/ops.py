"""Jit'd wrapper for the Pallas flash-attention kernel.

Drop-in for ``repro.models.attention.flash_attention`` on TPU: same
(B, S, H, Dh) interfaces and position-based masking.  The wrapper
flattens (B, H) onto the grid axis, pads sequence dims to block
multiples (padded keys get EMPTY_POS and self-mask), and restores
layout.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.runtime import INTERPRET, round_up

# np, not jnp: module-level jnp would compute at import time (RPL005).
EMPTY_POS = np.int32(2 ** 30)


@partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                   "interpret"))
def flash_attention_tpu(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        q_positions: jax.Array, k_positions: jax.Array,
                        window: int = 0, block_q: int = 128,
                        block_k: int = 128,
                        interpret: bool = INTERPRET) -> jax.Array:  # reprolint: disable=RPL004 -- validation wrapper: INTERPRET is False on every backend with a native lowering; production serving dispatches via cim_mvm
    """q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh) -> (B, Sq, H, Dh)."""
    B, Sq, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv

    bq = min(block_q, round_up(Sq, 8))
    bk = min(block_k, round_up(Skv, 8))
    sq_p, sk_p = round_up(Sq, bq), round_up(Skv, bk)

    qp = jnp.pad(q_positions.astype(jnp.int32), (0, sq_p - Sq))
    kp = jnp.pad(k_positions.astype(jnp.int32), (0, sk_p - Skv),
                 constant_values=EMPTY_POS)
    qt = jnp.pad(q, ((0, 0), (0, sq_p - Sq), (0, 0), (0, 0)))
    kt = jnp.pad(k, ((0, 0), (0, sk_p - Skv), (0, 0), (0, 0)))
    vt = jnp.pad(v, ((0, 0), (0, sk_p - Skv), (0, 0), (0, 0)))

    # (B, S, H, Dh) -> (B*H, S, Dh); kv -> (B*Hkv, S, Dh)
    qt = qt.transpose(0, 2, 1, 3).reshape(B * H, sq_p, Dh)
    kt = kt.transpose(0, 2, 1, 3).reshape(B * Hkv, sk_p, Dh)
    vt = vt.transpose(0, 2, 1, 3).reshape(B * Hkv, sk_p, Dh)

    out = flash_attention_pallas(
        qt, kt, vt, qp, kp, scale=Dh ** -0.5, window=window, group=G,
        block_q=bq, block_k=bk, interpret=interpret)
    out = out.reshape(B, H, sq_p, Dh).transpose(0, 2, 1, 3)
    return out[:, :Sq]
