"""Pure-jnp oracle: exact softmax attention with position masking."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, q_positions, k_positions, *, window: int = 0):
    """q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh). Exact (materialised)
    causal attention with absolute-position masking."""
    B, Sq, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    head_map = jnp.arange(H) // G
    kk = jnp.take(k, head_map, axis=2).astype(jnp.float32)
    vv = jnp.take(v, head_map, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk)
    s = s * Dh ** -0.5
    valid = k_positions[None, :] <= q_positions[:, None]
    if window:
        valid &= (q_positions[:, None] - k_positions[None, :]) < window
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    return out.astype(q.dtype)
