"""Pallas TPU flash attention (beyond-paper §Perf kernel).

The pure-JAX chunked flash attention keeps O(seq) memory but still
round-trips its (BQ, BK) score tiles and online-softmax carries through
HBM on every chunk — the dominant memory-term contributor of the dense
train cells.  This kernel keeps everything tile-resident in VMEM:

  * grid (B*H, Sq/BQ, Skv/BK); the KV axis is the fastest-varying grid
    dim, so the (m, l, acc) scratch accumulators persist in VMEM across
    a full KV sweep — HBM sees only q/k/v reads and one output write.
  * GQA without materialisation: the k/v BlockSpec index maps divide the
    head index by the group size, so each KV head's tile is fetched for
    its G query heads directly from the (B*Hkv, S, Dh) layout.
  * positions-based masking (causal + sliding window + ring-buffer
    validity) identical to the pure-JAX path.

MXU alignment: BQ/BK multiples of 128, Dh is the lane dim.  Validated
in interpret mode against the pure-jnp oracle (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_s, l_s, acc_s, *, scale: float, window: int,
                  n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)                     # (BQ, Dh)
    k = k_ref[0].astype(jnp.float32)                     # (BK, Dh)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qp = qpos_ref[...]                                   # (BQ,)
    kp = kpos_ref[...]                                   # (BK,)
    valid = kp[None, :] <= qp[:, None]
    if window:
        valid &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_s[...], l_s[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.maximum(m_new, NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None])
    corr = jnp.exp(jnp.minimum(m_prev - m_safe, 0.0))
    m_s[...] = m_new
    l_s[...] = l_prev * corr + jnp.sum(p, axis=-1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_s[...]
                    / jnp.maximum(l_s[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           q_positions: jax.Array, k_positions: jax.Array,
                           *, scale: float, window: int, group: int,
                           block_q: int, block_k: int,
                           interpret: bool) -> jax.Array:
    """q: (BH, Sq, Dh); k, v: (BHkv, Skv, Dh). Pre-padded to blocks."""
    BH, Sq, Dh = q.shape
    Skv = k.shape[1]
    n_q, n_k = Sq // block_q, Skv // block_k
    grid = (BH, n_q, n_k)

    kernel = functools.partial(_flash_kernel, scale=scale, window=window,
                               n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda bh, qi, ki: (qi,)),
            pl.BlockSpec((block_k,), lambda bh, qi, ki: (ki,)),
            pl.BlockSpec((1, block_q, Dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, Dh),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, Dh),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dh),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, k_positions, q, k, v)
