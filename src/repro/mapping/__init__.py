"""Composable mapping-strategy API for crossbar weight deployment.

The paper's MDM is one point in a family of spatial mapping strategies
(X-CHANGR bitline remapping, arXiv:1907.00285; partition/orientation
studies, arXiv:1912.08716).  This package replaces the planner's
hard-coded ``mode: str`` + ``fault_maps`` side-channel with a
:class:`MappingPipeline` of registered passes:

=============  ==========================================================
pass           strategies
=============  ==========================================================
dataflow       ``"conventional"`` | ``"reversed"`` (low-order-side
               feeding, paper MDM step 1)
rows           ``identity`` | ``mdm`` | ``fault_aware`` |
               ``significance_weighted`` | ``spare_line``
               (:mod:`repro.mapping.rows`)
cols           ``identity`` | ``xchangr`` | ``spare_line``
               (:mod:`repro.mapping.columns`)
partition      ``dense`` | ``expert`` ((E, I, N) MoE banks,
               :mod:`repro.mapping.partition`)
=============  ==========================================================

**Pass contract** (enforced conventions, see :mod:`repro.mapping.base`
for per-kind signatures):

1. *Pure*: a pass is a frozen dataclass whose output depends only on
   its inputs — no RNG, no hidden state — so plans are reproducible
   and pipelines are valid jit static arguments.
2. *Fingerprinted*: every pass has a stable registry name + param
   fingerprint; :meth:`MappingPipeline.cache_token` composes them into
   ``repro.deploy.cache`` plan keys, so strategy changes invalidate
   cached plans by construction (and *only* strategy changes do).
3. *Composition order is fixed*: dataflow orientation -> column order
   -> row order -> NF bookkeeping.  Column and row placement are
   independent terms of the Manhattan objective, but fault-aware row
   passes consume per-physical-column significance, which the column
   pass determines — hence columns settle first.  Partitioning runs
   host-side before any of this (tensor -> named 2-D matrices).

**Adding a strategy from a new paper** is one file: subclass the kind's
base, decorate with ``@register(kind, name)``, and every consumer —
``plan_tile_population``, the fused ``plan_matrices`` planner,
``deploy_model_params``, ``ServeEngine(pipeline=...)`` and the
benchmark sweeps — can select it by name, with correct cache keys, no
further threading.

Legacy ``mode`` strings ("baseline"/"reverse"/"sort"/"mdm") remain as
a deprecation shim via :func:`resolve_pipeline`: they resolve to the
canonical pipelines and produce bit-identical plans and identical
plan-cache keys (tests/test_mapping.py pins both).
"""
from repro.mapping.base import (  # noqa: F401
    KINDS,
    Strategy,
    available,
    get_strategy,
    register,
)
from repro.mapping.columns import (  # noqa: F401
    IdentityCols,
    SpareLineCols,
    XChangrCols,
)
from repro.mapping.partition import (  # noqa: F401
    DensePartition,
    ExpertPartition,
)
from repro.mapping.pipeline import (  # noqa: F401
    LEGACY_MODES,
    MappingPipeline,
    named_pipelines,
    register_pipeline,
    resolve_pipeline,
)
from repro.mapping.rows import (  # noqa: F401
    FaultAwareRows,
    IdentityRows,
    MdmRows,
    SignificanceWeightedRows,
    SpareLineRows,
)

__all__ = [
    "KINDS", "Strategy", "available", "get_strategy", "register",
    "IdentityCols", "SpareLineCols", "XChangrCols",
    "DensePartition", "ExpertPartition",
    "LEGACY_MODES", "MappingPipeline", "named_pipelines",
    "register_pipeline", "resolve_pipeline",
    "FaultAwareRows", "IdentityRows", "MdmRows",
    "SignificanceWeightedRows", "SpareLineRows",
]
