"""Row-order strategies: which logical row lands on which physical row.

All passes delegate to :mod:`repro.core.manhattan` primitives and are
vmapped over the tile population exactly the way the pre-pipeline
planner did, so the canonical pipelines reproduce the legacy
``mode``-string plans bit for bit (pinned in tests/test_mapping.py).
"""
from __future__ import annotations

import dataclasses

import jax

from repro.mapping.base import Strategy, register


def _manhattan():
    # Deferred: repro.core.mdm imports repro.mapping at module level, so
    # a top-level repro.core import here would be circular.
    from repro.core import manhattan
    return manhattan


@register("rows", "identity")
@dataclasses.dataclass(frozen=True)
class IdentityRows(Strategy):
    """Keep the original row order (the paper's baseline/reverse)."""

    uses_faults = False
    uses_col_significance = False

    def order_tiles(self, placed, stuck, col_sig, spec):
        return None


@register("rows", "mdm")
@dataclasses.dataclass(frozen=True)
class MdmRows(Strategy):
    """Paper step 3: densest rows to the positions nearest the rails."""

    uses_faults = False
    uses_col_significance = False

    def order_tiles(self, placed, stuck, col_sig, spec):
        return jax.vmap(_manhattan().optimal_row_order)(placed)


@register("rows", "fault_aware")
@dataclasses.dataclass(frozen=True)
class FaultAwareRows(Strategy):
    """MDM plus stuck-cell steering (uniform per-cell fault currency).

    With no fault maps supplied this reduces exactly to :class:`MdmRows`
    (and shares its cache keys), mirroring the legacy behaviour of
    ``mode="mdm"`` without ``fault_maps``.
    """

    uses_faults = True
    uses_col_significance = False

    def order_tiles(self, placed, stuck, col_sig, spec):
        if stuck is None:
            return jax.vmap(_manhattan().optimal_row_order)(placed)
        return jax.vmap(_manhattan().fault_aware_row_order,
                        in_axes=(0, 0, None))(placed, stuck, spec.nf_unit)


@register("rows", "spare_line")
@dataclasses.dataclass(frozen=True)
class SpareLineRows(Strategy):
    """Fault-aware MDM with a line-open surcharge (spare-row remap).

    Identical objective to :class:`FaultAwareRows` except that cells on
    OPEN lines (line-open faults, ``repro.nonideal.models``) carry an
    extra ``open_penalty`` surcharge on top of their stuck-OFF-like
    unit cost.  A fully-open wordline then outranks every healthy
    position's penalty, so the assignment shunts it the sparsest
    logical row — when the tile has spare capacity (all-zero rows from
    ``pad_to_tiles`` padding or weight sparsity), the dead wordline
    hosts a spare and the remap costs nothing.  Composes with
    :class:`repro.mapping.columns.SpareLineCols` as the ``spare_line``
    named pipeline.  Reduces exactly to :class:`MdmRows` with no fault
    map.
    """

    open_penalty: float = 4.0

    uses_faults = True
    uses_col_significance = False

    def order_tiles(self, placed, stuck, col_sig, spec):
        if stuck is None:
            return jax.vmap(_manhattan().optimal_row_order)(placed)
        return jax.vmap(
            lambda a, s: _manhattan().fault_aware_row_order(
                a, s, spec.nf_unit, open_penalty=self.open_penalty)
        )(placed, stuck)


@register("rows", "significance_weighted")
@dataclasses.dataclass(frozen=True)
class SignificanceWeightedRows(Strategy):
    """Fault steering weighted by bit significance 2^-(k+1).

    A stuck column hosting a high-order bit plane destroys far more
    *accuracy* than one hosting the LSB plane, even though both cost
    one NF unit; weighting the per-position fault penalty by the hosted
    plane's shift-add significance buys weighted-error reduction at
    equal NF (ROADMAP follow-up; measured in
    ``benchmarks/fault_tolerance.py``).  Reduces exactly to
    :class:`MdmRows` with no faults.
    """

    uses_faults = True
    uses_col_significance = True

    def order_tiles(self, placed, stuck, col_sig, spec):
        if stuck is None:
            return jax.vmap(_manhattan().optimal_row_order)(placed)
        return jax.vmap(_manhattan().fault_aware_row_order,
                        in_axes=(0, 0, None, 0))(placed, stuck,
                                                 spec.nf_unit, col_sig)
