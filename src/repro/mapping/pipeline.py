"""MappingPipeline: composed, fingerprinted weight-mapping strategy.

A pipeline is (dataflow orientation, row order, column order, tile
partition) — the full spatial mapping of a weight matrix onto crossbar
tiles.  Passes compose in a fixed order (see the package docstring);
the pipeline is a frozen dataclass, so it rides jit static arguments
and hashes into plan-cache keys.

**Legacy ``mode`` strings.**  The pre-pipeline planner took a
``mode: str`` in {"baseline", "reverse", "sort", "mdm"} plus an ad-hoc
``fault_maps`` side-channel.  :func:`resolve_pipeline` keeps those
strings working as a thin deprecation shim: each resolves to the
canonical pipeline below, and :meth:`MappingPipeline.cache_token`
returns the *original mode string* for exactly those canonical
combinations — so shim-resolved plans produce bit-identical
``PlanCache`` keys and existing caches stay warm (pinned in
tests/test_mapping.py).  New strategy combinations get a
``"pipe:..."`` token derived from the pass fingerprints.
"""
from __future__ import annotations

import dataclasses

from repro.mapping.base import Strategy, available, get_strategy
from repro.mapping.columns import IdentityCols, SpareLineCols, XChangrCols
from repro.mapping.partition import DensePartition, ExpertPartition
from repro.mapping.rows import (
    FaultAwareRows,
    IdentityRows,
    MdmRows,
    SignificanceWeightedRows,
    SpareLineRows,
)

DATAFLOWS = ("conventional", "reversed")


@dataclasses.dataclass(frozen=True)
class MappingPipeline:
    """Composable mapping strategy (dataflow, rows, cols, partition)."""

    dataflow: str = "reversed"
    rows: Strategy = MdmRows()
    cols: Strategy = IdentityCols()
    partition: Strategy = DensePartition()

    def __post_init__(self):
        if self.dataflow not in DATAFLOWS:
            raise ValueError(
                f"dataflow={self.dataflow!r} not in {DATAFLOWS}")

    @property
    def reversed_dataflow(self) -> bool:
        return self.dataflow == "reversed"

    def fingerprint(self) -> str:
        """Full stable identity of the pipeline (includes partition)."""
        return (f"df={self.dataflow};row={self.rows.fingerprint()};"
                f"col={self.cols.fingerprint()};"
                f"part={self.partition.fingerprint()}")

    def cache_token(self) -> str:
        """The string that enters per-matrix plan-cache keys.

        Canonical legacy combinations return the historical mode string
        so pre-redesign cache entries stay reachable.  ``fault_aware``
        rows intentionally share the ``"mdm"``/``"sort"`` token: the
        legacy key distinguished fault-aware planning purely by the
        fault-map fingerprint (see :func:`repro.deploy.cache.plan_key`),
        and :class:`FaultAwareRows` reduces exactly to :class:`MdmRows`
        when no maps are supplied.  The partition pass never enters the
        token — produced matrices are content-addressed individually.

        The collapse tests *exact equality* with the canonical
        default-constructed strategies, not ``isinstance``: a subclass
        (or a future parametrised variant) that carries behavioral
        fields must fall through to the ``pipe:...`` token that
        includes its fingerprint, or the :class:`PlanCache` would
        silently serve the unparametrised plan for it.  The semantic
        auditor (``repro.analysis.audit``) perturbs every registered
        strategy field and asserts the key moves; the mutation test in
        tests/test_analysis_audit.py pins this exact bug class.
        """
        if self.cols == IdentityCols():
            if self.rows == IdentityRows():
                return "reverse" if self.reversed_dataflow else "baseline"
            if self.rows == MdmRows() or self.rows == FaultAwareRows():
                return "mdm" if self.reversed_dataflow else "sort"
        return (f"pipe:df={self.dataflow};row={self.rows.fingerprint()};"
                f"col={self.cols.fingerprint()}")

    def spec(self) -> str:
        """Config-friendly spec string; inverse of :func:`from_spec`."""
        return (f"df={self.dataflow},row={self.rows.name},"
                f"col={self.cols.name},part={self.partition.name}")

    @staticmethod
    def from_spec(spec: str) -> "MappingPipeline":
        """Parse ``"df=reversed,row=mdm,col=xchangr,part=dense"``.

        Every field is optional and defaults to the canonical MDM
        pipeline's value; unknown keys or strategy names raise.
        """
        kw: dict = {}
        for item in spec.split(","):
            item = item.strip()
            if not item:
                continue
            if "=" not in item:
                raise ValueError(f"bad pipeline spec item {item!r} "
                                 f"in {spec!r} (want key=value)")
            k, v = (s.strip() for s in item.split("=", 1))
            if k == "df":
                kw["dataflow"] = v
            elif k in ("row", "rows"):
                kw["rows"] = get_strategy("rows", v)
            elif k in ("col", "cols"):
                kw["cols"] = get_strategy("cols", v)
            elif k in ("part", "partition"):
                kw["partition"] = get_strategy("partition", v)
            else:
                raise ValueError(f"unknown pipeline spec key {k!r} "
                                 f"in {spec!r}")
        return MappingPipeline(**kw)

    def replace(self, **kw) -> "MappingPipeline":
        return dataclasses.replace(self, **kw)


# --------------------------- named pipelines ------------------------------

_NAMED: dict[str, MappingPipeline] = {}


def register_pipeline(name: str, pipe: MappingPipeline,
                      override: bool = False) -> MappingPipeline:
    """Register a named pipeline (config / CLI shorthand).

    Duplicate names raise unless ``override=True`` — see
    :func:`repro.mapping.base.register` for why silent replacement is
    dangerous.
    """
    if not override and name in _NAMED:
        raise ValueError(f"pipeline {name!r} is already registered "
                         f"({_NAMED[name].fingerprint()}); pass "
                         "override=True to replace it")
    _NAMED[name] = pipe
    return pipe


def named_pipelines() -> dict[str, MappingPipeline]:
    return dict(_NAMED)


register_pipeline("baseline", MappingPipeline(
    dataflow="conventional", rows=IdentityRows()))
register_pipeline("reverse", MappingPipeline(rows=IdentityRows()))
register_pipeline("sort", MappingPipeline(dataflow="conventional"))
register_pipeline("mdm", MappingPipeline())
register_pipeline("fault_aware", MappingPipeline(rows=FaultAwareRows()))
register_pipeline("significance_weighted",
                  MappingPipeline(rows=SignificanceWeightedRows()))
register_pipeline("xchangr", MappingPipeline(cols=XChangrCols()))
register_pipeline("xchangr_fault_aware", MappingPipeline(
    rows=FaultAwareRows(), cols=XChangrCols()))
register_pipeline("spare_line", MappingPipeline(
    rows=SpareLineRows(), cols=SpareLineCols()))
register_pipeline("mdm_expert", MappingPipeline(
    partition=ExpertPartition()))

# The legacy planner modes.  They double as registered named pipelines
# (so cfg.cim.mode="mdm" stays first-class and warning-free); what makes
# them a *shim* is the fault-map auto-upgrade below and the historical
# cache tokens, both pinned by tests/test_mapping.py.
LEGACY_MODES = ("baseline", "reverse", "sort", "mdm")


def resolve_pipeline(mode, have_faults: bool = False) -> MappingPipeline:
    """Resolve a pipeline, a named/spec string, or a legacy mode.

    ``have_faults`` reproduces the legacy side-channel semantics: the
    old planner upgraded the sorting modes ("sort"/"mdm") to fault-aware
    placement whenever ``fault_maps`` was supplied, so the shim resolves
    those strings to :class:`FaultAwareRows` under the same condition
    (an explicit :class:`MappingPipeline` is never upgraded — pass
    ``rows=FaultAwareRows()`` to opt in).
    """
    if isinstance(mode, MappingPipeline):
        return mode
    if not isinstance(mode, str):
        raise TypeError(f"expected MappingPipeline or str, got "
                        f"{type(mode).__name__}")
    if have_faults and mode in ("sort", "mdm"):
        return _NAMED[mode].replace(rows=FaultAwareRows())
    if mode in _NAMED:
        return _NAMED[mode]
    if "=" in mode:
        return MappingPipeline.from_spec(mode)
    raise ValueError(
        f"unknown mapping pipeline {mode!r}; named pipelines: "
        f"{tuple(sorted(_NAMED))}, row strategies: {available('rows')}, "
        "or a 'df=...,row=...,col=...,part=...' spec string")
