"""Strategy registry and pass contract for the mapping pipeline.

A *strategy* is one composable pass of a :class:`repro.mapping.pipeline
.MappingPipeline` — a frozen dataclass registered under a ``(kind,
name)`` pair.  Three kinds exist:

``rows``
    Row-order passes.  ``order_tiles(placed, stuck, col_sig, spec)``
    maps a ``(T, rows, cols)`` batch of *placed* activity masks (tile
    columns already in physical layout: dataflow orientation and any
    column pass applied) to a ``(T, rows)`` permutation — ``perm[t, p]``
    is the tile-local logical row hosted at physical position ``p`` —
    or ``None`` for the identity.  ``stuck`` is the physical
    ``(T, rows, cols)`` int8 cell-state batch (or None), ``col_sig``
    the per-tile physical-column bit significance (or None); a pass
    declares what it consumes via ``uses_faults`` /
    ``uses_col_significance`` and must ignore the rest.

``cols``
    Column-order passes.  ``order_tiles(placed, stuck, col_sig, spec)``
    maps the dataflow-oriented mask batch to a ``(T, cols)``
    permutation (``perm[t, p]`` = dataflow-layout column hosted at
    physical bitline ``p``) or ``None`` for the identity.  ``col_sig``
    here is the *pre-permutation* per-logical-column bit significance
    (the plane each dataflow-layout column hosts — the cols pass is
    what decides where those columns land); the same
    ``uses_faults`` / ``uses_col_significance`` declarations gate what
    the planner threads in.

``partition``
    Host-side tensor partitioning.  ``split(name, w)`` maps one named
    weight tensor to a list of ``(sub_name, 2-D matrix)`` pairs, or
    ``None`` when the tensor is not partitionable by this strategy
    (the caller records it as skipped).

The contract every strategy must honour:

* **pure** — output depends only on the inputs (no hidden state, no
  RNG), so plans are reproducible and cache-correct;
* **fingerprinted** — :meth:`Strategy.fingerprint` is a stable string
  derived from the registry name plus the dataclass params, identical
  across processes and releases (it composes into
  ``repro.deploy.cache`` plan keys);
* **hashable** — strategies are frozen dataclasses so pipelines can be
  jit static arguments.
"""
from __future__ import annotations

import dataclasses

KINDS = ("rows", "cols", "partition")

_REGISTRY: dict[str, dict[str, type]] = {k: {} for k in KINDS}


class Strategy:
    """Mixin for registered mapping passes (frozen dataclasses).

    ``kind`` / ``name`` are stamped by :func:`register`; params are the
    dataclass fields.
    """

    kind: str = ""
    name: str = ""
    # Consumption declarations (rows *and* cols passes): the planner
    # only threads physical cell-state maps / column-significance grids
    # to passes that ask for them.
    uses_faults: bool = False
    uses_col_significance: bool = False

    def fingerprint(self) -> str:
        """Stable registry name + params, e.g. ``"mdm"``.

        Dataclass field order is the declaration order, so the string
        is deterministic across processes; values are ``repr``\\ s of
        plain python scalars only (the params of a registered strategy
        must be hashable primitives).
        """
        fields = dataclasses.fields(self)
        if not fields:
            return self.name
        params = ",".join(f"{f.name}={getattr(self, f.name)!r}"
                          for f in fields)
        return f"{self.name}({params})"


def register(kind: str, name: str, override: bool = False):
    """Class decorator: register a strategy under ``(kind, name)``.

    Duplicate names raise unless ``override=True``: a silently
    replaced strategy would keep emitting the original's cache token
    while producing different plans — poisoning every shared
    ``PlanCache``.
    """
    if kind not in KINDS:
        raise ValueError(f"kind={kind!r} not in {KINDS}")

    def deco(cls):
        if not override and name in _REGISTRY[kind]:
            raise ValueError(
                f"{kind} strategy {name!r} is already registered "
                f"({_REGISTRY[kind][name].__name__}); pass "
                "override=True to replace it")
        cls.kind, cls.name = kind, name
        _REGISTRY[kind][name] = cls
        return cls

    return deco


def unregister(kind: str, name: str) -> None:
    """Remove a registered strategy (test/tooling hook).

    The semantic auditor's mutation tests register deliberately broken
    strategies and must be able to take them back out; library code has
    no business calling this.
    """
    if kind not in KINDS:
        raise ValueError(f"kind={kind!r} not in {KINDS}")
    _REGISTRY[kind].pop(name, None)


def available(kind: str) -> tuple[str, ...]:
    """Registered strategy names of one kind, sorted."""
    if kind not in KINDS:
        raise ValueError(f"kind={kind!r} not in {KINDS}")
    return tuple(sorted(_REGISTRY[kind]))


def get_strategy(kind: str, name: str, **params):
    """Instantiate a registered strategy by name."""
    try:
        cls = _REGISTRY[kind][name]
    except KeyError:
        raise ValueError(
            f"unknown {kind} strategy {name!r}; "
            f"available: {available(kind)}") from None
    return cls(**params)
