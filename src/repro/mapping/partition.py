"""Tile-partitioning strategies: tensors -> named 2-D crossbar matrices.

Partitioning runs host-side, before planning: it decides how a weight
tensor decomposes into independent 2-D matmul matrices, each of which
then gets its own tile grid, plan and cache entry.  The partition pass
is part of the pipeline fingerprint but — deliberately — not of the
per-matrix plan-cache keys: each produced matrix is content-addressed
by its own bytes, so two pipelines that slice the same bank the same
way share cache entries.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.mapping.base import Strategy, register


@register("partition", "dense")
@dataclasses.dataclass(frozen=True)
class DensePartition(Strategy):
    """Plain 2-D matrices only (the pre-pipeline behaviour)."""

    expert_axis = False

    def split(self, name: str, w) -> list[tuple[str, np.ndarray]] | None:
        if np.ndim(w) != 2:
            return None
        return [(name, w)]


@register("partition", "expert")
@dataclasses.dataclass(frozen=True)
class ExpertPartition(Strategy):
    """Expert-axis-aware partitioning for MoE banks.

    A stacked ``(E, I, N)`` expert bank splits along the leading expert
    axis into E independent 2-D matrices named ``{name}/e{e}`` — each
    expert's projection deploys onto its own tile grid (experts never
    share crossbar rows, so per-expert planning is exact, not an
    approximation).  Plain 2-D matrices pass through unchanged.
    """

    expert_axis = True

    def split(self, name: str, w) -> list[tuple[str, np.ndarray]] | None:
        if np.ndim(w) == 2:
            return [(name, w)]
        if np.ndim(w) == 3:
            return [(f"{name}/e{e}", w[e]) for e in range(w.shape[0])]
        return None
