"""Column-order strategies: which bit column lands on which bitline.

Every crossbar column is sensed independently and shift-added
digitally, so *any* per-tile bitline permutation preserves the matmul
exactly (the column mux knows the mapping) — only the parasitic
exposure changes.  X-CHANGR (arXiv:1907.00285) exploits exactly this
freedom by remapping columns across crossbars; here the same idea is a
registered pass composing with the row sort.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.mapping.base import Strategy, register


@register("cols", "identity")
@dataclasses.dataclass(frozen=True)
class IdentityCols(Strategy):
    """Keep the (possibly dataflow-reversed) column order unchanged."""

    uses_faults = False

    def order_tiles(self, placed, stuck, col_sig, spec):
        return None


@register("cols", "xchangr")
@dataclasses.dataclass(frozen=True)
class XChangrCols(Strategy):
    """X-CHANGR-style bitline sort: densest columns nearest the rail.

    Under Eq 16 the column-placement term ``sum_c pos_c * m_c`` (``m_c``
    = active cells of column c) is independent of the row term, so by
    the rearrangement inequality the optimal bitline order sorts
    columns by active count descending — the exact column-wise dual of
    the MDM row sort, subsuming plain dataflow reversal whenever the
    low-order planes really are the dense ones.
    """

    uses_faults = False

    def order_tiles(self, placed, stuck, col_sig, spec):
        from repro.core import manhattan

        return jax.vmap(manhattan.optimal_col_order)(placed)


@register("cols", "spare_line")
@dataclasses.dataclass(frozen=True)
class SpareLineCols(Strategy):
    """Bitline sort steering logical columns off faulty/open bitlines.

    The column half of the spare-line remap: an OPEN bitline (line-open
    fault, ``repro.nonideal.models``) conducts nothing, so whichever
    logical column lands on it is lost entirely.  Sorting physical
    bitlines by fault penalty — with ``open_penalty`` surcharging open
    cells so a severed bitline ranks behind every merely-parasitic
    position — makes the dead line host the *sparsest* logical column.
    When the tile carries spare capacity (all-zero bit columns from
    padding or sparsity), the dead bitline absorbs a spare and costs
    nothing; identity column order would have sacrificed a live bit
    plane instead.  Reduces exactly to :class:`XChangrCols` when no
    fault map is supplied.

    The steering is **significance-weighted**: the planner threads the
    pre-permutation per-logical-column bit significance (2^-(k+1) of
    the plane each dataflow-layout column hosts) and the ranking key
    becomes significance x total column current — active cells plus the
    ``r_on / r_off`` off-current floor a severed bitline also silences
    — so the cheap sacrifice for a dead bitline is the lowest
    *significance-weighted current*, not merely the emptiest column.
    The loss the sort minimises is the shift-added output error, not
    raw cell count: a sparse MSB plane keeps its healthy bitline, a
    dense LSB plane is expendable.
    """

    open_penalty: float = 4.0

    uses_faults = True
    uses_col_significance = True

    def order_tiles(self, placed, stuck, col_sig, spec):
        from repro.core import manhattan

        if stuck is None:
            return jax.vmap(manhattan.optimal_col_order)(placed)
        if col_sig is None:
            return jax.vmap(
                lambda a, s: manhattan.fault_aware_col_order(
                    a, s, spec.nf_unit, open_penalty=self.open_penalty)
            )(placed, stuck)
        return jax.vmap(
            lambda a, s, w: manhattan.fault_aware_col_order(
                a, s, spec.nf_unit, col_weights=w,
                open_penalty=self.open_penalty,
                off_current=spec.r_on / spec.r_off)
        )(placed, stuck, col_sig)
