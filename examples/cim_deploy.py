"""Deploy a model's weight matrices onto memristive crossbars: per-layer
mapping-pipeline planning report (tiles, sparsity, NF before/after) and
a deployment image export through the bitslice_pack kernel.

    PYTHONPATH=src python examples/cim_deploy.py [--arch phi3-mini-3.8b] \
        [--mode mdm|xchangr|significance_weighted|"df=...,row=..."]

``--mode`` takes any named mapping pipeline or spec string resolved by
``repro.mapping.resolve_pipeline`` (the legacy mode strings keep
working through the deprecation shim).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CrossbarSpec
from repro.core.bitslice import bitslice
from repro.core.mdm import plan_from_bits
from repro.kernels.bitslice_pack import bitslice_pack
from repro.mapping import resolve_pipeline
from repro.models.model import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--mode", default="mdm",
                    help="mapping pipeline: named (mdm, xchangr, ...) "
                         "or 'df=...,row=...,col=...' spec string")
    ap.add_argument("--min-size", type=int, default=1024,
                    help="skip weight leaves smaller than this")
    ap.add_argument("--rows", type=int, default=64)
    ap.add_argument("--cols", type=int, default=64)
    args = ap.parse_args(argv)

    pipe = resolve_pipeline(args.mode)
    cfg = get_config(args.arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    spec = CrossbarSpec(rows=args.rows, cols=args.cols, n_bits=8)

    print(f"deploying {args.arch} (reduced config) with "
          f"pipeline={args.mode} [{pipe.fingerprint()}]")
    total_tiles, nf_b, nf_a = 0, 0.0, 0.0
    min_size = args.min_size
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        reps = 1
        if leaf.ndim == 3 and leaf.shape[1] * leaf.shape[2] >= min_size:
            reps, leaf = leaf.shape[0], leaf[0]   # scanned layer stack
        elif leaf.ndim == 4 and leaf.shape[-1] * leaf.shape[-2] >= min_size:
            reps, leaf = leaf.shape[0] * leaf.shape[1], leaf[0, 0]
        if leaf.ndim != 2 or leaf.size < min_size:
            continue
        name = jax.tree_util.keystr(path) + (f" x{reps}" if reps > 1 else "")
        w = leaf.astype(jnp.float32)
        sliced = bitslice(w, spec.n_bits)
        plan = plan_from_bits(sliced.bits, sliced.scale, spec, pipe)
        ti, tn = plan.nf_before.shape
        b, a = float(jnp.sum(plan.nf_before)), float(jnp.sum(plan.nf_after))
        total_tiles += ti * tn * reps
        nf_b += b * reps
        nf_a += a * reps
        sparsity = 1 - float(jnp.mean(sliced.bits))
        print(f"  {name:40s} {str(w.shape):14s} tiles={ti*tn:4d} "
              f"sparsity={sparsity:.2f} NF {b:8.3f} -> {a:8.3f}")
    print(f"TOTAL: {total_tiles} tiles, NF {nf_b:.2f} -> {nf_a:.2f} "
          f"({100*(1-nf_a/max(nf_b,1e-9)):.1f}% reduction)")

    # export one deployment image through the packing kernel
    w = params["lm_head"].astype(jnp.float32)
    from repro.core.bitslice import quantize_magnitude
    codes, sign, _ = quantize_magnitude(w, spec.n_bits)
    img = bitslice_pack(
        (codes.astype(jnp.int32) * sign).astype(jnp.int32), spec.n_bits,
        reversed_df=pipe.reversed_dataflow)
    print(f"deployment image for lm_head: {img.shape} uint8 "
          f"({img.size/1e6:.1f} MB)")


if __name__ == "__main__":
    main()
