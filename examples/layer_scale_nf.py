"""Layer-scale NF sweep on the device-sharded, mixed-precision solver.

    PYTHONPATH=src python examples/layer_scale_nf.py

The paper validates NF per tile; real conclusions need the full tile
population of a layer (X-CHANGR, Zhang & Hu).  This example bit-slices
a conv-sized weight matrix into its whole (Ti, Tn) tile grid, solves
every tile's Kirchhoff system in one sharded call
(``repro.distributed.solver_shard``: all local devices, f32 CG + f64
polish), and compares the measured NF distribution of the baseline
vs the MDM deployment plan.
"""
import os
import sys

# Simulate an 8-device host before JAX initialises (real accelerators
# take precedence if present).
flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in flags:
    os.environ["XLA_FLAGS"] = (
        flags + " --xla_force_host_platform_device_count=8").strip()
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CrossbarSpec
from repro.core.bitslice import bitslice
from repro.core.mdm import placed_masks, plan_from_bits
from repro.distributed.solver_shard import measured_nf_sharded


def main():
    # A ResNet-ish 3x3x128x128 conv flattened to (1152, 128).
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (1152, 128)) * 0.02
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    sliced = bitslice(w, spec.n_bits)

    print(f"devices: {len(jax.local_devices())}")
    for mode in ("baseline", "mdm"):
        plan = plan_from_bits(sliced.bits, sliced.scale, spec, mode)
        masks = placed_masks(sliced.bits, plan, spec)    # (Ti, Tn, J, K)
        ti, tn = masks.shape[:2]
        res = measured_nf_sharded(masks, spec, precision="mixed")
        nf = np.asarray(res.nf_total).ravel()
        print(f"{mode:9s} {ti * tn} tiles: NF mean {nf.mean():.5f}  "
              f"p95 {np.percentile(nf, 95):.5f}  max {nf.max():.5f}  "
              f"({int(res.iterations)} CG iters, "
              f"{int(res.unconverged)} unconverged)")


if __name__ == "__main__":
    main()
