"""Quickstart: Manhattan Distance Mapping on one weight matrix.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper end-to-end at toy scale with the composable mapping
API (``repro.mapping``): bit-slice a layer, build deployment plans for
the registered mapping pipelines (the paper's ablations plus the
X-CHANGR-style bitline sort), inspect the NF reduction, run the
PR-distorted CIM matmul through the fused kernel, and cross-check one
tile against the circuit-level Kirchhoff solver.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CrossbarSpec, plan_layer
from repro.core.bitslice import bitslice, unbitslice
from repro.core.mdm import placed_masks, plan_from_bits
from repro.crossbar.solver import measured_nf
from repro.kernels.cim_mvm.ops import cim_mvm, deploy
from repro.mapping import named_pipelines

# The paper's four ablations + the column-sorted composite, all from
# the strategy registry (add a registered pipeline and it shows up).
WALK_PIPELINES = ("baseline", "reverse", "sort", "mdm", "xchangr")


def main(in_dim: int = 256, out_dim: int = 64, batch: int = 8,
         spec: CrossbarSpec | None = None):
    """Run the walkthrough; shapes are overridable so the tier-1 smoke
    test (tests/test_examples.py) can drive it in-process at tiny
    scale."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (in_dim, out_dim)) * 0.02  # a small layer
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, in_dim))
    spec = spec or CrossbarSpec(rows=64, cols=64, n_bits=8)
    pipes = named_pipelines()

    # 1. mapping plans: dataflow reversal, row sort, bitline sort
    for name in WALK_PIPELINES:
        plan = plan_layer(w, spec, pipes[name])
        extra = (f" (reduction {float(plan.nf_reduction)*100:5.1f}%)"
                 if name in ("mdm", "xchangr") else "")
        print(f"pipeline={name:9s} aggregate NF = "
              f"{float(jnp.sum(plan.nf_after)):.4f}{extra}")

    # 2. semantics check: eta=0 CIM matmul == quantised matmul, even
    # under the bitline-permuted pipeline (the column mux inverts it)
    wq = unbitslice(bitslice(w, spec.n_bits))
    for name in ("mdm", "xchangr"):
        dep0, _ = deploy(w, spec, pipes[name], eta=0.0)
        y0 = cim_mvm(x, dep0)
        print(f"eta=0 kernel ({name}) vs quantised matmul max err:",
              float(jnp.max(jnp.abs(y0 - x @ wq))))

    # 3. PR-distorted inference (Eq 17) through the fused kernel
    dep, plan = deploy(w, spec, pipes["mdm"], eta=2e-3)
    y = cim_mvm(x, dep)
    dep0, _ = deploy(w, spec, pipes["mdm"], eta=0.0)
    y0 = cim_mvm(x, dep0)
    print("PR distortion shifts outputs by",
          f"{float(jnp.mean(jnp.abs(y - y0)) / jnp.mean(jnp.abs(y0))):.2%}")

    # 4. circuit-level cross-check of one tile
    sliced = bitslice(w, spec.n_bits)
    for name in ("baseline", "mdm", "xchangr"):
        p = plan_from_bits(sliced.bits, sliced.scale, spec, pipes[name])
        mask = placed_masks(sliced.bits, p, spec)[0, 0]
        res = measured_nf(mask, spec)
        print(f"circuit-measured NF ({name:8s}): "
              f"{float(res.nf_total):.5f}")


if __name__ == "__main__":
    main()
