"""Quickstart: Manhattan Distance Mapping on one weight matrix.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper end-to-end at toy scale: bit-slice a layer, build the
MDM plan, inspect the NF reduction, run the PR-distorted CIM matmul
through the fused Pallas kernel, and cross-check one tile against the
circuit-level Kirchhoff solver.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CrossbarSpec, plan_layer
from repro.core.bitslice import bitslice, unbitslice
from repro.core.mdm import placed_masks, plan_from_bits
from repro.crossbar.solver import measured_nf
from repro.kernels.cim_mvm.ops import cim_mvm, deploy


def main(in_dim: int = 256, out_dim: int = 64, batch: int = 8,
         spec: CrossbarSpec | None = None):
    """Run the walkthrough; shapes are overridable so the tier-1 smoke
    test (tests/test_examples.py) can drive it in-process at tiny
    scale."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (in_dim, out_dim)) * 0.02  # a small layer
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, in_dim))
    spec = spec or CrossbarSpec(rows=64, cols=64, n_bits=8)

    # 1. MDM plan: dataflow reversal + Manhattan row sort
    for mode in ("baseline", "reverse", "sort", "mdm"):
        plan = plan_layer(w, spec, mode)
        print(f"mode={mode:9s} aggregate NF = "
              f"{float(jnp.sum(plan.nf_after)):.4f} "
              f"(reduction {float(plan.nf_reduction)*100:5.1f}%)"
              if mode == "mdm" else
              f"mode={mode:9s} aggregate NF = "
              f"{float(jnp.sum(plan.nf_after)):.4f}")

    # 2. semantics check: eta=0 CIM matmul == quantised matmul
    dep0, _ = deploy(w, spec, "mdm", eta=0.0)
    y0 = cim_mvm(x, dep0)
    wq = unbitslice(bitslice(w, spec.n_bits))
    print("eta=0 kernel vs quantised matmul max err:",
          float(jnp.max(jnp.abs(y0 - x @ wq))))

    # 3. PR-distorted inference (Eq 17) through the fused kernel
    dep, plan = deploy(w, spec, "mdm", eta=2e-3)
    y = cim_mvm(x, dep)
    print("PR distortion shifts outputs by",
          f"{float(jnp.mean(jnp.abs(y - y0)) / jnp.mean(jnp.abs(y0))):.2%}")

    # 4. circuit-level cross-check of one tile
    sliced = bitslice(w, spec.n_bits)
    for mode in ("baseline", "mdm"):
        p = plan_from_bits(sliced.bits, sliced.scale, spec, mode)
        mask = placed_masks(sliced.bits, p, spec)[0, 0]
        res = measured_nf(mask, spec)
        print(f"circuit-measured NF ({mode:8s}): "
              f"{float(res.nf_total):.5f}")


if __name__ == "__main__":
    main()
