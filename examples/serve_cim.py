"""Serve a small LM with batched requests THROUGH a CIM deployment:
every large weight matrix carries Eq-17 parasitic-resistance distortion
under a chosen MDM mode — the paper's technique as a serving-time
feature.

    PYTHONPATH=src python examples/serve_cim.py [--mode mdm] [--eta 2e-3]

Trains a tiny LM briefly (or reuses examples/train_lm.py checkpoints if
present), then decodes the same batch of prompts with clean weights and
with CIM-distorted weights under each MDM ablation, reporting how many
generated tokens diverge — an end-to-end view of Fig 6.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core.noise import tree_noisy_weights
from repro.core.tiling import CrossbarSpec
from repro.data import SyntheticTokenDataset
from repro.serve import ServeEngine
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--eta", type=float, default=5e-3)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config("phi3-mini-3.8b", smoke=True).replace(
        dtype="float32", vocab_size=4096)
    tcfg = TrainConfig(total_steps=args.train_steps, learning_rate=2e-3,
                       checkpoint_every=10 ** 9,
                       checkpoint_dir="/tmp/repro_serve_cim")
    ds = SyntheticTokenDataset(cfg.vocab_size, 64, 16, seed=0)
    tr = Trainer(cfg, tcfg, ds)
    tr.init_state()
    log = tr.run(args.train_steps)
    print(f"trained {args.train_steps} steps, loss {log[-1]['loss']:.3f}")

    prompts = jnp.asarray(ds.batch_at(9999)[:args.batch, :32])
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)

    clean_eng = ServeEngine(cfg, tr.params, max_seq=96)
    ref = np.asarray(clean_eng.generate(prompts, args.gen))
    print(f"clean decode: {ref.shape[1]} tokens x {ref.shape[0]} requests")

    for mode in ("baseline", "reverse", "sort", "mdm"):
        noisy = tree_noisy_weights(tr.params, spec, mode, eta=args.eta,
                                   min_size=1024)
        eng = ServeEngine(cfg, noisy, max_seq=96)
        out = np.asarray(eng.generate(prompts, args.gen))
        div = (out != ref).mean()
        first = np.argmax((out != ref).any(axis=0)) if (out != ref).any() \
            else args.gen
        print(f"  CIM mode={mode:9s} eta={args.eta:g}: "
              f"{div:6.1%} tokens diverge from clean "
              f"(first divergence @ t={first})")


if __name__ == "__main__":
    main()
