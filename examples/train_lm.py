"""End-to-end training driver: train an LM on the synthetic corpus with
checkpointing, restart tolerance and the full framework stack.

    PYTHONPATH=src python examples/train_lm.py              # ~20M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --full       # ~110M params

The --full variant instantiates a ~110M-parameter phi3-family config
(the "train a ~100M model for a few hundred steps" deliverable); the
default is a CPU-friendly ~20M so the example finishes in minutes.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data import SyntheticTokenDataset
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~110M params / few hundred steps")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt", default="/tmp/repro_example_lm")
    args = ap.parse_args()

    base = get_config("phi3-mini-3.8b", smoke=True)
    if args.full:
        cfg = base.replace(n_layers=8, d_model=768, n_heads=12,
                           n_kv_heads=12, d_ff=2048, vocab_size=32064,
                           attn_chunk=128)
        steps = args.steps or 300
        batch, seq = 8, 256
    else:
        cfg = base.replace(n_layers=4, d_model=384, n_heads=6,
                           n_kv_heads=6, d_ff=1024, vocab_size=8192,
                           attn_chunk=128)
        steps = args.steps or 300
        batch, seq = 8, 128

    n_params = sum(
        int(__import__("numpy").prod(s.shape)) for s in
        __import__("jax").tree_util.tree_leaves(
            __import__("repro.models.schema",
                       fromlist=["model_schema"]).model_schema(cfg),
            is_leaf=lambda x: hasattr(x, "dims")))
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model})")

    tcfg = TrainConfig(total_steps=steps, learning_rate=1e-3,
                       warmup_steps=30, checkpoint_every=100,
                       checkpoint_dir=args.ckpt, log_every=20)
    ds = SyntheticTokenDataset(cfg.vocab_size, seq, batch, seed=0)
    tr = Trainer(cfg, tcfg, ds)
    if not tr.resume_or_init():
        print("starting fresh")
    else:
        print(f"resumed from step {tr.step}")
    log = tr.run(steps)
    for m in log:
        print(f"  step {m['step']:4d} loss {m['loss']:.4f} "
              f"({m['dt']*1e3:.0f} ms/step)")
    print(f"final loss: {log[-1]['loss']:.4f} "
          f"(uniform would be {__import__('math').log(cfg.vocab_size):.2f})")
    if tr.watchdog.stragglers:
        print(f"watchdog flagged {len(tr.watchdog.stragglers)} slow steps")


if __name__ == "__main__":
    main()
