"""Scale-out solver parity: sharded and mixed-precision solves against
the single-tile Jacobi-CG oracle (tier-1, ISSUE 2).

These run under the 8-way host-device CPU simulation that
tests/conftest.py forces (XLA_FLAGS=--xla_force_host_platform_
device_count=8) so shard_map exercises real multi-device dataflow, not
a degenerate 1-device mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tiling import CrossbarSpec
from repro.crossbar.batched import (
    F32,
    F64,
    MIXED,
    SolverPrecision,
    measured_nf_batched,
    resolve_precision,
)
from repro.crossbar.solver import SolveResult, measured_nf
from repro.distributed.sharding import ShardingCtx
from repro.distributed.solver_shard import (
    measured_nf_sharded,
    tile_mesh,
    tile_sharding_ctx,
)

SPEC = CrossbarSpec(rows=16, cols=16, n_bits=8)


def masks8(p=0.2):
    keys = jax.random.split(jax.random.PRNGKey(42), 8)
    return jnp.stack([(jax.random.uniform(k, (16, 16)) < p)
                      .astype(jnp.float32) for k in keys])


def oracle_currents(masks):
    """Single-tile Jacobi-CG path (repro.crossbar.solver), one by one."""
    return np.stack([np.asarray(measured_nf(masks[i], SPEC).currents)
                     for i in range(masks.shape[0])])


def test_simulated_device_count():
    """conftest's forcing gives the parity tests a real 8-way mesh."""
    assert len(jax.local_devices()) == 8


def test_sharded_matches_jacobi_oracle():
    m = masks8()
    oracle = oracle_currents(m)
    res = measured_nf_sharded(m, SPEC)
    np.testing.assert_allclose(np.asarray(res.currents), oracle, rtol=1e-6)
    assert int(res.unconverged) == 0
    assert float(np.asarray(res.residual).max()) < 1e-9


def test_mixed_precision_matches_jacobi_oracle():
    m = masks8()
    oracle = oracle_currents(m)
    res = measured_nf_batched(m, SPEC, precision=MIXED)
    np.testing.assert_allclose(np.asarray(res.currents), oracle, rtol=1e-6)


def test_sharded_mixed_tracks_f64_engine_tightly():
    """The mixed polish lands on the f64 fixed point: sharded+mixed vs
    the single-device f64 engine agree far tighter than either does
    with an independently-preconditioned solve."""
    m = masks8()
    f64 = measured_nf_batched(m, SPEC)
    res = measured_nf_sharded(m, SPEC, precision=MIXED)
    err = np.max(np.abs(np.asarray(res.currents) - np.asarray(f64.currents))
                 / np.abs(np.asarray(f64.currents)))
    assert err < 1e-6
    assert int(res.unconverged) == 0


def test_sharded_f64_matches_batched_to_roundoff():
    """Same arithmetic, same preconditioner, same per-tile iteration
    trajectory — sharding must not change the numerics beyond reduction
    -order roundoff."""
    m = masks8()
    a = measured_nf_batched(m, SPEC)
    b = measured_nf_sharded(m, SPEC)
    np.testing.assert_allclose(np.asarray(a.currents),
                               np.asarray(b.currents), rtol=1e-12)


def test_sharded_pads_non_divisible_batches():
    m = masks8()[:5]                      # 5 tiles on 8 devices
    full = measured_nf_batched(m, SPEC)
    res = measured_nf_sharded(m, SPEC)
    assert res.currents.shape == (5, 16)
    np.testing.assert_allclose(np.asarray(res.currents),
                               np.asarray(full.currents), rtol=1e-12)
    assert int(res.unconverged) == 0


def test_sharded_preserves_leading_batch_dims():
    m = masks8().reshape(2, 4, 16, 16)
    res = measured_nf_sharded(m, SPEC)
    assert res.nf_total.shape == (2, 4)
    assert res.currents.shape == (2, 4, 16)


def test_sharded_composes_with_sharding_ctx():
    """A caller-supplied ShardingCtx mesh routes through the logical
    "tiles" rule; a 2-device tile mesh and the default all-device mesh
    agree exactly."""
    m = masks8()
    a = measured_nf_sharded(m, SPEC, ctx=tile_sharding_ctx())
    b = measured_nf_sharded(m, SPEC, ctx=ShardingCtx(mesh=tile_mesh(2)))
    np.testing.assert_allclose(np.asarray(a.currents),
                               np.asarray(b.currents), rtol=1e-12)


def test_sharded_meshless_ctx_degrades_to_batched():
    """ShardingCtx() (mesh=None, single-device smoke mode) must still
    answer, via the fused single-device engine."""
    m = masks8()
    res = measured_nf_sharded(m, SPEC, ctx=ShardingCtx())
    full = measured_nf_batched(m, SPEC)
    np.testing.assert_allclose(np.asarray(res.currents),
                               np.asarray(full.currents), rtol=1e-12)


def test_sharded_early_exit_and_global_check():
    res = measured_nf_sharded(masks8(), SPEC)
    assert int(res.iterations) < 100      # line preconditioner: ~5
    assert int(res.unconverged) == 0


def test_precision_policy_resolution():
    assert resolve_precision(None) == F64
    assert resolve_precision("mixed") == MIXED
    assert resolve_precision("f32") == F32
    assert resolve_precision(MIXED) is MIXED
    assert resolve_precision("float64") == F64
    with pytest.raises(ValueError):
        resolve_precision("bf16")
    # hashable => usable as a jit static argument
    assert len({F64, MIXED, F32, SolverPrecision()}) == 3


def test_single_tile_precision_routing():
    """measured_nf with a non-default policy routes one tile through the
    batched engine and unwraps to a SolveResult."""
    m = masks8()[0]
    oracle = measured_nf(m, SPEC)
    mixed = measured_nf(m, SPEC, precision="mixed")
    assert isinstance(mixed, SolveResult)
    np.testing.assert_allclose(np.asarray(mixed.currents),
                               np.asarray(oracle.currents), rtol=1e-6)


def test_assoc_chain_kernel_matches_lax():
    """The associative-scan Thomas kernel (portable, log-depth — the
    option for backends without a batched tridiagonal_solve lowering)
    solves to the same fixed point as the lax scan kernel."""
    m = masks8()
    a = measured_nf_batched(m, SPEC, chain_impl="lax")
    b = measured_nf_batched(m, SPEC, chain_impl="assoc")
    np.testing.assert_allclose(np.asarray(b.currents),
                               np.asarray(a.currents), rtol=1e-10)
    c = measured_nf_sharded(m, SPEC, chain_impl="assoc")
    np.testing.assert_allclose(np.asarray(c.currents),
                               np.asarray(a.currents), rtol=1e-10)


def test_jacobi_chain_kernel_still_converges():
    """The probe-failure fallback path (Jacobi diagonal) reaches the
    same solution, just in more iterations."""
    m = masks8()[:2]
    a = measured_nf_batched(m, SPEC, chain_impl="lax")
    b = measured_nf_batched(m, SPEC, chain_impl="jacobi")
    # Different preconditioners converge to 1e-12 residual along
    # different iterates; the solution gap is cond-amplified roundoff
    # (~1e-7 of the tiny off-cell currents), orders below the NF signal.
    np.testing.assert_allclose(np.asarray(b.currents),
                               np.asarray(a.currents), rtol=1e-5)
    assert int(b.iterations) > int(a.iterations)


def test_f32_screening_mode_is_coarse_but_sane():
    """The polish-free f32 policy is only screening-grade: currents
    within f32 resolution of the oracle, residual at the coarse tol."""
    m = masks8()
    f64 = measured_nf_batched(m, SPEC)
    f32 = measured_nf_batched(m, SPEC, precision="f32")
    np.testing.assert_allclose(np.asarray(f32.currents),
                               np.asarray(f64.currents), rtol=1e-3)
    assert float(np.asarray(f32.residual).max()) < 1e-4
