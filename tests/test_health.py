"""Lifetime resilience: aging clock, drift detection, remediation
ladder, and the self-healing serving path.

Contracts under test:

(a) ``NonidealModel`` rejects unphysical parameters with clear errors,
    and the aging clock (``drift_factor_at`` / ``relax_sigma_at`` /
    ``aged_gain_host``) composes with the fold_in-tag PRNG discipline —
    re-aging a deployment moves it along the drift trajectory without
    reshuffling any draw.
(b) The drift detector has zero false trips on stationary streams, a
    guaranteed trip within a bounded number of probes after a step
    change, and hysteresis that prevents trip/clear flapping — across a
    seeded parametrize grid, no statistical luck involved.
(c) The health controller climbs the remediation ladder exactly
    recalibrate -> reprogram -> (recalibrate ->) demote, deterministic
    per seed, and the serving engine hot-swaps refreshed deployments
    atomically: the old cim tree is never mutated and a generation
    holds the bank it started with, bit-deterministically.
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.health import DetectorConfig, DriftDetector, HealthConfig
from repro.health.monitor import (
    estimate_recal,
    probe_error,
    probe_vectors,
)
from repro.nonideal import NonidealModel

# ------------------------- model validation -------------------------------


@pytest.mark.parametrize("kw", [
    {"p_stuck_off": -0.1},
    {"p_stuck_on": 1.5},
    {"p_open_wordline": -1e-9},
    {"p_open_bitline": 2.0},
    {"p_stuck_off": 0.7, "p_stuck_on": 0.6},
    {"sigma_program": -0.01},
    {"sigma_read": -1.0},
    {"sigma_corr": -0.5},
    {"sigma_relax": -0.2},
    {"drift_nu": -0.1},
    {"drift_time": 0.0},
    {"drift_time": -3.0},
    {"corr_length": 0.5},
    {"sigma_program": float("nan")},
])
def test_nonideal_model_rejects_bad_parameters(kw):
    with pytest.raises(ValueError):
        NonidealModel(**kw)


def test_nonideal_model_accepts_edge_values():
    NonidealModel(corr_length=1.0, drift_time=1e-9, sigma_relax=0.0,
                  p_stuck_off=0.5, p_stuck_on=0.5)


# --------------------------- aging clock ----------------------------------


def test_drift_factor_clock_semantics():
    m = NonidealModel(drift_nu=0.1)
    # Static property == the clock evaluated at the static read time.
    assert m.drift_factor == m.drift_factor_at(m.drift_time)
    # Power law, monotone decreasing past t0, clamped at/below t0.
    assert m.drift_factor_at(1.0) == 1.0
    assert m.drift_factor_at(0.5) == 1.0
    t = m.drift_factor_at(1000.0)
    assert abs(t - 1000.0 ** -0.1) < 1e-6
    assert m.drift_factor_at(1e6) < t < 1.0
    # No drift -> unit factor at any age.
    assert NonidealModel().drift_factor_at(1e9) == 1.0


def test_relax_sigma_envelope():
    m = NonidealModel(sigma_relax=0.2)
    assert m.relax_sigma_at(1.0) == 0.0
    assert m.relax_sigma_at(0.1) == 0.0
    s10, s100 = m.relax_sigma_at(10.0), m.relax_sigma_at(100.0)
    assert 0.0 < s10 < s100
    assert abs(s10 - 0.2 * np.sqrt(np.log(10.0))) < 1e-6
    assert NonidealModel().relax_sigma_at(100.0) == 0.0


def test_aged_gain_reduces_to_legacy_at_deployment_age():
    """At age == drift_time the aged gain is bit-identical to the
    legacy static path (deployments made before the clock existed)."""
    from repro.nonideal.inject import aged_gain_host, variation_gain_host

    rng = np.random.default_rng(0)
    codes = rng.integers(0, 255, (6, 5), dtype=np.uint32)
    gamma = np.exp(0.05 * rng.standard_normal((6, 5, 8))).astype(
        np.float32)
    relax = rng.standard_normal((6, 5, 8)).astype(np.float32)
    m = NonidealModel(drift_nu=0.08, sigma_relax=0.1,
                      sigma_program=0.05)
    aged = aged_gain_host(codes, None, gamma, relax, 8, m,
                          m.drift_time)
    legacy = variation_gain_host(codes, None, gamma, 8, m.drift_factor)
    np.testing.assert_array_equal(aged, legacy)


def test_reaging_never_reshuffles_draws():
    """The relaxation draw is ONE fixed unit-normal per cell; aging
    only rescales its envelope — so the aged gain is a deterministic
    function of age, and two evaluations at the same age are
    bit-identical (no hidden RNG on the re-aging path)."""
    from repro.nonideal.inject import aged_gain_host

    rng = np.random.default_rng(1)
    codes = rng.integers(0, 255, (4, 3), dtype=np.uint32)
    gamma = np.exp(0.05 * rng.standard_normal((4, 3, 8))).astype(
        np.float32)
    relax = rng.standard_normal((4, 3, 8)).astype(np.float32)
    m = NonidealModel(drift_nu=0.05, sigma_relax=0.1)
    g10a = aged_gain_host(codes, None, gamma, relax, 8, m, 10.0)
    g10b = aged_gain_host(codes, None, gamma, relax, 8, m, 10.0)
    np.testing.assert_array_equal(g10a, g10b)
    # Later age = same draws, wider envelope + deeper drift: the ratio
    # field is a deterministic reweighting, not a fresh sample.
    g100 = aged_gain_host(codes, None, gamma, relax, 8, m, 100.0)
    assert not np.array_equal(g10a, g100)
    # Drift-only model: aging scales every gain by the scalar factor.
    md = NonidealModel(drift_nu=0.05)
    d10 = aged_gain_host(codes, None, gamma, None, 8, md, 10.0)
    d100 = aged_gain_host(codes, None, gamma, None, 8, md, 100.0)
    np.testing.assert_allclose(
        d100, d10 * (md.drift_factor_at(100.0)
                     / md.drift_factor_at(10.0)), rtol=1e-5)


# ------------------------- drift detector ---------------------------------


def test_detector_config_enforces_hysteresis():
    with pytest.raises(ValueError):
        DetectorConfig(z_trip=4.0, z_clear=4.0)
    with pytest.raises(ValueError):
        DetectorConfig(z_trip=4.0, z_clear=6.0)
    with pytest.raises(ValueError):
        DetectorConfig(ewma_alpha=0.0)
    with pytest.raises(ValueError):
        DetectorConfig(warmup=1)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("alpha,warmup", [(0.3, 8), (0.5, 4), (1.0, 6)])
def test_detector_no_false_trips_stationary(seed, alpha, warmup):
    cfg = DetectorConfig(ewma_alpha=alpha, warmup=warmup)
    det = DriftDetector(cfg)
    rng = np.random.default_rng(seed)
    errs = 0.05 + 0.005 * rng.standard_normal(200)
    for e in errs:
        assert not det.update(float(e))
    assert det.n_trips == 0 and det.n_clears == 0


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("step", [3.0, 10.0])
def test_detector_trips_within_k_probes_after_step(seed, step):
    """A sustained level shift of `step` baseline sigmas must trip
    within K probes of the step (EWMA convergence bound: after k
    observations the EWMA has closed 1-(1-alpha)^k of the gap; with
    the CUSUM accumulating (step - k) sigma per probe the slower
    detector still fires within ~cusum_h/(step-k) probes)."""
    cfg = DetectorConfig(ewma_alpha=0.3, warmup=8, z_trip=8.0,
                         z_clear=2.0, cusum_k=1.0, cusum_h=12.0)
    det = DriftDetector(cfg)
    rng = np.random.default_rng(seed)
    mu, sig = 0.05, 0.005
    for e in mu + sig * rng.standard_normal(40):
        assert not det.update(float(e))
    sigma0 = max(det.sigma0, cfg.min_sigma, cfg.min_rel_sigma * mu)
    K = 16
    tripped_at = None
    for i in range(K):
        e = mu + step * sigma0 + sig * rng.standard_normal()
        if det.update(float(e)):
            tripped_at = i
            break
    assert tripped_at is not None, f"no trip within {K} probes"
    assert det.n_trips == 1


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_detector_hysteresis_no_flapping_at_threshold(seed):
    """An error level parked exactly at the trip threshold trips once
    and stays tripped: separated thresholds mean noise around the trip
    level can never produce trip/clear/trip churn."""
    cfg = DetectorConfig(ewma_alpha=0.3, warmup=8, z_trip=8.0,
                         z_clear=2.0)
    det = DriftDetector(cfg)
    rng = np.random.default_rng(seed)
    mu, sig = 0.05, 0.005
    for e in mu + sig * rng.standard_normal(40):
        det.update(float(e))
    sigma0 = max(det.sigma0, cfg.min_sigma, cfg.min_rel_sigma * mu)
    level = mu + cfg.z_trip * sigma0
    for e in level + sig * rng.standard_normal(100):
        det.update(float(e))
    assert det.n_trips == 1
    assert det.n_clears == 0
    assert det.tripped


def test_detector_rearm_keeps_baseline_restarts_ewma():
    cfg = DetectorConfig(ewma_alpha=0.3, warmup=4, z_trip=6.0,
                         z_clear=2.0)
    det = DriftDetector(cfg)
    for e in (0.05, 0.052, 0.048, 0.051, 0.05, 0.049):
        det.update(e)
    mu0 = det.mu0
    for _ in range(6):
        det.update(0.5)           # hard step: trips
    assert det.tripped
    det.rearm()
    assert not det.tripped and det.cusum == 0.0 and det.mu0 == mu0
    # A successful repair (healthy errors) must NOT re-trip: the EWMA
    # restarts from the next observation instead of smoothing the
    # pre-repair level down over several rounds.
    assert not det.update(0.05)
    assert det.z < cfg.z_trip
    # Rearm is not a spontaneous clear.
    assert det.n_clears == 0


# ------------------------ probes / recalibration --------------------------


def test_probe_vectors_deterministic_per_matrix():
    cfg = HealthConfig(n_probes=8, probe_seed=5)
    a = probe_vectors(cfg, 3, 16)
    b = probe_vectors(cfg, 3, 16)
    c = probe_vectors(cfg, 4, 16)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (8, 16)
    assert not np.array_equal(a, c)


def test_estimate_recal_recovers_columnwise_gain():
    rng = np.random.default_rng(0)
    y_ref = rng.standard_normal((32, 6)).astype(np.float32)
    alpha_true = np.array([1.0, 0.5, 2.0, 1.25, 0.8, 1.0],
                          np.float32)
    y_cim = y_ref / alpha_true
    alpha = estimate_recal(y_cim, y_ref, limit=20.0)
    np.testing.assert_allclose(alpha, alpha_true, rtol=1e-5)
    # Dead column keeps 1; absurd corrections clamp at the limit.
    y_dead = np.zeros_like(y_cim)
    alpha = estimate_recal(y_dead, y_ref, limit=20.0)
    np.testing.assert_array_equal(alpha, np.ones(6, np.float32))
    alpha = estimate_recal(y_cim * 1e-4, y_ref, limit=20.0)
    assert alpha.max() == 20.0
    assert probe_error(y_ref, y_ref) == 0.0


def test_health_config_validation():
    with pytest.raises(ValueError):
        HealthConfig(n_probes=0)
    with pytest.raises(ValueError):
        HealthConfig(max_reprograms=-1)


# ---------------------- serving path (end to end) -------------------------


def _cfg():
    from repro.configs.base import CimConfig, ModelConfig

    return ModelConfig(
        name="cim-health-test", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, block_pattern=("attn",),
        remat="none", dtype="float32", attn_chunk=32,
        cim=CimConfig(enabled=True, mode="mdm", rows=16, cols=16,
                      n_bits=4))


def _health(max_reprograms=1, age_per_token=0.0):
    return HealthConfig(
        n_probes=8, max_reprograms=max_reprograms,
        age_per_token=age_per_token,
        detector=DetectorConfig(warmup=3, z_trip=6.0, z_clear=2.0))


_AGING = NonidealModel(drift_nu=0.1, sigma_relax=0.08,
                       sigma_program=0.03)


def _engine(tmp, health=None, nonideal=_AGING, seed=3):
    from repro.deploy import PlanCache
    from repro.models.model import init_params
    from repro.serve import ServeEngine

    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, max_seq=64,
                       plan_cache=PlanCache(tmp), nonideal=nonideal,
                       nonideal_seed=seed, health=health)


def test_escalation_ladder_deterministic_per_seed():
    """Full lifetime arc, twice: warmup (no trips) -> heavy aging ->
    recalibrate -> more aging -> reprogram (clock reset) -> exhaust
    endurance -> recalibrate -> demote.  Every escalation identical
    across same-seed engines; zero spontaneous clears throughout."""
    with tempfile.TemporaryDirectory() as d:
        histories = []
        for _ in range(2):
            eng = _engine(d, health=_health(max_reprograms=1))
            assert len(eng.lifetime) > 0
            for _ in range(4):                      # healthy warmup
                rep = eng.check_health()
            assert rep.counters["trips"] == 0
            n = len(eng.lifetime)

            eng.advance(1e4)
            rep = eng.check_health()                # -> recalibrate
            assert rep.counters["recalibrations"] == n
            assert all(m["rung"] == 1 for m in rep.matrices.values())

            eng.advance(1e8)
            rep = eng.check_health()                # -> reprogram
            assert rep.counters["reprograms"] == n
            for m in rep.matrices.values():
                assert m["rung"] == 0 and m["age"] == 1.0

            eng.advance(1e4)
            rep = eng.check_health()                # -> recalibrate
            assert rep.counters["recalibrations"] == 2 * n

            eng.advance(1e8)
            rep = eng.check_health()                # -> demote
            assert rep.counters["demotions"] == n
            assert all(m["demoted"] for m in rep.matrices.values())
            assert rep.flaps == 0
            histories.append([(e["matrix"], e["event"])
                              for e in rep.events])
            # Demoted = digital fallback; serving still works.
            prompts = jax.random.randint(jax.random.PRNGKey(1),
                                         (2, 8), 0, 128)
            out = np.asarray(eng.generate(prompts, 3))
            assert out.shape == (2, 3)
        assert histories[0] == histories[1]


def test_recalibration_restores_probe_error():
    """One rung is enough for *pure drift*: the deterministic power-law
    decay is column-separable, so the per-column correction must pull
    the tripped probe error back near the healthy baseline with no
    re-trip.  (Stochastic relaxation is per-cell and NOT recoverable by
    a column gain — that escalation path is exercised by
    ``test_unmonitored_engine_drifts_monitored_recovers``.)"""
    drift_only = NonidealModel(drift_nu=0.1, sigma_program=0.03)
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, health=_health(), nonideal=drift_only)
        for _ in range(4):
            eng.check_health()
        base = {n: m.last_err
                for n, m in eng.health.monitors.items()}
        eng.advance(1e4)
        eng.check_health()                  # trips + recalibrates
        rep = eng.check_health()            # post-repair measurement
        assert rep.counters["trips"] == len(eng.lifetime)  # no re-trip
        for name, m in eng.health.monitors.items():
            assert m.last_err < 1.1 * base[name] + 0.02
        assert rep.flaps == 0


def test_unmonitored_engine_drifts_monitored_recovers():
    """The headline resilience claim in miniature: after heavy aging,
    an unmonitored engine's probe error degrades by >= 2x while the
    monitored engine stays within 10% (+abs slack) of fresh."""
    from repro.kernels.cim_mvm.ops import cim_mvm

    def probe_err(eng):
        errs = []
        for name, lt in eng.lifetime.items():
            mon = eng.health.monitors[name]
            y = np.asarray(cim_mvm(mon.probes_dev, lt.dep))
            errs.append(probe_error(y, mon.y_ref))
        return float(np.median(errs))

    with tempfile.TemporaryDirectory() as d:
        mon_eng = _engine(d, health=_health())
        fresh = probe_err(mon_eng)
        for _ in range(4):
            mon_eng.check_health()
        # Unmonitored twin: same aging, never probed/healed.
        un_eng = _engine(d, health=_health())
        un_eng.advance(1e4)
        mon_eng.advance(1e4)
        # The ladder climbs as far as it needs to: recalibration fixes
        # the column-separable drift but not the per-cell relaxation
        # residual, so the detector re-trips and the second check
        # escalates to a reprogram (fresh draw, clock reset).
        mon_eng.check_health()              # trip -> recalibrate
        mon_eng.check_health()              # re-trip -> reprogram
        degraded = probe_err(un_eng)
        healed = probe_err(mon_eng)
        assert degraded >= 2.0 * max(fresh, 1e-3)
        assert healed <= 1.1 * fresh + 0.02


def test_hot_swap_is_atomic_and_generation_deterministic():
    """advance() replaces the cim tree with fresh dicts — the old tree
    object and its leaves are never mutated — and same-seed engines
    aged identically generate bit-identical tokens across the swap."""
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, health=_health())
        old_tree = eng.cim
        old_subs = {k: v for k, v in old_tree.items()}
        old_leaves = jax.tree_util.tree_leaves(old_tree)
        eng.advance(1e4)
        assert eng.cim is not old_tree
        # Old tree untouched: same sub-dicts, same leaf objects.
        assert all(old_tree[k] is old_subs[k] for k in old_subs)
        for a, b in zip(jax.tree_util.tree_leaves(old_tree),
                        old_leaves):
            assert a is b
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                     0, 128)
        out = np.asarray(eng.generate(prompts, 4, seed=0))

        eng2 = _engine(d, health=_health())
        eng2.advance(1e4)
        np.testing.assert_array_equal(
            out, np.asarray(eng2.generate(prompts, 4, seed=0)))


def test_age_per_token_advances_clock_via_generate():
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, health=_health(age_per_token=2.0))
        ages0 = {n: lt.age for n, lt in eng.lifetime.items()}
        prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 4),
                                     0, 128)
        eng.generate(prompts, 3)
        for n, lt in eng.lifetime.items():
            assert lt.age == ages0[n] + 6.0


def test_health_requires_nonideal_model():
    """health= without a nonideal model (or with an ideal one) arms
    nothing — no lifetime capture, no controller, no probe overhead."""
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(d, health=_health(), nonideal=None)
        assert eng.health is None and eng.lifetime == {}
        assert eng.check_health() is None and eng.health_report is None
        eng.advance(10.0)  # no-op, must not raise


def test_demotion_sentinel_serves_digital_fallback():
    """A runtime-demoted deployment routes through the digital matmul.

    The sentinel is consumed at the *model* layer (``_cim_matmul`` has
    the full-precision weight; ``cim_mvm`` does not), so that is the
    routing under test: the served output equals x @ W exactly for the
    demoted deployment and stays on the quantised crossbar path for the
    healthy one."""
    from repro.core.tiling import CrossbarSpec
    from repro.kernels.cim_mvm.ops import deploy
    from repro.deploy.lifetime import DEMOTED_RUNTIME
    from repro.models.model import _cim_matmul

    spec = CrossbarSpec(rows=16, cols=16, n_bits=8)
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 8)) * 0.1
    dep, _ = deploy(w, spec, "mdm")
    dep = dataclasses.replace(dep, degraded=jnp.int32(0))
    demoted = dataclasses.replace(
        dep, degraded=jnp.int32(DEMOTED_RUNTIME))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    np.testing.assert_allclose(np.asarray(_cim_matmul(x, w, demoted)),
                               np.asarray(x @ w), rtol=1e-6)
    assert not np.allclose(np.asarray(_cim_matmul(x, w, dep)),
                           np.asarray(x @ w), rtol=1e-7)


# ---------------------- ragged probe-group padding ------------------------


def _ragged_lifetimes(shapes, model, seed=5):
    """Hand-built lifetimes forming one ragged (slot, pname) group."""
    from repro.core.tiling import CrossbarSpec
    from repro.deploy.engine import package_deployment_host
    from repro.deploy.lifetime import MatrixLifetime
    from repro.deploy.planner import plan_matrices
    from repro.nonideal.inject import sample_deployment_cells

    spec = CrossbarSpec(rows=16, cols=16, n_bits=4)
    rs = np.random.RandomState(0)
    mats = {f"s/p/0/{i}": rs.randn(*sh).astype(np.float32) * 0.1
            for i, sh in enumerate(shapes)}
    grids = {n: spec.grid(*w.shape) for n, w in mats.items()}
    key = jax.random.PRNGKey(seed)
    cells = sample_deployment_cells(key, grids, spec, model)
    plans, _ = plan_matrices(mats, spec, "mdm")
    lifetimes = {}
    for i, (name, w) in enumerate(mats.items()):
        cap: dict = {}
        plan = plans[name]
        dep = package_deployment_host(w, spec, "mdm", 0.02, plan,
                                      cells=cells[name], nonideal=model,
                                      noise_tag=i, capture=cap)
        lifetimes[name] = MatrixLifetime(
            name=name, noise_tag=i, spec=spec, model=model, eta=0.02,
            w=w, row_position=np.asarray(plan.row_position),
            reversed_df=bool(plan.reversed_dataflow),
            col_position=(None if plan.col_position is None else
                          np.asarray(plan.col_position, np.int32)),
            stuck_phys=cells[name].stuck,
            codes=cap["codes"], stuck_log=cap["stuck_log"],
            gamma_log=cap["gamma_log"], relax_log=cap["relax_log"],
            dep=dep, key=jax.random.fold_in(key, i),
            age=float(model.drift_time))
    return lifetimes


def test_pad_host_deployment_preserves_outputs():
    """Zero-drive padding is output-invariant: the padded deployment
    read with zero-padded inputs and sliced at the true out_dim equals
    the unpadded read (zero codes program no bits; every cell's
    distortion is a function of its own code/position only)."""
    from repro.deploy import pad_host_deployment
    from repro.kernels.cim_mvm.ops import cim_mvm

    model = NonidealModel(drift_nu=0.1, sigma_program=0.03)
    lt = _ragged_lifetimes([(24, 12)], model)["s/p/0/0"]
    dep = lt.dep
    i0, n0 = dep.codes.shape
    padded = pad_host_deployment(dep, i0 + 32, n0 + 8, dep.in_dim + 32,
                                 dep.out_dim + 2, rows=16)
    assert padded.codes.shape == (i0 + 32, n0 + 8)
    assert padded.in_dim == dep.in_dim + 32
    assert padded.out_dim == dep.out_dim + 2
    x = np.random.RandomState(3).randn(4, dep.in_dim).astype(np.float32)
    xp = np.zeros((4, padded.in_dim), np.float32)
    xp[:, :dep.in_dim] = x
    y_ref = np.asarray(cim_mvm(jnp.asarray(x), dep))
    y_pad = np.asarray(cim_mvm(jnp.asarray(xp), padded))
    assert y_pad.shape == (4, padded.out_dim)
    np.testing.assert_allclose(y_pad[:, :dep.out_dim], y_ref,
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):          # whole-tile units only
        pad_host_deployment(dep, i0 + 3, n0, dep.in_dim, dep.out_dim,
                            rows=16)


def test_controller_pads_ragged_group_into_one_vmap_dispatch():
    """A ragged (slot, pname) group rides the padded vmapped probe
    round — one host-level cim_mvm dispatch, per-matrix results equal
    to the sequential reads — and a full probe round over it feeds the
    detectors without tripping on the padding."""
    from repro.health import HealthController
    from repro.kernels.cim_mvm import ops as cim_ops

    model = NonidealModel(drift_nu=0.1, sigma_relax=0.08,
                          sigma_program=0.03)
    lifetimes = _ragged_lifetimes([(24, 12), (16, 8), (24, 8)], model)
    ctrl = HealthController(lifetimes, _health())
    live = list(lifetimes.items())
    assert not ctrl._stackable(live)         # genuinely ragged

    calls = {"n": 0}
    orig = cim_ops.cim_mvm

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    cim_ops.cim_mvm = counting
    try:
        results = ctrl._probe_reads(live, None)
    finally:
        cim_ops.cim_mvm = orig
    assert calls["n"] == 1                   # one vmapped dispatch
    for name, lt in live:
        ref = np.asarray(orig(ctrl.monitors[name].probes_dev, lt.dep))
        assert results[name].shape == ref.shape
        np.testing.assert_allclose(results[name], ref,
                                   rtol=1e-5, atol=1e-5)
    for _ in range(4):                       # warmup + steady state
        rep = ctrl.probe()
    assert ctrl.report().counters["trips"] == 0


def test_controller_ragged_meta_conflict_falls_back_sequential():
    """Members whose *static* meta genuinely conflicts (here: one
    member carrying a different parasitic eta) cannot share a padded
    tree; the round must fall back to per-matrix reads, not crash."""
    import dataclasses as dc

    from repro.health import HealthController
    from repro.kernels.cim_mvm.ops import cim_mvm

    model = NonidealModel(drift_nu=0.1, sigma_program=0.03)
    lifetimes = _ragged_lifetimes([(24, 12), (16, 8)], model)
    name0 = "s/p/0/0"
    lt0 = lifetimes[name0]
    lt0.dep = dc.replace(lt0.dep, eta=lt0.dep.eta * 2)
    ctrl = HealthController(lifetimes, _health())
    live = list(lifetimes.items())
    assert ctrl._padded_probe_reads(live, None) is None
    results = ctrl._probe_reads(live, None)
    for name, lt in live:
        ref = np.asarray(cim_mvm(ctrl.monitors[name].probes_dev,
                                 lt.dep))
        np.testing.assert_allclose(results[name], ref,
                                   rtol=1e-6, atol=1e-6)
