"""Tier-1 gate + mutation tests for the semantic registry auditor.

The audit must (a) pass on the real registries — every registered
strategy's params reach the fingerprint, pipeline and plan-cache
layers, the cache tokens are collision-free, the legacy mode tokens
are stable, every benchmark module is registered and nightly-
reachable, and the telemetry metric declarations match the live
default registry — and (b) demonstrably *fail* when handed a broken
registry: a leaky-fingerprint strategy, an unregistered benchmark
module, a typo'd nightly ``--only``, a dynamic/duplicated/dead metric
name.  (b) is what makes (a) trustworthy.
"""
from __future__ import annotations

import dataclasses

import pytest

from repro.analysis import audit
from repro.core.tiling import CrossbarSpec
from repro.deploy.cache import plan_key
from repro.mapping.base import register, unregister
from repro.mapping.pipeline import MappingPipeline, resolve_pipeline
from repro.mapping.rows import MdmRows


def test_live_registries_audit_clean():
    assert [f.format() for f in audit.run_audit()] == []


# ----------------------- fingerprint mutation test ------------------------


@pytest.fixture
def leaky_strategy():
    """Register a parametrised row pass whose fingerprint drops params.

    This is the exact bug class the audit exists for: ``alpha`` changes
    planning behaviour but not the cache identity, so two different
    deployments would share a PlanCache entry.
    """

    @register("rows", "_leaky_test")
    @dataclasses.dataclass(frozen=True)
    class LeakyRows(MdmRows):
        alpha: float = 1.0

        def fingerprint(self):  # drops alpha — deliberately broken
            return self.name

    try:
        yield LeakyRows
    finally:
        unregister("rows", "_leaky_test")


def test_audit_catches_leaky_fingerprint(leaky_strategy):
    findings = audit.audit_fingerprint_coverage()
    mine = [f for f in findings if f.subject == "rows/_leaky_test"]
    assert {f.code for f in mine} == {"AUD001", "AUD002", "AUD003"}
    assert any("alpha" in f.message for f in mine)
    # the real registries must still be clean around the mutant
    assert [f for f in findings if f.subject != "rows/_leaky_test"] == []


def test_audit_passes_honest_parametrised_strategy():
    """A field-carrying pass with the default fingerprint() is covered."""

    @register("rows", "_honest_test")
    @dataclasses.dataclass(frozen=True)
    class HonestRows(MdmRows):
        alpha: float = 1.0

    try:
        assert [f for f in audit.audit_fingerprint_coverage()
                if f.subject == "rows/_honest_test"] == []
        # and its two parametrisations get distinct cache addresses
        spec = CrossbarSpec()
        keys = {plan_key("0" * 64, spec,
                         MappingPipeline(rows=HonestRows(alpha=a)
                                         ).cache_token())
                for a in (1.0, 2.0)}
        assert len(keys) == 2
    finally:
        unregister("rows", "_honest_test")


def test_subclass_with_fields_never_gets_legacy_token(leaky_strategy):
    """cache_token collapses by exact equality, not isinstance: a
    parametrised MdmRows subclass must NOT reuse the bare "mdm" token
    (pinned here because the auditor's AUD003 depends on it)."""
    token = MappingPipeline(rows=leaky_strategy()).cache_token()
    assert token != "mdm"
    assert token.startswith("pipe:")
    assert MappingPipeline(rows=MdmRows()).cache_token() == "mdm"


def test_legacy_tokens_pinned():
    for mode in ("baseline", "reverse", "sort", "mdm"):
        assert resolve_pipeline(mode).cache_token() == mode
    assert resolve_pipeline("mdm", have_faults=True).cache_token() == "mdm"


# ------------------------- benchmark-registry audit -----------------------


def test_benchmark_audit_clean_on_real_repo():
    assert [f.format() for f in audit.audit_benchmark_registry()] == []


def test_benchmark_audit_flags_unregistered_module():
    import benchmarks.run as run

    files = sorted(run.registered_modules()) + ["shiny_new_bench"]
    findings = audit.audit_benchmark_registry(module_files=files)
    assert [f.code for f in findings] == ["AUD005"]
    assert "shiny_new_bench" in findings[0].message \
        or "shiny_new_bench" in findings[0].subject


def test_benchmark_audit_flags_missing_module_file():
    import benchmarks.run as run

    files = sorted(run.registered_modules() - {"theorem1"})
    findings = audit.audit_benchmark_registry(module_files=files)
    assert {f.code for f in findings} == {"AUD005"}
    assert any("theorem1" in f.message for f in findings)


def test_benchmark_audit_flags_bad_nightly_token():
    findings = audit.audit_benchmark_registry(
        nightly_text="python -m benchmarks.run --only fault_tolerence\n")
    assert [f.code for f in findings] == ["AUD006"]
    assert "fault_tolerence" in findings[0].message


def test_benchmark_audit_flags_nightly_without_benchmarks():
    findings = audit.audit_benchmark_registry(
        nightly_text="python -m pytest -q\n")
    assert [f.code for f in findings] == ["AUD006"]
    assert "never invokes" in findings[0].message


# -------------------------- --only validation -----------------------------


def test_resolve_only_by_name_module_and_error():
    import benchmarks.run as run

    # An exact registered-name match wins even when the token is also
    # a module name (fault_tolerance backs fault_line_open too; the
    # nightly lines must not double-run the sweep) ...
    assert [b.name for b in run.resolve_only("fault_tolerance")] \
        == ["fault_tolerance"]
    assert [b.name for b in run.resolve_only("solver_throughput")] \
        == ["solver_throughput"]
    # ... and a pure module token still fans out to every bench it
    # backs.
    assert [b.name for b in run.resolve_only("hypothesis_fit")] \
        == ["manhattan_hypothesis_fit"]
    with pytest.raises(KeyError, match="unknown benchmark"):
        run.resolve_only("no_such_bench")


# -------------------------- metric-registry audit -------------------------


_DECL = ('from repro import telemetry as tm\n'
         'C = tm.counter("repro_widget_total", "Widgets.")\n')


def test_metric_audit_clean_on_real_repo():
    assert [f.format() for f in audit.audit_metric_registry()] == []


def test_metric_audit_accepts_matching_declaration():
    findings = audit.audit_metric_registry(
        src_files={"a.py": _DECL}, live_names=["repro_widget_total"])
    assert findings == []


def test_metric_audit_flags_non_literal_name():
    src = ('from repro import telemetry as tm\n'
           'NAME = "repro_dynamic_total"\n'
           'C = tm.counter(NAME)\n')
    findings = audit.audit_metric_registry(src_files={"a.py": src},
                                           live_names=[])
    assert [f.code for f in findings] == ["AUD007"]
    assert "non-literal" in findings[0].message


def test_metric_audit_flags_duplicate_declaration():
    findings = audit.audit_metric_registry(
        src_files={"a.py": _DECL, "b.py": _DECL},
        live_names=["repro_widget_total"])
    assert [f.code for f in findings] == ["AUD007"]
    assert "already declared" in findings[0].message


def test_metric_audit_flags_declared_but_not_live():
    findings = audit.audit_metric_registry(src_files={"a.py": _DECL},
                                           live_names=[])
    assert [f.code for f in findings] == ["AUD007"]
    assert "absent from the live" in findings[0].message


def test_metric_audit_flags_live_undeclared_repro_metric():
    findings = audit.audit_metric_registry(
        src_files={}, live_names=["repro_ghost_total"])
    assert [f.code for f in findings] == ["AUD007"]
    assert "no module-level declaration" in findings[0].message
    # foreign namespaces are not ours to police
    assert audit.audit_metric_registry(src_files={},
                                       live_names=["python_info"]) == []
