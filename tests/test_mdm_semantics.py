"""MDM semantics preservation — the paper's core exactness guarantee.

MDM only relabels *physical positions* (dataflow mirror + row
permutation); inverting the permutation digitally at the input mux must
reproduce the original matmul exactly.  These tests round-trip
``deploy()`` -> ``permute_inputs``/``placed_masks`` -> ``cim_mvm``
against the plain bit-sliced quantised matmul at eta = 0.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitslice import bitslice, unbitslice
from repro.core.mdm import (
    MODES,
    permute_inputs,
    placed_masks,
    plan_from_bits,
)
from repro.core.tiling import CrossbarSpec, reverse_dataflow, tile_masks
from repro.kernels.cim_mvm.ops import cim_mvm, deploy

SPEC = CrossbarSpec(rows=16, cols=16, n_bits=8)


@pytest.mark.parametrize("mode", MODES)
def test_placed_masks_are_row_relabelling(mode):
    """placed_masks + permute_inputs == identity on the tile matmul:
    sum_p x'[p] * placed[p, c] must equal sum_q x[q] * mask[q, c] for
    every tile and every physical column c."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (48, 6)) * 0.1
    sliced = bitslice(w, SPEC.n_bits)
    plan = plan_from_bits(sliced.bits, sliced.scale, SPEC, mode)
    masks = tile_masks(sliced.bits, SPEC)                # (Ti, Tn, R, C)
    logical = reverse_dataflow(masks) if mode in ("reverse", "mdm") else masks
    placed = placed_masks(sliced.bits, plan, SPEC)
    ti, tn = masks.shape[:2]
    x = jax.random.normal(jax.random.PRNGKey(1), (ti, SPEC.rows))
    for a in range(ti):
        for b in range(tn):
            xp = permute_inputs(x[a], plan, a, b)
            got = np.asarray(xp @ placed[a, b].astype(jnp.float32))
            want = np.asarray(x[a] @ logical[a, b].astype(jnp.float32))
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("shape", [
    (48, 6),
    pytest.param((130, 21), marks=pytest.mark.slow),  # multi-tile grid
])
def test_deploy_eta0_equals_quantised_matmul(mode, shape):
    """End-to-end: the CIM path at eta = 0 is exactly x @ quantise(W)
    for EVERY deployment mode — the permutation never changes results."""
    I, N = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(I + N))
    w = jax.random.normal(k1, (I, N)) * 0.2
    x = jax.random.normal(k2, (4, I))
    wq = unbitslice(bitslice(w, SPEC.n_bits))
    dep, _ = deploy(w, SPEC, mode, eta=0.0)
    y = cim_mvm(x, dep)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ wq),
                               rtol=1e-5, atol=1e-5)


def test_all_modes_identical_at_eta0():
    """At eta = 0 the four ablations are the *same* function (they only
    differ in physical placement, which eta = 0 makes unobservable)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    w = jax.random.normal(k1, (64, 12)) * 0.15
    x = jax.random.normal(k2, (3, 64))
    outs = []
    for mode in MODES:
        dep, _ = deploy(w, SPEC, mode, eta=0.0)
        outs.append(np.asarray(cim_mvm(x, dep)))
    for other in outs[1:]:
        np.testing.assert_allclose(outs[0], other, rtol=1e-6, atol=1e-6)


def test_plan_permutation_is_bijective():
    """Every tile's row_perm is a true permutation and row_position its
    inverse (the digital mux can always undo the physical placement)."""
    w = jax.random.normal(jax.random.PRNGKey(11), (70, 9)) * 0.1
    sliced = bitslice(w, SPEC.n_bits)
    plan = plan_from_bits(sliced.bits, sliced.scale, SPEC, "mdm")
    perm = np.asarray(plan.row_perm)
    pos = np.asarray(plan.row_position)
    ti, tn, R = perm.shape
    for a in range(ti):
        for b in range(tn):
            assert sorted(perm[a, b].tolist()) == list(range(R))
            assert np.array_equal(perm[a, b][pos[a, b]], np.arange(R))
