"""Trip-count-aware HLO cost walker."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_scale_with_trip_count():
    def make(R):
        def f(ws, x):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y
        return f

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    flops = {}
    for R in (2, 8):
        ws = jax.ShapeDtypeStruct((R, 256, 256), jnp.float32)
        flops[R] = analyze(_compile(make(R), ws, x)).flops
        assert flops[R] >= 2 * 128 * 256 * 256 * R
    ratio = flops[8] / flops[2]
    assert 3.5 < ratio < 4.5


def test_plain_matmul_flops():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = analyze(_compile(f, a, b))
    expected = 2 * 64 * 128 * 32
    assert expected <= c.flops <= expected * 1.1


def test_collective_parsing_synthetic():
    """Regex-level check on hand-written HLO (collectives need >1 device
    to appear in real lowering)."""
    hlo = """
ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[256,256]{1,0} all-gather(%all-reduce.1), dimensions={0}
  ROOT %copy.1 = f32[128,256]{1,0} copy(%all-reduce.1)
}
"""
    c = analyze(hlo)
    ar = 128 * 256 * 4
    assert c.coll_breakdown["all-reduce"] == ar
    assert c.coll_breakdown["all-gather"] == ar  # operand bytes
    assert c.collective_bytes == 2 * ar


def test_loop_collective_multiplied():
    hlo = """
%body (arg: (s32[], f32[64])) -> (s32[], f32[64]) {
  %arg = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[64]{0} get-tuple-element(%arg), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %xr = f32[64]{0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[64]{0}) tuple(%i2, %xr)
}

%cond (arg2: (s32[], f32[64])) -> pred[] {
  %arg2 = (s32[], f32[64]{0}) parameter(0)
  %j = s32[] get-tuple-element(%arg2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%j, %n), direction=LT
}

ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[64]{0}) tuple(%zero, %p0)
  %w = (s32[], f32[64]{0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[64]{0} get-tuple-element(%w), index=1
}
"""
    c = analyze(hlo)
    assert c.coll_breakdown["all-reduce"] == 10 * 64 * 4
    assert c.loop_trip_counts == [10]
