"""Tier-1 gate: the repo lints clean under its own rules.

``src/`` plus the linted tool trees (benchmarks/, scripts/) must carry
zero unsuppressed findings — every intentional deviation needs an
inline justified suppression.  The budget assertion keeps the linter
honest about its design point: a pure-AST pass that never imports jax
stays fast enough to run on every commit.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

from repro.analysis import run_paths

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LINT_TREES = [os.path.join(ROOT, d)
              for d in ("src", "benchmarks", "scripts")]


def test_repo_lints_clean():
    t0 = time.perf_counter()
    findings, files = run_paths(LINT_TREES)
    dt = time.perf_counter() - t0
    unsuppressed = [f.format() for f in findings if not f.suppressed]
    assert unsuppressed == []
    assert files > 50  # the walk actually found the codebase
    assert dt < 5.0, f"lint took {dt:.1f}s; budget is 5s"


def test_every_suppression_has_a_justification():
    findings, _ = run_paths(LINT_TREES)
    for f in (f for f in findings if f.suppressed):
        with open(f.path) as fh:
            line = fh.read().splitlines()[f.line - 1]
        assert "--" in line.split("reprolint:")[1], (
            f"{f.path}:{f.line} suppresses {f.code} without a "
            f"'-- justification'")


def test_cli_exits_zero_and_stays_jax_free():
    """The lint CLI as the nightly runs it: exit 0, no jax import."""
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "lint.py"),
         "src", "benchmarks", "scripts"],
        cwd=ROOT, capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "unsuppressed" in proc.stdout
    # jax-free is the CLI's speed contract (audit is opt-in via --audit)
    probe = subprocess.run(
        [sys.executable, "-c",
         "import sys; sys.argv = ['reprolint', 'scripts'];"
         "sys.path.insert(0, 'src');"
         "from repro.analysis.cli import main; main();"
         "assert 'jax' not in sys.modules, 'lint CLI imported jax'"],
        cwd=ROOT, capture_output=True, text=True, timeout=60)
    assert probe.returncode == 0, probe.stdout + probe.stderr


def test_cli_select_and_json():
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "lint.py"),
         "--json", "--select", "RPL001", "src"],
        cwd=ROOT, capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    import json

    out = json.loads(proc.stdout)
    assert out["unsuppressed"] == 0
    assert all(f["code"] == "RPL001" for f in out["findings"])
