"""Circuit-level solver: CG vs dense oracle, physics sanity, and the
Manhattan Hypothesis (Fig-2/Fig-4 analogues at test scale).

Covers both the single-tile oracle path (repro.crossbar.solver) and the
fused batched engine (repro.crossbar.batched); large shapes are marked
``slow`` and run in the nightly profile (scripts/test_nightly.sh).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import manhattan
from repro.core.tiling import CrossbarSpec
from repro.crossbar.batched import measured_nf_batched
from repro.crossbar.solver import (
    column_currents_dense,
    measured_nf,
    measured_nf_sequential,
)

SPEC = CrossbarSpec(rows=16, cols=16, n_bits=8)


def rand_mask(key, j, k, p=0.2):
    return (jax.random.uniform(key, (j, k)) < p).astype(np.float32)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("shape", [(8, 8), (12, 6), (16, 16)])
def test_cg_matches_dense_oracle(seed, shape):
    J, K = shape
    m = rand_mask(jax.random.PRNGKey(seed), J, K)
    res = measured_nf(jnp.asarray(m), SPEC)
    dense = column_currents_dense(np.asarray(m),
                                  np.full(J, SPEC.v_read), SPEC)
    np.testing.assert_allclose(np.asarray(res.currents), dense, rtol=1e-7)
    assert float(res.residual) < 1e-9


def test_zero_wire_resistance_limit():
    """With r -> 0 the measured currents approach the ideal MVM."""
    m = rand_mask(jax.random.PRNGKey(3), 8, 8, 0.3)
    spec = CrossbarSpec(rows=8, cols=8, n_bits=8, r=1e-6)
    res = measured_nf(jnp.asarray(m), spec)
    np.testing.assert_allclose(np.asarray(res.currents),
                               np.asarray(res.ideal), rtol=1e-5)
    assert float(res.nf_total) < 1e-4


def test_nf_grows_with_distance():
    """A single active cell farther from the I/O corner has larger NF."""
    nfs = []
    for (j, k) in [(0, 0), (4, 4), (7, 7)]:
        m = np.zeros((8, 8), np.float32)
        m[j, k] = 1
        res = measured_nf(jnp.asarray(m), SPEC)
        nfs.append(float(res.nf_total))
    assert nfs[0] < nfs[1] < nfs[2]


def test_antidiagonal_symmetry_circuit():
    """Fig-2: mirror-related configurations measure (nearly) equal NF."""
    m = rand_mask(jax.random.PRNGKey(5), 12, 12, 0.25)
    r1 = measured_nf(jnp.asarray(m), SPEC)
    r2 = measured_nf(jnp.asarray(m.T), SPEC)
    a, b = float(r1.nf_total), float(r2.nf_total)
    assert abs(a - b) / max(a, b) < 0.05


def test_manhattan_hypothesis_correlation():
    """Measured NF correlates linearly with the Eq-16 prediction across
    random tiles of fixed sparsity (test-scale Fig 4)."""
    keys = jax.random.split(jax.random.PRNGKey(7), 24)
    masks = np.stack([rand_mask(k, 16, 16, 0.2) for k in keys])
    res = measured_nf(jnp.asarray(masks), SPEC)
    measured = np.asarray(res.nf_total)
    predicted = np.asarray(
        manhattan.nonideality_factor(jnp.asarray(masks), SPEC.r, SPEC.r_on))
    r = np.corrcoef(measured, predicted)[0, 1]
    assert r > 0.8, f"Manhattan Hypothesis correlation too weak: r={r}"


def test_batched_matches_dense_oracle():
    """The fused engine solves a mixed-density batch to oracle accuracy."""
    keys = jax.random.split(jax.random.PRNGKey(13), 6)
    masks = np.stack([rand_mask(k, 12, 12, p)
                      for k, p in zip(keys, (0.05, 0.1, 0.2, 0.3, 0.5, 0.8))])
    res = measured_nf_batched(jnp.asarray(masks), SPEC)
    assert float(np.asarray(res.residual).max()) < 1e-9
    for i in range(masks.shape[0]):
        dense = column_currents_dense(masks[i], np.full(12, SPEC.v_read),
                                      SPEC)
        np.testing.assert_allclose(np.asarray(res.currents[i]), dense,
                                   rtol=1e-7)


def test_batched_matches_single_tile_path():
    """measured_nf routes batches to the engine; per-tile results must
    equal the single-tile oracle path bit-for-tolerance."""
    keys = jax.random.split(jax.random.PRNGKey(17), 5)
    masks = np.stack([rand_mask(k, 16, 16) for k in keys])
    batched = measured_nf(jnp.asarray(masks), SPEC)   # routes to engine
    for i in range(5):
        single = measured_nf(jnp.asarray(masks[i]), SPEC)
        # The two paths use different preconditioners; they agree to the
        # CG tolerance (1e-12 residual -> ~1e-7 in the currents; nf_total
        # is |sum di| — a cancellation-amplified difference — so looser).
        np.testing.assert_allclose(np.asarray(batched.currents[i]),
                                   np.asarray(single.currents), rtol=1e-6)
        np.testing.assert_allclose(float(batched.nf_total[i]),
                                   float(single.nf_total), rtol=1e-3)


def test_batched_early_exit_and_batch_dims():
    """The shared loop exits early (iterations << maxiter) and leading
    batch dims are preserved through the engine."""
    masks = (jax.random.uniform(jax.random.PRNGKey(19), (2, 3, 8, 8))
             < 0.25).astype(np.float32)
    res = measured_nf(jnp.asarray(masks), SPEC)
    assert res.nf_total.shape == (2, 3)
    assert res.currents.shape == (2, 3, 8)
    assert int(res.iterations) < 100          # line preconditioner: ~5
    assert float(np.asarray(res.residual).max()) < 1e-9


@pytest.mark.parametrize("shape", [(8, 2), (2, 8), (1, 4), (8, 1)])
def test_batched_degenerate_geometries(shape):
    """rows/cols < 3 fall back to the Jacobi preconditioner (the
    tridiagonal solve needs chains >= 3) and still match the oracle."""
    J, K = shape
    m = rand_mask(jax.random.PRNGKey(37), J, K, 0.4)
    res = measured_nf_batched(jnp.asarray(m)[None], SPEC)
    dense = column_currents_dense(np.asarray(m), np.full(J, SPEC.v_read),
                                  SPEC)
    np.testing.assert_allclose(np.asarray(res.currents[0]), dense,
                               rtol=1e-7)


def test_batched_per_tile_drive_voltages():
    """(T, J) per-tile v_in is honoured (superposition sanity: doubling
    the drive doubles the currents)."""
    m = np.stack([rand_mask(jax.random.PRNGKey(23), 8, 8, 0.3)] * 2)
    v = np.stack([np.full(8, SPEC.v_read), np.full(8, 2 * SPEC.v_read)])
    res = measured_nf_batched(jnp.asarray(m), SPEC, v_in=jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(res.currents[1]),
                               2 * np.asarray(res.currents[0]), rtol=1e-7)


@pytest.mark.slow
def test_batched_matches_sequential_large():
    """Full-scale equivalence: 64-tile batch of the paper's 64x64 tiles,
    fused engine vs the seed lax.map walk."""
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    masks = (jax.random.uniform(jax.random.PRNGKey(29), (64, 64, 64))
             < 0.2).astype(np.float32)
    rb = measured_nf_batched(jnp.asarray(masks), spec)
    rs = measured_nf_sequential(jnp.asarray(masks), spec)
    np.testing.assert_allclose(np.asarray(rb.currents),
                               np.asarray(rs.currents), rtol=1e-7)
    np.testing.assert_allclose(np.asarray(rb.nf_total),
                               np.asarray(rs.nf_total), rtol=1e-6)


@pytest.mark.slow
def test_cg_matches_dense_oracle_paper_geometry():
    """Oracle check on the paper's 128x10 crossbar (1280-node system)."""
    spec = CrossbarSpec(rows=128, cols=10, n_bits=10)
    m = rand_mask(jax.random.PRNGKey(31), 128, 10, 0.3)
    res = measured_nf(jnp.asarray(m), spec)
    dense = column_currents_dense(np.asarray(m),
                                  np.full(128, spec.v_read), spec)
    np.testing.assert_allclose(np.asarray(res.currents), dense, rtol=1e-7)


def test_mdm_reduces_measured_nf():
    """End-to-end: the MDM permutation lowers *circuit-measured* NF, not
    just the analytical score."""
    from repro.core.bitslice import bitslice
    from repro.core.mdm import placed_masks, plan_from_bits

    key = jax.random.PRNGKey(11)
    w = jax.random.normal(key, (16, 2)) * 0.05
    spec = CrossbarSpec(rows=16, cols=16, n_bits=8)
    sliced = bitslice(w, 8)
    base = plan_from_bits(sliced.bits, sliced.scale, spec, "baseline")
    mdm = plan_from_bits(sliced.bits, sliced.scale, spec, "mdm")
    m_base = placed_masks(sliced.bits, base, spec)[0, 0]
    m_mdm = placed_masks(sliced.bits, mdm, spec)[0, 0]
    nf_base = float(measured_nf(m_base, spec).nf_total)
    nf_mdm = float(measured_nf(m_mdm, spec).nf_total)
    assert nf_mdm < nf_base
