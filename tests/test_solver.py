"""Circuit-level solver: CG vs dense oracle, physics sanity, and the
Manhattan Hypothesis (Fig-2/Fig-4 analogues at test scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import manhattan
from repro.core.tiling import CrossbarSpec
from repro.crossbar.solver import column_currents_dense, measured_nf

SPEC = CrossbarSpec(rows=16, cols=16, n_bits=8)


def rand_mask(key, j, k, p=0.2):
    return (jax.random.uniform(key, (j, k)) < p).astype(np.float32)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("shape", [(8, 8), (12, 6), (16, 16)])
def test_cg_matches_dense_oracle(seed, shape):
    J, K = shape
    m = rand_mask(jax.random.PRNGKey(seed), J, K)
    res = measured_nf(jnp.asarray(m), SPEC)
    dense = column_currents_dense(np.asarray(m),
                                  np.full(J, SPEC.v_read), SPEC)
    np.testing.assert_allclose(np.asarray(res.currents), dense, rtol=1e-7)
    assert float(res.residual) < 1e-9


def test_zero_wire_resistance_limit():
    """With r -> 0 the measured currents approach the ideal MVM."""
    m = rand_mask(jax.random.PRNGKey(3), 8, 8, 0.3)
    spec = CrossbarSpec(rows=8, cols=8, n_bits=8, r=1e-6)
    res = measured_nf(jnp.asarray(m), spec)
    np.testing.assert_allclose(np.asarray(res.currents),
                               np.asarray(res.ideal), rtol=1e-5)
    assert float(res.nf_total) < 1e-4


def test_nf_grows_with_distance():
    """A single active cell farther from the I/O corner has larger NF."""
    nfs = []
    for (j, k) in [(0, 0), (4, 4), (7, 7)]:
        m = np.zeros((8, 8), np.float32)
        m[j, k] = 1
        res = measured_nf(jnp.asarray(m), SPEC)
        nfs.append(float(res.nf_total))
    assert nfs[0] < nfs[1] < nfs[2]


def test_antidiagonal_symmetry_circuit():
    """Fig-2: mirror-related configurations measure (nearly) equal NF."""
    m = rand_mask(jax.random.PRNGKey(5), 12, 12, 0.25)
    r1 = measured_nf(jnp.asarray(m), SPEC)
    r2 = measured_nf(jnp.asarray(m.T), SPEC)
    a, b = float(r1.nf_total), float(r2.nf_total)
    assert abs(a - b) / max(a, b) < 0.05


def test_manhattan_hypothesis_correlation():
    """Measured NF correlates linearly with the Eq-16 prediction across
    random tiles of fixed sparsity (test-scale Fig 4)."""
    keys = jax.random.split(jax.random.PRNGKey(7), 24)
    masks = np.stack([rand_mask(k, 16, 16, 0.2) for k in keys])
    res = measured_nf(jnp.asarray(masks), SPEC)
    measured = np.asarray(res.nf_total)
    predicted = np.asarray(
        manhattan.nonideality_factor(jnp.asarray(masks), SPEC.r, SPEC.r_on))
    r = np.corrcoef(measured, predicted)[0, 1]
    assert r > 0.8, f"Manhattan Hypothesis correlation too weak: r={r}"


def test_mdm_reduces_measured_nf():
    """End-to-end: the MDM permutation lowers *circuit-measured* NF, not
    just the analytical score."""
    from repro.core.bitslice import bitslice
    from repro.core.mdm import placed_masks, plan_from_bits

    key = jax.random.PRNGKey(11)
    w = jax.random.normal(key, (16, 2)) * 0.05
    spec = CrossbarSpec(rows=16, cols=16, n_bits=8)
    sliced = bitslice(w, 8)
    base = plan_from_bits(sliced.bits, sliced.scale, spec, "baseline")
    mdm = plan_from_bits(sliced.bits, sliced.scale, spec, "mdm")
    m_base = placed_masks(sliced.bits, base, spec)[0, 0]
    m_mdm = placed_masks(sliced.bits, mdm, spec)[0, 0]
    nf_base = float(measured_nf(m_base, spec).nf_total)
    nf_mdm = float(measured_nf(m_mdm, spec).nf_total)
    assert nf_mdm < nf_base
