"""Tier-1 smoke tests for the example entry points.

The examples are the repo's front door; they run in-process here with
tiny shapes so a refactor that breaks their imports or call signatures
fails tier-1 instead of the first user.  Output goes to stdout (pytest
captures it); the assertions are "runs to completion" plus a couple of
cheap sanity greps on the printed physics.
"""
import jax

from repro.core.tiling import CrossbarSpec


def test_quickstart_runs_tiny(capsys):
    from examples import quickstart

    quickstart.main(in_dim=64, out_dim=8, batch=2,
                    spec=CrossbarSpec(rows=16, cols=16, n_bits=8))
    out = capsys.readouterr().out
    assert "pipeline=mdm" in out
    assert "pipeline=xchangr" in out
    assert "circuit-measured NF" in out
    # eta=0 semantics checks printed a small error (mdm AND xchangr)
    lines = [ln for ln in out.splitlines() if "max err" in ln]
    assert len(lines) == 2
    for line in lines:
        assert float(line.rsplit(":", 1)[1]) < 1e-5


def test_cim_deploy_runs_smoke_config(capsys):
    from examples import cim_deploy

    # Smallest smoke config; high --min-size keeps the per-leaf planning
    # to a handful of matrices, 16x16 tiles keep each one cheap.
    cim_deploy.main(["--arch", "phi3-mini-3.8b", "--mode", "mdm",
                     "--min-size", "4096", "--rows", "16",
                     "--cols", "16"])
    out = capsys.readouterr().out
    assert "TOTAL:" in out
    assert "deployment image for lm_head" in out


def test_examples_do_not_leak_x64():
    """The examples must not flip global precision state for the suite."""
    assert jax.numpy.zeros(1).dtype == jax.numpy.float32
