"""JAX version-compat shims: x64 scoping + AbstractMesh construction."""
import jax.numpy as jnp
import pytest

from repro.compat import enable_x64, make_abstract_mesh


def test_enable_x64_scopes_dtype():
    assert jnp.zeros((1,), jnp.float64).dtype == jnp.float32  # off outside
    with enable_x64():
        assert jnp.zeros((1,), jnp.float64).dtype == jnp.float64
    assert jnp.zeros((1,), jnp.float64).dtype == jnp.float32  # restored


def test_make_abstract_mesh_old_style_args():
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    assert dict(mesh.shape) == {"data": 16, "model": 16}
    pod = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert tuple(pod.axis_names) == ("pod", "data", "model")


def test_make_abstract_mesh_rejects_mismatched_args():
    with pytest.raises(ValueError):
        make_abstract_mesh((16, 16), ("data",))
