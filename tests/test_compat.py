"""JAX version-compat shims: x64 scoping, AbstractMesh construction,
and backend capability probes."""
import jax
import jax.numpy as jnp
import pytest

from repro.compat import (
    enable_x64,
    has_batched_tridiagonal_solve,
    make_abstract_mesh,
)


def test_enable_x64_scopes_dtype():
    assert jnp.zeros((1,), jnp.float64).dtype == jnp.float32  # off outside
    with enable_x64():
        assert jnp.zeros((1,), jnp.float64).dtype == jnp.float64
    assert jnp.zeros((1,), jnp.float64).dtype == jnp.float32  # restored


def test_make_abstract_mesh_old_style_args():
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    assert dict(mesh.shape) == {"data": 16, "model": 16}
    pod = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert tuple(pod.axis_names) == ("pod", "data", "model")


def test_make_abstract_mesh_rejects_mismatched_args():
    with pytest.raises(ValueError):
        make_abstract_mesh((16, 16), ("data",))


def test_tridiagonal_probe_on_active_backend():
    """CPU (and every backend this repo currently runs on) supports the
    batched tridiagonal_solve lowering the line preconditioner needs."""
    assert has_batched_tridiagonal_solve() is True
    # Cached: the second call must not re-execute the probe.
    assert has_batched_tridiagonal_solve() is True


def test_tridiagonal_probe_inside_trace():
    """The probe is consulted at trace time inside the engine's jit; it
    must return a concrete Python bool there, not a tracer (it escapes
    the ambient trace on a worker thread)."""
    has_batched_tridiagonal_solve.cache_clear()  # force a real probe
    seen = []

    @jax.jit
    def f(x):
        seen.append(has_batched_tridiagonal_solve())
        return x

    f(jnp.ones(2))
    assert seen == [True]


def test_tridiagonal_probe_unknown_platform_is_false():
    assert has_batched_tridiagonal_solve("no_such_backend") is False
