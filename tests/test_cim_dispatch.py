"""cim_mvm backend dispatch: three-way parity and the
never-interpret-on-a-hot-path guarantee.

Parity triangle per (mode, shape): the Pallas kernel in interpret mode
(bit-faithful block execution), the fused XLA fallback (the production
non-TPU path), and the materialised ``noisy_magnitude`` paper path
(``cim_mvm_ref``) must all agree to <= 1e-5 relative.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import has_pallas_lowering
from repro.core.mdm import MODES
from repro.core.tiling import CrossbarSpec
from repro.kernels.cim_mvm.ops import IMPLS, cim_mvm, deploy, resolve_impl
from repro.kernels.cim_mvm.ref import cim_mvm_ref

SPEC = CrossbarSpec(rows=16, cols=16, n_bits=8)


def _three_way(mode, shape, spec, eta=2e-3, n_bits=None):
    I, N, M = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(I * N + M))
    w = jax.random.normal(k1, (I, N)) * 0.2
    x = jax.random.normal(k2, (M, I))
    dep, plan = deploy(w, spec, mode, eta=eta)
    y_xla = np.asarray(cim_mvm(x, dep, impl="xla"))
    y_int = np.asarray(cim_mvm(x, dep, impl="interpret"))
    x_pad = jnp.pad(x, ((0, 0), (0, dep.codes.shape[0] - I)))
    y_ref = np.asarray(cim_mvm_ref(x_pad, dep.codes.astype(jnp.int32),
                                   plan, spec, eta)[:, :N])
    np.testing.assert_allclose(y_xla, y_int, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(y_xla, y_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(y_int, y_ref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("shape", [
    (48, 6, 4),             # non-divisible rows/cols
    (70, 13, 5),            # multi-tile, nothing divides
    pytest.param((130, 21, 9), marks=pytest.mark.slow),
])
def test_three_way_parity(mode, shape):
    _three_way(mode, shape, SPEC)


@pytest.mark.parametrize("mode", ["baseline", "mdm"])
def test_three_way_parity_odd_bits(mode):
    _three_way(mode, (33, 7, 3), CrossbarSpec(rows=32, cols=32, n_bits=4),
               eta=1e-3)


def test_resolve_impl_never_interprets():
    """"auto" resolves to a production path on every backend; interpret
    must be an explicit opt-in (tests only).  Pallas is TPU-gated: the
    kernel's grid accumulator assumes sequential grid semantics."""
    assert resolve_impl("auto") in ("pallas", "xla")
    assert resolve_impl("auto") != "interpret"
    expect = ("pallas" if jax.default_backend() == "tpu"
              and has_pallas_lowering() else "xla")
    assert resolve_impl("auto") == expect
    for impl in IMPLS:
        if impl != "auto":
            assert resolve_impl(impl) == impl
    with pytest.raises(ValueError):
        resolve_impl("mosaic")


def test_pallas_probe_is_cached_bool():
    a = has_pallas_lowering()
    assert isinstance(a, bool)
    assert has_pallas_lowering() == a
    if jax.default_backend() == "cpu":
        # 0.4.x CPU has no native pallas lowering; if this ever starts
        # passing, the dispatch upgrade to "pallas" is free and this
        # assert should be dropped.
        assert a is False


def test_xla_impl_batched_input():
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 16)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 64))
    dep, _ = deploy(w, CrossbarSpec(rows=64, cols=64, n_bits=8), "mdm")
    y = cim_mvm(x, dep, impl="xla")
    assert y.shape == (2, 3, 16)
    y_flat = cim_mvm(x.reshape(6, 64), dep, impl="xla")
    np.testing.assert_allclose(np.asarray(y).reshape(6, 16),
                               np.asarray(y_flat), rtol=1e-6)


def test_xla_matches_interpret_at_serving_scale():
    """Spot-check the default dispatch at a layer-like shape (the 2048^2
    10x-speed criterion is recorded by benchmarks/deploy_throughput; a
    tier-1 test just pins numerical agreement at a non-toy size)."""
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    w = jax.random.normal(jax.random.PRNGKey(3), (256, 192)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 256))
    dep, _ = deploy(w, spec, "mdm")
    y_auto = np.asarray(cim_mvm(x, dep))          # auto -> xla on CPU
    y_int = np.asarray(cim_mvm(x, dep, impl="interpret"))
    err = np.abs(y_auto - y_int) / np.maximum(np.abs(y_int), 1e-6)
    assert err.max() <= 1e-5
