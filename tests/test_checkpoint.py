"""Checkpoint: roundtrip (incl. bf16), retention, async, atomicity."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)


def tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"w": (jnp.ones((5,), jnp.bfloat16) * 1.5),
                  "n": jnp.asarray(7, jnp.int32)}}


def test_roundtrip_with_bf16():
    d = tempfile.mkdtemp()
    try:
        t = tree()
        save_checkpoint(d, 3, t)
        assert latest_step(d) == 3
        target = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        out = load_checkpoint(d, 3, target)
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(out)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_manager_retention_and_async():
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d, keep=2, async_save=True)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree())
        mgr.wait()
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
        assert steps == [3, 4]
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_atomic_no_tmp_left():
    d = tempfile.mkdtemp()
    try:
        save_checkpoint(d, 1, tree())
        assert not any(x.endswith(".tmp") for x in os.listdir(d))
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_restore_ignores_sharding_mismatch():
    """Elastic path: target ShapeDtypeStructs with no sharding restore to
    plain arrays (reshard-on-load happens via target sharding)."""
    d = tempfile.mkdtemp()
    try:
        t = {"w": jnp.ones((8, 8))}
        save_checkpoint(d, 1, t)
        out = load_checkpoint(
            d, 1, {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)})
        assert out["w"].shape == (8, 8)
    finally:
        shutil.rmtree(d, ignore_errors=True)
