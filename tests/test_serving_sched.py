"""Continuous-batching serving tier: scheduler policy, the slot-pool
recompilation guarantee, per-sequence determinism, and hot-swap
atomicity under load.

Contracts under test:

(a) ``RequestScheduler`` is strict-FIFO admission with validated
    submissions, correct live/finished bookkeeping, and streaming
    callbacks that fire once per sampled token with the done edge.
(b) ``SlotPool`` joins a prefilled sequence by index update — the slot
    clock takes the *true* prompt length and the padding tail of the
    fixed-shape prefill is masked to ``EMPTY_POS`` — and eviction
    self-masks the slot; neither changes a shape.
(c) ``ContinuousEngine`` at temperature 0 is token-identical to
    ``ServeEngine.generate``; per-request outputs are bit-deterministic
    across batch compositions, slot placement, staggered admission and
    mixed temperatures (the ``fold_in(PRNGKey(seed), n)`` schedule);
    and the decode/prefill/join/evict lowerables each compile exactly
    once per engine across all that churn.
(d) Hot swaps are atomic under the scheduler: a mid-load async
    redeploy (and a heal-driven epoch swap) leaves in-flight sequences
    bit-identical to a swap-free twin, lands with zero failed
    requests, and new admissions serve exactly the new bank.
(e) ``sample_tokens`` with an *array* temperature is a runtime operand
    (mixed temperatures, one trace) that agrees with the historical
    float path row-wise.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CimConfig, ModelConfig
from repro.models.attention import EMPTY_POS
from repro.nonideal import NonidealModel
from repro.serve import (
    ContinuousEngine,
    RequestScheduler,
    ServeEngine,
    SlotPool,
    sample_tokens,
)

VOCAB = 128


def _cfg(cim: bool = False) -> ModelConfig:
    return ModelConfig(
        name="cim-serving-sched", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=VOCAB,
        block_pattern=("attn",), remat="none", dtype="float32",
        attn_chunk=32,
        cim=CimConfig(enabled=cim, mode="mdm", rows=16, cols=16,
                      n_bits=4))


def _params(cfg, seed: int = 0):
    from repro.models.model import init_params
    return init_params(cfg, jax.random.PRNGKey(seed))


def _prompts(n, length=8, seed=5):
    rs = np.random.RandomState(seed)
    return rs.randint(0, VOCAB, size=(n, length)).astype(np.int32)


# --------------------------- scheduler policy -----------------------------


def test_scheduler_fifo_admission_and_bookkeeping():
    s = RequestScheduler()
    rids = [s.submit(np.array([1, 2, 3]), max_tokens=2) for _ in range(3)]
    assert rids == [0, 1, 2]
    assert s.queue_depth == 3 and s.pending == 3
    first = s.pop_admission()
    assert first.rid == 0                    # strict FIFO
    s.start(first, slot=1, epoch=0)
    assert s.pending == 3                    # 2 queued + 1 live
    with pytest.raises(ValueError):          # occupied slot
        s.start(s.pop_admission(), slot=1, epoch=0)
    assert not s.record_token(1, 7)          # 1/2 tokens: not done
    assert s.record_token(1, 9)              # 2/2: budget hit
    seq = s.finish(1)
    assert seq.tokens == [7, 9]
    assert s.results[0] == [7, 9]
    assert 1 not in s.live


def test_scheduler_validates_submissions():
    s = RequestScheduler()
    with pytest.raises(ValueError):
        s.submit(np.array([], np.int32), max_tokens=1)
    with pytest.raises(ValueError):
        s.submit(np.array([1]), max_tokens=0)


def test_scheduler_streams_tokens_with_done_edge():
    s = RequestScheduler()
    seen = []
    rid = s.submit(np.array([1]), max_tokens=2,
                   on_token=lambda r, t, d: seen.append((r, t, d)))
    s.start(s.pop_admission(), slot=0, epoch=0)
    s.record_token(0, 11)
    s.record_token(0, 12)
    assert seen == [(rid, 11, False), (rid, 12, True)]


# ----------------------------- slot pool ----------------------------------


def test_slot_pool_join_masks_padding_and_evict_self_masks():
    cfg = _cfg()
    pool = SlotPool(cfg, capacity=3, max_seq=16)
    slot_names = [k for k in pool.state if k != "pos"]
    st = pool.fresh_seq_state()
    # Simulate a prefill that wrote positions 0..15 into the B=1 cache.
    for name in slot_names:
        st[name]["kpos"] = jnp.broadcast_to(
            jnp.arange(16, dtype=jnp.int32),
            st[name]["kpos"].shape).astype(jnp.int32)
    assert pool.acquire() == 0               # lowest-free policy
    pool.join(0, st, length=5)
    pos = np.asarray(pool.state["pos"])
    assert pos[0] == 5 and pos[1] == 0
    kp = np.asarray(pool.state[slot_names[0]]["kpos"])[:, 0]
    # True prompt entries keep their positions; the padding tail the
    # fixed-shape prefill wrote is masked out of attention's view.
    assert np.array_equal(kp[:, :5],
                          np.broadcast_to(np.arange(5), kp[:, :5].shape))
    assert np.all(kp[:, 5:] == EMPTY_POS)
    pool.evict(0)
    assert np.asarray(pool.state["pos"])[0] == 0
    assert np.all(
        np.asarray(pool.state[slot_names[0]]["kpos"])[:, 0] == EMPTY_POS)
    assert pool.n_free == 3
    assert pool.traces == {"join": 1, "evict": 1, "merge": 0}


# ------------------------ engine determinism ------------------------------


def test_engine_greedy_matches_serve_engine():
    """Capacity-2 continuous decode == the single-batch reference."""
    cfg = _cfg()
    params = _params(cfg)
    prompts = _prompts(1)
    ref = np.asarray(ServeEngine(cfg, params, max_seq=64)
                     .generate(jnp.asarray(prompts), 8))[0]
    eng = ContinuousEngine(cfg, params, capacity=2, max_seq=64,
                           max_prompt=16)
    rid = eng.submit(prompts[0], max_tokens=8)
    out = eng.run()[rid]
    assert out == list(ref)


def test_composition_determinism_and_single_trace():
    """Per-request outputs don't depend on batchmates, admission order
    or slot placement; all the churn shares one trace per lowerable."""
    cfg = _cfg()
    params = _params(cfg)
    prompts = _prompts(4)
    temps = (0.0, 0.9, 1.3, 0.7)

    def alone(i):
        eng = ContinuousEngine(cfg, params, capacity=3, max_seq=64,
                               max_prompt=16)
        rid = eng.submit(prompts[i], max_tokens=6, temperature=temps[i],
                         seed=40 + i)
        return eng.run()[rid]

    solo = [alone(i) for i in range(4)]

    eng = ContinuousEngine(cfg, params, capacity=3, max_seq=64,
                           max_prompt=16)
    rids = [eng.submit(prompts[i], max_tokens=6, temperature=temps[i],
                       seed=40 + i) for i in range(2)]
    eng.step()                               # stagger: 2 in flight...
    rids += [eng.submit(prompts[i], max_tokens=6, temperature=temps[i],
                        seed=40 + i) for i in range(2, 4)]
    crowd = eng.run()
    for i, rid in enumerate(rids):
        assert crowd[rid] == solo[i], f"request {i} not bit-identical"
    assert eng.traces == {"prefill": 1, "decode": 1}
    assert eng.pool.traces["join"] == 1 and eng.pool.traces["evict"] == 1


def test_sample_tokens_array_temperature_matches_float_path():
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(jax.random.PRNGKey(4), (4, VOCAB))
    greedy = np.asarray(sample_tokens(logits, key, 0.0))
    hot = np.asarray(sample_tokens(logits, key, 0.8))
    mixed = np.asarray(sample_tokens(logits, key,
                                     jnp.array([0.0, 0.8, 0.0, 0.8])))
    assert np.array_equal(mixed[[0, 2]], greedy[[0, 2]])
    assert np.array_equal(mixed[[1, 3]], hot[[1, 3]])
    # Runtime operand: sweeping the temperature reuses one trace.
    traces = {"n": 0}

    def counted(lg, k, t):
        traces["n"] += 1
        return sample_tokens(lg, k, t)

    f = jax.jit(counted)
    for t in (0.0, 0.5, 1.5):
        f(logits, key, jnp.full((4,), t))
    assert traces["n"] == 1


# ------------------------- hot-swap atomicity -----------------------------


@pytest.mark.parametrize("swap", ["redeploy", "heal"])
def test_hot_swap_mid_load_atomicity(swap):
    """A mid-load bank swap never perturbs in-flight sequences.

    Twin engines serve the identical in-flight group; one takes a bank
    swap mid-decode (async redeploy to a second checkpoint, or a
    heal-driven aging restack), the other serves swap-free.  In-flight
    outputs must match bit-for-bit, every request must finish, and —
    for the redeploy — an admission after the swap must match a fresh
    engine deployed directly on the new checkpoint.
    """
    from repro.deploy import PlanCache
    from repro.health import DetectorConfig, HealthConfig

    cfg = _cfg(cim=True)
    params = _params(cfg)
    model = NonidealModel(drift_nu=0.05, sigma_program=0.02)
    health = (HealthConfig(n_probes=8,
                           detector=DetectorConfig(warmup=3))
              if swap == "heal" else None)
    prompts = _prompts(2, seed=9)

    with tempfile.TemporaryDirectory() as tmp:
        def engine(p):
            return ContinuousEngine(cfg, p, capacity=2, max_seq=64,
                                    max_prompt=16,
                                    plan_cache=PlanCache(tmp),
                                    nonideal=model, health=health)

        def fly(eng):
            rids = [eng.submit(prompts[i], max_tokens=6,
                               temperature=0.5 * i, seed=60 + i)
                    for i in range(2)]
            eng.step()                       # both in flight, epoch 0
            return rids

        ref = engine(params)
        ref_out = [ref.run()[r] for r in fly(ref)]

        eng = engine(params)
        rids = fly(eng)
        if swap == "redeploy":
            params2 = _params(cfg, seed=1)
            t = eng.begin_redeploy(params2)
            eng.run()
            t.join()
            eng.step()                       # install if not yet landed
        else:
            eng.advance(10.0)                # aging restack -> new epoch
            eng.run()
        assert eng.serving_epoch > 0
        out = [eng.results[r] for r in rids]
        assert out == ref_out                # in-flight: bit-identical
        assert all(len(t) == 6 for t in out)
        assert eng.traces["decode"] == 1     # epoch fan-out: same trace

        if swap == "redeploy":
            g2 = _prompts(1, seed=13)[0]
            rid = eng.submit(g2, max_tokens=6, temperature=0.7, seed=99)
            post = eng.run()[rid]
            fresh = engine(params2)
            rid_f = fresh.submit(g2, max_tokens=6, temperature=0.7,
                                 seed=99)
            assert post == fresh.run()[rid_f]


def test_engine_rejects_oversized_prompts_and_bad_configs():
    cfg = _cfg()
    params = _params(cfg)
    eng = ContinuousEngine(cfg, params, capacity=1, max_seq=32,
                           max_prompt=8)
    with pytest.raises(ValueError):
        eng.submit(np.arange(9, dtype=np.int32), max_tokens=1)
    with pytest.raises(ValueError):
        ContinuousEngine(cfg, params, capacity=1, max_seq=8,
                         max_prompt=16)
