import os
import sys

# Tests see the single real CPU device (the 512-device forcing is the
# dry-run's job only — see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
