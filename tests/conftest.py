import os
import sys

# 8-way host-device simulation so the sharded-solver parity tests
# (tests/test_solver_shard.py) exercise real multi-device shard_map in
# tier-1.  Must land before the first jax import initialises the
# backend; append so an operator-supplied XLA_FLAGS still wins.  (The
# 512-device forcing remains the dry-run's job only — launch/dryrun.py.)
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Persistent jit cache: tier-1 is compile-bound (a flat tail of ~200
# small jit compiles), so cache compiled executables across pytest runs
# in-repo (.jax_cache/, gitignored).  The min-compile-time floor is
# dropped to 0 because the tail is exactly the sub-second compiles the
# default threshold (1s) would refuse to cache.
import jax  # noqa: E402  (env above must precede backend init)

jax.config.update(
    "jax_compilation_cache_dir",
    os.path.join(os.path.dirname(__file__), "..", ".jax_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
