"""Whole-model deployment engine: fused-planner parity, plan cache,
model-param deployment and CIM serving end-to-end.

The engine's contract is *bit-identity*: the fused whole-model planner
(one jit over all layers' tiles, host-side bit-slicing) must produce
exactly the plans the per-layer ``plan_layer`` path produces, and a
cache hit must reproduce exactly what was stored.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CimConfig, ModelConfig
from repro.core.bitslice import magnitude_scale, magnitude_scale_host
from repro.core.mdm import MODES, plan_layer
from repro.core.tiling import CrossbarSpec
from repro.deploy import (
    PlanCache,
    collect_projection_matrices,
    deploy_model_params,
    fingerprint_matrices,
    plan_matrices,
)

SPEC = CrossbarSpec(rows=16, cols=16, n_bits=8)

# Mixed shapes, several of which don't divide the tile grid.
SHAPES = [(48, 6), (70, 13), (33, 7), (64, 12), (16, 2)]


def _mats(seed=0, scale=0.2):
    key = jax.random.PRNGKey(seed)
    return {f"m{j}_{i}x{n}": jax.random.normal(
        jax.random.fold_in(key, j), (i, n)) * scale
        for j, (i, n) in enumerate(SHAPES)}


def assert_plans_identical(a, b):
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


@pytest.mark.parametrize("mode", MODES)
def test_fused_plans_bit_identical_to_per_layer(mode):
    mats = _mats()
    plans, report = plan_matrices(mats, SPEC, mode)
    assert report["cache_misses"] == len(mats)
    for name, w in mats.items():
        assert_plans_identical(plans[name], plan_layer(w, SPEC, mode))


def test_fused_planner_sharded_over_tiles_mesh():
    """Sharding the tile population over the logical "tiles" mesh must
    not change a single bit of any plan."""
    from repro.distributed.solver_shard import tile_sharding_ctx

    mats = _mats(seed=3)
    base, _ = plan_matrices(mats, SPEC, "mdm")
    sharded, _ = plan_matrices(mats, SPEC, "mdm", ctx=tile_sharding_ctx())
    for name in mats:
        assert_plans_identical(base[name], sharded[name])


@pytest.mark.parametrize("n_bits", [4, 8])
def test_host_scale_mirror_bit_identical(n_bits):
    """magnitude_scale_host must track the eager-jnp chain bit-for-bit
    (it anchors the whole host bit-slicing parity argument)."""
    rng = np.random.default_rng(n_bits)
    for t in range(50):
        shape = (int(rng.integers(1, 200)), int(rng.integers(1, 200)))
        w = (rng.standard_normal(shape)
             * 10.0 ** rng.uniform(-6, 6)).astype(np.float32)
        a = np.float32(np.asarray(magnitude_scale(jnp.asarray(w), n_bits)))
        b = magnitude_scale_host(w, n_bits)
        assert a.tobytes() == b.tobytes(), (t, shape)


# ------------------------------- cache -----------------------------------

def test_plan_cache_hit_is_bit_identical(tmp_path):
    mats = _mats(seed=1)
    cache = PlanCache(str(tmp_path))
    for mode in ("baseline", "mdm"):
        cold, r1 = plan_matrices(mats, SPEC, mode, cache=cache)
        assert r1["cache_misses"] == len(mats)
        hit, r2 = plan_matrices(mats, SPEC, mode, cache=cache)
        assert r2["cache_hits"] == len(mats) and r2["cache_misses"] == 0
        for name in mats:
            assert_plans_identical(cold[name], hit[name])
            # ...and the cached plan still matches the per-layer oracle.
            assert_plans_identical(hit[name],
                                   plan_layer(mats[name], SPEC, mode))


def test_plan_cache_invalidation(tmp_path):
    mats = _mats(seed=2)
    cache = PlanCache(str(tmp_path))
    plan_matrices(mats, SPEC, "mdm", cache=cache)

    # Weight change -> replan (only the changed matrix misses).
    changed = dict(mats)
    name0 = next(iter(changed))
    changed[name0] = changed[name0] + 0.01
    _, r = plan_matrices(changed, SPEC, "mdm", cache=cache)
    assert r["cache_misses"] == 1 and r["cache_hits"] == len(mats) - 1

    # Mode change -> different keys -> full replan.
    _, r = plan_matrices(mats, SPEC, "sort", cache=cache)
    assert r["cache_misses"] == len(mats)

    # Spec change (device geometry) -> full replan.
    _, r = plan_matrices(mats, CrossbarSpec(rows=32, cols=32, n_bits=8),
                         "mdm", cache=cache)
    assert r["cache_misses"] == len(mats)

    # Keys are distinct across (weights, spec, mode).
    k1 = fingerprint_matrices(mats, SPEC, "mdm")
    k2 = fingerprint_matrices(mats, SPEC, "sort")
    k3 = fingerprint_matrices(changed, SPEC, "mdm")
    assert set(k1.values()).isdisjoint(k2.values())
    assert k1[name0] != k3[name0]


def test_plan_cache_corrupt_entry_is_a_miss(tmp_path):
    import shutil

    mats = {"m": jax.random.normal(jax.random.PRNGKey(0), (48, 6)) * 0.2}
    cache = PlanCache(str(tmp_path))
    plans, _ = plan_matrices(mats, SPEC, "mdm", cache=cache)
    keys = fingerprint_matrices(mats, SPEC, "mdm")
    path = cache._path(keys["m"])
    with open(path, "wb") as f:
        f.write(b"\x00garbage")
    # Remove the manifest too: it holds its own copy of the entry bytes
    # and would otherwise (correctly) mask the corrupted entry file.
    shutil.rmtree(tmp_path / "manifest")
    _, r = plan_matrices(mats, SPEC, "mdm", cache=cache)
    assert r["cache_misses"] == 1
    # The replan repaired the entry.
    fixed, r = plan_matrices(mats, SPEC, "mdm", cache=cache)
    assert r["cache_hits"] == 1
    assert_plans_identical(fixed["m"], plans["m"])


# ----------------------- per-checkpoint manifests -------------------------

def test_manifest_full_hit_one_read(tmp_path):
    """An unchanged checkpoint resolves every plan from ONE manifest
    read — no per-entry file opens — and bit-identically."""
    mats = _mats(seed=4)
    cache = PlanCache(str(tmp_path))
    cold, r1 = plan_matrices(mats, SPEC, "mdm", cache=cache)
    assert not r1["manifest_hit"]
    hit, r2 = plan_matrices(mats, SPEC, "mdm", cache=cache)
    assert r2["manifest_hit"]
    assert r2["cache_hits"] == len(mats) and r2["cache_misses"] == 0
    assert cache.stats.manifest_hits == 1
    # The per-entry store was never probed on the manifest hit.
    assert cache.stats.hits == 0
    for name in mats:
        assert_plans_identical(cold[name], hit[name])


def test_manifest_invalidation_on_weight_change(tmp_path):
    """Any changed matrix changes the manifest key: the stale manifest
    is unreachable, unchanged matrices still hit per-entry, and the new
    checkpoint gets its own manifest."""
    mats = _mats(seed=5)
    cache = PlanCache(str(tmp_path))
    plan_matrices(mats, SPEC, "mdm", cache=cache)

    changed = dict(mats)
    name0 = next(iter(changed))
    changed[name0] = changed[name0] + 0.01
    _, r = plan_matrices(changed, SPEC, "mdm", cache=cache)
    assert not r["manifest_hit"]
    assert r["cache_misses"] == 1 and r["cache_hits"] == len(mats) - 1
    # ...and the changed checkpoint now manifests too.
    _, r = plan_matrices(changed, SPEC, "mdm", cache=cache)
    assert r["manifest_hit"]
    # The original checkpoint's manifest still stands.
    _, r = plan_matrices(mats, SPEC, "mdm", cache=cache)
    assert r["manifest_hit"]


def test_manifest_corruption_falls_back_to_entries(tmp_path):
    import os

    mats = _mats(seed=6)
    cache = PlanCache(str(tmp_path))
    plans, _ = plan_matrices(mats, SPEC, "mdm", cache=cache)
    mdir = tmp_path / "manifest"
    mfiles = [os.path.join(r, f) for r, _, fs in os.walk(mdir)
              for f in fs]
    assert len(mfiles) == 1
    with open(mfiles[0], "wb") as f:
        f.write(b"{not json")
    fixed, r = plan_matrices(mats, SPEC, "mdm", cache=cache)
    assert not r["manifest_hit"]
    assert r["cache_hits"] == len(mats)     # per-entry fallback
    for name in mats:
        assert_plans_identical(fixed[name], plans[name])
    # The fallback rewrote a valid manifest.
    _, r = plan_matrices(mats, SPEC, "mdm", cache=cache)
    assert r["manifest_hit"]


# --------------------------- model deployment ----------------------------

SERVE_CFG = ModelConfig(
    name="cim-serve-test", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=2, d_ff=64, vocab_size=128, block_pattern=("attn",),
    remat="none", dtype="float32", attn_chunk=32,
    cim=CimConfig(enabled=True, mode="mdm", rows=16, cols=16, n_bits=4))


def test_deploy_model_params_structure(tmp_path):
    from repro.models.model import init_params

    params = init_params(SERVE_CFG, jax.random.PRNGKey(0))
    cache = PlanCache(str(tmp_path))
    cim, report = deploy_model_params(params, SERVE_CFG, cache=cache)
    slot = cim["slot0_attn"]
    # All seven projections deployed, stacked over the 2 pattern repeats.
    assert set(slot) == {"wq", "wk", "wv", "wo",
                         "ffn_w_gate", "ffn_w_up", "ffn_w_down"}
    reps = SERVE_CFG.pattern_repeats
    for dep in slot.values():
        assert dep.codes.shape[0] == reps
        assert dep.pos.shape[0] == reps
        assert dep.scale.shape == (reps,)
    assert report["n_matrices"] == 7 * reps
    # Redeploy of the unchanged params is a pure cache hit.
    _, report2 = deploy_model_params(params, SERVE_CFG, cache=cache)
    assert report2["cache_hits"] == report["n_matrices"]
    assert report2["cache_misses"] == 0


@pytest.mark.parametrize("mode", ["baseline", "mdm"])
def test_host_packaging_matches_device_deploy(mode):
    """package_deployment_host must reproduce ops.deploy bit-for-bit
    (codes, positions, scale) — it replaces the per-matrix device
    packaging loop at whole-model deployment time."""
    from repro.deploy import package_deployment_host
    from repro.kernels.cim_mvm.ops import deploy

    for shape in [(48, 6), (70, 13)]:
        w = jax.random.normal(jax.random.PRNGKey(sum(shape)), shape) * 0.2
        dep_dev, plan = deploy(w, SPEC, mode, eta=2e-3)
        dep_host = package_deployment_host(
            np.asarray(w, np.float32), SPEC, mode, 2e-3, plan)
        np.testing.assert_array_equal(np.asarray(dep_dev.codes),
                                      dep_host.codes)
        np.testing.assert_array_equal(np.asarray(dep_dev.pos),
                                      dep_host.pos)
        np.testing.assert_array_equal(np.asarray(dep_dev.scale),
                                      dep_host.scale)
        for f in ("n_bits", "wpt", "cols", "eta", "reversed_df",
                  "in_dim", "out_dim"):
            assert getattr(dep_dev, f) == getattr(dep_host, f)


def test_collect_projection_matrices_shapes():
    from repro.models.model import init_params

    params = init_params(SERVE_CFG, jax.random.PRNGKey(0))
    mats = collect_projection_matrices(params, SERVE_CFG)
    D = SERVE_CFG.d_model
    HDh = SERVE_CFG.n_heads * SERVE_CFG.resolved_head_dim
    assert mats["slot0_attn/wq/0"].shape == (D, HDh)
    assert mats["slot0_attn/wo/1"].shape == (HDh, D)
    assert mats["slot0_attn/ffn_w_up/0"].shape == (D, SERVE_CFG.d_ff)


# ------------------------------ serving ----------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_serve_engine_generates_under_cim(mode, tmp_path):
    """ServeEngine with cim.enabled serves tokens under every MDM
    ablation mode, through the backend-dispatched cim_mvm (never
    interpret — the generation would not finish otherwise)."""
    from repro.models.model import init_params
    from repro.serve import ServeEngine

    cfg = SERVE_CFG.replace(cim=CimConfig(
        enabled=True, mode=mode, rows=16, cols=16, n_bits=4))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seq=64,
                      plan_cache=PlanCache(str(tmp_path)))
    assert eng.cim is not None and eng.deploy_report["n_matrices"] == 14
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out = np.asarray(eng.generate(prompts, 3))
    assert out.shape == (2, 3)
    assert out.dtype == np.int32
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_serve_engine_clean_path_unchanged():
    from repro.models.model import init_params
    from repro.serve import ServeEngine

    cfg = SERVE_CFG.replace(cim=CimConfig(enabled=False))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seq=64)
    assert eng.cim is None
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out = np.asarray(eng.generate(prompts, 3))
    assert out.shape == (2, 3)
