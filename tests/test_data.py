"""Data pipeline determinism (the fault-tolerance keystone)."""
import os
import tempfile

import numpy as np

from repro.data import MemmapTokenDataset, SyntheticTokenDataset


def test_synthetic_deterministic_in_step():
    a = SyntheticTokenDataset(1000, 64, 8, seed=7)
    b = SyntheticTokenDataset(1000, 64, 8, seed=7)
    np.testing.assert_array_equal(a.batch_at(5), b.batch_at(5))
    assert not np.array_equal(a.batch_at(5), a.batch_at(6))


def test_synthetic_seed_changes_stream():
    a = SyntheticTokenDataset(1000, 64, 8, seed=1)
    b = SyntheticTokenDataset(1000, 64, 8, seed=2)
    assert not np.array_equal(a.batch_at(0), b.batch_at(0))


def test_synthetic_shapes_and_range():
    ds = SyntheticTokenDataset(517, 32, 4)
    x = ds.batch_at(0)
    assert x.shape == (4, 33) and x.dtype == np.int32
    assert x.min() >= 0 and x.max() < 517


def test_synthetic_is_learnable():
    """75% of transitions are a deterministic function of the previous
    two tokens — a competent LM must beat uniform entropy."""
    ds = SyntheticTokenDataset(256, 128, 4, seed=0)
    x = ds.batch_at(0).astype(np.int64)
    det = ((x[:, :-1] * 2654435761 + np.roll(x, 1, 1)[:, :-1] * 40503)
           % 256) == x[:, 1:]
    assert det.mean() > 0.5


def test_memmap_dataset():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "toks.bin")
        arr = (np.arange(10_000) % 900).astype(np.uint16)
        arr.tofile(path)
        ds = MemmapTokenDataset(path, 1000, 64, 4, seed=0)
        x = ds.batch_at(3)
        assert x.shape == (4, 65)
        np.testing.assert_array_equal(x, ds.batch_at(3))
        assert x.max() < 1000
