"""Gradient compression: quantisation bounds + error-feedback property.

Property tests are deterministic seeded parametrize grids (the
``hypothesis`` package is not installable in the offline CI image).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (
    compress_decompress,
    dequantize_int8,
    psum_compressed,
    quantize_int8,
)


@pytest.mark.parametrize("seed", [0, 1, 17, 123, 999])
@pytest.mark.parametrize("scale", [1e-6, 1e-2, 1.0, 37.5, 1e3])
def test_int8_roundtrip_error_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * scale
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-12


def test_error_feedback_unbiased_accumulation():
    """With error feedback, the *sum* of transmitted gradients tracks the
    sum of true gradients (residual never grows unboundedly)."""
    key = jax.random.PRNGKey(0)
    e = jnp.zeros((512,))
    sent_total = jnp.zeros((512,))
    true_total = jnp.zeros((512,))
    for i in range(50):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (512,)) * 0.01
        xq, e = compress_decompress(g + e)
        sent_total += xq
        true_total += g
    # residual bounded by one quantisation step of the last payload
    resid = np.abs(np.asarray(sent_total - true_total))
    assert resid.max() < 1e-3


def test_psum_compressed_single_shard():
    """On a 1-member axis, psum_compressed reduces to the identity up to
    quantisation error and returns a bounded residual."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("pod",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,))}
    e = {"w": jnp.zeros((64,))}

    def f(g, e):
        return psum_compressed(g, e, "pod")

    out, new_e = shard_map(f, mesh=mesh,
                           in_specs=({"w": P()}, {"w": P()}),
                           out_specs=({"w": P()}, {"w": P()}),
                           check_vma=False)(g, e)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=float(jnp.max(jnp.abs(g["w"]))) / 100)
    np.testing.assert_allclose(np.asarray(new_e["w"]),
                               np.asarray(g["w"] - out["w"]), atol=1e-6)


def test_training_converges_with_compression_math():
    """SGD on a quadratic with int8+EF compression converges like exact
    SGD (the classic error-feedback guarantee, small-scale)."""
    key = jax.random.PRNGKey(2)
    A = jax.random.normal(key, (32, 32)) / 8
    H = A @ A.T + 0.1 * jnp.eye(32)
    x_exact = jnp.ones((32,))
    x_comp = jnp.ones((32,))
    e = jnp.zeros((32,))
    lr = 0.1
    for _ in range(300):
        x_exact = x_exact - lr * (H @ x_exact)
        g = H @ x_comp
        gq, e = compress_decompress(g + e)
        x_comp = x_comp - lr * gq
    assert float(jnp.linalg.norm(x_comp)) < 1e-2 + \
        float(jnp.linalg.norm(x_exact)) * 2
