"""Theorem 1 (bit-level structured sparsity): property tests.

Property tests are deterministic seeded parametrize grids (the
``hypothesis`` package is not installable in the offline CI image).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import theory
from repro.core.bitslice import bitslice


@pytest.mark.parametrize("k", [1, 2, 3, 4, 6, 8])
@pytest.mark.parametrize("make,f0", [
    (lambda: theory.exponential(1.0), 1.0),
    (lambda: theory.exponential(3.0), 3.0),
    (lambda: theory.half_normal(0.5), np.sqrt(2 / np.pi) / 0.5),
    (lambda: theory.half_laplace(0.7), 1 / 0.7),
])
def test_theorem1_bound_quadrature(k, make, f0):
    """|p_k - 1/2| <= f(0)/2^(2+k) and p_k < 1/2, by quadrature."""
    f = make()
    p = float(theory.p_k_quadrature(f, k))
    bound = theory.theorem1_bound(f0, k)
    assert p < 0.5
    assert abs(p - 0.5) <= bound + 5e-4  # quadrature tolerance


@pytest.mark.parametrize("sigma", [0.05, 0.3, 1.0, 2.0])
@pytest.mark.parametrize("k", [1, 3, 6])
def test_theorem1_bound_empirical_halfnormal(sigma, k):
    """Sampled |w| ~ half-normal respects the bound within sampling noise."""
    key = jax.random.PRNGKey(int(sigma * 1e4) + k)
    w = jnp.abs(jax.random.normal(key, (200_000,)) * sigma)
    p = float(theory.p_k_empirical(w, k))
    f0 = float(np.sqrt(2 / np.pi) / sigma)
    bound = theory.theorem1_bound(f0, k)
    assert p < 0.5 + 0.01
    assert abs(p - 0.5) <= bound + 0.01


def test_pk_approaches_half():
    f = theory.exponential(1.0)
    ps = [float(theory.p_k_quadrature(f, k)) for k in (1, 4, 8)]
    assert abs(ps[2] - 0.5) < abs(ps[0] - 0.5)
    assert abs(ps[2] - 0.5) < 1e-2


def test_empirical_bit_densities_increase_with_k():
    """The structured sparsity MDM exploits: low-order planes denser."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (512, 512)) * 0.1
    dens = np.asarray(theory.empirical_bit_densities(w, 8))
    assert dens[0] < dens[-1]
    assert np.all(dens < 0.55)
    # high-order planes are sparse (the paper's >=76-80% sparsity regime)
    assert dens[0] < 0.1


def test_bit_indicator_matches_bitslice():
    """theory.bit_indicator and core.bitslice agree on the same planes."""
    key = jax.random.PRNGKey(1)
    w = jax.random.uniform(key, (1000,))
    n_bits = 6
    sliced = bitslice(w, n_bits, scale=jnp.asarray(1.0))
    # bitslice quantises first; compare on the quantised values
    q = jnp.round(w * 2 ** n_bits) / 2 ** n_bits
    q = jnp.clip(q, 0, 1 - 2.0 ** -n_bits)
    for k in range(1, n_bits + 1):
        ind = theory.bit_indicator(q, k)
        np.testing.assert_array_equal(np.asarray(ind),
                                      np.asarray(sliced.bits[:, k - 1]))
