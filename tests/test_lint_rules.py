"""Fixture tests for every reprolint rule (RPL001-RPL006).

Each rule has a paired bad/good fixture under tests/fixtures/lint/;
the bad file pins the exact (code, line) set the rule must report, the
good file pins zero findings under *all* rules — the good fixtures
deliberately exercise the rule's known near-miss patterns (terminating
branches, per-iteration fold_in, lambda parameter scopes, deferred jnp)
so false-positive regressions fail here, not in CI noise.

Fixtures are linted with an explicit ``role`` override: on disk they
live under tests/, where the key-discipline and interpret rules would
not apply.
"""
from __future__ import annotations

import os

import pytest

from repro.analysis import run_source
from repro.analysis.core import classify_path, suppressions

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures", "lint")


def lint_fixture(name: str, role: str = "library", select=None):
    path = os.path.join(FIXTURES, name)
    with open(path) as f:
        src = f.read()
    return run_source(path, src, role=role, select=select)


def codes_lines(findings, suppressed=False):
    return {(f.code, f.line) for f in findings
            if f.suppressed == suppressed}


BAD_EXPECTED = {
    # import bindings (3, 4), attribute uses (8, 12, 13), probe (18)
    "rpl001_bad.py": {("RPL001", 3), ("RPL001", 4), ("RPL001", 8),
                      ("RPL001", 12), ("RPL001", 13), ("RPL001", 18)},
    # float() (8), np.asarray (9), bool() (15), .item() (16)
    "rpl002_bad.py": {("RPL002", 8), ("RPL002", 9), ("RPL002", 15),
                      ("RPL002", 16)},
    # straight-line reuse (7), loop reuse (14), literal seed (19)
    "rpl003_bad.py": {("RPL003", 7), ("RPL003", 14), ("RPL003", 19)},
    # INTERPRET default (5), interpret=True (10), impl="interpret"
    # (14), kw-only None default (17)
    "rpl004_bad.py": {("RPL004", 5), ("RPL004", 10), ("RPL004", 14),
                      ("RPL004", 17)},
    # module constant (4), class body (8), function default (11)
    "rpl005_bad.py": {("RPL005", 4), ("RPL005", 8), ("RPL005", 11)},
    # time.perf_counter (7), time.time (8), time.monotonic (9),
    # from-imported perf_counter (10)
    "rpl006_bad.py": {("RPL006", 7), ("RPL006", 8), ("RPL006", 9),
                      ("RPL006", 10)},
}


@pytest.mark.parametrize("name", sorted(BAD_EXPECTED))
def test_bad_fixture_detected(name):
    findings = lint_fixture(name)
    assert codes_lines(findings) == BAD_EXPECTED[name]
    assert not codes_lines(findings, suppressed=True)


@pytest.mark.parametrize("name", ["rpl001_good.py", "rpl002_good.py",
                                  "rpl003_good.py", "rpl004_good.py",
                                  "rpl005_good.py", "rpl006_good.py"])
def test_good_fixture_clean(name):
    assert lint_fixture(name) == []


def test_select_isolates_rules():
    findings = lint_fixture("rpl001_bad.py", select={"RPL002"})
    assert findings == []


# ------------------------------ suppression -------------------------------


def test_suppressions_mark_but_keep_findings():
    findings = lint_fixture("suppressed.py")
    assert codes_lines(findings) == set()  # all suppressed
    assert codes_lines(findings, suppressed=True) == {
        ("RPL005", 5), ("RPL003", 9), ("RPL003", 14)}


def test_suppression_comment_parsing():
    src = ("x = 1  # reprolint: disable=RPL001\n"
           "y = 2  # reprolint: disable=RPL003, RPL005 -- reason\n"
           "z = 3  # unrelated comment\n")
    assert suppressions(src) == {1: {"RPL001"},
                                 2: {"RPL003", "RPL005"}}


def test_suppression_only_covers_its_line():
    src = ("import jax.numpy as jnp\n"
           "A = jnp.zeros(3)  # reprolint: disable=RPL005\n"
           "B = jnp.zeros(3)\n")
    findings = run_source("x.py", src, role="library")
    assert codes_lines(findings) == {("RPL005", 3)}
    assert codes_lines(findings, suppressed=True) == {("RPL005", 2)}


# ------------------------------- roles ------------------------------------


def test_tests_role_skips_key_and_interpret_rules():
    for name in ("rpl003_bad.py", "rpl004_bad.py"):
        assert lint_fixture(name, role="tests") == []


def test_compat_role_may_touch_wrapped_apis():
    assert codes_lines(lint_fixture("rpl001_bad.py", role="compat"),
                       ) == set()


def test_tools_role_still_checks_key_reuse():
    findings = lint_fixture("rpl003_bad.py", role="tools")
    # reuse rules apply to tools; the literal-seed rule is library-only
    assert codes_lines(findings) == {("RPL003", 7), ("RPL003", 14)}


def test_classify_path():
    assert classify_path("src/repro/compat.py") == "compat"
    assert classify_path("src/repro/core/mdm.py") == "library"
    assert classify_path("tests/test_mapping.py") == "tests"
    assert classify_path("benchmarks/run.py") == "tools"
    assert classify_path("scripts/lint.py") == "tools"


# ------------------------------ robustness --------------------------------


def test_syntax_error_yields_rpl000_not_exception():
    findings = run_source("broken.py", "def f(:\n", role="library")
    assert [f.code for f in findings] == ["RPL000"]
