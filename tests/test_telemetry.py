"""Tier-1 gate for the telemetry subsystem (metrics, spans, pipeline).

Pins the three contracts docs/observability.md promises:

* **disabled is free** — the off path allocates nothing and changes no
  state, and enabling telemetry cannot change a single computed value
  (the serve engine generates bit-identical tokens on vs. off);
* **exposition is deterministic** — golden Prometheus-text and JSON
  snapshots, strict once-only registration (the AUD007 hook);
* **traces reconstruct the run** — span JSONL round-trips through the
  ``repro.telemetry.report`` aggregator and the ``scripts/
  trace_report.py`` CLI with self-times telescoping to the root wall
  time (the >= 95% coverage acceptance gate holds by construction).

All metric-object tests use **local** ``MetricsRegistry`` instances so
the process-global default registry stays exactly what the library
modules declared — the semantic auditor (AUD007) checks that registry
against the static declarations.
"""
from __future__ import annotations

import gc
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import telemetry as tm
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.report import aggregate, coverage, load_spans

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _telemetry_reset():
    """Every test leaves telemetry off, untraced, and zeroed."""
    yield
    tm.disable()
    tm.trace_stop()
    tm.registry().reset()


# ------------------------------- metrics ----------------------------------


def test_counter_gauge_histogram_basic():
    tm.enable()
    reg = MetricsRegistry()
    c = reg.counter("t_ops_total", "Ops.")
    g = reg.gauge("t_depth", "Depth.")
    h = reg.histogram("t_lat_seconds", "Latency.", buckets=(0.1, 1.0))
    c.inc()
    c.inc(2)
    g.set(5.0)
    g.dec()
    h.observe(0.5)
    h.observe(1.0)  # le bounds are inclusive
    h.observe(5.0)  # overflow -> +Inf only
    snap = reg.snapshot()
    assert snap["t_ops_total"]["values"] == [{"labels": {}, "value": 3.0}]
    assert snap["t_depth"]["values"] == [{"labels": {}, "value": 4.0}]
    hv = snap["t_lat_seconds"]["values"][0]
    assert hv["counts"] == [0, 2, 1]
    assert hv["sum"] == 6.5 and hv["count"] == 3


def test_labels_create_children_and_validate():
    tm.enable()
    reg = MetricsRegistry()
    c = reg.counter("t_req_total", "Reqs.", labels=("kind",))
    c.labels(kind="a").inc()
    c.labels(kind="b").inc(4)
    vals = {tuple(v["labels"].items()): v["value"]
            for v in reg.snapshot()["t_req_total"]["values"]}
    assert vals == {(("kind", "a"),): 1.0, (("kind", "b"),): 4.0}
    with pytest.raises(ValueError, match="labels"):
        c.labels(wrong="x")


def test_counter_rejects_negative_and_bad_names():
    tm.enable()
    reg = MetricsRegistry()
    c = reg.counter("t_down_total")
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)
    with pytest.raises(ValueError, match="bad metric name"):
        reg.counter("Bad-Name")


def test_registration_is_strict_once_only():
    """The AUD007 hook: one name registers exactly once per registry."""
    reg = MetricsRegistry()
    reg.counter("t_dup_total")
    with pytest.raises(ValueError, match="AUD007"):
        reg.gauge("t_dup_total")


def test_prometheus_exposition_golden():
    tm.enable()
    reg = MetricsRegistry()
    c = reg.counter("g_requests_total", "Requests.", labels=("kind",))
    c.labels(kind="a").inc()
    c.labels(kind="a").inc(2)
    reg.gauge("g_temp", "Temp.").set(1.5)
    h = reg.histogram("g_lat_seconds", "Latency.", buckets=(0.1, 1.0))
    for v in (0.5, 1.0, 5.0):
        h.observe(v)
    assert reg.to_prometheus() == (
        "# HELP g_lat_seconds Latency.\n"
        "# TYPE g_lat_seconds histogram\n"
        'g_lat_seconds_bucket{le="0.1"} 0\n'
        'g_lat_seconds_bucket{le="1"} 2\n'
        'g_lat_seconds_bucket{le="+Inf"} 3\n'
        "g_lat_seconds_sum 6.5\n"
        "g_lat_seconds_count 3\n"
        "# HELP g_requests_total Requests.\n"
        "# TYPE g_requests_total counter\n"
        'g_requests_total{kind="a"} 3\n'
        "# HELP g_temp Temp.\n"
        "# TYPE g_temp gauge\n"
        "g_temp 1.5\n")


def test_json_snapshot_round_trips():
    tm.enable()
    reg = MetricsRegistry()
    reg.counter("t_j_total").inc(7)
    assert json.loads(reg.to_json())["t_j_total"]["values"][0][
        "value"] == 7.0


def test_reset_zeroes_values_keeps_registrations():
    tm.enable()
    reg = MetricsRegistry()
    c = reg.counter("t_r_total", labels=("k",))
    c.labels(k="x").inc(3)
    reg.reset()
    assert reg.names() == frozenset({"t_r_total"})
    assert reg.snapshot()["t_r_total"]["values"] == []
    c.labels(k="x").inc()  # children still usable after reset
    assert reg.snapshot()["t_r_total"]["values"][0]["value"] == 1.0


# --------------------------- disabled fast path ---------------------------


def test_disabled_records_nothing():
    tm.disable()
    reg = MetricsRegistry()
    c = reg.counter("t_off_total", labels=("k",))
    h = reg.histogram("t_off_seconds")
    g = reg.gauge("t_off_depth")
    c.inc()
    c.labels(k="x").inc(5)  # shared no-op child, no key created
    h.observe(1.0)
    g.set(9.0)
    snap = reg.snapshot()
    assert snap["t_off_total"]["values"] == []
    assert snap["t_off_seconds"]["values"][0]["count"] == 0
    assert snap["t_off_depth"]["values"][0]["value"] == 0.0


def test_disabled_fast_path_allocates_nothing():
    """The off path is a flag test + return: zero allocated blocks
    across 10k record calls (small slack for interpreter noise)."""
    tm.disable()
    reg = MetricsRegistry()
    c = reg.counter("t_alloc_total")
    h = reg.histogram("t_alloc_seconds")
    g = reg.gauge("t_alloc_depth")

    def burst(n):
        for _ in range(n):
            c.inc()
            h.observe(0.5)
            g.set(1.0)

    burst(1000)  # warm method caches
    gc.collect()
    before = sys.getallocatedblocks()
    burst(10000)
    gc.collect()
    assert sys.getallocatedblocks() - before <= 16


def test_disabled_overhead_smoke():
    """30k disabled record calls stay well under 100ms (~us/call)."""
    tm.disable()
    reg = MetricsRegistry()
    c = reg.counter("t_fast_total")
    t0 = tm.monotonic()
    for _ in range(30000):
        c.inc()
    assert tm.monotonic() - t0 < 0.1


def test_enable_after_import_activates_labels():
    """labels() taken at use time honours a later enable()."""
    tm.disable()
    reg = MetricsRegistry()
    c = reg.counter("t_late_total", labels=("k",))
    c.labels(k="x").inc()  # no-op child
    tm.enable()
    c.labels(k="x").inc()
    assert reg.snapshot()["t_late_total"]["values"][0]["value"] == 1.0


# -------------------------------- spans -----------------------------------


def test_span_noop_without_sink_or_enable(tmp_path):
    tm.enable()
    assert not tm.tracing()
    s = tm.span("x")  # no sink open
    assert s is tm.span("y")  # the shared no-op instance
    tm.trace_to(str(tmp_path / "t.jsonl"))
    tm.disable()
    assert tm.span("z") is s  # sink open but disabled


def test_span_jsonl_round_trip_and_coverage(tmp_path):
    tm.enable()
    path = tm.trace_to(str(tmp_path / "t.jsonl"))
    with tm.span("root", runs=1):
        with tm.span("child/a"):
            pass
        with tm.span("child/b", n=2):
            pass
    assert tm.trace_stop() == path
    spans = load_spans(path)
    # spans are written at exit: children first, root last
    assert [s["name"] for s in spans] == ["child/a", "child/b", "root"]
    by = {s["name"]: s for s in spans}
    assert by["root"]["parent"] is None and by["root"]["depth"] == 0
    assert by["child/a"]["parent"] == by["root"]["id"]
    assert by["child/b"]["depth"] == 1
    assert by["child/b"]["attrs"] == {"n": 2}
    assert all(s["dur"] >= 0 and s["t_end"] >= s["t_start"]
               for s in spans)
    stats, wall = aggregate(spans)
    assert wall == pytest.approx(by["root"]["dur"])
    # self-times telescope: the named phases cover the full wall time
    assert coverage(spans) == pytest.approx(1.0, abs=1e-6)
    assert stats["root"]["self"] == pytest.approx(
        by["root"]["dur"] - by["child/a"]["dur"] - by["child/b"]["dur"])


def test_trace_report_cli(tmp_path):
    tm.enable()
    path = tm.trace_to(str(tmp_path / "t.jsonl"))
    with tm.span("phase/outer"):
        with tm.span("phase/inner"):
            pass
    tm.trace_stop()
    res = subprocess.run(
        [sys.executable, os.path.join("scripts", "trace_report.py"),
         path],
        capture_output=True, text=True, cwd=REPO)
    assert res.returncode == 0, res.stderr
    assert "phase/outer" in res.stdout and "phase/inner" in res.stdout
    assert "cover" in res.stdout
    res = subprocess.run(
        [sys.executable, os.path.join("scripts", "trace_report.py"),
         "--json", path],
        capture_output=True, text=True, cwd=REPO)
    data = json.loads(res.stdout)
    assert data[path]["spans"] == 2
    assert set(data[path]["phases"]) == {"phase/outer", "phase/inner"}


def test_trace_report_cli_unreadable_file_fails():
    res = subprocess.run(
        [sys.executable, os.path.join("scripts", "trace_report.py"),
         "no/such/trace.jsonl"],
        capture_output=True, text=True, cwd=REPO)
    assert res.returncode == 1
    assert "cannot read" in res.stderr


def test_load_spans_skips_torn_lines(tmp_path):
    p = tmp_path / "torn.jsonl"
    p.write_text('{"name": "a", "id": 0, "parent": null, "dur": 1.0}\n'
                 'not json\n'
                 '{"other": "record"}\n'
                 '{"name": "b", "id": 1, "parent": 0, "du')
    spans = load_spans(str(p))
    assert [s["name"] for s in spans] == ["a"]


# --------------------- pipeline instrumentation (e2e) ---------------------


SERVE_CFG = None


def _serve_cfg():
    global SERVE_CFG
    if SERVE_CFG is None:
        from repro.configs.base import CimConfig, ModelConfig
        SERVE_CFG = ModelConfig(
            name="cim-telemetry-test", n_layers=2, d_model=32,
            n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
            block_pattern=("attn",), remat="none", dtype="float32",
            attn_chunk=32,
            cim=CimConfig(enabled=True, mode="mdm", rows=16, cols=16,
                          n_bits=4))
    return SERVE_CFG


def _engine(tmp_path):
    from repro.deploy import PlanCache
    from repro.models.model import init_params
    from repro.serve import ServeEngine

    cfg = _serve_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, max_seq=64,
                       plan_cache=PlanCache(str(tmp_path)))


def test_generation_bit_identical_on_vs_off(tmp_path):
    """Enabling telemetry + tracing must not move a single token."""
    eng = _engine(tmp_path / "cache")
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    tm.disable()
    off = np.asarray(eng.generate(prompts, 4, seed=3))
    tm.enable()
    tm.trace_to(str(tmp_path / "on.jsonl"))
    on = np.asarray(eng.generate(prompts, 4, seed=3))
    np.testing.assert_array_equal(off, on)


def test_deploy_serve_smoke_metrics_and_trace(tmp_path):
    """The acceptance smoke: telemetry-on deploy + serve produces a
    Prometheus snapshot with the pipeline's metrics and a JSONL trace
    whose phase self-times cover >= 95% of the run's wall time."""
    tm.enable()
    tm.registry().reset()
    path = tm.trace_to(str(tmp_path / "smoke.jsonl"))
    with tm.span("smoke/deploy_serve"):
        eng = _engine(tmp_path / "cache")
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                     0, 128)
        out = np.asarray(eng.generate(prompts, 3, seed=0))
    tm.trace_stop()
    assert out.shape == (2, 3)

    spans = load_spans(path)
    names = {s["name"] for s in spans}
    assert {"smoke/deploy_serve", "deploy/collect", "deploy/plan",
            "deploy/package", "serve/generate",
            "serve/prefill"} <= names
    assert coverage(spans) >= 0.95

    text = tm.registry().to_prometheus()
    for metric in ("repro_deploy_seconds", "repro_plan_seconds",
                   "repro_plan_cache_probes_total",
                   "repro_serve_prefill_seconds",
                   "repro_serve_decode_step_seconds"):
        assert metric in text, metric
    snap = tm.registry().snapshot()
    assert snap["repro_serve_requests_total"]["values"][0]["value"] == 1
    assert snap["repro_serve_tokens_total"]["values"][0]["value"] == 6
    deployed = {tuple(v["labels"].items()): v["value"] for v in
                snap["repro_deploy_matrices_total"]["values"]}
    assert deployed[(("status", "deployed"),)] > 0


def test_solver_and_mc_metrics_recorded():
    from repro.core.tiling import CrossbarSpec
    from repro.nonideal.models import NonidealModel
    from repro.nonideal.montecarlo import mc_nf

    tm.enable()
    tm.registry().reset()
    spec = CrossbarSpec(rows=16, cols=16, n_bits=8)
    masks = (jax.random.uniform(jax.random.PRNGKey(2), (2, 16, 16))
             < 0.25).astype(np.float32)
    res = mc_nf(masks, spec, NonidealModel(sigma_program=0.05), 2,
                jax.random.PRNGKey(0), precision="f64")
    assert int(res.unconverged) == 0
    snap = tm.registry().snapshot()
    assert snap["repro_mc_samples_total"]["values"][0]["value"] == 4
    assert snap["repro_solver_solves_total"]["values"][0]["value"] == 1
    assert snap["repro_solver_iterations_total"]["values"][0][
        "value"] > 0
    assert snap["repro_mc_nf_mean"]["values"][0]["value"] > 0
    assert snap["repro_mc_sweep_seconds"]["values"][0]["count"] == 1


def test_plan_cache_metrics_hit_and_miss(tmp_path):
    from repro.core.tiling import CrossbarSpec
    from repro.deploy import PlanCache
    from repro.deploy.planner import plan_matrices

    tm.enable()
    tm.registry().reset()
    spec = CrossbarSpec(rows=16, cols=16, n_bits=4)
    mats = {"m": jax.random.normal(jax.random.PRNGKey(0), (32, 32))}
    cache = PlanCache(str(tmp_path))
    plan_matrices(mats, spec, "mdm", cache=cache)
    plan_matrices(mats, spec, "mdm", cache=cache)

    def probes(metric, result):
        vals = {tuple(v["labels"].items()): v["value"] for v in
                tm.registry().snapshot()[metric]["values"]}
        return vals.get((("result", result),), 0.0)

    # first pass: manifest miss + per-entry miss; second pass resolves
    # the whole set from one manifest read (no per-entry probes).
    assert probes("repro_plan_cache_probes_total", "miss") >= 1
    assert probes("repro_plan_cache_manifest_probes_total", "hit") >= 1
    snap = tm.registry().snapshot()
    assert snap["repro_plan_cache_puts_total"]["values"][0]["value"] >= 1
    assert snap["repro_plan_cache_read_bytes_total"]["values"][0][
        "value"] > 0
