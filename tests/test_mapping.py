"""Composable mapping-strategy API: legacy-shim bit-identity, cache-key
back-compat, registry round-trips, and the new strategies end-to-end.

The redesign's contract (ISSUE 5): legacy ``mode`` strings resolve to
canonical pipelines that produce **bit-identical plans and identical
plan-cache keys** — existing caches stay warm — while new strategies
(significance-weighted fault steering, the X-CHANGR-style bitline sort,
expert-axis partitioning) are selectable end-to-end through
``ServeEngine`` by registry name.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import manhattan
from repro.core.bitslice import bitslice, unbitslice
from repro.core.mdm import (
    MODES,
    physical_column_significance,
    placed_masks,
    plan_from_bits,
    plan_layer,
)
from repro.core.tiling import CrossbarSpec
from repro.deploy import (
    PlanCache,
    deploy_model_params,
    fingerprint_matrices,
    plan_matrices,
)
from repro.mapping import (
    MappingPipeline,
    XChangrCols,
    available,
    get_strategy,
    named_pipelines,
    resolve_pipeline,
)
from repro.nonideal import sample_stuck

SPEC = CrossbarSpec(rows=16, cols=16, n_bits=8)
NAMED = named_pipelines()


def _w(seed=0, shape=(48, 6), scale=0.2):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


def _mats(seed=0):
    key = jax.random.PRNGKey(seed)
    return {f"m{j}": jax.random.normal(jax.random.fold_in(key, j),
                                       (i, n)) * 0.2
            for j, (i, n) in enumerate([(48, 6), (70, 13), (33, 7)])}


def assert_plans_identical(a, b):
    for fa, fb in zip(a, b):
        if fa is None or fb is None:
            assert fa is None and fb is None
            continue
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


# -------------------- legacy shim: plan bit-identity ----------------------

@pytest.mark.parametrize("mode", MODES)
def test_legacy_mode_strings_resolve_to_bit_identical_plans(mode):
    w = _w(seed=MODES.index(mode))
    assert_plans_identical(plan_layer(w, SPEC, mode),
                           plan_layer(w, SPEC, resolve_pipeline(mode)))


def test_legacy_fault_map_side_channel_resolves_to_fault_aware():
    """mode="mdm" + fault_maps was fault-aware planning; the shim must
    reproduce it exactly, and equal the explicit fault_aware pipeline."""
    w = _w(seed=3)
    ti, tn = SPEC.grid(*w.shape)
    stuck = sample_stuck(jax.random.PRNGKey(1),
                         (ti, tn, SPEC.rows, SPEC.cols), 0.1, 0.02)
    legacy = plan_layer(w, SPEC, "mdm", stuck)
    explicit = plan_layer(w, SPEC, NAMED["fault_aware"], stuck)
    assert_plans_identical(legacy, explicit)
    # ...and it is genuinely fault-aware (differs from plain MDM here).
    plain = plan_layer(w, SPEC, "mdm")
    assert not np.array_equal(np.asarray(legacy.row_perm),
                              np.asarray(plain.row_perm))


def test_pipeline_rows_ignore_faults_unless_declared():
    """An explicit MdmRows pipeline is never silently upgraded: fault
    maps are dropped from planning (and from cache keys)."""
    w = _w(seed=4)
    ti, tn = SPEC.grid(*w.shape)
    stuck = sample_stuck(jax.random.PRNGKey(2),
                         (ti, tn, SPEC.rows, SPEC.cols), 0.2, 0.0)
    assert_plans_identical(plan_layer(w, SPEC, NAMED["mdm"], stuck),
                           plan_layer(w, SPEC, NAMED["mdm"]))


# -------------------- legacy shim: cache-key identity ---------------------

def test_legacy_cache_entries_hit_under_pipeline_keys(tmp_path):
    """Entries written under mode strings must be pure hits when the
    same mapping is requested as a canonical pipeline — including the
    one-read manifest — and vice versa."""
    mats = _mats(seed=1)
    cache = PlanCache(str(tmp_path))
    cold, r1 = plan_matrices(mats, SPEC, "mdm", cache=cache)
    assert r1["cache_misses"] == len(mats)
    hit, r2 = plan_matrices(mats, SPEC, NAMED["mdm"], cache=cache)
    assert r2["cache_hits"] == len(mats) and r2["cache_misses"] == 0
    assert r2["manifest_hit"]
    for name in mats:
        assert_plans_identical(cold[name], hit[name])
    # Keys are equal string-for-string for every legacy mode.
    for mode in MODES:
        assert fingerprint_matrices(mats, SPEC, mode) == \
            fingerprint_matrices(mats, SPEC, resolve_pipeline(mode))


def test_new_strategies_get_distinct_cache_keys(tmp_path):
    mats = _mats(seed=2)
    keys = [frozenset(fingerprint_matrices(mats, SPEC, m).values())
            for m in ("mdm", "xchangr", "significance_weighted",
                      "baseline")]
    for i in range(len(keys)):
        for j in range(i + 1, len(keys)):
            assert keys[i].isdisjoint(keys[j])
    # Column-permuted plans round-trip through the cache bit-exactly.
    cache = PlanCache(str(tmp_path))
    cold, _ = plan_matrices(mats, SPEC, "xchangr", cache=cache)
    hit, r = plan_matrices(mats, SPEC, "xchangr", cache=cache)
    assert r["cache_hits"] == len(mats)
    for name in mats:
        assert hit[name].col_perm is not None
        assert_plans_identical(cold[name], hit[name])


# ------------------------- registry round-trips ---------------------------

def test_registry_roundtrip_name_pipeline_fingerprint():
    for name, pipe in NAMED.items():
        assert resolve_pipeline(name) == pipe
        # spec string -> pipeline -> fingerprint round-trips
        assert MappingPipeline.from_spec(pipe.spec()) == pipe
        assert MappingPipeline.from_spec(pipe.spec()).fingerprint() \
            == pipe.fingerprint()
    for kind in ("rows", "cols", "partition"):
        for sname in available(kind):
            s = get_strategy(kind, sname)
            assert s.name == sname and s.kind == kind


def test_fingerprints_stable_across_processes():
    """The cache tokens/fingerprints must be process-independent (no
    id()/hash()-derived content) — a fresh interpreter computes the
    same strings."""
    code = (
        "import sys; sys.path.insert(0, sys.argv[1])\n"
        "from repro.mapping import named_pipelines\n"
        "for n, p in sorted(named_pipelines().items()):\n"
        "    print(n, p.fingerprint(), p.cache_token())\n"
    )
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", code, src], check=True, timeout=120,
        capture_output=True, text=True).stdout
    want = "".join(f"{n} {p.fingerprint()} {p.cache_token()}\n"
                   for n, p in sorted(NAMED.items()))
    assert out == want


def test_cache_tokens_pin_legacy_strings():
    assert NAMED["baseline"].cache_token() == "baseline"
    assert NAMED["reverse"].cache_token() == "reverse"
    assert NAMED["sort"].cache_token() == "sort"
    assert NAMED["mdm"].cache_token() == "mdm"
    # fault_aware shares mdm's token (legacy keyed fault-awareness via
    # the fault-map fingerprint, not the mode string)...
    assert NAMED["fault_aware"].cache_token() == "mdm"
    # ...while genuinely new strategies get namespaced tokens.
    assert NAMED["xchangr"].cache_token().startswith("pipe:")
    assert NAMED["significance_weighted"].cache_token().startswith("pipe:")
    # The partition pass never enters the plan token.
    assert NAMED["mdm_expert"].cache_token() == "mdm"


def test_unknown_pipeline_raises():
    with pytest.raises(ValueError, match="unknown mapping pipeline"):
        resolve_pipeline("nope")
    with pytest.raises(ValueError):
        MappingPipeline.from_spec("row=nope")
    with pytest.raises(ValueError):
        MappingPipeline.from_spec("bogus_key=x")


# ----------------------- new strategy semantics ---------------------------

def test_significance_weighted_reduces_to_mdm_without_faults():
    w = _w(seed=5)
    assert_plans_identical(
        plan_layer(w, SPEC, NAMED["significance_weighted"]),
        plan_layer(w, SPEC, NAMED["mdm"]))


def test_uniform_col_weights_match_unweighted_fault_order():
    """col_weights=ones must reproduce the uniform-currency order (the
    significance weighting is a strict generalisation)."""
    key = jax.random.PRNGKey(0)
    m = (jax.random.uniform(key, (16, 16)) < 0.3).astype(jnp.float32)
    stuck = sample_stuck(jax.random.PRNGKey(1), (16, 16), 0.15, 0.05)
    a = manhattan.fault_aware_row_order(m, stuck, SPEC.nf_unit)
    b = manhattan.fault_aware_row_order(m, stuck, SPEC.nf_unit,
                                        jnp.ones((16,)))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_significance_weighted_steers_by_significance():
    """Every physical row carries exactly one stuck cell — uniform
    currency cannot tell them apart (it keeps the densest row at
    position 0) — but physical row 0's fault sits under the *most
    significant* bit plane, so the weighted order must route the dense
    row around it."""
    J = K = SPEC.rows
    m = jnp.zeros((J, K)).at[0, :].set(1.0)   # one dense logical row
    sig = np.asarray(physical_column_significance(SPEC, True))[0]
    hi, lo = int(np.argmax(sig)), int(np.argmin(sig))
    stuck = jnp.zeros((J, K), jnp.int8)
    for p in range(J):
        stuck = stuck.at[p, hi if p == 0 else lo].set(1)
    uniform = np.asarray(manhattan.fault_aware_row_order(
        m, stuck, SPEC.nf_unit))
    weighted = np.asarray(manhattan.fault_aware_row_order(
        m, stuck, SPEC.nf_unit, jnp.asarray(sig)))
    assert uniform[0] == 0           # uniform currency: equal penalties
    assert weighted[0] != 0          # weighted: MSB fault is expensive
    assert weighted[1] == 0          # ...dense row takes the next slot
    assert sorted(weighted.tolist()) == list(range(J))


def test_xchangr_col_perm_is_permutation_and_reduces_nf():
    w = _w(seed=6, shape=(64, 8))
    px = plan_layer(w, SPEC, NAMED["xchangr"])
    pm = plan_layer(w, SPEC, NAMED["mdm"])
    cp = np.asarray(px.col_perm)
    for a in range(cp.shape[0]):
        for b in range(cp.shape[1]):
            assert sorted(cp[a, b].tolist()) == list(range(SPEC.cols))
            np.testing.assert_array_equal(
                np.asarray(px.col_position)[a, b][cp[a, b]],
                np.arange(SPEC.cols))
    assert float(jnp.sum(px.nf_after)) <= float(jnp.sum(pm.nf_after)) + 1e-6


def test_xchangr_placed_masks_preserve_row_col_marginals():
    """The bitline permutation relabels columns inside each tile: cell
    multisets per tile are preserved (placement changes, content not)."""
    w = _w(seed=7)
    sliced = bitslice(w, SPEC.n_bits)
    plan = plan_layer(w, SPEC, NAMED["xchangr"])
    base = plan_layer(w, SPEC, NAMED["baseline"])
    a = np.asarray(placed_masks(sliced.bits, plan, SPEC))
    b = np.asarray(placed_masks(sliced.bits, base, SPEC))
    assert a.sum() == b.sum()
    np.testing.assert_array_equal(np.sort(a.sum((2, 3)).ravel()),
                                  np.sort(b.sum((2, 3)).ravel()))


# --------------------------- end-to-end serving ---------------------------

def test_xchangr_deployment_semantics_and_dispatch_guard():
    from repro.kernels.cim_mvm.ops import cim_mvm, deploy

    w = _w(seed=8)
    x = jax.random.normal(jax.random.PRNGKey(9), (4, w.shape[0]))
    dep, _ = deploy(w, SPEC, NAMED["xchangr"], eta=0.0)
    assert dep.col_pos is not None
    y = cim_mvm(x, dep)   # auto -> xla (col_pos unsupported in pallas)
    wq = unbitslice(bitslice(w, SPEC.n_bits))
    assert float(jnp.max(jnp.abs(y - x @ wq))) < 1e-5
    with pytest.raises(ValueError, match="column-permuted"):
        cim_mvm(x, dep, impl="interpret")
    # The distortion differs from plain MDM's (the permutation moved
    # bit cells to different Manhattan distances).
    dep_e, _ = deploy(w, SPEC, NAMED["xchangr"], eta=2e-3)
    dep_m, _ = deploy(w, SPEC, NAMED["mdm"], eta=2e-3)
    assert float(jnp.max(jnp.abs(cim_mvm(x, dep_e)
                                 - cim_mvm(x, dep_m)))) > 0


def test_eq17_evaluator_matches_kernel_under_new_pipelines():
    """noisy_weights (the model-eval path) and the serving kernel must
    agree on W' for the column-permuted pipeline too."""
    from repro.core.noise import noisy_weights
    from repro.kernels.cim_mvm.ops import cim_mvm, deploy

    w = _w(seed=10)
    x = jax.random.normal(jax.random.PRNGKey(11), (5, w.shape[0]))
    for name in ("mdm", "xchangr"):
        wn, plan = noisy_weights(w, SPEC, NAMED[name], eta=2e-3)
        dep, _ = deploy(w, SPEC, NAMED[name], eta=2e-3, plan=plan)
        y_kernel = cim_mvm(x, dep, impl="xla")
        y_eval = x @ wn
        rel = float(jnp.max(jnp.abs(y_kernel - y_eval))
                    / jnp.max(jnp.abs(y_eval)))
        assert rel < 1e-5, (name, rel)


def _serve_cfg(**cim_kw):
    from repro.configs.base import CimConfig, ModelConfig

    return ModelConfig(
        name="map-serve-test", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, block_pattern=("attn",),
        remat="none", dtype="float32", attn_chunk=32,
        cim=CimConfig(enabled=True, rows=16, cols=16, n_bits=4,
                      **cim_kw))


def test_serve_engine_generates_under_xchangr_pipeline(tmp_path):
    """A genuinely new strategy is selectable end-to-end through
    ServeEngine via cfg.cim.mode (named pipeline string)."""
    from repro.models.model import init_params
    from repro.serve import ServeEngine

    cfg = _serve_cfg(mode="xchangr")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seq=64,
                      plan_cache=PlanCache(str(tmp_path)))
    assert eng.deploy_report["matrices"]["n_deployed"] == 14
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out = np.asarray(eng.generate(prompts, 3))
    assert out.shape == (2, 3) and (out >= 0).all()


# ------------------- collection summary / expert banks --------------------

def _moe_cfg():
    from repro.configs.base import CimConfig, ModelConfig

    return ModelConfig(
        name="map-moe-test", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, block_pattern=("attn",),
        remat="none", dtype="float32", attn_chunk=32, n_experts=4,
        n_experts_per_token=2, moe_d_ff=48,
        cim=CimConfig(enabled=True, mode="mdm", rows=16, cols=16,
                      n_bits=4))


def test_collection_summary_accounts_for_every_parameter():
    """No silent dropping: every non-deployed parameter appears in the
    skip record with a reason; MoE banks deploy under expert-axis
    partitioning."""
    from repro.deploy import collect_model_matrices
    from repro.models.model import init_params

    cfg = _moe_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    mats, summary = collect_model_matrices(params, cfg, "mdm")
    # dense partition: expert banks recorded as skipped, with a hint
    assert any("ffn_we_gate" in k for k in summary["skipped"])
    assert any("expert" in v for v in summary["skipped"].values())
    n_slot_params = sum(len(v) for k, v in params.items()
                        if k.startswith("slot"))
    n_top = sum(1 for k in params if not k.startswith("slot"))
    deployed_params = {n.rsplit("/", 1)[0].replace("/", ".", 1)
                      for n in summary["deployed"]}
    assert len(deployed_params) + summary["n_skipped"] \
        == n_slot_params + n_top

    mats_e, summary_e = collect_model_matrices(params, cfg,
                                               NAMED["mdm_expert"])
    E, reps = cfg.n_experts, cfg.pattern_repeats
    assert "slot0_attn/ffn_we_gate/0/e0" in mats_e
    # 4 attn projections + 3 expert banks x E, per repeat
    assert summary_e["n_deployed"] == reps * (4 + 3 * E)
    assert not any("ffn_we" in k for k in summary_e["skipped"])
    assert mats_e["slot0_attn/ffn_we_down/0/e1"].shape == (48, 32)


def test_fault_aware_flag_steers_non_legacy_pipelines(tmp_path):
    """fault_aware=True must upgrade ANY plain-MDM-rows pipeline (e.g.
    xchangr), not just the legacy "sort"/"mdm" strings — sampled fault
    maps must never be silently dropped."""
    from repro.models.model import init_params
    from repro.nonideal import NonidealModel

    cfg = _serve_cfg(mode="mdm")
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = NonidealModel(p_stuck_off=0.05)
    kw = dict(nonideal=model, nonideal_key=7)
    _, r_aware = deploy_model_params(
        params, cfg, cache=PlanCache(str(tmp_path / "a")),
        pipeline=NAMED["xchangr"], fault_aware=True, **kw)
    assert r_aware["fault_aware"]
    # The fault maps entered the plan keys: replanning without them
    # (fault_aware=False) misses the cache.
    _, r_plain = deploy_model_params(
        params, cfg, cache=PlanCache(str(tmp_path / "a")),
        pipeline=NAMED["xchangr"], fault_aware=False, **kw)
    assert r_plain["cache_misses"] == r_plain["n_matrices"]
    # Identity-row pipelines keep the legacy no-op (never upgraded).
    _, r_base = deploy_model_params(
        params, cfg, cache=PlanCache(str(tmp_path / "b")),
        pipeline=NAMED["baseline"], fault_aware=True, **kw)
    assert not r_base["fault_aware"]


def test_deploy_layout_follows_supplied_plan():
    """deploy(plan=...) must take the physical layout from the plan,
    even when the mode argument disagrees (cache-hit path)."""
    from repro.kernels.cim_mvm.ops import deploy

    w = _w(seed=12)
    plan = plan_layer(w, SPEC, "sort")       # conventional dataflow
    dep, _ = deploy(w, SPEC, plan=plan)      # mode left at its default
    assert dep.reversed_df is False
    xplan = plan_layer(w, SPEC, NAMED["xchangr"])
    dep2, _ = deploy(w, SPEC, "baseline", plan=xplan)
    assert dep2.reversed_df is True and dep2.col_pos is not None


def test_deploy_report_carries_matrix_summary(tmp_path):
    from repro.models.model import init_params

    cfg = _serve_cfg(mode="mdm")
    params = init_params(cfg, jax.random.PRNGKey(0))
    _, report = deploy_model_params(params, cfg,
                                    cache=PlanCache(str(tmp_path)))
    s = report["matrices"]
    assert s["n_deployed"] == report["n_matrices"] == 14
    assert s["n_skipped"] > 0
    assert all(isinstance(v, str) and v for v in s["skipped"].values())


@pytest.mark.slow
def test_serve_engine_moe_expert_partition_generates(tmp_path):
    """MoE expert banks deploy per-expert and the expert matmuls route
    through vmapped cim_mvm end-to-end."""
    from repro.models.model import init_params
    from repro.serve import ServeEngine

    cfg = _moe_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seq=64,
                      plan_cache=PlanCache(str(tmp_path)),
                      pipeline=NAMED["mdm_expert"])
    slot = eng.cim["slot0_attn"]
    assert {"ffn_we_gate", "ffn_we_up", "ffn_we_down"} <= set(slot)
    reps, E = cfg.pattern_repeats, cfg.n_experts
    assert slot["ffn_we_gate"].codes.shape[:2] == (reps, E)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    out = np.asarray(eng.generate(prompts, 3))
    assert out.shape == (2, 3)
