"""Logical-axis rule resolution: divisibility fallback, axis reuse."""
from jax.sharding import PartitionSpec as P

from repro.compat import make_abstract_mesh
from repro.distributed.sharding import RULE_SETS, logical_spec

MESH = make_abstract_mesh((16, 16), ("data", "model"))
POD = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
RULES = RULE_SETS["default"]


def test_basic_2d_weight():
    assert logical_spec((4096, 14336), ("embed", "mlp"), MESH, RULES) \
        == P("data", "model")


def test_divisibility_fallback_heads():
    # 56 heads don't divide 16 -> replicate; head_dim 128 picks model up
    assert logical_spec((7168, 56, 128), ("embed", "heads", "head_dim"),
                        MESH, RULES) == P("data", None, "model")


def test_axis_used_once():
    # both dims want "model": first wins, second replicates
    assert logical_spec((4096, 4096), ("mlp", "inner"), MESH, RULES) \
        == P("model")


def test_batch_prefers_pod_data():
    assert logical_spec((256, 4097), ("batch", "seq"), POD, RULES) \
        == P(("pod", "data"))
    # batch=8 not divisible by 32 -> falls to data(16)? 8%16!=0 -> None
    assert logical_spec((8, 4097), ("batch", "seq"), POD, RULES) == P()


def test_odd_vocab_replicates():
    assert logical_spec((32001, 1600), ("vocab", "embed"), MESH, RULES) \
        == P(None, "data")


def test_fsdp_pods_ruleset():
    rules = RULE_SETS["fsdp_pods"]
    assert logical_spec((8192, 28672), ("embed", "mlp"), POD, rules) \
        == P(("pod", "data"), "model")


def test_no_mesh_is_noop():
    assert logical_spec((4, 4), ("embed", "mlp"), None, RULES) == P()


def test_tiles_rule_prefers_dedicated_mesh_axis():
    # repro.distributed.solver_shard's tile batches: a dedicated "tiles"
    # mesh wins outright ...
    tiles = make_abstract_mesh((8,), ("tiles",))
    assert logical_spec((512,), ("tiles",), tiles, RULES) == P("tiles")
    # ... and on a training mesh the batch falls to the data axes.
    assert logical_spec((512,), ("tiles",), MESH, RULES) == P("data")
    assert logical_spec((512,), ("tiles",), POD, RULES) == P(("pod", "data"))
