"""Fixture: RPL003-clean — split/fold_in discipline, scoped lambdas."""
import jax


def sample(key):
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (4,))
    b = jax.random.uniform(kb, (4,))
    return a + b


def branch(key, fast):
    if fast:
        return jax.random.normal(key, (4,))
    return jax.random.uniform(key, (4,))


def loop(key, n):
    out = []
    for i in range(n):
        out.append(jax.random.normal(jax.random.fold_in(key, i), (4,)))
    return out


def counter_loop(key, n):
    out, k = [], 0
    for _ in range(n):
        k += 1
        out.append(jax.random.normal(jax.random.fold_in(key, k), (4,)))
    return out


SAMPLERS = {
    "normal": lambda k: jax.random.normal(k, (4,)),
    "uniform": lambda k: jax.random.uniform(k, (4,)),
}


def rebind(key):
    a = jax.random.normal(key, (4,))
    key = jax.random.fold_in(key, 1)
    b = jax.random.normal(key, (4,))
    return a + b


def make(seed: int):
    return jax.random.PRNGKey(seed)
