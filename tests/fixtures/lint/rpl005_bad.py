"""Fixture: RPL005 — jnp computation at import time."""
import jax.numpy as jnp

SCALE = jnp.float32(2.0)


class Config:
    TABLE = jnp.arange(8)


def f(x, default=jnp.zeros(4)):
    return x + default
