"""Fixture: RPL005-clean — numpy constants, jnp deferred to call time."""
import jax.numpy as jnp
import numpy as np

SCALE = np.float32(2.0)
MAKE_TABLE = lambda: jnp.arange(8)  # noqa: E731 — deferred, not import-time


def f(x):
    return x + jnp.zeros(4)
