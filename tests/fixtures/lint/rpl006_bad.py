"""RPL006 bad: raw time.* clock calls in library code."""
import time
from time import perf_counter


def slow_path():
    t0 = time.perf_counter()
    started = time.time()
    m = time.monotonic()
    n = perf_counter()
    return t0, started, m, n
