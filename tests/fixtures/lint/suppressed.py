"""Fixture: real violations carrying inline justified suppressions."""
import jax
import jax.numpy as jnp

EMPTY = jnp.int32(0)  # reprolint: disable=RPL005 -- fixture: intentional


def make():
    return jax.random.PRNGKey(0)  # reprolint: disable=RPL003 -- fixture: pinned seed


def two(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))  # reprolint: disable=RPL001,RPL003 -- fixture: multi-code
    return a + b
