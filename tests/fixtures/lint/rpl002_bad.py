"""Fixture: RPL002 — tracer escapes inside traced functions."""
import jax
import numpy as np


@jax.jit
def f(x):
    scale = float(x.mean())
    host = np.asarray(x)
    return x * scale + host.sum()


@jax.jit
def g(x):
    if bool(x.any()):
        return x.sum().item()
    return x
