"""Fixture: RPL004-clean — interpret is an explicit opt-in, default False."""


def op(pallas_call, kernel, x, interpret: bool = False):
    return pallas_call(kernel, interpret=interpret)(x)


def serve(mvm, x):
    return mvm(x, impl="xla")
