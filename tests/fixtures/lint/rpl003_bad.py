"""Fixture: RPL003 — PRNG key reuse and literal library seeds."""
import jax


def sample(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))
    return a + b


def loop(key, n):
    out = []
    for _ in range(n):
        out.append(jax.random.normal(key, (4,)))
    return out


def make():
    return jax.random.PRNGKey(0)
