"""RPL006 good: clocks route through the telemetry front door.

Near-misses exercised: the telemetry re-exports of the same clocks
(allowed — that *is* the front door) and non-clock ``time`` helpers
(``strftime`` formats, it does not read a timing-relevant clock).
"""
from repro import telemetry as tm
from repro.telemetry import monotonic, wall_time


def timed_path():
    t0 = monotonic()
    started = wall_time()
    return started, tm.monotonic() - t0


def formats_are_fine():
    import time

    return time.strftime("%Y-%m-%d")
