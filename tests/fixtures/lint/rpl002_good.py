"""Fixture: RPL002-clean — host conversions stay outside tracing."""
import jax
import numpy as np


@jax.jit
def f(x):
    return x * 2.0


def host_summary(x):
    return float(np.asarray(x).mean())
