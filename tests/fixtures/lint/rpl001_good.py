"""Fixture: RPL001-clean — wrapped APIs come from repro.compat."""
from repro.compat import (
    enable_x64,
    has_batched_tridiagonal_solve,
    make_abstract_mesh,
    shard_map,
)


def run(f, mesh):
    with enable_x64():
        return shard_map(f, mesh=mesh, in_specs=None, out_specs=None)


def pick_solver():
    return "batched" if has_batched_tridiagonal_solve() else "scan"
