"""Fixture: RPL001 — version-sensitive JAX APIs touched outside compat."""
import jax
from jax.experimental.shard_map import shard_map
from jax.experimental import enable_x64


def make_mesh(axes):
    return jax.sharding.AbstractMesh(axes)


def run(f, mesh):
    with enable_x64():
        return shard_map(f, mesh=mesh, in_specs=None, out_specs=None)


def probe():
    try:
        jax.lax.linalg.tridiagonal_solve(None, None, None, None)
        return True
    except Exception:
        return False
