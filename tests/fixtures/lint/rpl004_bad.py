"""Fixture: RPL004 — interpret dispatch outside tests."""
INTERPRET = True


def op(pallas_call, kernel, x, interpret=INTERPRET):
    return pallas_call(kernel, interpret=interpret)(x)


def debug(pallas_call, kernel, x):
    return pallas_call(kernel, interpret=True)(x)


def serve(mvm, x):
    return mvm(x, impl="interpret")


def wrapper(x, *, interpret=None):
    return x if interpret else -x
