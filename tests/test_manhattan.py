"""Manhattan NF model + MDM algorithm invariants.

Property tests are deterministic seeded parametrize grids (the
``hypothesis`` package is not installable in the offline CI image).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import manhattan
from repro.core.bitslice import bitslice
from repro.core.mdm import MODES, plan_from_bits, plan_layer
from repro.core.tiling import CrossbarSpec, tile_masks, untile_masks


def rand_mask(key, j=16, k=16, p=0.2):
    return (jax.random.uniform(key, (j, k)) < p).astype(jnp.float32)


def test_distance_grid():
    d = manhattan.distance_grid(3, 4)
    assert d[0, 0] == 0 and d[2, 3] == 5 and d[1, 2] == 3


def test_aggregate_distance_manual():
    m = jnp.zeros((4, 4)).at[1, 2].set(1).at[3, 3].set(1)
    assert float(manhattan.aggregate_distance(m)) == (1 + 2) + (3 + 3)


def test_antidiagonal_symmetry_analytical():
    """Configs related by the diagonal mirror have identical Eq-16 NF."""
    key = jax.random.PRNGKey(0)
    m = rand_mask(key)
    nf1 = manhattan.nonideality_factor(m, 2.5, 300e3)
    nf2 = manhattan.nonideality_factor(manhattan.antidiagonal_mirror(m),
                                       2.5, 300e3)
    assert jnp.allclose(nf1, nf2)


@pytest.mark.parametrize("seed", [0, 7, 123, 999, 4242, 9001])
@pytest.mark.parametrize("p", [0.05, 0.2, 0.35, 0.5])
def test_optimal_row_order_beats_random(seed, p):
    """The count-descending order minimises sum_j pos_j * n_j: it must be
    <= any random permutation's placement cost (rearrangement ineq.)."""
    key = jax.random.PRNGKey(seed)
    m = rand_mask(key, 16, 16, p)
    perm = manhattan.optimal_row_order(m)
    placed = m[perm]
    cost_opt = float(manhattan.placement_cost(placed))
    for i in range(5):
        rp = jax.random.permutation(jax.random.PRNGKey(seed + 13 * i + 1), 16)
        cost_rnd = float(manhattan.placement_cost(m[rp]))
        assert cost_opt <= cost_rnd + 1e-4


def test_perm_is_permutation():
    key = jax.random.PRNGKey(3)
    m = rand_mask(key)
    perm = np.asarray(manhattan.optimal_row_order(m))
    assert sorted(perm.tolist()) == list(range(16))


def test_row_order_secondary_key_survives_wide_tiles():
    """Regression (ISSUE 2): the seed's packed float key
    ``n * (J*16) + s/(s.max()+1)`` loses the sub-1 score term to f32
    rounding once ``n * (J*16)`` is large (wide tiles, K/16 >= J), so
    equal-count rows fell back to index order.  The lexsort key must
    keep ordering equal-count rows by descending Manhattan score."""
    J, K = 4, 4096
    m = np.zeros((J, K), np.float32)
    m[0, :4000] = 1          # n=4000, lower score (low-order columns)
    m[1, 10:4010] = 1        # n=4000, higher score
    m[2, :] = 1              # n=4096: densest, must come first
    perm = np.asarray(manhattan.optimal_row_order(jnp.asarray(m)))
    # densest row first; among the equal-count pair the higher-score row
    # wins; the empty row goes last.
    assert perm.tolist() == [2, 1, 0, 3]


def test_row_order_ties_break_by_index():
    """Rows identical in count AND score keep original order (lexsort
    stability), so plans stay deterministic."""
    m = np.zeros((4, 8), np.float32)
    m[1, 2] = 1
    m[3, 2] = 1              # same count, same score as row 1
    perm = np.asarray(manhattan.optimal_row_order(jnp.asarray(m)))
    assert perm.tolist() == [1, 3, 0, 2]


@pytest.mark.parametrize("jk", [(16, 16), (64, 64), (128, 10), (4, 1024)])
@pytest.mark.parametrize("seed", [0, 11, 77])
def test_packed_key_sort_equals_lexsort(jk, seed):
    """The packed single-key int32 sort (one argsort) must reproduce the
    two-argsort lexsort exactly — count desc, score desc, index asc —
    at every production geometry (wide tiles beyond int32 range fall
    back to lexsort, covered by the wide-tile regression above)."""
    j, k = jk
    key = jax.random.PRNGKey(seed)
    m = (jax.random.uniform(key, (j, k)) < 0.25).astype(jnp.float32)
    ref = jnp.lexsort((-manhattan.row_scores(m),
                       -manhattan.row_counts(m)))
    got = manhattan.optimal_row_order(m)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_packed_key_sort_dense_extremes():
    """All-ones / all-zeros rows exercise the packed key's bounds."""
    m = np.zeros((6, 64), np.float32)
    m[2] = 1.0               # full row: max count, max score
    m[4, :32] = 1.0
    ref = jnp.lexsort((-manhattan.row_scores(jnp.asarray(m)),
                       -manhattan.row_counts(jnp.asarray(m))))
    got = manhattan.optimal_row_order(jnp.asarray(m))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_mdm_reduces_nf_bell_shaped():
    """Full MDM (reverse + sort) reduces aggregate NF on gaussian weights,
    and each ablation is internally consistent."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (256, 64)) * 0.05
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    nf = {}
    for mode in MODES:
        plan = plan_layer(w, spec, mode)
        nf[mode] = float(jnp.sum(plan.nf_after))
        if mode == "baseline":
            assert jnp.allclose(plan.nf_before, plan.nf_after)
    assert nf["mdm"] < nf["baseline"]
    assert nf["sort"] <= nf["baseline"]
    assert nf["mdm"] <= nf["reverse"]  # sorting on top of reversal helps


def test_reversal_helps_when_low_order_denser():
    """Theorem-1-shaped masks benefit from reversed dataflow."""
    key = jax.random.PRNGKey(1)
    w = jnp.abs(jax.random.normal(key, (64, 8)) * 0.05)
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    p_base = plan_layer(w, spec, "baseline")
    p_rev = plan_layer(w, spec, "reverse")
    assert float(jnp.sum(p_rev.nf_after)) < float(jnp.sum(p_base.nf_after))


def test_tiling_roundtrip():
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (100, 23))
    spec = CrossbarSpec(rows=32, cols=32, n_bits=8)
    bits = bitslice(w, 8).bits
    masks = tile_masks(bits, spec)
    ti, tn = spec.grid(100, 23)
    assert masks.shape == (ti, tn, 32, 32)
    back = untile_masks(masks, 100, 23, spec)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(bits))


def test_plan_positions_inverse_of_perm():
    key = jax.random.PRNGKey(4)
    w = jax.random.normal(key, (128, 16)) * 0.1
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    plan = plan_layer(w, spec, "mdm")
    perm = np.asarray(plan.row_perm)
    pos = np.asarray(plan.row_position)
    ti, tn, R = perm.shape
    for a in range(ti):
        for b in range(tn):
            assert np.array_equal(pos[a, b][perm[a, b]], np.arange(R))
