"""Beyond-paper §Perf kernels: flash attention + sLSTM scan, interpret-
mode allclose sweeps vs pure-jnp oracles.

Sweeps are deterministic seeded parametrize grids (the ``hypothesis``
package is not installable in the offline CI image).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_tpu
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.slstm_scan import slstm_scan
from repro.kernels.slstm_scan.ref import slstm_scan_ref
from repro.models.attention import flash_attention


@pytest.mark.parametrize("case", [
    # B, Sq, Skv, H, Hkv, Dh, window
    (2, 64, 64, 4, 2, 32, 0),
    (1, 40, 72, 6, 3, 16, 24),
    (2, 1, 96, 4, 4, 32, 0),        # decode shape
    (1, 33, 33, 8, 1, 16, 0),       # MQA
])
def test_flash_kernel_vs_exact(case):
    B, Sq, Skv, H, Hkv, Dh, win = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case)), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, Dh), jnp.float32)
    qpos = jnp.arange(Sq) + max(0, Skv - Sq)
    kpos = jnp.arange(Skv)
    out = flash_attention_tpu(q, k, v, q_positions=qpos, k_positions=kpos,
                              window=win, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, qpos, kpos, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_matches_pure_jax_path():
    """The TPU kernel and the model zoo's chunked-scan implementation are
    the same function."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 48, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 48, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 48, 2, 32), jnp.float32)
    pos = jnp.arange(48)
    a = flash_attention_tpu(q, k, v, q_positions=pos, k_positions=pos,
                            block_q=16, block_k=16)
    b = flash_attention(q, k, v, q_positions=pos, k_positions=pos, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,t,h,dh,seed", [
    (1, 3, 1, 4, 0),        # minimal dims, t < chunk
    (5, 70, 4, 16, 1),      # strategy maxima, t spans many chunks
    (2, 16, 2, 8, 2),       # t == chunk exactly
    (3, 17, 1, 16, 3),      # one past a chunk boundary
    (1, 33, 4, 4, 42),
    (4, 15, 2, 8, 99),      # one short of a chunk boundary
])
def test_slstm_kernel_sweep(b, t, h, dh, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    gx = jax.random.normal(ks[0], (b, t, h, 4 * dh)) * 0.5
    r = jax.random.normal(ks[1], (h, dh, 4 * dh)) * 0.1
    h0 = jax.random.normal(ks[2], (b, h, dh)) * 0.1
    c0 = jax.random.normal(ks[3], (b, h, dh)) * 0.1
    hs, hT, cT = slstm_scan(gx, r, h0, c0, block_b=2, chunk=16)
    hs2, hT2, cT2 = slstm_scan_ref(gx, r, h0, c0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cT), np.asarray(cT2),
                               rtol=1e-5, atol=1e-6)
