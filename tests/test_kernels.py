"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret
mode on CPU — the kernel body executes block-by-block faithfully; the
cim_mvm calls pin ``impl="interpret"`` because its production default
now dispatches to the fused XLA fallback off-TPU, covered by
tests/test_cim_dispatch.py).

Sweeps are deterministic seeded parametrize grids (the ``hypothesis``
package is not installable in the offline CI image); the cases keep the
original strategies' edge coverage (minimal dims, non-multiples of the
block sizes, both n_bits).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tiling import CrossbarSpec
from repro.kernels.bitslice_pack import bitslice_pack
from repro.kernels.bitslice_pack.ref import bitslice_pack_ref
from repro.kernels.cim_mvm.ops import cim_mvm, deploy
from repro.kernels.cim_mvm.ref import cim_mvm_ref
from repro.kernels.manhattan_score import manhattan_score
from repro.kernels.manhattan_score.ref import manhattan_score_ref


# ------------------------------ cim_mvm ----------------------------------

@pytest.mark.parametrize("mode", ["baseline", "reverse", "sort", "mdm"])
@pytest.mark.parametrize("shape", [
    (64, 8, 4), (70, 13, 5),
    pytest.param((200, 100, 130), marks=pytest.mark.slow),
])
def test_cim_mvm_matches_ref(mode, shape):
    I, N, M = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(I * N + M))
    w = jax.random.normal(k1, (I, N)) * 0.2
    x = jax.random.normal(k2, (M, I))
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    dep, plan = deploy(w, spec, mode, eta=2e-3)
    y = cim_mvm(x, dep, impl="interpret")
    x_pad = jnp.pad(x, ((0, 0), (0, dep.codes.shape[0] - I)))
    y_ref = cim_mvm_ref(x_pad, dep.codes.astype(jnp.int32), plan, spec,
                        2e-3)[:, :N]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("i,n,m,n_bits,seed", [
    (4, 2, 1, 4, 0),        # minimal dims
    pytest.param(96, 40, 40, 8, 1, marks=pytest.mark.slow),  # maxima
    (33, 7, 5, 4, 2),       # nothing divides the tile
    (32, 4, 8, 8, 3),       # exact tile fit
    pytest.param(64, 17, 13, 8, 5, marks=pytest.mark.slow),
    (48, 40, 1, 4, 6),      # single activation row
    pytest.param(96, 2, 16, 8, 7, marks=pytest.mark.slow),
    pytest.param(31, 9, 40, 4, 8, marks=pytest.mark.slow),
    (7, 5, 11, 8, 99),
])
def test_cim_mvm_property_sweep(i, n, m, n_bits, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (i, n)) * 0.5
    x = jax.random.normal(k2, (m, i))
    spec = CrossbarSpec(rows=32, cols=32, n_bits=n_bits)
    dep, plan = deploy(w, spec, "mdm", eta=1e-3)
    y = cim_mvm(x, dep, impl="interpret")
    x_pad = jnp.pad(x, ((0, 0), (0, dep.codes.shape[0] - i)))
    y_ref = cim_mvm_ref(x_pad, dep.codes.astype(jnp.int32), plan, spec,
                        1e-3)[:, :n]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


def test_cim_mvm_eta0_equals_quantized_matmul():
    """Semantics preservation: with eta=0 the CIM path is exactly the
    bit-sliced quantisation of W (MDM is a pure permutation)."""
    from repro.core.bitslice import bitslice, unbitslice
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(k1, (128, 32)) * 0.3
    x = jax.random.normal(k2, (16, 128))
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    wq = unbitslice(bitslice(w, 8))
    for mode in ("baseline", "mdm"):
        dep, _ = deploy(w, spec, mode, eta=0.0)
        y = cim_mvm(x, dep, impl="interpret")
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ wq),
                                   rtol=1e-5, atol=1e-5)


def test_cim_mvm_batched_input():
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 16)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 64))
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    dep, _ = deploy(w, spec)
    y = cim_mvm(x, dep, impl="interpret")
    assert y.shape == (2, 3, 16)


# --------------------------- manhattan_score -----------------------------

@pytest.mark.parametrize("t,r,c,seed", [
    (1, 16, 16, 0),         # single tile, smallest geometry
    (9, 64, 64, 1),         # strategy maxima
    (3, 16, 64, 2),         # rectangular both ways
    (5, 64, 16, 3),
    (2, 64, 64, 42),
    (7, 16, 16, 99),
])
def test_manhattan_score_sweep(t, r, c, seed):
    masks = (jax.random.uniform(jax.random.PRNGKey(seed), (t, r, c)) < 0.3
             ).astype(jnp.uint8)
    s, n, nf = manhattan_score(masks, nf_unit=2.5 / 300e3)
    sr, nr, nfr = manhattan_score_ref(masks, 2.5 / 300e3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(n), np.asarray(nr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(nf), np.asarray(nfr), rtol=1e-6)


def test_manhattan_score_batch_dims():
    masks = (jax.random.uniform(jax.random.PRNGKey(3), (2, 5, 16, 16)) < 0.2
             ).astype(jnp.float32)
    s, n, nf = manhattan_score(masks)
    assert s.shape == (2, 5, 16) and nf.shape == (2, 5)


# ---------------------------- bitslice_pack ------------------------------

@pytest.mark.parametrize("i,n,n_bits,rev,seed", [
    (1, 1, 4, False, 0),    # minimal dims
    (130, 70, 12, True, 1), # strategy maxima
    (128, 64, 8, False, 2), # power-of-two block fit
    (129, 65, 8, True, 3),  # one past the block
    (17, 33, 4, True, 4),
    (64, 1, 12, False, 5),
    (1, 70, 8, True, 42),
    (100, 23, 4, False, 99),
])
def test_bitslice_pack_sweep(i, n, n_bits, rev, seed):
    codes = jax.random.randint(jax.random.PRNGKey(seed), (i, n),
                               -(2 ** n_bits) + 1, 2 ** n_bits)
    out = bitslice_pack(codes, n_bits, rev)
    ref = bitslice_pack_ref(codes, n_bits, rev)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
