"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret
mode on CPU — the kernel body executes block-by-block faithfully)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tiling import CrossbarSpec
from repro.kernels.bitslice_pack import bitslice_pack
from repro.kernels.bitslice_pack.ref import bitslice_pack_ref
from repro.kernels.cim_mvm.ops import cim_mvm, deploy
from repro.kernels.cim_mvm.ref import cim_mvm_ref
from repro.kernels.manhattan_score import manhattan_score
from repro.kernels.manhattan_score.ref import manhattan_score_ref


# ------------------------------ cim_mvm ----------------------------------

@pytest.mark.parametrize("mode", ["baseline", "reverse", "sort", "mdm"])
@pytest.mark.parametrize("shape", [(64, 8, 4), (70, 13, 5), (200, 100, 130)])
def test_cim_mvm_matches_ref(mode, shape):
    I, N, M = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(I * N + M))
    w = jax.random.normal(k1, (I, N)) * 0.2
    x = jax.random.normal(k2, (M, I))
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    dep, plan = deploy(w, spec, mode, eta=2e-3)
    y = cim_mvm(x, dep)
    x_pad = jnp.pad(x, ((0, 0), (0, dep.codes.shape[0] - I)))
    y_ref = cim_mvm_ref(x_pad, dep.codes.astype(jnp.int32), plan, spec,
                        2e-3)[:, :N]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    i=st.integers(4, 96), n=st.integers(2, 40), m=st.integers(1, 40),
    n_bits=st.sampled_from([4, 8]), seed=st.integers(0, 99),
)
def test_cim_mvm_property_sweep(i, n, m, n_bits, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (i, n)) * 0.5
    x = jax.random.normal(k2, (m, i))
    spec = CrossbarSpec(rows=32, cols=32, n_bits=n_bits)
    dep, plan = deploy(w, spec, "mdm", eta=1e-3)
    y = cim_mvm(x, dep)
    x_pad = jnp.pad(x, ((0, 0), (0, dep.codes.shape[0] - i)))
    y_ref = cim_mvm_ref(x_pad, dep.codes.astype(jnp.int32), plan, spec,
                        1e-3)[:, :n]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


def test_cim_mvm_eta0_equals_quantized_matmul():
    """Semantics preservation: with eta=0 the CIM path is exactly the
    bit-sliced quantisation of W (MDM is a pure permutation)."""
    from repro.core.bitslice import bitslice, unbitslice
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(k1, (128, 32)) * 0.3
    x = jax.random.normal(k2, (16, 128))
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    wq = unbitslice(bitslice(w, 8))
    for mode in ("baseline", "mdm"):
        dep, _ = deploy(w, spec, mode, eta=0.0)
        y = cim_mvm(x, dep)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ wq),
                                   rtol=1e-5, atol=1e-5)


def test_cim_mvm_batched_input():
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 16)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 64))
    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    dep, _ = deploy(w, spec)
    y = cim_mvm(x, dep)
    assert y.shape == (2, 3, 16)


# --------------------------- manhattan_score -----------------------------

@settings(max_examples=10, deadline=None)
@given(t=st.integers(1, 9), r=st.sampled_from([16, 64]),
       c=st.sampled_from([16, 64]), seed=st.integers(0, 99))
def test_manhattan_score_sweep(t, r, c, seed):
    masks = (jax.random.uniform(jax.random.PRNGKey(seed), (t, r, c)) < 0.3
             ).astype(jnp.uint8)
    s, n, nf = manhattan_score(masks, nf_unit=2.5 / 300e3)
    sr, nr, nfr = manhattan_score_ref(masks, 2.5 / 300e3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(n), np.asarray(nr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(nf), np.asarray(nfr), rtol=1e-6)


def test_manhattan_score_batch_dims():
    masks = (jax.random.uniform(jax.random.PRNGKey(3), (2, 5, 16, 16)) < 0.2
             ).astype(jnp.float32)
    s, n, nf = manhattan_score(masks)
    assert s.shape == (2, 5, 16) and nf.shape == (2, 5)


# ---------------------------- bitslice_pack ------------------------------

@settings(max_examples=10, deadline=None)
@given(i=st.integers(1, 130), n=st.integers(1, 70),
       n_bits=st.sampled_from([4, 8, 12]), rev=st.booleans(),
       seed=st.integers(0, 99))
def test_bitslice_pack_sweep(i, n, n_bits, rev, seed):
    codes = jax.random.randint(jax.random.PRNGKey(seed), (i, n),
                               -(2 ** n_bits) + 1, 2 ** n_bits)
    out = bitslice_pack(codes, n_bits, rev)
    ref = bitslice_pack_ref(codes, n_bits, rev)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
