"""Eq-17 PR noise injection semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitslice import bitslice, unbitslice
from repro.core.noise import noisy_weights, tree_noisy_weights
from repro.core.tiling import CrossbarSpec

SPEC = CrossbarSpec(rows=64, cols=64, n_bits=8)
KEY = jax.random.PRNGKey(0)


def test_eta_zero_is_pure_quantisation():
    w = jax.random.normal(KEY, (128, 32)) * 0.2
    wq = unbitslice(bitslice(w, 8))
    for mode in ("baseline", "mdm"):
        wn, _ = noisy_weights(w, SPEC, mode, eta=0.0)
        np.testing.assert_allclose(np.asarray(wn), np.asarray(wq),
                                   atol=1e-7)


def test_noise_magnitude_scales_with_eta():
    w = jax.random.normal(KEY, (128, 32)) * 0.2
    wq = unbitslice(bitslice(w, 8))
    devs = []
    for eta in (1e-4, 1e-3, 1e-2):
        wn, _ = noisy_weights(w, SPEC, "baseline", eta=eta)
        devs.append(float(jnp.mean(jnp.abs(wn - wq))))
    assert devs[0] < devs[1] < devs[2]
    np.testing.assert_allclose(devs[1] / devs[0], 10.0, rtol=0.05)


def test_sort_reduces_injected_distortion():
    """Row sorting lowers the injected (significance-weighted) distortion
    of Eq 17: dense rows move to small row positions.

    Note: dataflow *reversal* reduces the paper's unweighted NF but NOT
    the 2^-k-weighted first-order weight distortion (high-order bits are
    exactly the ones moved far from the rail).  Its accuracy benefit is a
    second-order circuit effect — dense low-order columns near the input
    drain the row current early, shrinking the IR drop the sparse
    high-order cells see — which the circuit solver captures
    (benchmarks/nf_reduction.py) but Eq 17's first-order form does not.
    """
    w = jax.random.normal(KEY, (256, 64)) * 0.05
    wq = unbitslice(bitslice(w, 8))
    dev = {}
    for mode in ("baseline", "sort", "reverse", "mdm"):
        wn, _ = noisy_weights(w, SPEC, mode, eta=2e-3)
        dev[mode] = float(jnp.sum(jnp.abs(wn - wq)))
    assert dev["sort"] < dev["baseline"]
    assert dev["mdm"] < dev["reverse"]


def test_tree_noisy_weights_targets_matrices_only():
    params = {
        "w": jax.random.normal(KEY, (64, 64)),
        "norm": jnp.ones((64,)),
        "tiny": jnp.ones((2, 2)),
        "stack": jax.random.normal(KEY, (2, 64, 64)),
    }
    out = tree_noisy_weights(params, SPEC, "mdm", eta=2e-3, min_size=1024)
    assert not np.allclose(np.asarray(out["w"]), np.asarray(params["w"]))
    np.testing.assert_array_equal(np.asarray(out["norm"]),
                                  np.asarray(params["norm"]))
    np.testing.assert_array_equal(np.asarray(out["tiny"]),
                                  np.asarray(params["tiny"]))
    assert out["stack"].shape == (2, 64, 64)
    assert not np.allclose(np.asarray(out["stack"]),
                           np.asarray(params["stack"]))


def test_calibrate_eta_against_circuit():
    """eta calibrated on the circuit solver: must exceed the naive
    first-order coefficient r/R_on (shared-rail interactions amplify the
    per-cell drop) and sit within the physically sensible decade span
    bracketed by the paper's SPICE value (2e-3)."""
    from repro.core.noise import calibrate_eta

    eta = calibrate_eta(CrossbarSpec(rows=32, cols=32, n_bits=8),
                        n_tiles=6)
    first_order = 2.5 / 300e3
    assert eta > first_order            # interactions amplify
    assert eta < 2e-2                   # and stay physical


def test_calibrate_eta_precision_policy_agrees():
    """The mixed f32/f64 engine policy calibrates the same eta as the
    all-f64 oracle far below the least-squares fit noise, so sweeps can
    use it safely (the policy is threaded via repro.crossbar.batched)."""
    from repro.core.noise import calibrate_eta

    spec = CrossbarSpec(rows=32, cols=32, n_bits=8)
    eta64 = calibrate_eta(spec, n_tiles=6)
    etamx = calibrate_eta(spec, n_tiles=6, precision="mixed")
    assert abs(etamx - eta64) / eta64 < 1e-8
