"""Training loop: restart determinism, failure recovery, microbatching."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data import SyntheticTokenDataset
from repro.distributed.sharding import ShardingCtx
from repro.models import model as M
from repro.train import Trainer
from repro.train.step import make_train_step
from repro.optim.adamw import adamw_init

CFG = get_config("phi3-mini-3.8b", smoke=True)


def make_trainer(d, **kw):
    tcfg = TrainConfig(total_steps=10, checkpoint_every=4, checkpoint_dir=d,
                       log_every=2, learning_rate=1e-3,
                       async_checkpoint=False, **kw)
    ds = SyntheticTokenDataset(CFG.vocab_size, 32, 8, seed=3)
    return Trainer(CFG, tcfg, ds)


@pytest.mark.slow
def test_restart_reproduces_trajectory():
    d = tempfile.mkdtemp()
    try:
        tr = make_trainer(d)
        tr.init_state()
        log = tr.run(10)
        ref = {m["step"]: m["loss"] for m in log}

        tr2 = make_trainer(d)
        assert tr2.resume_or_init()
        assert tr2.step == 8
        log2 = tr2.run(10)
        for m in log2:
            assert m["step"] > 8
            np.testing.assert_allclose(m["loss"], ref[m["step"]], rtol=1e-5)
    finally:
        shutil.rmtree(d, ignore_errors=True)


@pytest.mark.slow
def test_injected_failure_recovery():
    """A mid-run failure recovers from checkpoint and converges to the
    same final loss as an uninterrupted run."""
    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        clean = make_trainer(d1)
        clean.init_state()
        ref = clean.run(10)

        faulty = make_trainer(d2)
        faulty.init_state()
        log = faulty.run(10, fail_at={6})
        assert log[-1]["step"] == 10
        np.testing.assert_allclose(log[-1]["loss"], ref[-1]["loss"],
                                   rtol=1e-5)
    finally:
        shutil.rmtree(d1, ignore_errors=True)
        shutil.rmtree(d2, ignore_errors=True)


@pytest.mark.slow
def test_microbatch_grad_accumulation_equivalence():
    """microbatches=4 produces (numerically) the same update as one batch."""
    ctx = ShardingCtx()
    ds = SyntheticTokenDataset(CFG.vocab_size, 32, 8, seed=5)
    batch = {"tokens": jnp.asarray(ds.batch_at(0))}
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    outs = {}
    for n in (1, 4):
        tcfg = TrainConfig(microbatches=n, learning_rate=1e-3)
        step = make_train_step(CFG, tcfg, ctx)
        opt = adamw_init(params)
        p2, _, metrics = jax.jit(step)(params, opt, batch)
        outs[n] = (p2, metrics["loss"])
    np.testing.assert_allclose(float(outs[1][1]), float(outs[4][1]),
                               rtol=1e-4)
    a = jax.tree_util.tree_leaves(outs[1][0])
    b = jax.tree_util.tree_leaves(outs[4][0])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=1e-2, atol=1e-3)


def test_loss_decreases():
    d = tempfile.mkdtemp()
    try:
        tr = make_trainer(d)
        tr.init_state()
        log = tr.run(10)
        assert log[-1]["loss"] < log[0]["loss"] + 0.05
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_watchdog_flags_stragglers():
    from repro.train.trainer import Watchdog
    wd = Watchdog(threshold=2.0)
    assert not wd.observe(0, 1.0)
    assert not wd.observe(1, 1.1)
    assert wd.observe(2, 5.0)        # straggler
    assert wd.stragglers[0][0] == 2
